"""DeepSeekMoE-16B — fine-grained 64-expert top-6 routing + 2 shared experts,
first layer dense [arXiv:2401.06066; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,  # MHA
    d_ff=10944,  # dense first layer hidden
    vocab_size=102400,
    head_dim=128,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    source="arXiv:2401.06066",
)
