"""Llama-4-Maverick-400B-A17B — 128-expert top-1 MoE + shared expert
[hf:meta-llama/Llama-4-*]. Early-fusion multimodal frontend is a stub per
the brief (backbone only)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,  # shared/dense MLP hidden
    vocab_size=202048,
    head_dim=128,
    num_experts=128,
    num_shared_experts=1,
    top_k=1,
    moe_d_ff=8192,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (scaled per brief)",
)
