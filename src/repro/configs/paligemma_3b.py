"""PaliGemma-3B — SigLIP vision frontend (stub) + Gemma-2B backbone
[arXiv:2407.07726; hf]. ``input_specs()`` provides precomputed patch
embeddings as a 256-token prefix."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,  # MQA (gemma-2b)
    d_ff=16384,
    vocab_size=257216,
    head_dim=256,
    mlp="geglu",
    frontend="vision_patches",
    frontend_tokens=256,
    tie_embeddings=True,
    source="arXiv:2407.07726",
)
