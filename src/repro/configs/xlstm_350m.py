"""xLSTM-350M — alternating mLSTM (matrix-memory) and sLSTM blocks
[arXiv:2405.04517]. d_ff=0: xLSTM blocks carry their own up/down
projections; there is no separate FFN."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=256,
    mlp="none",
    block_pattern=("mlstm", "slstm"),
    tie_embeddings=True,
    subquadratic=True,  # recurrent state: O(1) decode per token
    source="arXiv:2405.04517",
)
