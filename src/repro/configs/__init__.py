"""Architecture registry: ``--arch <id>`` → ArchConfig."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES  # noqa: F401

_ARCH_MODULES = {
    "minitron-8b": "minitron_8b",
    "yi-9b": "yi_9b",
    "qwen1.5-0.5b": "qwen15_05b",
    "phi3-medium-14b": "phi3_medium_14b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "xlstm-350m": "xlstm_350m",
    "paligemma-3b": "paligemma_3b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_arch(arch_id: str) -> ArchConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def get_shape(shape_id: str) -> ShapeConfig:
    if shape_id not in SHAPES:
        raise KeyError(f"unknown shape {shape_id!r}; known: {sorted(SHAPES)}")
    return SHAPES[shape_id]


def cell_is_runnable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """40-cell applicability matrix (skips documented in DESIGN.md)."""
    if shape.name.startswith("long_") and not arch.subquadratic:
        return False, "long_500k needs sub-quadratic attention; pure full-attention arch (skip per brief)"
    return True, ""
