"""Architecture + shape configuration dataclasses.

Each assigned architecture gets one module in this package holding an
``ArchConfig`` named ``CONFIG`` with the exact figures from the public
source cited in the brief. Reduced ("smoke") variants for CPU tests are
derived with :func:`ArchConfig.reduced`.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | encdec | hybrid | ssm | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    qkv_bias: bool = False
    mlp: str = "swiglu"  # swiglu | relu2 | geglu | none
    # --- MoE ---
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # routed-expert hidden dim (0 -> d_ff)
    moe_capacity_factor: float = 1.25
    first_dense_layers: int = 0  # leading dense layers in an otherwise-MoE stack
    # --- encoder-decoder ---
    enc_layers: int = 0
    dec_layers: int = 0
    # --- modality frontend (stub; input_specs() provides embeddings) ---
    frontend: str = "none"  # none | audio_frames | vision_patches
    frontend_tokens: int = 0  # prefix length contributed by the frontend
    # --- hybrid / ssm block pattern ---
    block_pattern: Tuple[str, ...] = ()  # cycled over layers; () -> all "attn"
    lru_width: int = 0
    window: int = 0  # local-attention window (0 -> full/causal)
    conv1d_width: int = 0  # temporal conv width in recurrent blocks
    # --- general ---
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    subquadratic: bool = False  # can serve long_500k
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads % max(self.num_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: num_heads must be divisible by num_kv_heads")

    # ---- derived sizes (used by the analytic model & docs) ----
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def block_kind(self, layer: int) -> str:
        if not self.block_pattern:
            return "attn"
        return self.block_pattern[layer % len(self.block_pattern)]

    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.block_kind(i) for i in range(self.num_layers))

    def param_count(self) -> int:
        """Total parameters (embedding included once if tied)."""
        d, h = self.d_model, self.head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            per_attn += self.q_dim + 2 * self.kv_dim
        def mlp_params(ff):
            if ff == 0 or self.mlp == "none":
                return 0
            gates = 3 if self.mlp in ("swiglu", "geglu") else 2
            return gates * d * ff
        total = emb
        for i in range(self.num_layers):
            kind = self.block_kind(i)
            total += 2 * d  # two norms
            if kind == "attn":
                total += per_attn
            elif kind == "rglru":
                w = self.lru_width or d
                # in/out proj + gates (a, input) + conv1d
                total += 2 * d * w + 2 * w * w // max(self.num_heads, 1) + (self.conv1d_width or 4) * w
            elif kind == "mlstm":
                w = 2 * d  # expansion 2
                total += d * w * 2 + 3 * w * (w // max(self.num_heads, 1)) + w * d
            elif kind == "slstm":
                total += 4 * d * d + 4 * d * d // max(self.num_heads, 1)
            if self.family == "moe" and i >= self.first_dense_layers and kind == "attn":
                ff = self.moe_d_ff or self.d_ff
                total += self.num_experts * 3 * d * ff + self.num_shared_experts * 3 * d * ff
                total += d * self.num_experts  # router
            else:
                ff = self.d_ff if not (self.family == "moe" and i < self.first_dense_layers) else self.d_ff
                total += mlp_params(ff)
        if self.family == "encdec":
            # decoder stack with self- and cross-attention
            total += self.dec_layers * (2 * per_attn + mlp_params(self.d_ff) + 3 * self.d_model)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed top-k + shared)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        ff = self.moe_d_ff or self.d_ff
        inactive = (self.num_experts - self.top_k) * 3 * d * ff
        n_moe = sum(
            1 for i in range(self.num_layers)
            if i >= self.first_dense_layers and self.block_kind(i) == "attn"
        )
        return self.param_count() - n_moe * inactive

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 4 if self.block_pattern else 2),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            moe_d_ff=0 if self.moe_d_ff == 0 else 64,
            vocab_size=256,
            num_experts=min(self.num_experts, 8),
            num_shared_experts=min(self.num_shared_experts, 1),
            top_k=min(self.top_k, 2),
            moe_capacity_factor=8.0,  # dropless for numeric parity tests
            first_dense_layers=min(self.first_dense_layers, 1),
            enc_layers=min(self.enc_layers, 2),
            dec_layers=min(self.dec_layers, 2),
            lru_width=0 if self.lru_width == 0 else 64,
            window=0 if self.window == 0 else 16,
            frontend_tokens=0 if self.frontend_tokens == 0 else 8,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    def reduced(self) -> "ShapeConfig":
        return ShapeConfig(self.name + "-smoke", min(self.seq_len, 32), min(self.global_batch, 2), self.kind)


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
