"""RecurrentGemma-2B (Griffin) — RG-LRU recurrent blocks + local attention,
pattern 2 recurrent : 1 local-attn [arXiv:2402.19427; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,  # MQA in the local-attention blocks
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    mlp="geglu",
    block_pattern=("rglru", "rglru", "attn"),
    lru_width=2560,
    window=2048,
    conv1d_width=4,
    tie_embeddings=True,
    subquadratic=True,  # local attention + recurrence: O(S) decode state
    source="arXiv:2402.19427",
)
