"""SeamlessM4T-medium — encoder-decoder multimodal translator
[arXiv:2308.11596; hf]. Audio frontend is a stub: ``input_specs()``
provides precomputed frame embeddings to the encoder."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=24,  # 12 encoder + 12 decoder
    enc_layers=12,
    dec_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    head_dim=64,
    frontend="audio_frames",
    source="arXiv:2308.11596",
)
