"""Sharded, atomic, resumable checkpoints (no external deps).

Layout per step::

    <dir>/step_000123/
        index.json            # tree structure, shapes, dtypes, data-iter state
        shard_<host>.npz      # this host's param/optimizer shards
    <dir>/LATEST              # atomic pointer (written last)

Properties needed at 1000+ nodes (DESIGN.md §6):
  * atomicity — a crash mid-save never corrupts LATEST (tmp dir + rename);
  * logical indexing — arrays are stored with global shapes + a shard box,
    so restore re-shards onto *any* mesh (elastic restart);
  * keep-k garbage collection;
  * async save (background thread) so the train loop never blocks on disk;
  * corrupt-checkpoint tolerance — restore falls back to the newest
    checkpoint whose index verifies.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # ------------------------------ save ------------------------------
    def save(self, step: int, tree: PyTree, extra: Optional[dict] = None,
             host_index: int = 0, block: bool = False):
        # materialise on host before handing to the writer thread —
        # device_get gathers sharded leaves to their full *logical*
        # arrays, so a checkpoint written on one mesh carries no trace of
        # that mesh's layout (the plan-invariance restore_sharded relies
        # on)
        leaves = [(k, np.asarray(jax.device_get(v)))
                  for k, v in _flatten_with_paths(tree)]
        treedef = jax.tree.structure(tree)

        def write():
            tmp = self.dir / f".tmp_step_{step:09d}_{host_index}"
            final = self.dir / f"step_{step:09d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / f"shard_{host_index}.npz",
                     **{k: v for k, v in leaves})
            index = {
                "step": step,
                "treedef": str(treedef),
                "leaves": [{"key": k, "shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in leaves],
                "extra": extra or {},
            }
            (tmp / "index.json").write_text(json.dumps(index))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            latest_tmp = self.dir / ".LATEST.tmp"
            latest_tmp.write_text(final.name)
            latest_tmp.rename(self.dir / "LATEST")  # atomic pointer flip
            self._gc()

        if self.async_save and not block:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.dir.glob("step_*"))
        for old in steps[: max(len(steps) - self.keep, 0)]:
            shutil.rmtree(old, ignore_errors=True)

    # ----------------------------- restore ----------------------------
    def available_steps(self) -> List[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*"))

    def _verify(self, path: pathlib.Path, host_index: int) -> bool:
        try:
            idx = json.loads((path / "index.json").read_text())
            with np.load(path / f"shard_{host_index}.npz") as z:
                names = set(z.files)
            return all(l["key"] in names for l in idx["leaves"])
        except Exception:
            return False

    def restore(self, like: PyTree, step: Optional[int] = None,
                host_index: int = 0) -> Tuple[Optional[PyTree], Optional[dict], int]:
        """Restore newest verifiable checkpoint (≤ step if given).

        `like`: a pytree of arrays or ShapeDtypeStructs giving the target
        structure; restored leaves are reshaped/cast to match (elastic
        restore re-shards by simply loading the full logical array — shard
        placement is applied by the caller via device_put with the target
        sharding).
        Returns (tree | None, extra | None, restored_step | -1).
        """
        self.wait()
        candidates = [s for s in self.available_steps() if step is None or s <= step]
        for s in reversed(candidates):
            path = self.dir / f"step_{s:09d}"
            if not self._verify(path, host_index):
                continue
            idx = json.loads((path / "index.json").read_text())
            with np.load(path / f"shard_{host_index}.npz") as z:
                data = {k: z[k] for k in z.files}
            flat_like = _flatten_with_paths(like)
            leaves = []
            ok = True
            for key, leaf in flat_like:
                if key not in data:
                    ok = False
                    break
                arr = data[key]
                want = tuple(leaf.shape)
                if tuple(arr.shape) != want:
                    ok = False
                    break
                leaves.append(arr.astype(leaf.dtype))
            if not ok:
                continue
            tree = jax.tree.unflatten(jax.tree.structure(like), leaves)
            return tree, idx.get("extra", {}), s
        return None, None, -1

    def restore_sharded(self, like: PyTree, shardings: Optional[PyTree] = None,
                        step: Optional[int] = None, host_index: int = 0
                        ) -> Tuple[Optional[PyTree], Optional[dict], int]:
        """Plan-invariant restore: :meth:`restore` + placement.

        Checkpoints store logical (global) arrays, so a tree saved on one
        mesh restores onto *any* other — pass the **destination** plan's
        ``shardings`` (e.g. ``plan_b.param_shardings(like, mesh_b)``) and
        the restored leaves are ``device_put`` straight onto it. With
        ``shardings=None`` this is exactly :meth:`restore` (host arrays;
        the caller places them). The serving restore-onto-a-different-mesh
        path (``serving_equiv --replan`` certifies it) is::

            like = jax.eval_shape(lambda: REG.init_params(arch, key, dtype))
            params, _, step = ckpt.restore_sharded(
                like, plan_b.param_shardings(like, mesh_b))
        """
        tree, extra, s = self.restore(like, step, host_index)
        if tree is not None and shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, extra, s
