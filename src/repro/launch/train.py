"""Training launcher: plan → compile → fault-tolerant loop.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --steps 50 --batch 8 --seq 256 --ckpt /tmp/ckpt [--xfer on|off]

On this CPU container it runs reduced configs end-to-end; on a pod the
same entrypoint runs the full config (the mesh comes from jax.devices()).
The whole flow is the three-stage API: the chosen ShardingPlan drives the
NamedShardings the params/optimizer are placed with and the jitted step.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.api import plan
from repro.configs import ARCH_IDS
from repro.configs.base import ShapeConfig
from repro.optim import adamw as OPT


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--xfer", choices=("on", "off", "auto"), default="auto")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    shape = ShapeConfig("train_cli", args.seq, args.batch, "train")
    force_xfer = {"on": True, "off": False, "auto": None}[args.xfer]
    xplan = plan(args.arch, shape, reduced=args.reduced, force_xfer=force_xfer)
    print(f"[train] {xplan.describe()}")

    driver = xplan.compile().train(
        steps=args.steps, ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every,
        opt_cfg=OPT.AdamWConfig(lr=args.lr), seed=args.seed)
    t0 = time.time()
    result = driver.run()
    dt = time.time() - t0
    losses = [m["loss"] for m in result["log"]]
    print(f"[train] {len(losses)} steps in {dt:.1f}s "
          f"({dt/max(len(losses),1)*1e3:.1f} ms/step) "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"restarts={result['restarts']} stragglers={result['straggler_events']}")
    assert np.isfinite(losses[-1])
    return result


if __name__ == "__main__":
    main()
