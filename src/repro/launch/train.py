"""Training launcher: plan → shard → fault-tolerant loop.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --steps 50 --batch 8 --seq 256 --ckpt /tmp/ckpt [--xfer on|off]

On this CPU container it runs reduced configs end-to-end; on a pod the
same entrypoint runs the full config (the mesh comes from jax.devices()).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import ARCH_IDS, get_arch
from repro.configs.base import ShapeConfig
from repro.core.planner import plan_cell
from repro.core.xfer import ShardingCtx, tree_shardings
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import mesh_axes
from repro.models import registry as REG
from repro.optim import adamw as OPT
from repro.runtime.driver import DriverConfig, TrainDriver
from repro.runtime.elastic import replan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--xfer", choices=("on", "off", "auto"), default="auto")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if args.reduced:
        arch = arch.reduced()
    shape = ShapeConfig("train_cli", args.seq, args.batch, "train")

    mesh, ctx, rep = replan(arch, shape)
    print(f"[train] mesh={dict(mesh.shape)} plan=[{rep.plan.describe()}] "
          f"predicted={rep.predicted_seconds*1e3:.1f}ms/step")

    key = jax.random.PRNGKey(args.seed)
    dtype = jnp.float32 if jax.default_backend() == "cpu" else jnp.bfloat16
    params = REG.init_params(arch, key, dtype)
    cfg = OPT.AdamWConfig(lr=args.lr)
    opt_state = OPT.adamw_init(params, cfg)

    p_sh = tree_shardings(ctx, params, REG.param_dims(arch))
    o_sh = tree_shardings(ctx, opt_state, OPT.opt_state_dims(REG.param_dims(arch)))
    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(opt_state, o_sh)

    schedule = OPT.cosine_schedule(args.lr, warmup=max(args.steps // 20, 2),
                                   total=args.steps)
    step_fn = REG.build_train_step(arch, cfg, ctx, schedule)
    with mesh:
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

        pipeline = TokenPipeline(arch, shape, seed=args.seed)
        ckpt = Checkpointer(args.ckpt, keep=3)
        driver = TrainDriver(
            jit_step, params, opt_state, pipeline, ckpt,
            DriverConfig(total_steps=args.steps,
                         checkpoint_every=args.ckpt_every))
        t0 = time.time()
        result = driver.run()
    dt = time.time() - t0
    losses = [m["loss"] for m in result["log"]]
    print(f"[train] {len(losses)} steps in {dt:.1f}s "
          f"({dt/max(len(losses),1)*1e3:.1f} ms/step) "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"restarts={result['restarts']} stragglers={result['straggler_events']}")
    assert np.isfinite(losses[-1])
    return result


if __name__ == "__main__":
    main()
