"""Trip-count-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts a ``while`` body **once**, so every
scan (layer stack, CE-loss chunks, attention q-blocks, XFER gathers inside
the layer scan) is undercounted by its trip count. This module re-derives
the three roofline terms from the optimized HLO with loop multipliers:

  * FLOPs        — from dot/convolution ops (2 · out_elems · contraction)
  * HBM bytes    — per top-level op: operands + outputs (post-fusion HLO,
                   so fusion internals are free — XLA's own traffic model)
  * collectives  — wire bytes per type with ring factor (g-1)/g

Computations are resolved bottom-up: ``fusion`` contributes its callee's
FLOPs but only its own boundary bytes; ``while`` multiplies its body by the
trip count recovered from the loop condition's comparison constant.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+([a-z][a-z0-9\-]*)\((.*)$")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*n[^0-9]*(\d+)')
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_CALL_RE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_ZERO_COST_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
    "opt-barrier", "domain", "convert",
}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    elems = bytes_ = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    # traffic of ops inside a "flashattn" named scope: the Pallas flash
    # kernels keep these tensors in VMEM on the TPU target, so they are
    # reported separately and excluded from the HBM roofline term.
    vmem_resident_bytes: float = 0.0
    coll: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: {"count": 0.0, "wire_bytes": 0.0}))

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.vmem_resident_bytes += other.vmem_resident_bytes * mult
        for k, v in other.coll.items():
            self.coll[k]["count"] += v["count"] * mult
            self.coll[k]["wire_bytes"] += v["wire_bytes"] * mult

    @property
    def collective_wire_bytes(self) -> float:
        return sum(v["wire_bytes"] for v in self.coll.values())

    def as_dict(self) -> dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "vmem_resident_bytes": self.vmem_resident_bytes,
                "collective_wire_bytes": self.collective_wire_bytes,
                "collectives": {k: dict(v) for k, v in self.coll.items()}}


_VMEM_SCOPE = "flashattn"


def _in_vmem_scope(ins: "_Instr") -> bool:
    return _VMEM_SCOPE in ins.rest


@dataclasses.dataclass
class _Instr:
    name: str
    opcode: str
    type_str: str
    rest: str  # operand list + attributes
    operands: Tuple[str, ...] = ()


def _head_operands(rest: str) -> Tuple[str, Tuple[str, ...]]:
    """Split rest into (operand-list-string, operand names)."""
    depth = 0
    head = rest
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth < 0:
                head = rest[:i]
                break
    names = tuple(tok.strip().lstrip("%") for tok in re.findall(r"%[\w.\-]+", head))
    return head, names


@dataclasses.dataclass
class _Comp:
    instrs: List[_Instr]
    types: Dict[str, str]  # instr name -> type string

    def by_name(self, name: str) -> Optional[_Instr]:
        if not hasattr(self, "_idx"):
            self._idx = {i.name: i for i in self.instrs}
        return self._idx.get(name)


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _parse_computations(hlo: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if "/*" in line:
            line = _COMMENT_RE.sub("", line)
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = _Comp([], {})
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name = m.group(1).lstrip("%")
            _, opnds = _head_operands(m.group(4))
            ins = _Instr(name, m.group(3), m.group(2), m.group(4), opnds)
            comps[cur].instrs.append(ins)
            comps[cur].types[name] = m.group(2)
    return comps


def _dot_flops(instr: _Instr, types: Dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(instr.type_str)
    lhs_type = types.get(instr.operands[0], "") if instr.operands else ""
    mshape = _SHAPE_RE.search(lhs_type)
    if not mshape:
        return 2.0 * out_elems  # unknown contraction: lower bound
    lhs_dims = [int(d) for d in mshape.group(2).split(",") if d]
    m = _CONTRACT_RE.search(instr.rest)
    contract = 1
    if m and m.group(1):
        for i in m.group(1).split(","):
            idx = int(i)
            if idx < len(lhs_dims):
                contract *= lhs_dims[idx]
    return 2.0 * out_elems * contract


def _conv_flops(instr: _Instr, types: Dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(instr.type_str)
    ktype = types.get(instr.operands[1], "") if len(instr.operands) > 1 else ""
    mshape = _SHAPE_RE.search(ktype)
    if not mshape:
        return 2.0 * out_elems
    kdims = [int(d) for d in mshape.group(2).split(",") if d]
    out_dims_m = _SHAPE_RE.search(instr.type_str)
    if not out_dims_m:
        return 0.0
    k = 1
    for d in kdims:
        k *= d
    cout = max([int(d) for d in out_dims_m.group(2).split(",") if d] or [1])
    return 2.0 * out_elems * max(k // max(cout, 1), 1)


def _operand_bytes(instr: _Instr, types: Dict[str, str],
                   comp: Optional["_Comp"] = None,
                   comps: Optional[Dict[str, "_Comp"]] = None) -> float:
    total = 0.0
    for name in instr.operands:
        if comp is not None and comps is not None:
            total += _storage_bytes(name, comp, comps)
        else:
            t = types.get(name)
            if t:
                _, b = _shape_elems_bytes(t)
                total += b
    if total == 0.0:  # inline-shape dump style fallback
        head, _ = _head_operands(instr.rest)
        _, total = _shape_elems_bytes(head)
    return total


# --- effective-read modelling -------------------------------------------------
# dynamic-slice / gather read only their output; dynamic-update-slice /
# scatter write only the update (XLA updates in place). Without these rules
# an embedding lookup would "read" the whole 2 GB table and a scanned layer
# stack would re-read all L layers' params every iteration.

_SLICE_OPS = {"dynamic-slice", "gather"}
_INPLACE_OPS = {"dynamic-update-slice", "scatter"}


_PASSTHRU_OPS = {"bitcast", "reshape", "copy", "transpose", "convert"}

# dtype-narrowing chain: ops that preserve the logical tensor while the CPU
# backend may have widened it (bf16->f32 `convert` legalisation around dots).
# On the TPU target the tensor's storage dtype is the narrow one.
_NARROW_CHAIN = {"convert", "bitcast", "copy", "transpose", "reshape"}


def _storage_bytes(name: str, comp: "_Comp", comps: Dict[str, "_Comp"],
                   depth: int = 0) -> float:
    """Effective storage bytes of a value: min along its producer chain of
    layout/dtype-preserving ops (TPU keeps the narrow dtype end-to-end)."""
    _, b = _shape_elems_bytes(comp.types.get(name, ""))
    if depth > 6 or b == 0:
        return b
    prod = comp.by_name(name)
    if prod is None or not prod.operands:
        return b
    if prod.opcode in _NARROW_CHAIN:
        return min(b, _storage_bytes(prod.operands[0], comp, comps, depth + 1))
    if prod.opcode == "fusion":
        m = _CALL_RE.search(prod.rest)
        callee = comps.get(m.group(1)) if m else None
        if callee and callee.instrs:
            # follow the callee root through layout/dtype ops to a parameter;
            # the true storage is the matching outer operand's
            node = callee.instrs[-1]
            for _ in range(6):
                if node is None:
                    break
                if node.opcode == "parameter":
                    mi = re.match(r"\s*(\d+)", node.rest)
                    if mi and int(mi.group(1)) < len(prod.operands):
                        return min(b, _storage_bytes(
                            prod.operands[int(mi.group(1))], comp, comps, depth + 1))
                    break
                if node.opcode in _NARROW_CHAIN and node.operands:
                    nxt = callee.by_name(node.operands[0])
                    if nxt is None:  # operand is a callee parameter by name
                        break
                    node = nxt
                    continue
                # root computes something real: its narrowest side still
                # bounds the storage (e.g. convert deep inside)
                _, rb = _shape_elems_bytes(node.type_str)
                if node.opcode == "convert" and node.operands:
                    _, src = _shape_elems_bytes(callee.types.get(node.operands[0], ""))
                    if src:
                        return min(b, src)
                break
    return b


def _fusion_param_reads(comp: "_Comp") -> Dict[int, float]:
    """Per-parameter effective read bytes inside a fused computation."""
    # consumers per instr name
    consumers: Dict[str, List[_Instr]] = defaultdict(list)
    params: Dict[str, int] = {}
    for ins in comp.instrs:
        if ins.opcode == "parameter":
            m = re.match(r"\s*(\d+)", ins.rest)
            if m:
                params[ins.name] = int(m.group(1))
        for opnd in ins.operands:
            consumers[opnd].append(ins)

    def effective_uses(name: str, depth: int = 0) -> List[_Instr]:
        out: List[_Instr] = []
        for u in consumers.get(name, []):
            if u.opcode in _PASSTHRU_OPS and depth < 4:
                out += effective_uses(u.name, depth + 1)
            else:
                out.append(u)
        return out

    reads: Dict[int, float] = {}
    for pname, pidx in params.items():
        _, full = _shape_elems_bytes(comp.types.get(pname, ""))
        uses = effective_uses(pname)
        if uses and all(u.opcode in _SLICE_OPS for u in uses):
            eff = sum(_shape_elems_bytes(u.type_str)[1] for u in uses)
            reads[pidx] = min(eff, full)
        elif uses and all(u.opcode in _INPLACE_OPS and u.operands
                          and u.operands[0] == pname for u in uses):
            reads[pidx] = 0.0  # in-place destination alias
        else:
            reads[pidx] = full
    return reads


def _fusion_bytes(instr: _Instr, types: Dict[str, str],
                  callee: Optional["_Comp"],
                  comp: Optional["_Comp"] = None,
                  comps: Optional[Dict[str, "_Comp"]] = None) -> float:
    _, out_b = _shape_elems_bytes(instr.type_str)
    if callee is None:
        return out_b + _operand_bytes(instr, types, comp, comps)
    reads = _fusion_param_reads(callee)
    total = out_b
    for i, name in enumerate(instr.operands):
        _, full = _shape_elems_bytes(types.get(name, ""))
        if comp is not None and comps is not None:
            full = min(full, _storage_bytes(name, comp, comps)) if full else full
        total += min(reads.get(i, full), full) if full else reads.get(i, 0.0)
    # in-place root: output traffic is the update, not the buffer. Handles
    # both a bare DUS root and a tuple of DUS results (k+v cache updates
    # stacked by one scan fusion).
    root = callee.instrs[-1] if callee.instrs else None
    # walk the root through dtype/layout ops (CPU wraps the DUS in converts)
    for _ in range(4):
        if root is not None and root.opcode in _NARROW_CHAIN and root.operands:
            root = callee.by_name(root.operands[0])
        else:
            break
    if root is not None:
        dus_nodes = []
        if root.opcode in _INPLACE_OPS:
            dus_nodes = [root]
        elif root.opcode == "tuple" and root.operands:
            nodes = [callee.by_name(n) for n in root.operands]
            if nodes and all(n is not None and n.opcode in _INPLACE_OPS
                             for n in nodes):
                dus_nodes = nodes
        if dus_nodes:
            upd = 0.0
            for n in dus_nodes:
                if len(n.operands) > 1:
                    _, u = _shape_elems_bytes(callee.types.get(n.operands[1], ""))
                    upd += u
            total = total - out_b + upd
    return total


def _collective_wire(instr: _Instr) -> Tuple[str, float]:
    kind = instr.opcode.replace("-start", "").replace("-done", "")
    _, out_bytes = _shape_elems_bytes(instr.type_str)
    m = _GROUPS_IOTA_RE.search(instr.rest)
    if m:
        g = int(m.group(2))
    else:
        m = _GROUPS_RE.search(instr.rest)
        g = len(m.group(1).split(",")) if m else 2
    ring = (g - 1) / g if g > 1 else 0.0
    if kind == "all-reduce":
        factor = 2.0 * ring
    elif kind == "collective-permute":
        factor = 1.0
    else:
        factor = ring
    return kind, out_bytes * factor


def _trip_count(while_instr: _Instr, cond: Optional["_Comp"]) -> int:
    m = _TRIP_RE.search(while_instr.rest)
    if m:
        return int(m.group(1))
    consts = []
    for ins in (cond.instrs if cond else []):
        if ins.opcode == "constant":
            mm = re.match(r"\s*(\d+)", ins.rest)
            if mm:
                consts.append(int(mm.group(1)))
        for mm in _CONST_RE.finditer(ins.rest):
            consts.append(int(mm.group(1)))
    return max(consts) if consts else 1


def analyze(hlo: str) -> Cost:
    comps = _parse_computations(hlo)
    memo: Dict[str, Cost] = {}
    entry = None
    # the last computation in the module is the entry in XLA dumps; prefer
    # one whose name starts with main
    for name in comps:
        if name.split(".")[0].endswith("main") or name.startswith("main"):
            entry = name
    if entry is None and comps:
        entry = list(comps)[-1]

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()  # cycle guard
        c = Cost()
        comp = comps.get(name)
        if comp is not None:
            for ins in comp.instrs:
                ic = instr_cost(ins, comp.types, comp)
                if ins.opcode not in ("while", "call", "conditional") and _in_vmem_scope(ins):
                    ic.vmem_resident_bytes += ic.hbm_bytes
                    ic.hbm_bytes = 0.0
                c.add(ic)
        memo[name] = c
        return c

    def instr_cost(ins: _Instr, types: Dict[str, str],
                   comp: Optional["_Comp"] = None) -> Cost:
        c = Cost()
        op = ins.opcode
        base = op.replace("-start", "").replace("-done", "")
        if op in _ZERO_COST_OPS:
            return c
        if base in _COLLECTIVES:
            if op.endswith("-done"):
                return c
            kind, wire = _collective_wire(ins)
            if comp is not None and ins.operands:
                _, ob_full = _shape_elems_bytes(ins.type_str)
                src = sum(_storage_bytes(n, comp, comps) for n in ins.operands)
                full = _operand_bytes(ins, types)
                if full > 0 and src > 0:
                    wire *= min(src / full, 1.0)  # TPU moves the storage dtype
            c.coll[kind]["count"] += 1
            c.coll[kind]["wire_bytes"] += wire
            _, ob = _shape_elems_bytes(ins.type_str)
            c.hbm_bytes += min(ob, ob) + _operand_bytes(ins, types, comp, comps)
            return c
        if op == "fusion":
            m = _CALL_RE.search(ins.rest)
            callee = None
            if m:
                callee_name = m.group(1).strip().strip("%")
                callee = comps.get(callee_name)
                inner = comp_cost(callee_name)
                c.flops += inner.flops  # flops inside count; bytes don't
                for k, v in inner.coll.items():
                    c.coll[k]["count"] += v["count"]
                    c.coll[k]["wire_bytes"] += v["wire_bytes"]
            c.hbm_bytes += _fusion_bytes(ins, types, callee, comp, comps)
            return c
        if op == "while":
            mc = re.search(r"condition=%?([\w.\-]+)", ins.rest)
            mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
            cond = comps.get(mc.group(1)) if mc else None
            trips = max(_trip_count(ins, cond), 1)
            if mb:
                c.add(comp_cost(mb.group(1)), mult=trips)
            if mc:
                c.add(comp_cost(mc.group(1)), mult=trips)
            return c
        if op in ("call", "conditional", "async-start", "custom-call"):
            has_body = False
            for m in re.finditer(r"(?:calls|branch_computations|to_apply)=\{?%?([\w.\-]+)",
                                 ins.rest):
                if m.group(1) in comps:
                    has_body = True
                    c.add(comp_cost(m.group(1)))
            # call/conditional with a resolvable body are inlined scheduling,
            # not data movement: the callee already accounts for its own
            # traffic (charging boundary bytes here would re-read e.g. a
            # whole embedding table the callee only gathers 32 rows of).
            # custom-call/async-start bodies are helper lambdas (comparator,
            # reducer) that do NOT model the op's operand traffic — their
            # boundary bytes stay.
            if not has_body or op in ("custom-call", "async-start"):
                _, ob = _shape_elems_bytes(ins.type_str)
                c.hbm_bytes += ob + _operand_bytes(ins, types)
            return c
        if op == "dot":
            c.flops += _dot_flops(ins, types)
            _, ob = _shape_elems_bytes(ins.type_str)
            if comp is not None:
                # CPU legalizes bf16 dots to f32 + convert-back; on TPU the
                # dot writes the requested (narrow) dtype directly.
                for other in comp.instrs:
                    if other.opcode == "convert" and ins.name in other.operands:
                        _, cb = _shape_elems_bytes(other.type_str)
                        if cb:
                            ob = min(ob, cb)
            c.hbm_bytes += ob + _operand_bytes(ins, types, comp, comps)
            return c
        if op == "convolution":
            c.flops += _conv_flops(ins, types)
            _, ob = _shape_elems_bytes(ins.type_str)
            c.hbm_bytes += ob + _operand_bytes(ins, types, comp, comps)
            return c
        if op in _SLICE_OPS:
            _, ob = _shape_elems_bytes(ins.type_str)
            c.hbm_bytes += 2.0 * ob  # read slice + write slice
            return c
        if op in _INPLACE_OPS and len(ins.operands) > 1:
            _, upd = _shape_elems_bytes(types.get(ins.operands[1], ""))
            c.hbm_bytes += 2.0 * upd
            return c
        if op in _PASSTHRU_OPS and ins.operands and comp is not None:
            # pure layout ops: TPU traffic is the narrow storage, both sides
            nb = _storage_bytes(ins.operands[0], comp, comps)
            c.hbm_bytes += 2.0 * nb
            return c
        # generic op: traffic only
        _, ob = _shape_elems_bytes(ins.type_str)
        c.hbm_bytes += ob + _operand_bytes(ins, types, comp, comps)
        return c

    return comp_cost(entry) if entry else Cost()
