from repro.testing.mesh_fixtures import force_host_device_count

force_host_device_count(512)
# ^ MUST precede the first XLA backend creation (the device count locks
# then — merely importing jax, as the repro import chain above does, is
# fine as long as nothing touches jax.devices() at module scope). Appends
# to (never overwrites) user-set XLA_FLAGS, and no-ops with a warning when
# a backend already exists in this process. This is dry-run-only;
# tests/benches see the real (1-CPU) device count.

import argparse  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, cell_is_runnable, get_arch, get_shape  # noqa: E402
from repro.core.planner import plan_cell  # noqa: E402
from repro.core.xfer import ShardingCtx, tree_shardings  # noqa: E402
from repro.launch.collectives import parse_collectives  # noqa: E402
from repro.launch.hlo_analysis import analyze  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_axes  # noqa: E402
from repro.models import registry as REG  # noqa: E402
from repro.optim import adamw as OPT  # noqa: E402

OUT_DEFAULT = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "temp_size_in_bytes", "alias_size_in_bytes",
            "host_generated_code_size_in_bytes", "host_argument_size_in_bytes",
            "host_output_size_in_bytes", "host_temp_size_in_bytes",
            "peak_memory_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def lower_cell(arch_id: str, shape_id: str, multi_pod: bool,
               force_xfer=None, pp: bool = False):
    """Build plan + shardings, lower and compile one (arch × shape × mesh)."""
    arch = get_arch(arch_id)
    shape = get_shape(shape_id)
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh_axes(mesh)

    rep = plan_cell(arch, shape, axes, force_xfer=force_xfer)
    plan = rep.plan
    ctx = ShardingCtx(mesh, plan)
    dtype = jnp.bfloat16
    quantize = "int8" in rep.note

    params_sds = jax.eval_shape(lambda k: REG.init_params(arch, k, dtype),
                                jax.random.PRNGKey(0))
    p_dims = REG.param_dims(arch)
    p_sh = tree_shardings(ctx, params_sds, p_dims)
    batch_sds = REG.input_specs(arch, shape, dtype)
    b_sh = tree_shardings(ctx, batch_sds, REG.input_dims(arch, shape))
    scalar_sh = NamedSharding(mesh, P())

    with mesh:
        if shape.kind == "train":
            cfg = OPT.AdamWConfig(quantize=quantize)
            opt_sds = jax.eval_shape(lambda p: OPT.adamw_init(p, cfg), params_sds)
            o_sh = tree_shardings(ctx, opt_sds, OPT.opt_state_dims(p_dims, quantize))
            fn = REG.build_train_step(arch, cfg, ctx)
            m_sh = {"loss": scalar_sh, "lr": scalar_sh, "grad_norm": scalar_sh,
                    "clip_scale": scalar_sh}
            jfn = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                          out_shardings=(p_sh, o_sh, m_sh),
                          donate_argnums=(0, 1))
            lowered = jfn.lower(params_sds, opt_sds, batch_sds)
        elif shape.kind == "prefill":
            fn = REG.build_prefill_step(arch, shape, ctx, cache_dtype=dtype)
            jfn = jax.jit(fn, in_shardings=(p_sh, b_sh))
            lowered = jfn.lower(params_sds, batch_sds)
        else:  # decode
            caches_sds = jax.eval_shape(
                lambda: REG.make_caches(arch, shape.global_batch, shape.seq_len, dtype))
            c_sh = tree_shardings(ctx, caches_sds, REG.cache_dims(arch))
            tok_sh = NamedSharding(mesh, ctx.spec((shape.global_batch,), ("batch",)))
            fn = REG.build_serve_step(arch, ctx)
            jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, b_sh),
                          out_shardings=(tok_sh, c_sh), donate_argnums=(1,))
            lowered = jfn.lower(params_sds, caches_sds, batch_sds)
    return rep, mesh, lowered


def run_cell(arch_id: str, shape_id: str, multi_pod: bool, outdir: pathlib.Path,
             force_xfer=None, tag: str = "") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cellname = f"{arch_id}__{shape_id}{('__' + tag) if tag else ''}"
    outpath = outdir / mesh_name / f"{cellname}.json"
    outpath.parent.mkdir(parents=True, exist_ok=True)

    arch = get_arch(arch_id)
    shape = get_shape(shape_id)
    runnable, why = cell_is_runnable(arch, shape)
    rec = {"arch": arch_id, "shape": shape_id, "mesh": mesh_name, "tag": tag}
    if not runnable:
        rec.update({"skipped": why})
        outpath.write_text(json.dumps(rec, indent=1))
        print(f"[dryrun] SKIP {cellname}: {why}")
        return rec

    t0 = time.time()
    rep, mesh, lowered = lower_cell(arch_id, shape_id, multi_pod, force_xfer)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = _mem_dict(compiled.memory_analysis())
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax<=0.4.x: one dict per device set
        cost = cost[0] if cost else {}
    cost = dict(cost)
    hlo_text = compiled.as_text()
    coll = parse_collectives(hlo_text)
    t0 = time.time()
    deep = analyze(hlo_text)  # trip-count-aware FLOPs / bytes / collectives
    t_analyze = time.time() - t0
    ndev = mesh.devices.size
    rec.update({
        "plan": rep.plan.describe(),
        "plan_note": rep.note,
        "predicted_seconds": rep.predicted_seconds,
        "plan_hbm_bytes": rep.hbm_bytes_per_device,
        "num_devices": int(ndev),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "analyze_s": round(t_analyze, 1),
        # raw XLA numbers (while bodies counted once — kept for reference)
        "xla_flops_per_device": cost.get("flops", 0.0),
        "xla_bytes_accessed_per_device": cost.get("bytes accessed", 0.0),
        # trip-count-aware per-device numbers (launch/hlo_analysis.py)
        "flops_per_device": deep.flops,
        "hbm_bytes_per_device": deep.hbm_bytes,
        "collective_wire_bytes_per_device": deep.collective_wire_bytes,
        "collectives_by_type": {k: dict(v) for k, v in deep.coll.items()},
        "memory_analysis": mem,
        "collectives_raw": coll,
    })
    outpath.write_text(json.dumps(rec, indent=1))
    print(f"[dryrun] OK {mesh_name}/{cellname}: plan=[{rep.plan.describe()}] "
          f"lower={t_lower:.0f}s compile={t_compile:.0f}s "
          f"flops/dev={deep.flops:.3e} hbm/dev={deep.hbm_bytes:.3e} "
          f"wire/dev={deep.collective_wire_bytes:.3e}")
    print(f"[dryrun] memory_analysis: {mem}")
    return rec


def run_all(multi_pod: bool, outdir: pathlib.Path, timeout: int = 3000,
            skip_existing: bool = True, force_xfer=None, tag: str = ""):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    failures = []
    for arch_id in ARCH_IDS:
        for shape_id in SHAPES:
            cellname = f"{arch_id}__{shape_id}{('__' + tag) if tag else ''}"
            outpath = outdir / mesh_name / f"{cellname}.json"
            if skip_existing and outpath.exists():
                data = json.loads(outpath.read_text())
                if "error" not in data:
                    print(f"[dryrun] cached {mesh_name}/{cellname}")
                    continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch_id, "--shape", shape_id, "--out", str(outdir)]
            if multi_pod:
                cmd.append("--multi-pod")
            if force_xfer is not None:
                cmd += ["--xfer", "on" if force_xfer else "off"]
            if tag:
                cmd += ["--tag", tag]
            t0 = time.time()
            try:
                r = subprocess.run(cmd, timeout=timeout, capture_output=True, text=True)
                sys.stdout.write(r.stdout)
                if r.returncode != 0:
                    err = r.stderr.strip().splitlines()[-15:]
                    outpath.parent.mkdir(parents=True, exist_ok=True)
                    outpath.write_text(json.dumps(
                        {"arch": arch_id, "shape": shape_id, "mesh": mesh_name,
                         "tag": tag, "error": "\n".join(err)}, indent=1))
                    failures.append(cellname)
                    print(f"[dryrun] FAIL {cellname} rc={r.returncode}: {err[-1] if err else '?'}")
            except subprocess.TimeoutExpired:
                outpath.write_text(json.dumps(
                    {"arch": arch_id, "shape": shape_id, "mesh": mesh_name,
                     "tag": tag, "error": f"timeout {timeout}s"}, indent=1))
                failures.append(cellname)
                print(f"[dryrun] TIMEOUT {cellname} after {time.time()-t0:.0f}s")
    print(f"[dryrun] done mesh={mesh_name}; {len(failures)} failures: {failures}")
    return failures


def main():
    ap = argparse.ArgumentParser(description="Multi-pod dry-run (lower+compile)")
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--xfer", choices=("on", "off", "auto"), default="auto")
    ap.add_argument("--tag", default="", help="suffix for output filenames")
    ap.add_argument("--out", default=str(OUT_DEFAULT))
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()
    outdir = pathlib.Path(args.out)
    force_xfer = {"on": True, "off": False, "auto": None}[args.xfer]
    if args.all:
        run_all(args.multi_pod, outdir, timeout=args.timeout,
                force_xfer=force_xfer, tag=args.tag)
        return
    assert args.arch and args.shape, "--arch/--shape or --all required"
    try:
        run_cell(args.arch, args.shape, args.multi_pod, outdir,
                 force_xfer=force_xfer, tag=args.tag)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
