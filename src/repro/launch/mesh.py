"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state. The dry-run entrypoint
(`launch/dryrun.py`) forces 512 host devices *before* any JAX import;
everything else sees the real device count.
"""
from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 = 256-chip pod; 2×16×16 = 512-chip two-pod slice."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, int], ...]:
    return tuple((name, size) for name, size in mesh.shape.items())


def make_test_mesh(devices=None) -> Mesh:
    """Degenerate (1,1)/(n,1) mesh for CPU tests — same axis names."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    return jax.make_mesh((n, 1), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto),
                         devices=devices)
