"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state. The dry-run entrypoint
(`launch/dryrun.py`) forces 512 host devices *before* any JAX import;
everything else sees the real device count.

``make_mesh`` papers over the ``axis_types`` API gap: newer JAX exposes
``jax.sharding.AxisType`` and ``jax.make_mesh(..., axis_types=...)``;
older releases (<= 0.4.x) have neither, and plain ``Auto`` axes are the
default there anyway.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5 (older releases default every axis to Auto)
    from jax.sharding import AxisType  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - exercised on jax 0.4.x
    AxisType = None


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence] = None) -> Mesh:
    """Version-portable ``jax.make_mesh`` with Auto axis types."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if AxisType is not None:
        kwargs["axis_types"] = (AxisType.Auto,) * len(axes)
    return jax.make_mesh(tuple(shape), tuple(axes), **kwargs)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 = 256-chip pod; 2×16×16 = 512-chip two-pod slice."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, int], ...]:
    return tuple((name, size) for name, size in mesh.shape.items())


def make_test_mesh(devices=None) -> Mesh:
    """Degenerate (1,1)/(n,1) mesh for CPU tests — same axis names."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    return make_mesh((n, 1), ("data", "model"), devices=devices)
