"""Parse collective traffic out of compiled/optimized HLO text.

`compiled.cost_analysis()` has no collective-bytes entry, so the roofline's
third term (DESIGN.md, ROOFLINE ANALYSIS) is derived here: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op's tensor size is extracted from the HLO text together with its replica
group size, and converted to *wire bytes* with the ring factor (g-1)/g.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-gather.7 = bf16[2,4096,512]{2,1,0} all-gather(...), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9\[\],{}\s]+?)\)?\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Returns {op_kind: {count, tensor_bytes, wire_bytes}} + a _total entry.

    tensor_bytes: sum of result-shape bytes (per device, per op);
    wire_bytes:   tensor_bytes × (g-1)/g for ring algorithms (×2 for
                  all-reduce = reduce-scatter + all-gather).
    """
    out: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "tensor_bytes": 0.0, "wire_bytes": 0.0})
    for line in hlo_text.splitlines():
        if "-start(" in line and any(f"{c}-start(" in line for c in _COLLECTIVES):
            pass  # async start carries the shapes
        elif "-done(" in line:
            continue  # avoid double counting async pairs
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2).lower()
        nbytes = _shape_bytes(shape_str)
        if nbytes == 0:
            continue
        g = _group_size(line)
        ring = (g - 1) / g if g > 1 else 0.0
        factor = 2.0 * ring if kind == "all-reduce" else ring
        if kind == "collective-permute":
            factor = 1.0
        rec = out[kind]
        rec["count"] += 1
        rec["tensor_bytes"] += nbytes
        rec["wire_bytes"] += nbytes * factor
    total = {"count": sum(r["count"] for r in out.values()),
             "tensor_bytes": sum(r["tensor_bytes"] for r in out.values()),
             "wire_bytes": sum(r["wire_bytes"] for r in out.values())}
    out = dict(out)
    out["_total"] = total
    return out


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format [num_groups,group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2
