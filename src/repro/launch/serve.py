"""Serving launcher: batched low-latency inference with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --requests 12 --slots 4 --max-len 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.models import registry as REG
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--reduced", action="store_true", default=True)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    if args.reduced:
        arch = arch.reduced()
    rng = np.random.RandomState(0)
    params = REG.init_params(arch, jax.random.PRNGKey(0), jnp.float32)
    engine = ServingEngine(arch, params, slots=args.slots, max_len=args.max_len,
                           dtype=jnp.float32)

    for i in range(args.requests):
        prompt = rng.randint(1, arch.vocab_size, size=rng.randint(4, 17)).astype(np.int32)
        engine.submit(Request(rid=i, prompt=prompt, max_new_tokens=args.new_tokens))

    t0 = time.time()
    steps = engine.run_until_drained()
    dt = time.time() - t0
    lat = [r.finished_at - r.submitted_at for r in engine.completed]
    print(f"[serve] {len(engine.completed)}/{args.requests} requests in {steps} steps, "
          f"{dt:.2f}s wall; mean latency {np.mean(lat)*1e3:.1f}ms, "
          f"p99 {np.percentile(lat, 99)*1e3:.1f}ms")
    for r in engine.completed[:3]:
        print(f"  rid={r.rid} out={r.out_tokens[:8]}")
    assert len(engine.completed) == args.requests
    return engine


if __name__ == "__main__":
    main()
