"""Serving launcher: plan → compile → continuous-batching inference.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --requests 12 --slots 4 --max-len 128

The launcher is a thin shell over the three-stage API: the planner picks
the ShardingPlan for a decode cell on the live mesh, ``compile()`` places
params/caches with the plan's NamedShardings, and the returned engine runs
the plan-aware jitted decode step.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.api import plan
from repro.configs import ARCH_IDS
from repro.configs.base import ShapeConfig
from repro.serving import ServeConfig
from repro.serving.engine import Request
from repro.serving.sampler import SamplingParams


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--xfer", choices=("on", "off", "auto"), default="auto")
    # on-device sampling knobs (greedy when --temperature is unset)
    ap.add_argument("--temperature", type=float, default=None,
                    help="sample instead of greedy decode (default 1.0 "
                         "when only --top-k is given)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the k largest logits")
    ap.add_argument("--lookahead", type=int, default=1,
                    help="dispatch depth (1 = double-buffered, 0 = sync)")
    args = ap.parse_args()

    sampling = None
    if args.temperature is not None or args.top_k:
        sampling = SamplingParams(
            method="top_k" if args.top_k else "temperature",
            temperature=1.0 if args.temperature is None else args.temperature,
            top_k=args.top_k)

    shape = ShapeConfig("serve_cli", args.max_len, args.slots, "decode")
    force_xfer = {"on": True, "off": False, "auto": None}[args.xfer]
    xplan = plan(args.arch, shape, reduced=args.reduced, force_xfer=force_xfer)
    print(f"[serve] {xplan.describe()}")
    engine = xplan.compile().serve(config=ServeConfig(
        slots=args.slots, max_len=args.max_len,
        sampling=sampling, lookahead=args.lookahead))

    rng = np.random.RandomState(0)
    arch = xplan.arch
    for i in range(args.requests):
        prompt = rng.randint(1, arch.vocab_size, size=rng.randint(4, 17)).astype(np.int32)
        engine.submit(Request(rid=i, prompt=prompt, max_new_tokens=args.new_tokens))

    t0 = time.time()
    steps = engine.run_until_drained()
    dt = time.time() - t0
    lat = [r.finished_at - r.submitted_at for r in engine.completed]
    stats = engine.step_stats()
    print(f"[serve] {len(engine.completed)}/{args.requests} requests in {steps} steps, "
          f"{dt:.2f}s wall; mean latency {np.mean(lat)*1e3:.1f}ms, "
          f"p99 {np.percentile(lat, 99)*1e3:.1f}ms; "
          f"step p50 {stats['step_p50_ms']:.2f}ms, "
          f"{stats['tokens_per_s']:.0f} tok/s")
    for r in engine.completed[:3]:
        print(f"  rid={r.rid} out={r.out_tokens[:8]}")
    assert len(engine.completed) == args.requests
    return engine


if __name__ == "__main__":
    main()
