"""Serving launcher: plan → compile → continuous-batching inference.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --requests 12 --slots 4 --max-len 128

The launcher is a thin shell over the three-stage API: the planner picks
the ShardingPlan for a decode cell on the live mesh, ``compile()`` places
params/caches with the plan's NamedShardings, and the returned engine runs
the plan-aware jitted decode step.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.api import plan
from repro.configs import ARCH_IDS
from repro.configs.base import ShapeConfig
from repro.serving.engine import Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--xfer", choices=("on", "off", "auto"), default="auto")
    args = ap.parse_args()

    shape = ShapeConfig("serve_cli", args.max_len, args.slots, "decode")
    force_xfer = {"on": True, "off": False, "auto": None}[args.xfer]
    xplan = plan(args.arch, shape, reduced=args.reduced, force_xfer=force_xfer)
    print(f"[serve] {xplan.describe()}")
    engine = xplan.compile().serve(slots=args.slots, max_len=args.max_len)

    rng = np.random.RandomState(0)
    arch = xplan.arch
    for i in range(args.requests):
        prompt = rng.randint(1, arch.vocab_size, size=rng.randint(4, 17)).astype(np.int32)
        engine.submit(Request(rid=i, prompt=prompt, max_new_tokens=args.new_tokens))

    t0 = time.time()
    steps = engine.run_until_drained()
    dt = time.time() - t0
    lat = [r.finished_at - r.submitted_at for r in engine.completed]
    print(f"[serve] {len(engine.completed)}/{args.requests} requests in {steps} steps, "
          f"{dt:.2f}s wall; mean latency {np.mean(lat)*1e3:.1f}ms, "
          f"p99 {np.percentile(lat, 99)*1e3:.1f}ms")
    for r in engine.completed[:3]:
        print(f"  rid={r.rid} out={r.out_tokens[:8]}")
    assert len(engine.completed) == args.requests
    return engine


if __name__ == "__main__":
    main()
