from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule, opt_state_dims  # noqa: F401
