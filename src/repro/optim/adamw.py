"""AdamW in pure JAX with ZeRO-style sharded states.

Optimizer states reuse the param sharding roles with "xfer" replaced by
"zero" (states shard over the weight-sharing group even when the params
themselves are replicated — ZeRO-1). Optional blockwise-int8 state
quantisation (`quantize=True`) cuts state HBM from 8 to 2 bytes/param,
which the planner uses to fit very large models (DESIGN.md §7.4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.quant import QTensor, dequantize, quantize

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    quantize: bool = False  # int8 m/v with per-tensor scales


# _quant / _dequant route through the shared repro.quant helper: the
# historical local copy cast round(x/scale) straight to int8 with no
# clip, so fp error at the amax element could round to 128 and wrap to
# -128 — flipping the sign of the largest moment entry.
def _quant(x: jax.Array) -> QTensor:
    return quantize(x)


def _dequant(t: QTensor) -> jax.Array:
    return dequantize(t)


def adamw_init(params: PyTree, cfg: AdamWConfig) -> PyTree:
    def zero_like(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _quant(z) if cfg.quantize else z
    return {
        "m": jax.tree.map(zero_like, params),
        "v": jax.tree.map(zero_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params: PyTree, grads: PyTree, opt_state: PyTree,
                 cfg: AdamWConfig, lr: jax.Array) -> Tuple[PyTree, PyTree, dict]:
    step = opt_state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-12))

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        mf = _dequant(m) if cfg.quantize else m
        vf = _dequant(v) if cfg.quantize else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * jnp.square(g)
        upd_ = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        new_p = (p.astype(jnp.float32) - lr * (upd_ + cfg.weight_decay * p.astype(jnp.float32))).astype(p.dtype)
        return new_p, (_quant(mf) if cfg.quantize else mf), (_quant(vf) if cfg.quantize else vf)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    is_q = lambda x: isinstance(x, QTensor)
    flat_m = jax.tree.leaves(opt_state["m"], is_leaf=is_q)
    flat_v = jax.tree.leaves(opt_state["v"], is_leaf=is_q)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_p, new_state, {"grad_norm": gn, "clip_scale": scale}


def opt_state_dims(param_dims: PyTree, quantize: bool = False) -> PyTree:
    """Sharding roles for opt states: like params but 'xfer' -> 'zero'."""
    def conv(d):
        roles = tuple("zero" if r == "xfer" else r for r in d)
        return QTensor(q=roles, scale=()) if quantize else roles
    is_dims = lambda x: isinstance(x, tuple) and not isinstance(x, QTensor)
    md = jax.tree.map(conv, param_dims, is_leaf=is_dims)
    return {"m": md, "v": md, "step": ()}


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(step / max(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return fn
