"""Gradient compression with error feedback (distributed-optimization trick).

int8 symmetric quantisation per-tensor with an error-feedback residual
buffer (Seide et al. 2014; Karimireddy et al. 2019 EF-SGD): the quantiser's
error is carried to the next step, so convergence matches full-precision
all-reduce asymptotically while DP gradient traffic drops 4× (f32→int8)
— directly reducing the paper's Eq. 22 column traffic for the gradient
all-reduce.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.quant import dequantize, quantize

PyTree = Any


def init_error_feedback(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (int8 payload, scale, new error residual)."""
    corrected = g.astype(jnp.float32) + err
    t = quantize(corrected)
    new_err = corrected - dequantize(t)
    return t.q, t.scale, new_err


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_grads(grads: PyTree, err: PyTree) -> Tuple[PyTree, PyTree]:
    """Quantise→dequantise the whole gradient tree with error feedback.

    Under GSPMD the int8 payload is what crosses the wire when the
    reduction happens after quantisation; numerically this is the
    EF-compressed gradient either way.
    """
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e, edef = jax.tree.flatten(err)
    if edef != tdef:
        raise ValueError(
            f"error-feedback tree does not match the gradient tree — "
            f"residuals would silently pair with the wrong leaves "
            f"(e.g. after an elastic replan changed the param tree; "
            f"re-init with init_error_feedback(params)).\n"
            f"  grads: {tdef}\n  err:   {edef}")
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = compress(g, e)
        out_g.append(decompress(q, s).astype(g.dtype))
        out_e.append(ne)
    return jax.tree.unflatten(tdef, out_g), jax.tree.unflatten(tdef, out_e)
