"""Fault-tolerant training driver: checkpoint/restart, straggler watch,
elastic re-planning.

The driver owns the outer loop a pod-scale job needs (DESIGN.md §6):

  * run N steps, checkpointing every K;
  * on ANY step failure (device loss, preemption signal, numerical blowup)
    → restore the newest valid checkpoint, rebuild the mesh from whatever
    devices exist now, re-plan partition factors for the new device count
    (the paper's DSE re-run, §5E), and continue;
  * per-step wall-clock EWMA straggler monitor — on TPU pods the actionable
    mitigation is restart-on-resliced-mesh, which reuses the same restore
    path;
  * deterministic data replay: the pipeline state is one integer, stored in
    the checkpoint's `extra`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.execution_plan import ExecutionPlan
from repro.data.pipeline import TokenPipeline

PyTree = Any


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA + threshold outlier detection on step wall-clock."""

    alpha: float = 0.1
    threshold: float = 2.5
    warmup: int = 5
    _mean: float = 0.0
    _count: int = 0
    events: int = 0

    def observe(self, dt: float) -> bool:
        self._count += 1
        if self._count <= self.warmup:
            self._mean = dt if self._mean == 0 else (self._mean + dt) / 2
            return False
        slow = dt > self.threshold * self._mean
        if slow:
            self.events += 1
        self._mean = (1 - self.alpha) * self._mean + self.alpha * dt
        return slow


@dataclasses.dataclass
class DriverConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    keep: int = 3
    max_restarts: int = 3
    straggler_restart_after: int = 10  # consecutive straggler events


class TrainDriver:
    """Wraps (step_fn, state, pipeline) with checkpoint/restart semantics.

    Plan-aware construction takes an :class:`ExecutionPlan` first::

        driver = TrainDriver(plan, ckpt=Checkpointer(path), cfg=DriverConfig())

    which compiles the plan (mesh + shardings + jitted step), initialises
    and places params/opt state per the plan, and defaults the data
    pipeline. The original ``TrainDriver(step_fn, params, opt_state, ...)``
    construction remains supported; :meth:`repro.api.Executable.train` is
    the full-featured factory.
    """

    def __init__(self, step_fn, params: Optional[PyTree] = None,
                 opt_state: Optional[PyTree] = None,
                 pipeline: Optional[TokenPipeline] = None,
                 ckpt: Optional[Checkpointer] = None,
                 cfg: DriverConfig = DriverConfig(),
                 on_failure_rebuild: Optional[Callable[[], Callable]] = None,
                 plan: Optional[ExecutionPlan] = None):
        if isinstance(step_fn, ExecutionPlan):
            # delegate assembly to the facade so there is exactly one
            # plan -> (sharded state, jitted step, defaults) code path
            built = step_fn.compile().train(
                params=params, opt_state=opt_state, pipeline=pipeline,
                ckpt=ckpt, cfg=cfg, on_failure_rebuild=on_failure_rebuild)
            self.__dict__.update(built.__dict__)
            return
        if params is None or opt_state is None or pipeline is None or ckpt is None:
            raise TypeError("TrainDriver needs (step_fn, params, opt_state, "
                            "pipeline, ckpt) or an ExecutionPlan first argument")
        self.plan = plan
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.pipeline = pipeline
        self.ckpt = ckpt
        self.cfg = cfg
        self.monitor = StragglerMonitor()
        self.on_failure_rebuild = on_failure_rebuild
        self.restarts = 0
        self.metrics_log: list = []

    # -------------------------------------------------------------
    def _restore(self) -> int:
        tree = {"params": self.params, "opt": self.opt_state}
        restored, extra, step = self.ckpt.restore(tree)
        if restored is None:
            return 0
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.pipeline.state.step = int((extra or {}).get("data_step", step))
        return int((extra or {}).get("train_step", step))

    def _save(self, step: int, block: bool = False):
        self.ckpt.save(step, {"params": self.params, "opt": self.opt_state},
                       extra={"train_step": step,
                              "data_step": self.pipeline.state.step},
                       block=block)

    # -------------------------------------------------------------
    def run(self, start_step: Optional[int] = None) -> Dict[str, Any]:
        step = self._restore() if start_step is None else start_step
        consecutive_stragglers = 0
        while step < self.cfg.total_steps:
            batch = self.pipeline.next_batch()
            t0 = time.time()
            try:
                self.params, self.opt_state, metrics = self.step_fn(
                    self.params, self.opt_state, batch)
                loss = float(metrics["loss"])
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {step}")
            except Exception as e:  # device loss / preemption / blowup
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError(f"exceeded max_restarts: {e}") from e
                if self.on_failure_rebuild is not None:
                    self.step_fn = self.on_failure_rebuild()
                step = self._restore()
                continue
            dt = time.time() - t0
            if self.monitor.observe(dt):
                consecutive_stragglers += 1
                if (consecutive_stragglers >= self.cfg.straggler_restart_after
                        and self.on_failure_rebuild is not None):
                    # persistent straggler: checkpoint + restart on fresh mesh
                    self._save(step, block=True)
                    self.step_fn = self.on_failure_rebuild()
                    consecutive_stragglers = 0
            else:
                consecutive_stragglers = 0
            self.metrics_log.append({"step": step, "loss": loss, "time_s": dt})
            step += 1
            if step % self.cfg.checkpoint_every == 0:
                self._save(step)
        self._save(self.cfg.total_steps, block=True)
        self.ckpt.wait()
        return {"final_step": self.cfg.total_steps, "restarts": self.restarts,
                "straggler_events": self.monitor.events,
                "log": self.metrics_log}
