"""Elastic scaling: rebuild mesh + plan for whatever devices exist now.

Two consumers:

* **Restart** (the original path): on failure the driver calls
  :func:`replan`, which queries the live device set, picks the largest
  (data, model)-factorable sub-grid, re-runs the paper's DSE
  (core/planner.plan_cell) for the new count, and returns a fresh mesh +
  ShardingCtx; checkpoints restore onto it because they are stored with
  logical (global) shapes (see ``Checkpointer.restore_sharded``).

* **Live resize** (elastic serving): :class:`LoadController` watches a
  running :class:`~repro.serving.engine.ServingEngine`'s ``step_stats()``
  / ``prefill_stats()`` telemetry (queue backlog, step p50) and, when the
  load signal crosses the :class:`~repro.serving.config.ElasticConfig`
  thresholds, builds the target plan via :func:`replan_execution` and
  migrates the deployment with ``engine.migrate(new_plan)`` — params, KV
  caches and in-flight decode state move between the two plans'
  NamedShardings without dropping streams.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.execution_plan import ExecutionPlan
from repro.core.planner import PlanReport, plan_cell
from repro.core.xfer import ShardingCtx
from repro.launch.mesh import make_mesh

__all__ = ["replan", "replan_execution", "LoadController"]


def _best_grid(n: int, arch: Optional[ArchConfig] = None) -> Tuple[int, int]:
    """Largest usable (data, model) grid from n devices (prefer square-ish,
    model a power of two).

    When ``arch`` is given, model-axis candidates that do not divide the
    arch's head count are rejected (as ``plan_cell`` does when scoring
    ``tp`` against ``arch.kv_dim``): a model axis the heads can't split
    over would silently fall back to replicated attention — worse than a
    smaller, actually-partitionable axis.
    """
    best = (n, 1)
    for model in (1, 2, 4, 8, 16, 32):
        if model > n:
            break
        if arch is not None and arch.num_heads % model != 0:
            continue
        data = n // model
        if data * model > best[0] * best[1] or (
                data * model == best[0] * best[1] and abs(data - model) < abs(best[0] - best[1])):
            best = (data, model)
    return best


def replan(arch: ArchConfig, shape: ShapeConfig,
           devices=None) -> Tuple[jax.sharding.Mesh, ShardingCtx, PlanReport]:
    devices = list(devices if devices is not None else jax.devices())
    data, model = _best_grid(len(devices), arch)
    mesh = make_mesh((data, model), ("data", "model"),
                     devices=devices[: data * model])
    rep = plan_cell(arch, shape, (("data", data), ("model", model)))
    return mesh, ShardingCtx(mesh, rep.plan), rep


def replan_execution(arch: ArchConfig, shape: ShapeConfig,
                     devices=None) -> ExecutionPlan:
    """:func:`replan`, packaged as a deployable :class:`ExecutionPlan`
    (what ``ServingEngine.migrate`` consumes)."""
    devices = list(devices if devices is not None else jax.devices())
    data, model = _best_grid(len(devices), arch)
    rep = plan_cell(arch, shape, (("data", data), ("model", model)))
    return ExecutionPlan(arch=arch, shape=shape, report=rep,
                         mesh_axes=(("data", data), ("model", model)),
                         devices=devices[: data * model])


class LoadController:
    """Grow/shrink a live serving deployment from its own telemetry.

    Load-signal contract (all host-side, no device sync): the controller
    reads ``engine.step_stats()["queue_depth"]`` (mean backlog observed
    at step dispatch since the last reset) and ``["step_p50_ms"]``, plus
    ``engine.prefill_stats()["prefills"]`` for context. Call
    :meth:`observe` once per serving-loop iteration; it decides via
    :meth:`decide` and, when a resize is due and allowed (cooldown
    elapsed, a different rung on the device ladder exists), replans and
    calls ``engine.migrate`` — returning the
    :class:`~repro.serving.engine.MigrationReport` (else ``None``).

    ``device_ladder``: usable device counts in ascending order. Defaults
    to halvings of the visible device count down to
    ``config.min_devices``.
    """

    def __init__(self, engine, config=None, *,
                 devices=None, device_ladder: Optional[List[int]] = None):
        from repro.serving.config import ElasticConfig
        self.engine = engine
        self.config = config if config is not None else ElasticConfig()
        self.devices = list(devices if devices is not None else jax.devices())
        hi = len(self.devices)
        if self.config.max_devices is not None:
            hi = min(hi, int(self.config.max_devices))
        lo = max(1, int(self.config.min_devices))
        if device_ladder is None:
            device_ladder = []
            n = hi
            while n >= lo:
                device_ladder.append(n)
                n //= 2
            device_ladder.reverse()
        self.device_ladder = sorted(set(device_ladder))
        if not self.device_ladder:
            raise ValueError("LoadController: empty device ladder")
        self._steps_at_last_resize = 0
        self._steps_seen = 0

    def current_devices(self) -> int:
        plan = self.engine.plan
        return plan.num_devices if plan is not None else 1

    def _neighbor(self, direction: int) -> Optional[int]:
        """Next rung up (+1) or down (-1) from the engine's current size."""
        cur = self.current_devices()
        if direction > 0:
            ups = [n for n in self.device_ladder if n > cur]
            return ups[0] if ups else None
        downs = [n for n in self.device_ladder if n < cur]
        return downs[-1] if downs else None

    def decide(self) -> Tuple[str, Optional[int]]:
        """("grow"|"shrink"|"hold", target_device_count | None)."""
        stats = self.engine.step_stats()
        self._steps_seen = int(stats["steps"])
        depth = stats["queue_depth"]
        if depth >= self.config.grow_queue_depth:
            target = self._neighbor(+1)
            if target is not None:
                return "grow", target
        if depth <= self.config.shrink_queue_depth:
            p50_ok = (self.config.shrink_step_p50_ms is None
                      or stats["step_p50_ms"] <= self.config.shrink_step_p50_ms)
            target = self._neighbor(-1)
            if p50_ok and target is not None:
                return "shrink", target
        return "hold", None

    def observe(self):
        """One controller tick; migrates when a resize is due. Returns the
        MigrationReport for a performed resize, else None."""
        action, target = self.decide()
        if target is None:
            return None
        if (self._steps_seen - self._steps_at_last_resize
                < self.config.cooldown_steps):
            return None
        new_plan = replan_execution(self.engine.plan.arch,
                                    self.engine.plan.shape,
                                    self.devices[:target])
        report = self.engine.migrate(new_plan)
        self._steps_at_last_resize = self._steps_seen
        return report
