"""Elastic scaling: rebuild mesh + plan for whatever devices exist now.

On failure the driver calls :func:`replan`, which
  1. queries the live device set,
  2. picks the largest (data, model)-factorable sub-grid,
  3. re-runs the paper's DSE (core/planner.plan_cell) for the new count,
  4. returns a fresh mesh + ShardingCtx; checkpoints restore onto it
     because they are stored with logical (global) shapes.
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.planner import PlanReport, plan_cell
from repro.core.xfer import ShardingCtx
from repro.launch.mesh import make_mesh


def _best_grid(n: int) -> Tuple[int, int]:
    """Largest usable (data, model) grid from n devices (prefer square-ish,
    model a power of two for head/ff divisibility)."""
    best = (n, 1)
    for model in (1, 2, 4, 8, 16, 32):
        if model > n:
            break
        data = n // model
        if data * model > best[0] * best[1] or (
                data * model == best[0] * best[1] and abs(data - model) < abs(best[0] - best[1])):
            best = (data, model)
    return best


def replan(arch: ArchConfig, shape: ShapeConfig,
           devices=None) -> Tuple[jax.sharding.Mesh, ShardingCtx, PlanReport]:
    devices = list(devices if devices is not None else jax.devices())
    data, model = _best_grid(len(devices))
    mesh = make_mesh((data, model), ("data", "model"),
                     devices=devices[: data * model])
    rep = plan_cell(arch, shape, (("data", data), ("model", model)))
    return mesh, ShardingCtx(mesh, rep.plan), rep
