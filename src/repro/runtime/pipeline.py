"""GPipe-style pipeline parallelism over the `pod` axis — the ISLPED16
baseline the paper compares against (§1/§6: layer pipelining preserves
throughput but not latency).

The layer stack is split into `S` contiguous stages (stage = pod index);
microbatches stream through with `collective_permute` hand-offs between
stages. Under SPMD every device executes the same tick loop; a device is
"active" when its stage holds a valid microbatch. Autodiff flows through
`collective_permute` (its transpose is the reverse permute), so the same
construction trains.

This exists as a *comparison baseline*: the paper's point (and ours —
benchmarks/tpu_xfer.py::pipeline_baseline) is that Super-LIP partitioning
beats pipelining on latency at equal throughput for low-batch inference.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import lm as LM

PyTree = Any


def _stage_apply(arch: ArchConfig, stage_params: PyTree, x: jax.Array,
                 positions: jax.Array) -> jax.Array:
    """Run this stage's slice of the layer stack (scan over local layers)."""
    pat = arch.block_pattern or ("attn",)
    assert pat == ("attn",), "pipeline baseline supports uniform attn stacks"

    def body(h, p):
        h, _ = LM._block_apply("attn", arch, p["b0_attn"], h, None,
                               positions=positions, cache=None,
                               prefix_len=None, moe=False)
        return h, None

    x, _ = jax.lax.scan(body, x, stage_params)
    return x


def pipelined_forward(arch: ArchConfig, params: PyTree, tokens: jax.Array,
                      mesh, *, stage_axis: str = "pod",
                      num_microbatches: int = 4) -> jax.Array:
    """Forward pass with the body pipelined across `stage_axis`.

    params: standard LM params; `params['body']` leaves are [L, ...] and are
    sharded over `stage_axis` on dim 0 (L % stages == 0). Embed/unembed are
    replicated across stages. Returns hidden states [B, S, D].
    """
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map

    stages = dict(mesh.shape)[stage_axis]
    b, s = tokens.shape
    m = num_microbatches
    assert b % m == 0
    x = L.embed_tokens(params["embed"], tokens) * jnp.asarray(
        arch.d_model ** 0.5, jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                 (b // m, s))
    xs = x.reshape(m, b // m, s, arch.d_model)

    body_specs = jax.tree.map(lambda _: P(stage_axis), params["body"])

    def run(xs_local, stage_params):
        # xs_local: [M, mb, S, D] (replicated over the stage axis)
        idx = jax.lax.axis_index(stage_axis)
        ticks = m + stages - 1
        buf = jnp.zeros_like(xs_local[0])
        outs = jnp.zeros_like(xs_local)

        def tick(carry, t):
            buf, outs = carry
            feed = xs_local[jnp.minimum(t, m - 1)]
            x_in = jnp.where(idx == 0, feed, buf)
            y = _stage_apply(arch, stage_params, x_in, positions)
            # last stage emits microbatch t-(stages-1); others forward
            out_t = t - (stages - 1)
            emit = jnp.logical_and(idx == stages - 1, out_t >= 0)
            slot = jnp.maximum(out_t, 0)
            cur = jax.lax.dynamic_index_in_dim(outs, slot, 0, keepdims=False)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(emit, y.astype(outs.dtype), cur), slot, 0)
            perm = [(i, (i + 1) % stages) for i in range(stages)]
            buf = jax.lax.ppermute(y, stage_axis, perm)
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # broadcast the last stage's outputs to every stage (replicated out)
        if stages > 1:
            outs = jax.lax.psum(
                jnp.where(idx == stages - 1, outs, jnp.zeros_like(outs)),
                stage_axis)
        return outs

    kwargs = dict(mesh=mesh, in_specs=(P(*([None] * 4)), body_specs),
                  out_specs=P(*([None] * 4)))
    try:
        fn = shard_map(run, check_vma=False, **kwargs)
    except TypeError:  # pragma: no cover
        fn = shard_map(run, check_rep=False, **kwargs)
    outs = fn(xs, params["body"])
    hidden = outs.reshape(b, s, arch.d_model)
    return L.rms_norm(hidden, params["final_norm"])


def pipelined_loss(arch: ArchConfig, params: PyTree, tokens, labels, mesh, *,
                   stage_axis: str = "pod", num_microbatches: int = 4):
    hidden = pipelined_forward(arch, params, tokens, mesh,
                               stage_axis=stage_axis,
                               num_microbatches=num_microbatches)
    return L.cross_entropy_chunked(LM.unembed_matrix(arch, params), hidden,
                                   labels)
