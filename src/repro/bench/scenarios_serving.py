"""End-to-end serving scenario through the plan → compile → execute facade.

This is the system-level number the paper's whole argument terminates in:
real decode steps on the live device set, measured by the engine's own
step-timing hooks, printed beside the planner's predicted step time. The
quick variant runs the reduced Qwen config on CPU so CI exercises the
complete pipeline (DSE → NamedShardings → jitted decode → continuous
batching) every push.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.bench.registry import scenario
from repro.bench.schema import BenchResult
from repro.bench.timers import percentile
from repro.configs.base import ShapeConfig

_N_REQUESTS = 8
_NEW_TOKENS = 8


def _warmed_engine(shape_name: str, *, n_prompts: int, prompt_len: int = 6,
                   slots: int = 4, max_len: int = 48,
                   warmup_tokens: int = 2, warmup_steps: int = 20):
    """Shared scaffolding for the local serving scenarios: reduced-Qwen
    plan → engine, warmup drained, timing hooks reset. Returns
    (arch, plan, engine, prompts).

    Warmup covers every admission group size 1..slots: batched bucket
    prefill compiles one jit per (bucket, group size), and churn produces
    arbitrary sizes mid-run — without this the measured window would pay
    those compiles (observed: +100x on the admission-path gates)."""
    import repro
    from repro.serving import ServeConfig
    from repro.serving.engine import Request

    arch = repro.get_arch("qwen1.5-0.5b").reduced()
    plan = repro.plan(arch, ShapeConfig(shape_name, 32, 4, "decode"))
    engine = plan.compile().serve(
        config=ServeConfig(slots=slots, max_len=max_len))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, 100, size=prompt_len).astype(np.int32)
               for _ in range(n_prompts)]
    wid = -1
    for group in range(1, slots + 1):
        for _ in range(group):
            engine.submit(Request(rid=wid, prompt=prompts[0],
                                  max_new_tokens=warmup_tokens))
            wid -= 1
        engine.run_until_drained(max_steps=warmup_steps + slots * group)
    engine.reset_step_stats()
    return arch, plan, engine, prompts


# Budget 9.0 (10x): step time is absolute wall-clock on whatever host runs
# the gate, so only order-of-magnitude regressions (e.g. a shape bug that
# recompiles the decode step every iteration) should trip it.
@scenario("serve_decode", tags=("serving", "e2e"),
          gate_metric="step_p50_ms", tolerance=9.0)
def serve_decode() -> BenchResult:
    """Continuous-batching decode throughput/latency, plan-aware engine."""
    from repro.serving.engine import Request

    arch, plan, engine, prompts = _warmed_engine("bench_decode",
                                                 n_prompts=_N_REQUESTS)
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=_NEW_TOKENS))
    steps = engine.run_until_drained(max_steps=200)
    stats = engine.step_stats()
    done = [r for r in engine.completed if r.rid >= 0]
    lat_ms = [(r.finished_at - r.submitted_at) * 1e3 for r in done]

    metrics = {
        "step_p50_ms": stats["step_p50_ms"],
        "step_p95_ms": stats["step_p95_ms"],
        "tokens_per_s": stats["tokens_per_s"],
        "request_latency_p50_ms": percentile(lat_ms, 50),
        "request_latency_p95_ms": percentile(lat_ms, 95),
        "steps": float(steps),
        "completed": float(len(done)),
    }
    return BenchResult(
        name="serve_decode", device_kind=jax.default_backend(),
        config={"arch": arch.name, "slots": 4, "max_len": 48,
                "requests": _N_REQUESTS, "new_tokens": _NEW_TOKENS,
                "mesh": [list(a) for a in plan.mesh_axes]},
        metrics=metrics,
        model_predicted_s=plan.predicted_seconds,
        measured_s=stats["step_p50_ms"] * 1e-3,
        extras={"plan": plan.sharding_plan.describe()})


_PREFILL_PROMPT_LEN = 12
_PREFILL_REQUESTS = 8


# Budget 9.0 (10x): same absolute-wall-clock reasoning as serve_decode.
@scenario("prefill_latency", tags=("serving", "e2e"),
          gate_metric="prefill_p50_ms", tolerance=9.0)
def prefill_latency() -> BenchResult:
    """Per-request prefill latency through the engine's admission path.

    Real-time serving pays prefill on the critical path of time-to-first-
    token; the engine's ``prefill_stats`` hook times exactly the admission
    work: bucketed prefill dispatch + cache splice + device state update
    (the prefill compute itself overlaps the in-flight decode step).
    """
    from repro.serving.engine import Request

    arch, plan, engine, prompts = _warmed_engine(
        "bench_prefill", n_prompts=_PREFILL_REQUESTS,
        prompt_len=_PREFILL_PROMPT_LEN, warmup_tokens=1, warmup_steps=10)
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=1))
    engine.run_until_drained(max_steps=50)
    stats = engine.prefill_stats()

    return BenchResult(
        name="prefill_latency", device_kind=jax.default_backend(),
        config={"arch": arch.name, "slots": 4, "max_len": 48,
                "prompt_len": _PREFILL_PROMPT_LEN,
                "requests": _PREFILL_REQUESTS,
                "mesh": [list(a) for a in plan.mesh_axes]},
        metrics={
            "prefill_p50_ms": stats["prefill_p50_ms"],
            "prefill_p95_ms": stats["prefill_p95_ms"],
            "prefill_tokens_per_s": stats["prefill_tokens_per_s"],
            "prefills": stats["prefills"],
        },
        measured_s=stats["prefill_p50_ms"] * 1e-3,
        extras={"plan": plan.sharding_plan.describe()})


_TPUT_REQUESTS = 16
_TPUT_NEW_TOKENS = 4
_TPUT_SLOTS = 4


# Budget 9.0 (10x): wall-clock-derived ratio on a shared runner, same
# reasoning as serve_decode.
@scenario("serve_throughput", tags=("serving", "e2e"),
          gate_metric="ms_per_token", tolerance=9.0)
def serve_throughput() -> BenchResult:
    """Sustained decode throughput at full occupancy with slot churn.

    4x oversubscription with short emissions keeps every slot busy while
    requests constantly finish and re-admit — the continuous-batching
    steady state. The gate metric is the lower-is-better inverse
    throughput (wall ms per emitted token) over the drained run.
    """
    from repro.serving.engine import Request

    arch, plan, engine, prompts = _warmed_engine(
        "bench_tput", n_prompts=_TPUT_REQUESTS, slots=_TPUT_SLOTS)
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p,
                              max_new_tokens=_TPUT_NEW_TOKENS))
    steps = engine.run_until_drained(max_steps=400)
    stats = engine.step_stats()
    done = [r for r in engine.completed if r.rid >= 0]
    assert len(done) == _TPUT_REQUESTS, len(done)
    tput = stats["tokens_per_s"]

    return BenchResult(
        name="serve_throughput", device_kind=jax.default_backend(),
        config={"arch": arch.name, "slots": _TPUT_SLOTS, "max_len": 48,
                "requests": _TPUT_REQUESTS, "new_tokens": _TPUT_NEW_TOKENS,
                "mesh": [list(a) for a in plan.mesh_axes]},
        metrics={
            "ms_per_token": 1e3 / tput if tput > 0 else 0.0,
            "tokens_per_s": tput,
            "step_p50_ms": stats["step_p50_ms"],
            "step_p95_ms": stats["step_p95_ms"],
            "steps": float(steps),
            "completed": float(len(done)),
        },
        # model-validation pair in matching units: predicted vs measured
        # seconds per decode step (ms_per_token is the gate metric only)
        model_predicted_s=plan.predicted_seconds,
        measured_s=stats["step_p50_ms"] * 1e-3,
        extras={"plan": plan.sharding_plan.describe()})


_ADMIT_REQUESTS = 24
_ADMIT_SLOTS = 4


# Budget 9.0 (10x): per-dispatch admission wall is host wall-clock on a
# shared runner, same reasoning as serve_decode.
@scenario("serve_admission", tags=("serving", "e2e"),
          gate_metric="admit_ms", tolerance=9.0)
def serve_admission() -> BenchResult:
    """p50 admission latency under churn with batched bucket prefill.

    6x oversubscription with 1-token emissions makes every decode step an
    admission wave; waiting requests that share a bucket become one
    batched prefill dispatch. The gate is the per-dispatch admission wall
    (``admit_p50_ms``); ``prefill_batch_mean`` > 1 certifies batching
    actually engaged (the first wave admits a full slot grid at once).
    """
    from repro.serving.engine import Request

    arch, plan, engine, _ = _warmed_engine("bench_admit",
                                           n_prompts=1, slots=_ADMIT_SLOTS)
    rng = np.random.RandomState(1)
    # mixed prompt lengths across two buckets (8 and 16) so waves exercise
    # both same-bucket batching and multi-group admission
    prompts = [rng.randint(1, 100, size=int(rng.randint(4, 13)))
               .astype(np.int32) for _ in range(_ADMIT_REQUESTS)]
    # two passes over the identical workload: the first compiles every
    # (bucket, group-size) prefill signature the churn produces, the
    # second measures steady-state admission dispatch only
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=1))
    engine.run_until_drained(max_steps=300)
    engine.reset_step_stats()
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=_ADMIT_REQUESTS + i, prompt=p,
                              max_new_tokens=1))
    steps = engine.run_until_drained(max_steps=300)
    stats = engine.prefill_stats()
    assert stats["prefills"] == float(_ADMIT_REQUESTS), stats
    assert stats["prefill_batch_mean"] > 1.0, stats

    return BenchResult(
        name="serve_admission", device_kind=jax.default_backend(),
        config={"arch": arch.name, "slots": _ADMIT_SLOTS, "max_len": 48,
                "requests": _ADMIT_REQUESTS, "new_tokens": 1,
                "mesh": [list(a) for a in plan.mesh_axes]},
        metrics={
            "admit_ms": stats["admit_p50_ms"],
            "admit_p95_ms": stats["admit_p95_ms"],
            "prefill_dispatches": stats["prefill_dispatches"],
            "prefill_batch_mean": stats["prefill_batch_mean"],
            "prefill_p50_ms": stats["prefill_p50_ms"],
            "steps": float(steps),
        },
        measured_s=stats["admit_p50_ms"] * 1e-3,
        extras={"plan": plan.sharding_plan.describe()})


_PAGED_MAX_LEN = 2048
_PAGED_PAGE_SIZE = 64
_PAGED_DENSE_SLOTS = 4
_PAGED_SLOTS = 16
_PAGED_PREFIX = 70   # > one full page: sharers alias the owner's page
_PAGED_NEW = 4


# Capacity is a structural count (streams resident in a fixed KV byte
# budget), not a wall-clock number — but the gate still rides the shared
# 10x serving budget in case future changes erode the ratio gradually.
@scenario("serve_paged_capacity", tags=("serving", "e2e", "paged"),
          gate_metric="inv_capacity_ratio", tolerance=9.0)
def serve_paged_capacity() -> BenchResult:
    """Concurrent-stream capacity at a fixed KV byte budget: paged vs dense.

    The dense engine reserves ``slots x max_len`` KV rows up front, so a
    ``max_len=2048`` deployment holding 4 slots spends 8192 token-slots of
    KV memory regardless of the tokens actually in flight. The paged
    engine gets the *same* byte budget as a page pool (128 pages of 64
    tokens) and serves 16 concurrent slots out of it, because short
    requests pin only the pages they touch — plus prefix sharing: the 15
    sharers alias the owner's first prompt page instead of rewriting it.
    The gate metric is the lower-is-better inverse capacity ratio
    (dense streams / paged streams); the run also replays the identical
    workload through the dense engine and requires bit-equal streams —
    capacity must not cost correctness.
    """
    import repro
    from repro.serving import PagingConfig, ServeConfig
    from repro.serving.engine import Request

    arch = repro.get_arch("qwen1.5-0.5b").reduced()
    rng = np.random.RandomState(0)
    prefix = rng.randint(1, 100, size=_PAGED_PREFIX).astype(np.int32)
    tails = [rng.randint(1, 100, size=int(rng.randint(6, 11)))
             .astype(np.int32) for _ in range(_PAGED_SLOTS)]
    prompts = [np.concatenate([prefix, t]) for t in tails]
    # usable pages == the dense budget exactly; +1 is the reserved null
    # page every paged deployment carries (constant, not per-stream)
    budget_pages = _PAGED_DENSE_SLOTS * _PAGED_MAX_LEN // _PAGED_PAGE_SIZE

    def submit_all(engine):
        engine.submit(Request(rid=0, prompt=prompts[0].copy(),
                              max_new_tokens=_PAGED_NEW))
        engine.step()  # owner admitted first -> its prefix pages register
        for i, p in enumerate(prompts[1:], start=1):
            engine.submit(Request(rid=i, prompt=p.copy(),
                                  max_new_tokens=_PAGED_NEW))

    plan = repro.plan(arch, ShapeConfig("bench_paged", 32, 4, "decode"))
    engine = plan.compile().serve(config=ServeConfig(
        slots=_PAGED_SLOTS, max_len=_PAGED_MAX_LEN,
        paging=PagingConfig(paged=True, page_size=_PAGED_PAGE_SIZE,
                            kv_pages=budget_pages + 1)))
    submit_all(engine)
    peak_active = peak_pages = 0
    shared_first_pages = False
    for _ in range(400):
        engine.step()
        sched = engine.scheduler
        peak_active = max(peak_active,
                          sum(r is not None for r in engine.active.values()))
        peak_pages = max(peak_pages, sched.pool.used_pages)
        firsts = [c[0] for c in sched.slot_pages.values() if c]
        shared_first_pages |= len(firsts) > len(set(firsts))
        if (all(r is None for r in engine.active.values())
                and not sched.queue):
            break
    got = {r.rid: r.out_tokens for r in engine.completed}
    hit_rate = engine.prefill_stats()["prefix_hit_rate"]
    assert len(got) == _PAGED_SLOTS, len(got)
    assert peak_pages <= budget_pages, (peak_pages, budget_pages)
    assert shared_first_pages, "prefix pages were not aliased"
    assert hit_rate > 0, hit_rate

    dense = plan.compile().serve(config=ServeConfig(
        slots=_PAGED_DENSE_SLOTS, max_len=_PAGED_MAX_LEN))
    submit_all(dense)
    dense.run_until_drained(max_steps=600)
    want = {r.rid: r.out_tokens for r in dense.completed}
    assert got == want, "paged streams diverged from dense at capacity"

    ratio = peak_active / _PAGED_DENSE_SLOTS
    assert ratio >= 2.0, ratio  # the acceptance floor: >= 2x streams
    return BenchResult(
        name="serve_paged_capacity", device_kind=jax.default_backend(),
        config={"arch": arch.name, "max_len": _PAGED_MAX_LEN,
                "page_size": _PAGED_PAGE_SIZE,
                "dense_slots": _PAGED_DENSE_SLOTS,
                "paged_slots": _PAGED_SLOTS,
                "budget_pages": budget_pages,
                "requests": _PAGED_SLOTS, "new_tokens": _PAGED_NEW,
                "mesh": [list(a) for a in plan.mesh_axes]},
        metrics={
            "inv_capacity_ratio": 1.0 / ratio,
            "capacity_ratio": ratio,
            "peak_concurrent_streams": float(peak_active),
            "peak_pool_pages": float(peak_pages),
            "budget_pages": float(budget_pages),
            "prefix_hit_rate": hit_rate,
            "completed": float(len(got)),
        },
        measured_s=0.0,
        extras={"plan": plan.sharding_plan.describe(),
                "budget_token_slots": _PAGED_DENSE_SLOTS * _PAGED_MAX_LEN})


# Child script: runs the decode loop on an 8-fake-device (4 data x 2 model)
# mesh so the plan's XFER/TP gathers are real collectives inside the
# measured step, then prints one JSON line the parent scenario wraps.
_MULTIDEV_SCRIPT = r"""
import json
import jax
import numpy as np
import repro
from repro.configs.base import ShapeConfig
from repro.serving import ServeConfig
from repro.serving.engine import Request

arch = repro.get_arch("qwen1.5-0.5b").reduced()
shape = ShapeConfig("bench_decode8", 32, 8, "decode")
plan = repro.plan(arch, shape, (("data", 4), ("model", 2)))
engine = plan.compile().serve(config=ServeConfig(slots=4, max_len=48))

rng = np.random.RandomState(0)
prompts = [rng.randint(1, 100, size=6).astype(np.int32) for _ in range(8)]
engine.submit(Request(rid=-1, prompt=prompts[0], max_new_tokens=2))
engine.run_until_drained(max_steps=20)
engine.reset_step_stats()
for i, p in enumerate(prompts):
    engine.submit(Request(rid=i, prompt=p, max_new_tokens=8))
engine.run_until_drained(max_steps=200)
stats = engine.step_stats()
done = sum(1 for r in engine.completed if r.rid >= 0)
print("MULTIDEV_BENCH " + json.dumps({
    "devices": jax.device_count(),
    "plan": plan.sharding_plan.describe(),
    "predicted_s": plan.predicted_seconds,
    "completed": done,
    **stats,
}))
"""


# Ratio of an 8-fake-device step to work actually done: still wall clock on
_QUANT_MAX_LEN = 256
_QUANT_FP_SLOTS = 4
_QUANT_NEW = 4
_KV_LEAVES = ("k", "v", "kp", "vp", "k_scale", "v_scale", "kps", "vps")


def _kv_bytes_per_slot(arch, max_len: int, kv_quant: bool) -> int:
    """KV payload bytes of one slot's cache row, from the actual cache
    tree (eval_shape — nothing allocated): the k/v leaves plus, under
    int8, their per-token scale leaves. Bookkeeping leaves (pos/count)
    are identical either way and excluded."""
    import jax.numpy as jnp

    from repro.models import registry as REG
    caches = jax.eval_shape(
        lambda: REG.make_caches(arch, 1, max_len, jnp.float32,
                                kv_quant=kv_quant))
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(caches)[0]:
        if any(getattr(p, "key", None) in _KV_LEAVES for p in path):
            total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
    return total


# Capacity is a structural count like serve_paged_capacity: admitted
# streams inside a fixed KV byte budget, gated on the inverse ratio.
@scenario("serve_quant_capacity", tags=("serving", "e2e", "quant"),
          gate_metric="inv_capacity_ratio", tolerance=9.0)
def serve_quant_capacity() -> BenchResult:
    """Admitted-stream capacity at a fixed KV HBM budget: FP32 vs INT8 KV.

    The FP32 deployment reserves ``slots x max_len`` KV rows at 4 B per
    element; the INT8 deployment stores the same rows at 1 B plus one
    f32 scale per (token, kv-head) — measured off the *actual* cache
    trees, not the analytic model — so the same byte budget admits ~4x
    the concurrent decode streams (the scale leaves shave the ratio
    below a clean 4x). The scenario then actually serves that many
    streams through the INT8 engine (weights and KV quantized,
    ``QuantConfig(weights="int8", kv="int8")``): every stream must
    complete with all slots concurrently resident, certifying the
    planner-level capacity claim against the runtime that has to honor
    it. Gate metric is the lower-is-better inverse capacity ratio.
    """
    import repro
    from repro.quant import INT8_SERVE
    from repro.serving import ServeConfig
    from repro.serving.engine import Request

    arch = repro.get_arch("qwen1.5-0.5b").reduced()
    fp_bytes = _kv_bytes_per_slot(arch, _QUANT_MAX_LEN, kv_quant=False)
    q_bytes = _kv_bytes_per_slot(arch, _QUANT_MAX_LEN, kv_quant=True)
    budget = _QUANT_FP_SLOTS * fp_bytes
    q_slots = budget // q_bytes
    ratio = q_slots / _QUANT_FP_SLOTS
    assert ratio >= 2.0, (ratio, fp_bytes, q_bytes)  # acceptance floor

    plan = repro.plan(arch, ShapeConfig("bench_quant", 32, 4, "decode"),
                      quant=INT8_SERVE)
    engine = plan.compile().serve(config=ServeConfig(
        slots=int(q_slots), max_len=_QUANT_MAX_LEN, quant=INT8_SERVE))
    from repro.models import registry as REG
    assert REG.caches_quantized(engine.caches)
    rng = np.random.RandomState(0)
    for i in range(int(q_slots)):
        engine.submit(Request(
            rid=i, prompt=rng.randint(1, 100, size=6).astype(np.int32),
            max_new_tokens=_QUANT_NEW))
    peak_active = 0
    for _ in range(200):
        engine.step()
        peak_active = max(peak_active,
                          sum(r is not None for r in engine.active.values()))
        if (all(r is None for r in engine.active.values())
                and not engine.scheduler.queue):
            break
    done = {r.rid for r in engine.completed}
    assert len(done) == q_slots, (len(done), q_slots)
    assert peak_active == q_slots, (peak_active, q_slots)

    return BenchResult(
        name="serve_quant_capacity", device_kind=jax.default_backend(),
        config={"arch": arch.name, "max_len": _QUANT_MAX_LEN,
                "fp32_slots": _QUANT_FP_SLOTS,
                "new_tokens": _QUANT_NEW,
                "mesh": [list(a) for a in plan.mesh_axes]},
        metrics={
            "inv_capacity_ratio": 1.0 / ratio,
            "capacity_ratio": ratio,
            "int8_slots": float(q_slots),
            "fp32_kv_bytes_per_slot": float(fp_bytes),
            "int8_kv_bytes_per_slot": float(q_bytes),
            "budget_bytes": float(budget),
            "peak_concurrent_streams": float(peak_active),
            "completed": float(len(done)),
        },
        measured_s=0.0,
        extras={"plan": plan.sharding_plan.describe()})


_SPEC_SLOTS = 4
_SPEC_REQUESTS = 8
_SPEC_NEW = 18          # 2 full k+1 chains at k=8
_SPEC_K = 8
_SPEC_MAX_RATIO = 0.75  # hard gate: spec must beat target-only by >= 25%


_SPEC_ROUNDS = 3  # min-of-N drains; see measurement note in the scenario


def _spec_bench_engine(plan, params, config, prompts):
    """Warm one engine over every admission group size; return a
    ``measure()`` closure that runs the workload through a fresh stats
    window and yields (ms_per_token, step_stats)."""
    from repro.serving.engine import Request

    engine = plan.compile().serve(params, config=config)
    wid = -1
    for group in range(1, _SPEC_SLOTS + 1):
        for _ in range(group):
            engine.submit(Request(rid=wid, prompt=prompts[0],
                                  max_new_tokens=_SPEC_NEW))
            wid -= 1
        engine.run_until_drained(max_steps=200)

    def measure(round_no: int):
        base_rid = round_no * _SPEC_REQUESTS
        for i, p in enumerate(prompts):
            engine.submit(Request(rid=base_rid + i, prompt=p,
                                  max_new_tokens=_SPEC_NEW))
        engine.run_until_drained(max_steps=600)
        stats = engine.step_stats()
        done = [r for r in engine.completed if r.rid >= base_rid]
        assert len(done) == _SPEC_REQUESTS, len(done)
        assert all(len(r.out_tokens) == _SPEC_NEW for r in done)
        tput = stats["tokens_per_s"]
        return (1e3 / tput if tput > 0 else float("inf")), stats

    return measure


# The gate metric is the spec/target-only ms-per-token ratio measured
# back-to-back on the same host, so host speed cancels; the absolute
# _SPEC_MAX_RATIO assert inside is the real contract and the baseline
# tolerance only catches order-of-magnitude breakage.
@scenario("serve_spec_speedup", tags=("serving", "e2e", "spec"),
          gate_metric="spec_ratio", tolerance=9.0)
def serve_spec_speedup() -> BenchResult:
    """Speculative decoding speedup: draft-k + batched verify vs
    target-only, identical workload, same planned engine.

    Acceptance-friendly by construction: both models run zero params, so
    greedy argmax proposes/commits token 0 everywhere and every k-chain
    fully accepts — the measured ratio isolates the *mechanism* (one
    fused step committing k+1 tokens vs k+1 sequential step dispatches)
    from draft quality. The pairing mirrors the intended deployment
    shape: a 16x-deeper target (the model worth speculating for) against
    a 1-layer draft, so the k proposal forwards are genuinely cheap next
    to a target step. Hard-asserts spec ms/token <= 0.75x target-only.
    """
    import dataclasses

    import repro
    from repro.models import registry as REG
    from repro.serving import ServeConfig, SpecConfig

    small = repro.get_arch("qwen1.5-0.5b").reduced()
    arch = dataclasses.replace(small, name=f"{small.name}-deep16l",
                               num_layers=16)
    draft = dataclasses.replace(small, name=f"{small.name}-draft1l",
                                num_layers=1)
    tparams = jax.tree.map(np.zeros_like,
                           REG.init_params(arch, jax.random.PRNGKey(0)))
    dparams = jax.tree.map(np.zeros_like,
                           REG.init_params(draft, jax.random.PRNGKey(1)))
    plan = repro.plan(arch, ShapeConfig("bench_spec", 32, 4, "decode"),
                      draft=draft)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, 100, size=6).astype(np.int32)
               for _ in range(_SPEC_REQUESTS)]

    measure_base = _spec_bench_engine(
        plan, tparams, ServeConfig(slots=_SPEC_SLOTS, max_len=64), prompts)
    measure_spec = _spec_bench_engine(
        plan, {"target": tparams, "draft": dparams},
        ServeConfig(slots=_SPEC_SLOTS, max_len=64,
                    spec=SpecConfig(k=_SPEC_K)), prompts)

    # Interleaved min-of-N: both ms/token figures are host wall-clock on
    # a tiny CPU workload, so a transient load spike on either drain
    # skews the ratio badly. Alternating drains and taking each side's
    # minimum measures the undisturbed cost of each engine.
    base_ms, base_stats = measure_base(0)
    spec_ms, spec_stats = measure_spec(0)
    for rnd in range(1, _SPEC_ROUNDS):
        b = measure_base(rnd)
        s = measure_spec(rnd)
        if b[0] < base_ms:
            base_ms, base_stats = b
        if s[0] < spec_ms:
            spec_ms, spec_stats = s

    ratio = spec_ms / base_ms if base_ms > 0 else float("inf")
    assert ratio <= _SPEC_MAX_RATIO, (
        f"speculative serving must cut ms/token by >= "
        f"{(1 - _SPEC_MAX_RATIO) * 100:.0f}%: spec {spec_ms:.3f} vs "
        f"target-only {base_ms:.3f} ms/token (ratio {ratio:.3f})")
    assert spec_stats["accepted_tokens_mean"] > 1.0, spec_stats

    return BenchResult(
        name="serve_spec_speedup", device_kind=jax.default_backend(),
        config={"arch": arch.name, "draft": draft.name, "k": _SPEC_K,
                "slots": _SPEC_SLOTS, "max_len": 64,
                "requests": _SPEC_REQUESTS, "new_tokens": _SPEC_NEW,
                "mesh": [list(a) for a in plan.mesh_axes]},
        metrics={
            "spec_ratio": ratio,
            "speedup": 1.0 / ratio if ratio > 0 else 0.0,
            "spec_ms_per_token": spec_ms,
            "base_ms_per_token": base_ms,
            "accepted_tokens_mean": spec_stats["accepted_tokens_mean"],
            "draft_acceptance": spec_stats.get("draft_acceptance", 0.0),
            "spec_step_p50_ms": spec_stats["step_p50_ms"],
            "base_step_p50_ms": base_stats["step_p50_ms"],
        },
        model_predicted_s=plan.predicted_seconds,
        measured_s=spec_stats["step_p50_ms"] * 1e-3,
        extras={"plan": plan.sharding_plan.describe()})


# a shared runner where 8 "devices" timeshare the same cores -> 10x budget.
@scenario("serve_decode_multidev", tags=("serving", "e2e", "multidev"),
          gate_metric="step_p50_ms", tolerance=9.0)
def serve_decode_multidev() -> BenchResult:
    """Decode step time on an 8-fake-device mesh (XFER/TP gathers in-loop).

    Runs in a subprocess with a forced host device count (fresh XLA
    client), so the measured step includes the plan's inter-device
    collectives — the ROADMAP's multi-device ``serve_decode`` variant.
    """
    import json

    from repro.testing.mesh_fixtures import run_in_subprocess

    r = run_in_subprocess(_MULTIDEV_SCRIPT, devices=8, timeout=900,
                          marker="MULTIDEV_BENCH")
    line = next(l for l in r.stdout.splitlines()
                if l.startswith("MULTIDEV_BENCH "))
    child = json.loads(line[len("MULTIDEV_BENCH "):])
    assert child["completed"] == 8, child
    assert child["devices"] == 8, child
    return BenchResult(
        name="serve_decode_multidev", device_kind=jax.default_backend(),
        config={"arch": "qwen1.5-0.5b-smoke", "slots": 4, "max_len": 48,
                "requests": 8, "new_tokens": 8, "devices": 8,
                "mesh": [["data", 4], ["model", 2]]},
        metrics={
            "step_p50_ms": child["step_p50_ms"],
            "step_p95_ms": child["step_p95_ms"],
            "tokens_per_s": child["tokens_per_s"],
            "steps": child["steps"],
            "completed": float(child["completed"]),
        },
        model_predicted_s=child["predicted_s"],
        measured_s=child["step_p50_ms"] * 1e-3,
        extras={"plan": child["plan"], "subprocess": True})


# Child script: identical churn workload through the fused engine and the
# disaggregated engine on the same 8-fake-device grid (dp4_tp2; disagg
# splits it 2+2 data rows). The figure of merit is decode-step *jitter*
# (p95 - p50 step wall) under a sustained admission storm: fused prefill
# contends with decode on the same devices, the disaggregated engine runs
# prefill on its own slice and splices arriving KV without stalling the
# step. Also reconciles the engine's analytic KV-transfer bytes against
# the compiled prefill HLO (hard assert, same band as verify_xfer).
_DISAGG_SCRIPT = r"""
import json
import jax
import numpy as np
import repro
from repro.configs.base import ShapeConfig
from repro.serving import DisaggConfig, Request, ServeConfig

arch = repro.get_arch("qwen1.5-0.5b").reduced()
shape = ShapeConfig("bench_disagg", 32, 8, "decode")
plan = repro.plan(arch, shape, (("data", 4), ("model", 2)))
exe = plan.compile()

rng = np.random.RandomState(0)
# mixed lengths across two buckets; 6x slot oversubscription with short
# emissions keeps an admission wave in flight for most decode steps
prompts = [rng.randint(1, 100, size=int(rng.randint(4, 13)))
           .astype(np.int32) for _ in range(24)]

def run(engine):
    # pass 1 compiles every (bucket, group-size) signature the churn
    # produces (both engines); pass 2 is the measured storm
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=-1 - i, prompt=p.copy(),
                              max_new_tokens=4))
    engine.run_until_drained(max_steps=600)
    engine.reset_step_stats()
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=4))
    engine.run_until_drained(max_steps=600)
    stats = engine.step_stats()
    done = sum(1 for r in engine.completed if r.rid >= 0)
    assert done == len(prompts), done
    return stats

fused = run(exe.serve(config=ServeConfig(slots=4, max_len=48)))
dis_engine = exe.serve(config=ServeConfig(
    slots=4, max_len=48, disagg=DisaggConfig(prefill_data=2)))
dis = run(dis_engine)
xfer = dis_engine.xfer_stats()
assert xfer["kv_xfer_bytes"] > 0 and xfer["kv_xfer_inflight"] == 0, xfer
recon = dis_engine.verify_xfer()  # raises outside the documented band

eps = 0.05  # ms; damps the ratio when both engines are near-uniform
fused_jitter = fused["step_p95_ms"] - fused["step_p50_ms"]
dis_jitter = dis["step_p95_ms"] - dis["step_p50_ms"]
print("DISAGG_BENCH " + json.dumps({
    "devices": jax.device_count(),
    "plan": plan.sharding_plan.describe(),
    "predicted_s": plan.predicted_seconds,
    "fused_step_p50_ms": fused["step_p50_ms"],
    "fused_step_p95_ms": fused["step_p95_ms"],
    "fused_jitter_ms": fused_jitter,
    "disagg_step_p50_ms": dis["step_p50_ms"],
    "disagg_step_p95_ms": dis["step_p95_ms"],
    "disagg_jitter_ms": dis_jitter,
    "jitter_ratio": (dis_jitter + eps) / (fused_jitter + eps),
    "kv_xfer_bytes": xfer["kv_xfer_bytes"],
    "kv_xfer_dispatches": xfer["kv_xfer_dispatches"],
    "hlo_signatures": len(recon),
}))
"""


# Budget 9.0 (10x): the gate metric is a ratio of two wall-clock tails
# measured in the same child process, so host-speed changes cancel; the
# wide budget guards only against the disaggregated path structurally
# re-acquiring prefill work on the decode slice.
@scenario("serve_disagg", tags=("serving", "e2e", "multidev", "disagg"),
          gate_metric="jitter_ratio", tolerance=9.0)
def serve_disagg() -> BenchResult:
    """Decode-step jitter under an admission storm: disagg vs fused.

    The paper's resource-partitioning argument applied to serving: give
    prefill its own device slice and the decode tail latency stops
    depending on admission pressure. The hard acceptance gate is
    ``jitter_ratio <= 1.0`` (disagg p95-p50 step jitter no worse than the
    fused engine under the identical storm); the committed baseline then
    guards the ratio against regression.
    """
    import json

    from repro.testing.mesh_fixtures import run_in_subprocess

    r = run_in_subprocess(_DISAGG_SCRIPT, devices=8, timeout=1200,
                          marker="DISAGG_BENCH")
    line = next(l for l in r.stdout.splitlines()
                if l.startswith("DISAGG_BENCH "))
    child = json.loads(line[len("DISAGG_BENCH "):])
    assert child["devices"] == 8, child
    assert child["jitter_ratio"] <= 1.0, (
        f"disaggregated decode jitter exceeds fused under the same "
        f"admission storm: {child}")
    return BenchResult(
        name="serve_disagg", device_kind=jax.default_backend(),
        config={"arch": "qwen1.5-0.5b-smoke", "slots": 4, "max_len": 48,
                "requests": 24, "new_tokens": 4, "devices": 8,
                "mesh": [["data", 4], ["model", 2]], "prefill_data": 2},
        metrics={
            "jitter_ratio": child["jitter_ratio"],
            "fused_jitter_ms": child["fused_jitter_ms"],
            "disagg_jitter_ms": child["disagg_jitter_ms"],
            "fused_step_p95_ms": child["fused_step_p95_ms"],
            "disagg_step_p95_ms": child["disagg_step_p95_ms"],
            "disagg_step_p50_ms": child["disagg_step_p50_ms"],
            "kv_xfer_bytes": child["kv_xfer_bytes"],
            "kv_xfer_dispatches": child["kv_xfer_dispatches"],
        },
        model_predicted_s=child["predicted_s"],
        measured_s=child["disagg_step_p50_ms"] * 1e-3,
        extras={"plan": child["plan"], "subprocess": True,
                "hlo_signatures": child["hlo_signatures"]})


# Child script: one engine, one stream of requests, two live resizes —
# grow 4dev(dp2_tp2) -> 8dev(dp4_tp2) mid-stream, then shrink back —
# with requests in flight and a queue behind them the whole time. The
# figure of merit is the migrate() stall (flush + cross-mesh device_put
# + jit rebuild of the fused step on the new mesh); the hard contract is
# zero tokens lost: every request still emits exactly max_new tokens.
_REPLAN_SCRIPT = r"""
import json
import jax
import numpy as np
import repro
from repro.configs.base import ShapeConfig
from repro.serving import ServeConfig
from repro.serving.engine import Request

arch = repro.get_arch("qwen1.5-0.5b").reduced()
shape = ShapeConfig("bench_replan", 32, 8, "decode")
plan_a = repro.plan(arch, shape, (("data", 2), ("model", 2)))
plan_b = repro.plan(arch, shape, (("data", 4), ("model", 2)))
engine = plan_a.compile().serve(config=ServeConfig(slots=4, max_len=48))

rng = np.random.RandomState(0)
requests, new_tokens = 12, 6
prompts = [rng.randint(1, 100, size=6).astype(np.int32)
           for _ in range(requests)]
engine.submit(Request(rid=-1, prompt=prompts[0], max_new_tokens=2))
engine.run_until_drained(max_steps=20)
engine.reset_step_stats()
for i, p in enumerate(prompts):
    engine.submit(Request(rid=i, prompt=p, max_new_tokens=new_tokens))
steps = 0
while engine.queue or engine.scheduler.has_active():
    if steps == 2:
        grow = engine.migrate(plan_b)
        assert grow.active_slots > 0 and grow.verified, grow
    if steps == 8:
        shrink = engine.migrate(plan_a)
        assert shrink.verified, shrink
    engine.step()
    steps += 1
    assert steps < 400
engine._flush()
done = [r for r in engine.completed if r.rid >= 0]
produced = sum(len(r.out_tokens) for r in done)
mstats = engine.migration_stats()
assert mstats["migrations"] == 2.0, mstats
lost = requests * new_tokens - produced
print("REPLAN_BENCH " + json.dumps({
    "devices": jax.device_count(),
    "plan_a": plan_a.sharding_plan.describe(),
    "plan_b": plan_b.sharding_plan.describe(),
    "predicted_s": plan_b.predicted_seconds,
    "completed": len(done),
    "tokens_lost": lost,
    "grow_stall_ms": grow.stall_s * 1e3,
    "shrink_stall_ms": shrink.stall_s * 1e3,
    "grow_moved_bytes": grow.moved_bytes,
    "shrink_moved_bytes": shrink.moved_bytes,
    **mstats,
    **engine.step_stats(),
}))
"""


# The stall includes the new mesh's jit rebuild, so the baseline number
# is compile-dominated on CPU; the zero-tokens-lost assert inside the
# child is the real contract, the gate only catches order-of-magnitude
# stall regressions.
@scenario("serve_replan", tags=("serving", "e2e", "multidev", "elastic"),
          gate_metric="migration_stall_ms", tolerance=9.0)
def serve_replan() -> BenchResult:
    """Live replan stall: grow 4->8 devices and shrink back mid-stream
    with slots active and a queue waiting; zero tokens may be lost.

    Runs in a subprocess with 8 forced host devices. Each migrate()
    splices the in-flight DecodeState onto the new mesh, so every
    request finishes with exactly its requested token count across two
    resizes — ``tokens_lost_per_resize`` is hard-asserted to be 0 here
    and re-checked by the baseline gate.
    """
    import json

    from repro.testing.mesh_fixtures import run_in_subprocess

    r = run_in_subprocess(_REPLAN_SCRIPT, devices=8, timeout=1200,
                          marker="REPLAN_BENCH")
    line = next(l for l in r.stdout.splitlines()
                if l.startswith("REPLAN_BENCH "))
    child = json.loads(line[len("REPLAN_BENCH "):])
    assert child["devices"] == 8, child
    assert child["completed"] == 12, child
    assert child["migrations"] == 2.0, child
    lost_per_resize = child["tokens_lost"] / child["migrations"]
    assert lost_per_resize == 0.0, (
        f"live replan dropped tokens: {child['tokens_lost']} lost over "
        f"{child['migrations']:.0f} resizes ({child})")
    return BenchResult(
        name="serve_replan", device_kind=jax.default_backend(),
        config={"arch": "qwen1.5-0.5b-smoke", "slots": 4, "max_len": 48,
                "requests": 12, "new_tokens": 6, "devices": 8,
                "mesh_a": [["data", 2], ["model", 2]],
                "mesh_b": [["data", 4], ["model", 2]]},
        metrics={
            "migration_stall_ms": child["migration_stall_p50_ms"],
            "migration_stall_max_ms": child["migration_stall_max_ms"],
            "tokens_lost_per_resize": lost_per_resize,
            "grow_stall_ms": child["grow_stall_ms"],
            "shrink_stall_ms": child["shrink_stall_ms"],
            "moved_bytes": child["migration_moved_bytes"],
            "step_p50_ms": child["step_p50_ms"],
            "completed": float(child["completed"]),
        },
        model_predicted_s=child["predicted_s"],
        measured_s=child["migration_stall_p50_ms"] * 1e-3,
        extras={"plan_a": child["plan_a"], "plan_b": child["plan_b"],
                "subprocess": True})
