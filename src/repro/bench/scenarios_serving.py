"""End-to-end serving scenario through the plan → compile → execute facade.

This is the system-level number the paper's whole argument terminates in:
real decode steps on the live device set, measured by the engine's own
step-timing hooks, printed beside the planner's predicted step time. The
quick variant runs the reduced Qwen config on CPU so CI exercises the
complete pipeline (DSE → NamedShardings → jitted decode → continuous
batching) every push.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.bench.registry import scenario
from repro.bench.schema import BenchResult
from repro.bench.timers import percentile
from repro.configs.base import ShapeConfig

_N_REQUESTS = 8
_NEW_TOKENS = 8


# Budget 9.0 (10x): step time is absolute wall-clock on whatever host runs
# the gate, so only order-of-magnitude regressions (e.g. a shape bug that
# recompiles the decode step every iteration) should trip it.
@scenario("serve_decode", tags=("serving", "e2e"),
          gate_metric="step_p50_ms", tolerance=9.0)
def serve_decode() -> BenchResult:
    """Continuous-batching decode throughput/latency, plan-aware engine."""
    import repro
    from repro.serving.engine import Request

    arch = repro.get_arch("qwen1.5-0.5b").reduced()
    shape = ShapeConfig("bench_decode", 32, 4, "decode")
    plan = repro.plan(arch, shape)
    engine = plan.compile().serve(slots=4, max_len=48)

    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, 100, size=6).astype(np.int32)
               for _ in range(_N_REQUESTS)]
    # warmup: one request through, to pay jit/prefill compile outside the
    # measured window, then reset the step-timing hooks.
    engine.submit(Request(rid=-1, prompt=prompts[0], max_new_tokens=2))
    engine.run_until_drained(max_steps=20)
    engine.reset_step_stats()

    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=_NEW_TOKENS))
    steps = engine.run_until_drained(max_steps=200)
    stats = engine.step_stats()
    done = [r for r in engine.completed if r.rid >= 0]
    lat_ms = [(r.finished_at - r.submitted_at) * 1e3 for r in done]

    metrics = {
        "step_p50_ms": stats["step_p50_ms"],
        "step_p95_ms": stats["step_p95_ms"],
        "tokens_per_s": stats["tokens_per_s"],
        "request_latency_p50_ms": percentile(lat_ms, 50),
        "request_latency_p95_ms": percentile(lat_ms, 95),
        "steps": float(steps),
        "completed": float(len(done)),
    }
    return BenchResult(
        name="serve_decode", device_kind=jax.default_backend(),
        config={"arch": arch.name, "slots": 4, "max_len": 48,
                "requests": _N_REQUESTS, "new_tokens": _NEW_TOKENS,
                "mesh": [list(a) for a in plan.mesh_axes]},
        metrics=metrics,
        model_predicted_s=plan.predicted_seconds,
        measured_s=stats["step_p50_ms"] * 1e-3,
        extras={"plan": plan.sharding_plan.describe()})
