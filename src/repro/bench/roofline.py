"""§Roofline assembly: read the dry-run JSONs and emit the three-term table.

    compute    = FLOPs_dev / peak_FLOPs          (197 TF bf16)
    memory     = HBM_bytes_dev / HBM_bw          (819 GB/s)
    collective = wire_bytes_dev / ICI axis bw    (2 links x 50 GB/s)

All three come from the trip-count-aware HLO analysis
(launch/hlo_analysis.py) of the compiled single-pod dry-run. The dominant
term is the bottleneck the §Perf loop iterates on. MODEL_FLOPS uses
6·N_active·D (train) / 2·N_active·D (inference).
"""
from __future__ import annotations

import json
import pathlib
from typing import List, Optional

from repro.configs import SHAPES, get_arch
from repro.core.hw import V5E
from repro.core.layer_model import model_flops_estimate

# repo_root/experiments/dryrun (this file sits at src/repro/bench/)
DRYRUN_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def load_cells(mesh: str = "pod16x16", tag: str = "") -> List[dict]:
    cells = []
    for f in sorted((DRYRUN_DIR / mesh).glob("*.json")):
        r = json.loads(f.read_text())
        if tag and r.get("tag") != tag:
            continue
        if not tag and r.get("tag"):
            continue
        cells.append(r)
    return cells


def roofline_terms(rec: dict) -> Optional[dict]:
    if "skipped" in rec or "error" in rec:
        return None
    ndev = rec["num_devices"]
    flops = rec["flops_per_device"]
    hbm = rec["hbm_bytes_per_device"]
    wire = rec["collective_wire_bytes_per_device"]
    t_c = flops / V5E.peak_flops_bf16
    t_m = hbm / V5E.hbm_bandwidth
    t_x = wire / V5E.ici_axis_bandwidth()
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dominant = max(terms, key=terms.get)
    bound = max(t_c, t_m, t_x)
    arch = get_arch(rec["arch"])
    shape = SHAPES[rec["shape"]]
    mf = model_flops_estimate(arch, shape)
    hlo_total = flops * ndev
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "bound_s": bound,
        "roofline_fraction": t_c / bound if bound > 0 else 0.0,
        "model_flops": mf,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "predicted_s": rec.get("predicted_seconds", 0.0),
        "plan": rec.get("plan", ""),
    }


_MOVE = {
    "compute": "already compute-bound: scale out or reduce redundant recompute",
    "memory": "raise arithmetic intensity: bigger tiles/fusion, bf16 boundaries, "
              "cut resharding copies",
    "collective": "cut reshard collectives: shard-stable attention layouts, "
                  "bf16 ag/rs, overlap gathers (XFER prefetch)",
}


def table(mesh: str = "pod16x16", tag: str = "") -> List[dict]:
    rows = []
    for rec in load_cells(mesh, tag):
        base = {"arch": rec["arch"], "shape": rec["shape"]}
        if "skipped" in rec:
            rows.append({**base, "skipped": rec["skipped"]})
            continue
        if "error" in rec:
            rows.append({**base, "error": rec["error"][:80]})
            continue
        t = roofline_terms(rec)
        rows.append({**base, **t, "action": _MOVE[t["dominant"]]})
    return rows


def render(mesh: str = "pod16x16", tag: str = "") -> str:
    rows = table(mesh, tag)
    out = [f"### Roofline — {mesh}" + (f" [{tag}]" if tag else ""),
           "| arch | shape | compute(s) | memory(s) | collective(s) | bound | "
           "roofline frac | useful FLOP ratio |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | {r['dominant']} | "
            f"{r['roofline_fraction']*100:.1f}% | {r['useful_ratio']*100:.1f}% |")
    return "\n".join(out)


def main():
    for mesh in ("pod16x16",):
        print(render(mesh))


if __name__ == "__main__":
    main()
