"""Cycle-domain design search for the paper-parity scenarios.

The paper's testbed is a ZCU102 (XCZU9EG): 2520 DSP48, 1824 BRAM18K.
Cycle-domain searches replicate the paper's design constraints (Eqs. 1-7)
and port presets (§5A: ⟨2,2,2⟩ @100MHz for 32b float, ⟨4,8,4⟩ @200MHz for
16b fixed). Formerly ``benchmarks/common.py``; the CNN descriptor sets
(VGG16/YOLOv1/SqueezeNet) for Fig. 15 live here too.
"""
from __future__ import annotations

import itertools
from typing import Iterable, List, Optional, Tuple

from repro.core.layer_model import ConvLayer
from repro.core.partition import PartitionFactors, enumerate_partitions
from repro.core.perf_model import Ports, TilePipelineModel, Tiling

ZCU102_DSP = 2520
ZCU102_BRAM18 = 1824
FREQ = {32: 100e6, 16: 200e6}
PORTS = {32: Ports(2, 2, 2, b2b=2), 16: Ports(4, 8, 4, b2b=8)}

MODEL = TilePipelineModel()


def _tiling_candidates(layer: ConvLayer, p: PartitionFactors,
                       bits: int) -> Iterable[Tiling]:
    from repro.core.perf_model import _device_dims
    _, R, C, M, N = _device_dims(layer, p)
    tms = sorted({min(t, M) for t in (8, 16, 32, 48, 64, 96, 128)})
    tns = sorted({min(t, N) for t in (3, 7, 10, 16, 20, 24, 26, 32, 48)})
    trs = sorted({min(t, R) for t in (1, 7, 13, 14, 26, 27, 55, R)})
    tcs = sorted({min(t, C) for t in (1, 7, 13, 14, 26, 27, 55, C)})
    for tm, tn, tr, tc in itertools.product(tms, tns, trs, tcs):
        yield Tiling(tm, tn, tr, tc)


def feasible(layer: ConvLayer, t: Tiling, bits: int) -> bool:
    if MODEL.dsp_usage(t, bits) > ZCU102_DSP:
        return False
    if MODEL.bram_usage(layer, t, bits) > ZCU102_BRAM18 * 1.02:
        return False  # 2% slack: the paper itself reports 92-103% figures
    return True


def best_design_cycles(layer: ConvLayer, bits: int,
                       p: PartitionFactors = PartitionFactors(),
                       xfer: bool = False,
                       tiling: Optional[Tiling] = None) -> Tuple[float, Tiling]:
    """Paper Eq. 15 for one layer in the cycle domain (ZCU102 constraints)."""
    ports = PORTS[bits]
    best = (float("inf"), None)
    cands = [tiling] if tiling is not None else _tiling_candidates(layer, p, bits)
    for t in cands:
        tc = t.clamp(layer, p)
        if not feasible(layer, tc, bits):
            continue
        lat = MODEL.cycles(layer, tc, ports, p, xfer=xfer)
        if lat.total < best[0]:
            best = (lat.total, tc)
    if best[1] is None:  # smallest fallback
        tc = Tiling(8, 3, 1, 1).clamp(layer, p)
        best = (MODEL.cycles(layer, tc, ports, p, xfer=xfer).total, tc)
    return best


def net_cycles(layers: List[ConvLayer], bits: int,
               p: PartitionFactors = PartitionFactors(), xfer: bool = False,
               tiling: Optional[Tiling] = None) -> float:
    return sum(best_design_cycles(l, bits, p, xfer, tiling)[0] * l.count
               for l in layers)


def best_partition(layers: List[ConvLayer], num_devices: int, bits: int,
                   xfer: bool = True,
                   tiling: Optional[Tiling] = None
                   ) -> Tuple[float, PartitionFactors]:
    """Uniform partition factors across layers (paper §4.5)."""
    best = (float("inf"), PartitionFactors())
    for p in enumerate_partitions(num_devices, B=max(l.B for l in layers),
                                  R=max(l.R for l in layers),
                                  C=max(l.C for l in layers),
                                  M=max(l.M for l in layers),
                                  N=max(l.N for l in layers),
                                  allow_pn=False):
        total = net_cycles(layers, bits, p, xfer, tiling)
        if total < best[0]:
            best = (total, p)
    return best


# ---------------------------------------------------------------------------
# Public CNN descriptor sets for the paper's Fig. 15 (besides AlexNet).
# Spatial dims follow the published architectures.
# ---------------------------------------------------------------------------

def vgg16_layers(batch: int = 1) -> List[ConvLayer]:
    cfg = [(64, 3, 224), (64, 64, 224), (128, 64, 112), (128, 128, 112),
           (256, 128, 56), (256, 256, 56), (256, 256, 56),
           (512, 256, 28), (512, 512, 28), (512, 512, 28),
           (512, 512, 14), (512, 512, 14), (512, 512, 14)]
    return [ConvLayer(f"conv{i}", batch, m, n, r, r, 3)
            for i, (m, n, r) in enumerate(cfg, 1)]


def yolov1_layers(batch: int = 1) -> List[ConvLayer]:
    cfg = [(64, 3, 224, 7), (192, 64, 56, 3), (128, 192, 28, 1),
           (256, 128, 28, 3), (256, 256, 28, 1), (512, 256, 28, 3),
           (256, 512, 14, 1), (512, 256, 14, 3), (256, 512, 14, 1),
           (512, 256, 14, 3), (256, 512, 14, 1), (512, 256, 14, 3),
           (256, 512, 14, 1), (512, 256, 14, 3), (512, 512, 14, 1),
           (1024, 512, 14, 3), (512, 1024, 7, 1), (1024, 512, 7, 3),
           (512, 1024, 7, 1), (1024, 512, 7, 3), (1024, 1024, 7, 3),
           (1024, 1024, 7, 3), (1024, 1024, 7, 3), (1024, 1024, 7, 3)]
    return [ConvLayer(f"conv{i}", batch, m, n, r, r, k)
            for i, (m, n, r, k) in enumerate(cfg, 1)]


def squeezenet_layers(batch: int = 1) -> List[ConvLayer]:
    out: List[ConvLayer] = [ConvLayer("conv1", batch, 96, 3, 111, 111, 7)]
    fires = [  # (squeeze, expand, in_ch, spatial)
        (16, 64, 96, 55), (16, 64, 128, 55), (32, 128, 128, 55),
        (32, 128, 256, 27), (48, 192, 256, 27), (48, 192, 384, 27),
        (64, 256, 384, 27), (64, 256, 512, 13)]
    for i, (s, e, cin, sp) in enumerate(fires, 2):
        out.append(ConvLayer(f"fire{i}.squeeze", batch, s, cin, sp, sp, 1))
        out.append(ConvLayer(f"fire{i}.e1", batch, e, s, sp, sp, 1))
        out.append(ConvLayer(f"fire{i}.e3", batch, e, s, sp, sp, 3))
    out.append(ConvLayer("conv10", batch, 1000, 512, 13, 13, 1))
    return out
