"""Calibrate the analytic perf model against measured runs.

The paper validates its Eq. 8–14 model against on-board measurements and
reports <3% error — that closed loop is what makes the DSE trustworthy.
This module is the jax_pallas analog: measure a family of real matmul
workloads on the live backend, fit the :class:`~repro.core.perf_model.
Calibration` constants (effective compute rate, effective memory
bandwidth, per-layer dispatch overhead) that minimise log-space error,
and report per-layer model-vs-measured relative error before and after.

The fit is a deterministic coordinate descent over shrinking log-space
grids — no optimiser dependencies, same answer every run for the same
measurements.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.bench.registry import scenario
from repro.bench.schema import BenchResult
from repro.bench.timers import measure, percentile
from repro.core.layer_model import ConvLayer
from repro.core.perf_model import Calibration, TilePipelineModel, Tiling


@dataclasses.dataclass(frozen=True)
class Sample:
    """One measured workload: a layer descriptor and what the clock said."""

    layer: ConvLayer
    measured_s: float
    tiling: Optional[Tiling] = None

    def resolve_tiling(self) -> Tiling:
        if self.tiling is not None:
            return self.tiling
        l = self.layer
        return Tiling(Tm=min(128, l.M), Tn=min(128, l.N), Tr=min(256, l.R))


def predict_seconds(model: TilePipelineModel, sample: Sample) -> float:
    dtype = "float32" if sample.layer.bytes_per_elem == 4 else "bfloat16"
    return model.seconds(sample.layer, sample.resolve_tiling(),
                         dtype=dtype).total


def per_layer_errors(model: TilePipelineModel,
                     samples: Sequence[Sample]) -> List[float]:
    """|predicted - measured| / measured per sample."""
    return [abs(predict_seconds(model, s) - s.measured_s) / s.measured_s
            for s in samples]


def _objective(model: TilePipelineModel, samples: Sequence[Sample],
               calib: Calibration) -> float:
    m = model.calibrated(calib)
    err = 0.0
    for s in samples:
        err += (math.log(max(predict_seconds(m, s), 1e-12))
                - math.log(max(s.measured_s, 1e-12))) ** 2
    return err


def _log_grid(lo: float, hi: float, n: int) -> List[float]:
    la, lb = math.log10(lo), math.log10(hi)
    return [10 ** (la + (lb - la) * i / (n - 1)) for i in range(n)]


def fit_calibration(samples: Sequence[Sample],
                    model: Optional[TilePipelineModel] = None,
                    rounds: int = 6) -> Calibration:
    """Coordinate descent on (flops_scale, hbm_scale, overhead_s).

    Each round sweeps every constant over a log grid centred on the
    current best (repeating the sweep while it keeps improving — the
    constants interact: overhead and compute rate both explain small-
    layer time); grids shrink each round. ``ici_scale`` is left at 1.0 —
    single-host runs exercise no inter-device link.
    """
    model = model or TilePipelineModel()
    spans: Dict[str, Tuple[float, float]] = {
        "flops_scale": (1e-7, 10.0),
        "hbm_scale": (1e-7, 10.0),
        "overhead_s": (1e-9, 1.0),
    }
    # Stage 1 — joint coarse scan over (flops, hbm) planes: the two bus
    # scales trade off against each other, so seeding them independently
    # strands the refinement in a ravine of the objective surface.
    best = Calibration()
    best_err = _objective(model, samples, best)
    coarse = _log_grid(1e-6, 10.0, 13)
    for fs in coarse:
        for hs in coarse:
            for oh in (0.0, 1e-4):
                cand = Calibration(flops_scale=fs, hbm_scale=hs, overhead_s=oh)
                err = _objective(model, samples, cand)
                if err < best_err:
                    best, best_err = cand, err
    # Stage 2 — shrinking coordinate sweeps around the seed.
    width = {k: (hi / lo) for k, (lo, hi) in spans.items()}
    for r in range(rounds):
        for _sweep in range(3):
            improved = False
            for key, (lo, hi) in spans.items():
                c = max(getattr(best, key), lo)
                w = width[key] ** (0.4 ** r)
                grid = _log_grid(max(lo, c / w), min(hi, c * w), 25)
                if key == "overhead_s":
                    grid = [0.0] + grid
                for val in grid:
                    cand = dataclasses.replace(best, **{key: val})
                    err = _objective(model, samples, cand)
                    if err < best_err * (1.0 - 1e-9):
                        best, best_err = cand, err
                        improved = True
            if not improved:
                break
    return best


# ---------------------------------------------------------------------------
# Live measurement: matmul families on the current jax backend.
# ---------------------------------------------------------------------------

# (tokens R, input N, output M): square compute-heavy shapes plus wide
# low-arithmetic-intensity shapes so both roofs are observable in the fit.
# All dims ≥ the MXU tile (128) so the model's systolic-array efficiency
# penalty — a TPU-geometry effect — does not distort a CPU/GPU host fit.
_HOST_SHAPES = [
    (256, 256, 256),
    (384, 384, 384),
    (512, 512, 512),
    (1024, 128, 256),
    (2048, 128, 128),
    (512, 1024, 512),
]


def measure_host_samples(repeats: int = 7) -> List[Sample]:
    """Time jitted f32 matmuls for each calibration shape.

    Uses the min over repeats: the least contention-sensitive statistic,
    which is what a *model* of the hardware should be fitted to.
    """
    import jax
    import jax.numpy as jnp

    f = jax.jit(lambda x, w: x @ w)
    out = []
    for r, n, m in _HOST_SHAPES:
        key = jax.random.PRNGKey(r + n + m)
        x = jax.random.normal(key, (r, n), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (n, m), jnp.float32)
        stats = measure(lambda: jax.block_until_ready(f(x, w)),
                        repeats=repeats, warmup=2)
        layer = ConvLayer(f"matmul_{r}x{n}x{m}", B=1, M=m, N=n, R=r, C=1,
                          bytes_per_elem=4, tokens_folded=True)
        out.append(Sample(layer=layer, measured_s=stats.min_ms * 1e-3))
    return out


@scenario("calibration", tags=("model",),
          gate_metric="rel_err_after_p50", tolerance=4.0)
def calibration() -> BenchResult:
    """Fit model constants to this host; report per-layer error."""
    import jax

    model = TilePipelineModel()
    samples = measure_host_samples()
    before = per_layer_errors(model, samples)
    calib = fit_calibration(samples, model)
    after = per_layer_errors(model.calibrated(calib), samples)
    per_layer = [
        {"layer": s.layer.name,
         "measured_ms": s.measured_s * 1e3,
         "predicted_ms": predict_seconds(model.calibrated(calib), s) * 1e3,
         "rel_err_before": eb, "rel_err_after": ea}
        for s, eb, ea in zip(samples, before, after)]
    return BenchResult(
        name="calibration", device_kind=jax.default_backend(),
        config={"shapes": _HOST_SHAPES, "dtype": "float32"},
        metrics={
            "rel_err_before_p50": percentile(before, 50),
            "rel_err_after_p50": percentile(after, 50),
            "rel_err_after_max": max(after),
            "flops_scale": calib.flops_scale,
            "hbm_scale": calib.hbm_scale,
            "overhead_us": calib.overhead_s * 1e6,
        },
        extras={"per_layer": per_layer, "calibration": calib.as_dict()})
