"""Full-set scenarios wrapping the paper-parity tables and TPU transplant.

These run the cycle-domain analytic model over the paper's own vehicles
(Tables 1/3/4, Figs 3/14/15) and the time-domain XFER-vs-baseline study.
They are ``--full``-only: minutes of pure-Python search, all derived from
closed-form model evaluations, so they validate reproduction fidelity
rather than host speed (no regression gate on wall time).
"""
from __future__ import annotations

import jax

from repro.bench.registry import scenario
from repro.bench.schema import BenchResult


def _rows_result(name: str, rows, config: dict) -> BenchResult:
    total_us = sum(us for _, us, _ in rows)
    return BenchResult(
        name=name, device_kind=jax.default_backend(), config=config,
        metrics={"wall_ms": total_us / 1e3, "rows": float(len(rows))},
        measured_s=total_us / 1e6,
        extras={"rows": [{"name": n, "wall_us": us, "derived": d}
                         for n, us, d in rows]})


@scenario("paper_tables", quick=False, tags=("paper", "cycle-domain"),
          gate_metric=None)
def paper_tables() -> BenchResult:
    """Tables 1/3/4 + Figs 3/14/15 through the cycle-domain model."""
    from repro.bench import paper_tables as T
    rows = []
    rows += T.table1_uniform_vs_custom()
    rows += T.table3_xfer_speedup()
    rows += T.table4_bottleneck_detection()
    rows += T.fig3_pipeline_beat()
    rows += T.fig14_model_accuracy()
    rows += T.fig15_scaling()
    return _rows_result("paper_tables", rows,
                        {"vehicle": "alexnet+squeezenet+vgg16+yolov1",
                         "domain": "cycles", "testbed": "zcu102"})


@scenario("tpu_xfer", quick=False, tags=("paper", "time-domain"),
          gate_metric=None)
def tpu_xfer() -> BenchResult:
    """XFER vs replicate vs layer-pipelining, time-domain on a 16x16 mesh."""
    from repro.bench import tpu_scenarios as X
    rows = X.xfer_vs_baseline() + X.pipeline_baseline()
    return _rows_result("tpu_xfer", rows,
                        {"mesh": [list(a) for a in X.MESH], "domain": "seconds"})
