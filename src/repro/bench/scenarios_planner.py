"""Planner DSE scenarios — wall time of Eq. 15 search + its prediction.

The gate metric is the *predicted* step time, which is a pure function of
(arch, shape, mesh, model constants): any PR that shifts it by >15% has
changed the analytic model or the search, and the bench gate forces that
to be a conscious decision. Search wall time is reported alongside.
"""
from __future__ import annotations

import jax

from repro.bench.registry import scenario
from repro.bench.schema import BenchResult
from repro.bench.timers import measure
from repro.configs import SHAPES, get_arch
from repro.core.planner import candidate_plans, plan_cell

_MESH = (("data", 16), ("model", 16))
_ARCH, _SHAPE = "minitron-8b", "decode_32k"


@scenario("planner_dse", tags=("planner",),
          gate_metric="predicted_ms", tolerance=0.15)
def planner_dse() -> BenchResult:
    """plan_cell over a 256-chip mesh: search cost and chosen plan."""
    arch, shape = get_arch(_ARCH), SHAPES[_SHAPE]
    stats = measure(lambda: plan_cell(arch, shape, _MESH), repeats=3, warmup=1)
    rep = plan_cell(arch, shape, _MESH)
    n_cand = len(candidate_plans(arch, shape, _MESH))
    return BenchResult(
        name="planner_dse", device_kind=jax.default_backend(),
        config={"arch": _ARCH, "shape": _SHAPE, "mesh": [list(a) for a in _MESH]},
        metrics={"dse_wall_ms": stats.p50_ms,
                 "dse_wall_p95_ms": stats.p95_ms,
                 "predicted_ms": rep.predicted_seconds * 1e3,
                 "hbm_gb": rep.hbm_bytes_per_device / 2**30,
                 "candidates": float(n_cand)},
        model_predicted_s=rep.predicted_seconds,
        extras={"plan": rep.plan.describe(), "note": rep.note,
                "feasible": rep.feasible and rep.fits_hbm})
