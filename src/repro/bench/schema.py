"""Schema-versioned benchmark result records.

Every scenario run emits one ``BENCH_<scenario>.json`` holding a
:class:`BenchResult`: what ran (config + stable hash), where it ran
(device kind, jax version), what was measured (metrics dict, latency
percentiles, tokens/s where applicable), and the analytic model's
prediction next to the measured number — the paper's model-validation
loop (their table reports <3% model error) as a machine-readable
artifact.

The schema is versioned so ``--compare`` can refuse to diff records it
does not understand instead of silently mis-reading them.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
from typing import Any, Dict, Optional, Union

SCHEMA_VERSION = 1

PathLike = Union[str, pathlib.Path]


def config_hash(config: Dict[str, Any]) -> str:
    """Stable short hash of a scenario's configuration dict."""
    blob = json.dumps(config, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()[:12]


@dataclasses.dataclass
class BenchResult:
    """One scenario's measured outcome.

    ``metrics`` values are numbers; lower-is-better for every metric used
    as a regression gate (times in ms, relative errors as fractions).
    Informational higher-is-better numbers (``tokens_per_s``) live in
    ``metrics`` too but are never gated on. Non-numeric payloads
    (per-layer tables, derived strings) go in ``extras``.
    """

    name: str
    device_kind: str                      # jax.default_backend(): cpu/tpu/gpu
    config: Dict[str, Any]
    metrics: Dict[str, float]
    schema_version: int = SCHEMA_VERSION
    config_hash: str = ""
    jax_version: str = ""
    # model-validation pair: analytic prediction vs what the clock said
    model_predicted_s: Optional[float] = None
    measured_s: Optional[float] = None
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        # Coerce metrics to native floats up front: a stray np.float32 /
        # jnp scalar would otherwise be silently stringified by
        # json.dumps(default=str) and crash the regression gate later.
        # (Also repairs string-typed metrics when re-reading old records.)
        self.metrics = {k: float(v) for k, v in self.metrics.items()}
        if not self.config_hash:
            self.config_hash = config_hash(self.config)
        if not self.jax_version:
            try:
                import jax
                self.jax_version = jax.__version__
            except Exception:
                self.jax_version = "unknown"

    @property
    def model_rel_error(self) -> Optional[float]:
        """|predicted - measured| / measured, when both sides exist."""
        if not self.model_predicted_s or not self.measured_s:
            return None
        return abs(self.model_predicted_s - self.measured_s) / self.measured_s

    # ------------------------------ json ------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        err = self.model_rel_error
        if err is not None:
            d["model_rel_error"] = err
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "BenchResult":
        d = dict(d)
        ver = d.get("schema_version", 0)
        if ver != SCHEMA_VERSION:
            raise ValueError(
                f"BENCH record {d.get('name', '?')!r} has schema_version "
                f"{ver}, this reader understands {SCHEMA_VERSION}")
        d.pop("model_rel_error", None)  # derived, not stored state
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    def write(self, out_dir: PathLike) -> pathlib.Path:
        path = pathlib.Path(out_dir) / bench_filename(self.name)
        path.write_text(json.dumps(self.to_dict(), indent=2, sort_keys=True,
                                   default=str) + "\n")
        return path

    @classmethod
    def read(cls, path: PathLike) -> "BenchResult":
        return cls.from_dict(json.loads(pathlib.Path(path).read_text()))


def bench_filename(scenario_name: str) -> str:
    return f"BENCH_{scenario_name}.json"


def load_results(path: PathLike) -> Dict[str, BenchResult]:
    """Load one BENCH_*.json file or every one under a directory."""
    p = pathlib.Path(path)
    files = sorted(p.glob("BENCH_*.json")) if p.is_dir() else [p]
    out: Dict[str, BenchResult] = {}
    for f in files:
        r = BenchResult.read(f)
        out[r.name] = r
    return out
