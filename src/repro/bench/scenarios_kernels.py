"""Pallas kernel scenarios — each kernel timed against its jnp oracle.

On CPU the kernels run in interpret mode (the correctness path CI can
execute); on TPU the same scenarios time the real Pallas lowering. The
gate metric is ``ratio_vs_ref`` — kernel time normalised by the oracle's
time on the *same* host — so the committed baseline stays comparable
across machines of different absolute speed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.registry import scenario
from repro.bench.schema import BenchResult
from repro.bench.timers import measure
from repro.core.layer_model import ConvLayer
from repro.core.perf_model import TilePipelineModel, Tiling

_REPEATS = 7


def _kernel_result(name: str, config: dict, kernel_fn, ref_fn, args,
                   predicted_s=None) -> BenchResult:
    """Time jitted kernel vs jitted oracle on the same operands.

    Both sides are jitted with the operands as real arguments (a zero-arg
    closure would constant-fold the whole computation at trace time), so
    the measured window is steady-state execution, not retracing.
    """
    k_j = jax.jit(kernel_fn)
    r_j = jax.jit(ref_fn)
    out_k = k_j(*args)
    out_r = r_j(*args)
    max_abs_err = float(jnp.max(jnp.abs(out_k.astype(jnp.float32)
                                        - out_r.astype(jnp.float32))))
    ks = measure(lambda: jax.block_until_ready(k_j(*args)), repeats=_REPEATS)
    rs = measure(lambda: jax.block_until_ready(r_j(*args)), repeats=_REPEATS)
    # min-over-repeats is the most noise-robust microbench statistic; the
    # ratio of mins is what the regression gate tracks across hosts.
    metrics = {**ks.as_metrics(), **rs.as_metrics("ref_"),
               "ratio_vs_ref": ks.min_ms / max(rs.min_ms, 1e-9),
               "max_abs_err": max_abs_err}
    return BenchResult(name=name, device_kind=jax.default_backend(),
                       config=config, metrics=metrics,
                       model_predicted_s=predicted_s, measured_s=ks.p50_s)


@scenario("kernel_xfer_matmul", tags=("kernel",),
          gate_metric="ratio_vs_ref", tolerance=2.0)
def kernel_xfer_matmul() -> BenchResult:
    """Tiled Pallas matmul vs jnp.dot, with the Eq. 8-14 model prediction."""
    from repro.kernels import ops
    n = 256
    tile = 128
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (n, n), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (n, n), jnp.float32)
    layer = ConvLayer("xfer_matmul", B=1, M=n, N=n, R=n, C=1,
                      bytes_per_elem=4, tokens_folded=True)
    pred = TilePipelineModel().seconds(layer, Tiling(tile, tile, tile),
                                       dtype="float32").total
    return _kernel_result(
        "kernel_xfer_matmul",
        {"shape": [n, n, n], "tile": tile, "dtype": "float32"},
        lambda a, b: ops.matmul(a, b, tr=tile, tm=tile, tn=tile),
        ops.matmul_ref, (x, w),
        predicted_s=pred)


@scenario("kernel_flash_attention", tags=("kernel",),
          gate_metric="ratio_vs_ref", tolerance=2.0)
def kernel_flash_attention() -> BenchResult:
    """Blockwise flash attention vs the masked-softmax oracle."""
    from repro.kernels import ops
    bh, s, d = 4, 256, 64
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (bh, s, d), jnp.float32)
    return _kernel_result(
        "kernel_flash_attention",
        {"shape": [bh, s, d], "causal": True, "block": 128},
        lambda a, b, c: ops.attention(a, b, c, bq=128, bk=128),
        ops.attention_ref, (q, q, q))


@scenario("kernel_rglru_scan", tags=("kernel",),
          gate_metric="ratio_vs_ref", tolerance=2.0)
def kernel_rglru_scan() -> BenchResult:
    """Chunked RG-LRU associative scan vs the sequential reference."""
    from repro.kernels import ops
    b, s, w = 2, 256, 128
    k = jax.random.PRNGKey(0)
    a = jax.nn.sigmoid(jax.random.normal(k, (b, s, w), jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, w), jnp.float32)
    h0 = jnp.zeros((b, w), jnp.float32)
    return _kernel_result(
        "kernel_rglru_scan",
        {"shape": [b, s, w], "block": 128},
        lambda u, v, h: ops.lru_scan(u, v, h, bs=128),
        ops.lru_scan_ref, (a, x, h0))


@scenario("kernel_mlstm", tags=("kernel",),
          gate_metric="ratio_vs_ref", tolerance=2.0)
def kernel_mlstm() -> BenchResult:
    """Chunkwise mLSTM kernel vs the strict per-step recurrence."""
    from repro.kernels import ops
    bh, s, d = 2, 128, 64
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (bh, s, d), jnp.float32)
    kk = jax.random.normal(jax.random.PRNGKey(1), (bh, s, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (bh, s, d), jnp.float32)
    it = jax.random.normal(jax.random.PRNGKey(3), (bh, s), jnp.float32)
    ft = jax.random.normal(jax.random.PRNGKey(4), (bh, s), jnp.float32) + 3.0
    return _kernel_result(
        "kernel_mlstm",
        {"shape": [bh, s, d], "block": 64},
        lambda a, b, c, d, e: ops.mlstm(a, b, c, d, e, bq=64),
        ops.mlstm_ref, (q, kk, v, it, ft))
