"""§Perf measurement harness: one (arch × shape) cell, baseline vs optimized.

    PYTHONPATH=src:. python -m benchmarks.hillclimb <arch> <shape> [baseline|optimized]

`baseline` sets REPRO_EXPLICIT_SPMD=0 (pure-GSPMD paths: no shard_map
attention locality, no explicit EP all-to-all, no flash-decoding, original
head-sharded cache layout) — the paper-faithful GSPMD implementation.
`optimized` (default) is the beyond-paper explicit-SPMD path.

Must run as its own process: the 512-device host platform and the env
toggle are locked at jax import.
"""
import os
import sys


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    mode = sys.argv[3] if len(sys.argv) > 3 else "optimized"
    if mode == "baseline":
        os.environ["REPRO_EXPLICIT_SPMD"] = "0"
    # importing dryrun forces the 512-device host platform (via
    # testing.mesh_fixtures: appends to XLA_FLAGS, never overwrites)
    from repro.launch.dryrun import lower_cell
    from repro.launch import hlo_analysis as H

    rep, mesh, lowered = lower_cell(arch, shape, False)
    c = H.analyze(lowered.compile().as_text())
    scale, u = (1e3, "ms") if shape.startswith(("decode", "long")) else (1.0, "s")
    print(f"RESULT {arch} {shape} {mode}: "
          f"compute {c.flops * scale / 197e12:.3f}{u} "
          f"memory {c.hbm_bytes * scale / 819e9:.3f}{u} "
          f"collective {c.collective_wire_bytes * scale / 100e9:.3f}{u} "
          f"plan=[{rep.plan.describe()}]")


if __name__ == "__main__":
    main()
