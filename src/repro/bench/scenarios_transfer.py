"""Transfer-path scenarios: XFER weight gather + collective accounting.

The paper's XFER core (§4.3) replaces local re-reads of the shared tensor
with an inter-device exchange. On a CPU host the exchange itself reduces
to a shard concatenation; the scenario times that datapath and prints the
analytic ring all-gather prediction (`core.hw.all_gather_time`) beside
it. The HLO-accounting scenario exercises `launch/collectives.py` — the
component that derives the roofline's wire-bytes term — against a
synthetic HLO module whose traffic is known in closed form, so its gate
metric (total wire GB) is deterministic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.registry import scenario
from repro.bench.schema import BenchResult
from repro.bench.timers import measure
from repro.core import hw


@scenario("xfer_weight_gather", tags=("transfer",),
          gate_metric=None)
def xfer_weight_gather() -> BenchResult:
    """Gather a weight matrix from P shards (XFER Fig. 8 datapath)."""
    P = 8
    m, n = 1024, 1024
    shards = [jax.random.normal(jax.random.PRNGKey(i), (m // P, n), jnp.float32)
              for i in range(P)]

    @jax.jit
    def gather(*xs):
        return jnp.concatenate(xs, axis=0)

    stats = measure(lambda: jax.block_until_ready(gather(*shards)), repeats=5)
    bytes_per_dev = m // P * n * 4
    pred = hw.all_gather_time(bytes_per_dev, P)
    return BenchResult(
        name="xfer_weight_gather", device_kind=jax.default_backend(),
        config={"shards": P, "shape": [m, n], "dtype": "float32"},
        metrics={**stats.as_metrics(),
                 "gathered_mb": m * n * 4 / 2**20,
                 "predicted_ici_ms": pred * 1e3},
        model_predicted_s=pred, measured_s=stats.p50_s)


_N_OPS = 200


def _synthetic_hlo(n_ops: int = _N_OPS) -> str:
    lines = ["HloModule bench_synthetic"]
    kinds = ["all-gather", "all-reduce", "reduce-scatter", "all-to-all"]
    for i in range(n_ops):
        kind = kinds[i % len(kinds)]
        dim = 128 * (1 + i % 8)
        lines.append(
            f"  %{kind}.{i} = bf16[4,{dim},512]{{2,1,0}} {kind}(%p.{i}), "
            f"replica_groups={{{{0,1,2,3}}}}, dimensions={{0}}")
    return "\n".join(lines)


@scenario("collectives_hlo_parse", tags=("transfer",),
          gate_metric="wire_gb", tolerance=0.15)
def collectives_hlo_parse() -> BenchResult:
    """Wire-byte derivation from HLO text (the roofline's third term)."""
    from repro.launch.collectives import parse_collectives
    hlo = _synthetic_hlo()
    stats = measure(lambda: parse_collectives(hlo), repeats=5)
    rec = parse_collectives(hlo)
    total = rec["_total"]
    return BenchResult(
        name="collectives_hlo_parse", device_kind=jax.default_backend(),
        config={"ops": _N_OPS, "group_size": 4},
        metrics={**stats.as_metrics(),
                 "wire_gb": total["wire_bytes"] / 1e9,
                 "collective_ops": float(total["count"])},
        measured_s=stats.p50_s,
        extras={"per_kind": {k: v for k, v in rec.items() if k != "_total"}})
