"""``python -m repro.bench`` — the benchmark subsystem's front door.

    python -m repro.bench --quick                 # CI CPU gate (<~5 min)
    python -m repro.bench --full                  # + paper-parity scenarios
    python -m repro.bench --quick --filter 'kernel_*'
    python -m repro.bench --quick --compare benchmarks/baseline
    python -m repro.bench --list

Exit codes: 0 ok · 1 regression vs baseline (or an ineffective gate that
compared zero scenarios — fail closed) · 2 scenario error.
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.bench import registry, runner


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.bench",
        description="Benchmark registry/runner with perf-model calibration.")
    mode = p.add_mutually_exclusive_group()
    mode.add_argument("--quick", action="store_true",
                      help="quick scenario set (CPU-safe CI gate; default)")
    mode.add_argument("--full", action="store_true",
                      help="every scenario incl. paper-parity tables")
    p.add_argument("--filter", metavar="GLOB", default=None,
                   help="run only scenarios matching this glob")
    p.add_argument("--out", metavar="DIR", default=".",
                   help="directory for BENCH_*.json files (default: .)")
    p.add_argument("--compare", metavar="BASELINE", default=None,
                   help="baseline BENCH_*.json file or directory; exits 1 "
                        "when a gate metric regresses past its budget")
    p.add_argument("--list", action="store_true", dest="list_only",
                   help="list registered scenarios and exit")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    quick_only = not args.full
    scenarios = registry.select(quick_only=quick_only, pattern=args.filter)
    if args.list_only:
        for s in registry.select(quick_only=False, pattern=args.filter):
            gate = (f"gate={s.gate_metric} (+{s.tolerance * 100:.0f}%)"
                    if s.gate_metric else "report-only")
            print(f"{s.name:<28} {'quick' if s.quick else 'full ':<5} "
                  f"{gate:<32} {s.doc}")
        return 0
    if not scenarios:
        print(f"no scenarios match --filter {args.filter!r}")
        return 2
    print(f"repro.bench: {len(scenarios)} scenario(s) "
          f"[{'quick' if quick_only else 'full'}] -> {args.out}")
    report = runner.run(scenarios, out_dir=args.out)
    rc = 0
    if args.compare and report.results:
        cmp = runner.compare(report.results, args.compare)
        for n in cmp.notes:
            print(f"  note: {n}")
        if cmp.regressions:
            print(f"REGRESSION vs {args.compare}:")
            for r in cmp.regressions:
                print(f"  {r.describe()}")
            rc = 1
        elif cmp.gated == 0 and cmp.gateable > 0:
            # Fail closed: gateable scenarios ran but none were compared
            # (missing/unreadable baseline, schema mismatch, config drift)
            # — that must not report success.
            print(f"GATE INEFFECTIVE vs {args.compare}: 0 of {cmp.gateable} "
                  "gateable scenarios gated — regenerate the baseline "
                  "(see BENCHMARKS.md)")
            rc = 1
        elif cmp.gateable == 0:
            # e.g. --filter selected only report-only scenarios
            print(f"compare vs {args.compare}: nothing to gate "
                  "(report-only selection)")
        else:
            print(f"compare vs {args.compare}: no regressions "
                  f"({cmp.gated} gated)")
    if report.errors:
        print(f"{len(report.errors)} scenario(s) failed: "
              f"{', '.join(sorted(report.errors))}")
        rc = 2
    return rc


if __name__ == "__main__":
    sys.exit(main())
