"""Scenario registry — benchmarks as declared, discoverable objects.

A scenario is a named callable returning a :class:`~repro.bench.schema.
BenchResult`; registration declares everything the runner and the
``--compare`` regression gate need to know about it:

* ``quick`` — safe for the CI CPU gate (the whole quick set must stay
  under ~5 minutes);
* ``gate_metric`` — which (lower-is-better) metric the regression gate
  diffs against the committed baseline, or ``None`` for report-only
  scenarios whose primary number is absolute wall time on unknown
  hardware;
* ``tolerance`` — allowed relative growth of the gate metric before the
  gate trips (default 0.15 = the 15% CI regression budget; ratio-style
  metrics on shared CI runners get looser budgets at registration).
"""
from __future__ import annotations

import dataclasses
import fnmatch
from typing import Callable, Dict, List, Optional, Tuple

from repro.bench.schema import BenchResult

DEFAULT_TOLERANCE = 0.15


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    fn: Callable[..., BenchResult]
    quick: bool = True
    tags: Tuple[str, ...] = ()
    gate_metric: Optional[str] = "p50_ms"
    tolerance: float = DEFAULT_TOLERANCE
    doc: str = ""


_REGISTRY: Dict[str, Scenario] = {}


def scenario(name: str, *, quick: bool = True, tags: Tuple[str, ...] = (),
             gate_metric: Optional[str] = "p50_ms",
             tolerance: float = DEFAULT_TOLERANCE):
    """Decorator: register ``fn`` as a benchmark scenario."""

    def deco(fn: Callable[..., BenchResult]):
        if name in _REGISTRY:
            raise ValueError(f"duplicate bench scenario {name!r}")
        doc = (fn.__doc__ or "").strip()
        _REGISTRY[name] = Scenario(
            name=name, fn=fn, quick=quick, tags=tuple(tags),
            gate_metric=gate_metric, tolerance=tolerance,
            doc=doc.splitlines()[0] if doc else "")
        return fn

    return deco


def _load_scenario_modules() -> None:
    """Import every module that registers scenarios (idempotent)."""
    import repro.bench.calibrate  # noqa: F401
    import repro.bench.scenarios_kernels  # noqa: F401
    import repro.bench.scenarios_paper  # noqa: F401
    import repro.bench.scenarios_planner  # noqa: F401
    import repro.bench.scenarios_serving  # noqa: F401
    import repro.bench.scenarios_training  # noqa: F401
    import repro.bench.scenarios_transfer  # noqa: F401


def all_scenarios() -> Dict[str, Scenario]:
    _load_scenario_modules()
    return dict(_REGISTRY)


def select(quick_only: bool = True,
           pattern: Optional[str] = None) -> List[Scenario]:
    """Scenarios matching the CLI's ``--quick/--full`` and ``--filter``."""
    out = []
    for s in sorted(all_scenarios().values(), key=lambda s: s.name):
        if quick_only and not s.quick:
            continue
        if pattern and not fnmatch.fnmatch(s.name, pattern):
            continue
        out.append(s)
    return out
