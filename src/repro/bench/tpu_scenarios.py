"""TPU-adaptation benchmark: the paper's Table 2/3 comparison transplanted
to the pod — analytic time-domain model, baseline (replicate) vs XFER
(distribute+exchange) vs the pipelined multi-device baseline (ISLPED16).
"""
from __future__ import annotations

import time
from typing import List

from repro.configs import SHAPES, get_arch
from repro.core.planner import plan_cell

MESH = (("data", 16), ("model", 16))


def xfer_vs_baseline() -> List[tuple]:
    out = []
    for arch_id, shape_id in (("minitron-8b", "train_4k"),
                              ("phi3-medium-14b", "train_4k"),
                              ("minitron-8b", "decode_32k")):
        arch, shape = get_arch(arch_id), SHAPES[shape_id]
        t0 = time.perf_counter()
        on = plan_cell(arch, shape, MESH, force_xfer=True)
        off = plan_cell(arch, shape, MESH, force_xfer=False)
        us = (time.perf_counter() - t0) * 1e6
        out.append((f"tpu_xfer_{arch_id}_{shape_id}", us,
                    f"xfer={on.predicted_seconds*1e3:.1f}ms "
                    f"baseline={off.predicted_seconds*1e3:.1f}ms "
                    f"hbm {on.hbm_bytes_per_device/2**30:.1f}GB vs "
                    f"{off.hbm_bytes_per_device/2**30:.1f}GB "
                    f"(XFER trades {off.hbm_bytes_per_device/max(on.hbm_bytes_per_device,1):.1f}x "
                    f"capacity for ICI exchange)"))
    return out


def pipeline_baseline() -> List[tuple]:
    """ISLPED16-style layer pipelining across 2 pods vs Super-LIP partitioning:
    pipelining preserves throughput but not latency (paper §1/§6)."""
    arch, shape = get_arch("yi-9b"), SHAPES["prefill_32k"]
    t0 = time.perf_counter()
    # Super-LIP: all chips cooperate on one request
    sl = plan_cell(arch, shape, (("pod", 2),) + MESH).predicted_seconds
    # pipelined: 2 stages of 256; latency = sum of stage latencies (fill),
    # throughput = 1/stage_time
    stage = plan_cell(arch, shape, MESH).predicted_seconds
    pipe_latency = 2 * (stage / 2)  # half the model per stage, two stages
    pipe_throughput = 1 / (stage / 2)
    sl_throughput = 1 / sl
    us = (time.perf_counter() - t0) * 1e6
    return [("pipeline_vs_superlip", us,
             f"latency superlip={sl*1e3:.0f}ms pipeline={pipe_latency*1e3:.0f}ms "
             f"thpt superlip={sl_throughput:.2f}req/s pipeline={pipe_throughput:.2f}req/s "
             f"(pipelining matches throughput, loses latency: paper §6)")]
