"""Execute scenarios, write ``BENCH_*.json``, gate regressions.

The comparison contract: each scenario's registered ``gate_metric`` (a
lower-is-better number) may grow by at most ``tolerance`` relative to the
committed baseline; anything beyond that is a regression and the run
exits non-zero — the CI gate every speed PR gets its before/after number
from.
"""
from __future__ import annotations

import dataclasses
import pathlib
import time
import traceback
from typing import Dict, List, Optional, Sequence

from repro.bench.registry import Scenario, all_scenarios
from repro.bench.schema import BenchResult, PathLike, load_results


@dataclasses.dataclass
class RunReport:
    results: Dict[str, BenchResult] = dataclasses.field(default_factory=dict)
    errors: Dict[str, str] = dataclasses.field(default_factory=dict)
    written: List[pathlib.Path] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.errors


@dataclasses.dataclass(frozen=True)
class Regression:
    scenario: str
    metric: str
    baseline: float
    current: float
    tolerance: float

    @property
    def growth(self) -> float:
        return self.current / self.baseline - 1.0

    def describe(self) -> str:
        return (f"{self.scenario}: {self.metric} {self.baseline:.4g} -> "
                f"{self.current:.4g} (+{self.growth * 100:.1f}%, "
                f"budget {self.tolerance * 100:.0f}%)")


def run(scenarios: Sequence[Scenario], out_dir: PathLike = ".",
        verbose: bool = True) -> RunReport:
    """Run each scenario; one failure never hides the others' results."""
    report = RunReport()
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    for s in scenarios:
        t0 = time.perf_counter()
        try:
            result = s.fn()
        except Exception:
            report.errors[s.name] = traceback.format_exc()
            if verbose:
                print(f"  {s.name:<28} ERROR\n{report.errors[s.name]}")
            continue
        if result.name != s.name:
            # A drifted result name would write BENCH_<other>.json, never
            # match the baseline, and silently drop out of the gate.
            report.errors[s.name] = (
                f"scenario returned BenchResult(name={result.name!r}), "
                f"expected {s.name!r}")
            if verbose:
                print(f"  {s.name:<28} ERROR  {report.errors[s.name]}")
            continue
        try:
            report.written.append(result.write(out))
        except (OSError, TypeError, ValueError):
            report.errors[s.name] = traceback.format_exc()
            if verbose:
                print(f"  {s.name:<28} WRITE ERROR\n{report.errors[s.name]}")
            continue
        report.results[s.name] = result
        if verbose:
            wall = time.perf_counter() - t0
            gate = (f"{s.gate_metric}={result.metrics.get(s.gate_metric):.4g}"
                    if s.gate_metric and s.gate_metric in result.metrics
                    else "report-only")
            print(f"  {s.name:<28} {wall:6.1f}s  {gate}")
    return report


@dataclasses.dataclass
class CompareResult:
    """Outcome of a baseline comparison.

    ``gated`` counts scenarios whose gate metric was actually diffed: a
    comparison that gated nothing (baseline unreadable, schema mismatch,
    every config drifted) is NOT a pass — callers must treat
    ``gated == 0`` as a failed gate, otherwise the CI gate fails open.
    """

    regressions: List[Regression] = dataclasses.field(default_factory=list)
    notes: List[str] = dataclasses.field(default_factory=list)
    gated: int = 0      # scenarios whose gate metric was actually diffed
    gateable: int = 0   # scenarios in the run that declare a gate metric

    @property
    def ok(self) -> bool:
        # No regressions, and if anything *could* be gated, something was.
        return not self.regressions and (self.gateable == 0 or self.gated > 0)


def compare(results: Dict[str, BenchResult], baseline_path: PathLike,
            scenarios: Optional[Dict[str, Scenario]] = None) -> CompareResult:
    """Diff gate metrics against a baseline file or directory of them.

    Notes cover scenarios that could not be compared (absent from
    baseline, report-only, config drift, unreadable records).
    """
    scenarios = scenarios if scenarios is not None else all_scenarios()
    out = CompareResult()
    out.gateable = sum(1 for name in results
                       if scenarios.get(name) is not None
                       and scenarios[name].gate_metric is not None)
    try:
        baseline = load_results(baseline_path)
    except (OSError, ValueError, TypeError, KeyError) as e:
        # TypeError/KeyError: structurally broken records (missing fields)
        out.notes.append(f"baseline unreadable at {baseline_path}: {e}")
        return out
    for name, cur in sorted(results.items()):
        spec = scenarios.get(name)
        if spec is None or spec.gate_metric is None:
            out.notes.append(f"{name}: report-only (no gate metric)")
            continue
        base = baseline.get(name)
        if base is None:
            out.notes.append(f"{name}: not in baseline — nothing to gate")
            continue
        metric = spec.gate_metric
        b = base.metrics.get(metric)
        c = cur.metrics.get(metric)
        if b is None or c is None or b <= 0:
            out.notes.append(f"{name}: gate metric {metric!r} missing/degenerate")
            continue
        if base.config_hash != cur.config_hash:
            out.notes.append(f"{name}: config changed "
                             f"({base.config_hash} -> {cur.config_hash}); "
                             "baseline needs refreshing — not gated")
            continue
        out.gated += 1
        if c > b * (1.0 + spec.tolerance):
            out.regressions.append(Regression(name, metric, b, c, spec.tolerance))
    return out
