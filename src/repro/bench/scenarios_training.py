"""Training-path scenario: one full fwd+bwd+optim step through the facade.

Closes the first ROADMAP bench-coverage gap: nothing measured the
train-step datapath (``Executable.train_step`` — jitted loss, backward,
AdamW update, plan-sharded state) even though the planner's train-cell
predictions (``max(fwd, gather) + max(bwd, sync)``) are exactly about it.
Quick variant runs the reduced Qwen config on CPU, so CI re-measures the
complete plan → compile → train-step pipeline every push.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.bench.registry import scenario
from repro.bench.schema import BenchResult
from repro.bench.timers import stats_from_samples
from repro.configs.base import ShapeConfig

_STEPS = 5


# Budget 9.0 (10x): absolute wall-clock on an unknown CI host — only
# order-of-magnitude regressions (a recompile-per-step shape bug, a
# sharding that gathers the full opt state every step) should trip.
@scenario("train_step", tags=("training", "e2e"),
          gate_metric="step_p50_ms", tolerance=9.0)
def train_step() -> BenchResult:
    """Fwd+bwd+AdamW step wall time, plan-aware jitted train step."""
    import time

    import repro
    from repro.data.pipeline import TokenPipeline
    from repro.optim import adamw as OPT

    arch = repro.get_arch("qwen1.5-0.5b").reduced()
    shape = ShapeConfig("bench_train", 64, 8, "train")
    plan = repro.plan(arch, shape)
    exe = plan.compile()

    params = exe.init_params(jax.random.PRNGKey(0))
    cfg = OPT.AdamWConfig()
    opt_state = exe.shard_opt_state(OPT.adamw_init(params, cfg))
    step = exe.train_step(cfg)
    pipeline = iter(TokenPipeline(arch, shape, seed=0))

    # warmup: the first call pays XLA compilation, outside the window
    params, opt_state, metrics = step(params, opt_state, next(pipeline))
    jax.block_until_ready(metrics["loss"])

    samples = []
    losses = []
    for _ in range(_STEPS):
        batch = next(pipeline)
        t0 = time.perf_counter()
        params, opt_state, metrics = step(params, opt_state, batch)
        loss = float(metrics["loss"])  # forces device sync
        samples.append(time.perf_counter() - t0)
        losses.append(loss)
    assert all(np.isfinite(losses)), f"non-finite loss in bench: {losses}"

    stats = stats_from_samples(samples)
    tokens_per_step = shape.global_batch * shape.seq_len
    metrics_out = {
        "step_p50_ms": stats.p50_ms,
        "step_p95_ms": stats.p95_ms,
        "step_mean_ms": stats.mean_ms,
        "tokens_per_s": tokens_per_step / stats.p50_s if stats.p50_s > 0 else 0.0,
        "steps": float(_STEPS),
        "final_loss": losses[-1],
    }
    return BenchResult(
        name="train_step", device_kind=jax.default_backend(),
        config={"arch": arch.name, "seq_len": shape.seq_len,
                "global_batch": shape.global_batch, "steps": _STEPS,
                "mesh": [list(a) for a in plan.mesh_axes]},
        metrics=metrics_out,
        model_predicted_s=plan.predicted_seconds,
        measured_s=stats.p50_s,
        extras={"plan": plan.sharding_plan.describe()})
