"""Paper-parity benchmarks — one function per Super-LIP table/figure.

All run the paper's own vehicle (AlexNet et al.) through the *cycle-domain*
analytic model (Eqs. 8–22 verbatim, ZCU102 resource constraints), so the
paper's headline numbers are reproducible on this CPU container:

  Table 1 — layer-specific vs uniform cross-layer designs
  Table 3 — 1-FPGA baseline vs 2-FPGA Super-LIP (32b and 16b)
  Table 4 — bottleneck detection + XFER alleviation (designs A-D)
  Fig. 3  — XFER pipeline beat improvement
  Fig. 14 — our model vs the FPGA'15 roofline model (deviation structure)
  Fig. 15 — scaling 1→16 devices, four CNNs, super-linear check
"""
from __future__ import annotations

import time
from typing import List

from repro.bench import designs as C
from repro.core.bottleneck import diagnose
from repro.core.layer_model import alexnet_layers
from repro.core.partition import PartitionFactors
from repro.core.perf_model import Ports, TilePipelineModel, Tiling

MODEL = TilePipelineModel()


def table1_uniform_vs_custom() -> List[tuple]:
    """Paper Table 1: per-layer-customised designs vs one uniform design."""
    layers = alexnet_layers(batch=4)  # the table uses partitions of 4
    rows = []
    t0 = time.perf_counter()
    custom_total = 0.0
    for l in layers:
        best = (float("inf"), None, None)
        for p in (PartitionFactors(Pb=4), PartitionFactors(Pr=2, Pb=2),
                  PartitionFactors(Pm=2, Pb=2), PartitionFactors(Pm=4),
                  PartitionFactors(Pr=4)):
            cyc, t = C.best_design_cycles(l, 16, p, xfer=True)
            if cyc < best[0]:
                best = (cyc, p, t)
        custom_total += best[0]
        rows.append((l.name, best[0], best[1].as_dict()))
    uni_cyc, uni_p = C.best_partition(layers, 4, 16, xfer=True)
    us = (time.perf_counter() - t0) * 1e6
    rel = uni_cyc / max(custom_total, 1)
    rows.append(("uniform_total", uni_cyc, uni_p.as_dict()))
    # paper: uniform within 5% of layer-customised (and avoids reconfig)
    return [("table1_uniform_vs_custom", us,
             f"uniform/custom={rel:.3f} (paper: ~1.04) custom={custom_total:.0f}cyc "
             f"uniform={uni_cyc:.0f}cyc")]


def table3_xfer_speedup() -> List[tuple]:
    """Paper Table 3: Super-LIP 2 devices vs single device; paper reports
    2.25x (32b float, ⟨64,7⟩) and 3.48x (16b fixed).

    Two port settings per precision: the paper's idealized §5A ports, and a
    measured-DDR setting (half effective write-side bandwidth) matching the
    paper's own observation (Fig. 2) that real memory systems run below the
    idealized model — super-linearity lives in that memory-bound regime.
    """
    layers = alexnet_layers(batch=1)
    out = []
    for bits, tile, ports, label, paper in (
            (32, Tiling(64, 7, 13, 13), C.PORTS[32], "idealized", 2.25),
            (16, Tiling(128, 10, 13, 13), C.PORTS[16], "idealized", 3.48),
            (16, Tiling(128, 10, 13, 13), Ports(4, 4, 4, b2b=8), "measured-ddr", 3.48)):
        t0 = time.perf_counter()
        single = sum(C.MODEL.cycles(l, tile.clamp(l, PartitionFactors()), ports).total
                     for l in layers)
        best2 = float("inf")
        bestp = None
        from repro.core.partition import enumerate_partitions
        for p in enumerate_partitions(2, 1, 55, 55, 384, 256, allow_pn=False):
            tot = sum(C.MODEL.cycles(l, tile.clamp(l, p), ports, p, xfer=True).total
                      for l in layers)
            if tot < best2:
                best2, bestp = tot, p
        speed = single / best2
        ms_single = single / C.FREQ[bits] * 1e3
        ms_dual = best2 / C.FREQ[bits] * 1e3
        out.append((f"table3_xfer_speedup_{bits}b_{label}",
                    (time.perf_counter() - t0) * 1e6,
                    f"speedup={speed:.2f}x (paper {paper}x) "
                    f"lat {ms_single:.2f}ms->{ms_dual:.2f}ms "
                    f"superlinear={'yes' if speed > 2 else 'no'} "
                    f"partition={bestp.as_dict()}"))
    return out


def table4_bottleneck_detection() -> List[tuple]:
    """Paper Table 4: detect the bound (IFM/weights), apply XFER, measure
    the alleviation (paper: 3.3x / 3.43x for designs A->B, C->D)."""
    out = []
    t0 = time.perf_counter()
    cases = [
        ("A->B", 32, Tiling(8, 32, 13, 13), PartitionFactors(Pm=2), 3.30),
        ("C->D", 16, Tiling(64, 20, 13, 13), PartitionFactors(Pr=2), 3.43),
    ]
    layers = alexnet_layers(batch=1)
    for name, bits, tile, part, paper in cases:
        ports = C.PORTS[bits]
        l5 = layers[4]
        single = MODEL.cycles(l5, tile.clamp(l5, PartitionFactors()), ports)
        diag = diagnose(l5, tile, ports, domain="cycles")
        dual = MODEL.cycles(l5, tile.clamp(l5, part), ports, part, xfer=True)
        diag2 = diagnose(l5, tile, ports, part, xfer=True, domain="cycles")
        speed = single.total / dual.total
        out.append((f"table4_{name}", (time.perf_counter() - t0) * 1e6,
                    f"bound={diag.bottleneck}->{diag2.bottleneck} "
                    f"speedup={speed:.2f}x (paper {paper}x) "
                    f"superlinear={'yes' if speed > part.total else 'no'}"))
    return out


def fig3_pipeline_beat() -> List[tuple]:
    """Paper Fig. 3: XFER reduces the pipeline beat Lat2 (2953→1782 cycles,
    39.65%). We reproduce the *mechanism*: same layer/tile, XFER on/off."""
    l2 = alexnet_layers(batch=1)[1]
    tile = Tiling(64, 24, 7, 14)  # weights-bound design (the Fig. 3 regime)
    ports = Ports(2, 2, 2, b2b=2)
    p = PartitionFactors(Pb=1, Pr=2)
    t0 = time.perf_counter()
    base = MODEL.cycles(l2, tile.clamp(l2, p), ports, p, xfer=False)
    xf = MODEL.cycles(l2, tile.clamp(l2, p), ports, p, xfer=True)
    impr = 1 - xf.lat2 / base.lat2
    return [("fig3_beat_improvement", (time.perf_counter() - t0) * 1e6,
             f"lat2 {base.lat2:.0f}->{xf.lat2:.0f}cyc improv={impr*100:.1f}% "
             f"(paper 39.65%)")]


def fig14_model_accuracy() -> List[tuple]:
    """Paper Fig. 14: the FPGA'15 roofline model (sum/uninterrupted-BW view)
    under-predicts latency for communication-bound designs; our pipeline-of-
    maxes model does not. Compares both models' predictions per design;
    paper's measured deviations: ours 2.53% avg, FPGA'15 up to 45.47%."""
    l5 = alexnet_layers(batch=1)[4]
    ports = Ports(2, 2, 2, b2b=2)
    out = []
    t0 = time.perf_counter()
    for tm, tn in ((12, 16), (10, 22), (8, 32)):
        tile = Tiling(tm, tn, 13, 13)
        ours = MODEL.cycles(l5, tile.clamp(l5, PartitionFactors()), ports)
        # FPGA'15-style estimate: compute and every memory stream fully
        # overlap at peak bandwidth (no pipeline beats)
        trips = ours.trip_outer * ours.trip_inner
        comp = ours.t_comp * trips
        mem = (ours.t_ifm + ours.t_wei) * trips + ours.t_ofm * ours.trip_outer
        fpga15 = max(comp, mem)
        dev = (ours.total - fpga15) / ours.total * 100
        bound = diagnose(l5, tile, ports, domain="cycles").bottleneck
        out.append((f"fig14_design_{tm}x{tn}", (time.perf_counter() - t0) * 1e6,
                    f"ours={ours.total:.0f}cyc fpga15={fpga15:.0f}cyc "
                    f"fpga15_underpredicts_by={dev:.1f}% bound={bound}"))
    return out


def fig15_scaling() -> List[tuple]:
    """Paper Fig. 15: 1→16 devices for AlexNet/SqueezeNet/VGG/YOLO (16b).
    Paper: consistent super-linear for AlexNet/VGG/YOLO; SqueezeNet loses
    super-linearity (compute-bound 1x1 kernels); AlexNet 126.6ms→4.53ms =
    27.93x for YOLO at 16."""
    nets = {
        "alexnet": (alexnet_layers(1), Tiling(128, 10, 13, 13)),
        "squeezenet": (C.squeezenet_layers(1), Tiling(64, 16, 13, 13)),
        "vgg": (C.vgg16_layers(1), Tiling(64, 26, 14, 14)),
        "yolo": (C.yolov1_layers(1), Tiling(64, 25, 14, 14)),
    }
    out = []
    for name, (layers, tile) in nets.items():
        t0 = time.perf_counter()
        base = C.net_cycles(layers, 16, tiling=tile)
        curve = []
        for n in (2, 4, 8, 16):
            cyc, p = C.best_partition(layers, n, 16, xfer=True, tiling=tile)
            curve.append((n, base / cyc))
        us = (time.perf_counter() - t0) * 1e6
        pts = " ".join(f"{n}:{s:.2f}x" for n, s in curve)
        superlin = all(s > n for n, s in curve[:2])
        out.append((f"fig15_{name}", us,
                    f"{pts} superlinear@2-4={'yes' if superlin else 'no'} "
                    f"lat1={base/C.FREQ[16]*1e3:.2f}ms "
                    f"lat16={base/curve[-1][1]/C.FREQ[16]*1e3:.2f}ms"))
    return out
