"""repro.bench — first-class benchmark & perf-model calibration subsystem.

The paper's method only works because its analytic model is *validated*:
predictions are checked against measured runs before the DSE is trusted
(<3% reported error). This package gives the reproduction the same loop:

* :mod:`repro.bench.registry` — scenarios as declared objects (quick/full
  sets, per-scenario regression budgets);
* :mod:`repro.bench.runner` — execution + schema-versioned
  ``BENCH_<scenario>.json`` emission + ``--compare`` regression gate;
* :mod:`repro.bench.calibrate` — fits :class:`repro.core.perf_model.
  Calibration` constants from measured runs and reports per-layer
  model-vs-measured error;
* scenario modules — Pallas kernels vs oracles, transfer/collective
  accounting, planner DSE, end-to-end serving decode through the
  ``plan → compile → execute`` facade, and the paper-parity tables.

Entry point: ``python -m repro.bench --quick|--full`` (see ``cli.py``).
"""
from repro.bench.registry import Scenario, all_scenarios, scenario, select
from repro.bench.runner import (CompareResult, Regression, RunReport, compare,
                                run)
from repro.bench.schema import (SCHEMA_VERSION, BenchResult, bench_filename,
                                load_results)

__all__ = [
    "SCHEMA_VERSION", "BenchResult", "bench_filename", "load_results",
    "Scenario", "scenario", "select", "all_scenarios",
    "RunReport", "Regression", "CompareResult", "run", "compare",
]
