"""Wall-clock measurement helpers: warmup, repeats, percentiles.

JAX dispatch is async — callables passed to :func:`measure` must force
their own results (``block_until_ready`` / ``np.asarray``); the helpers
here only own the clock and the statistics.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Sequence

from repro.core.stats import percentile  # noqa: F401  (re-export: bench API)


@dataclasses.dataclass(frozen=True)
class TimingStats:
    """Per-call wall time distribution over the repeat loop."""

    samples_ms: tuple
    p50_ms: float
    p95_ms: float
    mean_ms: float
    min_ms: float

    @property
    def p50_s(self) -> float:
        return self.p50_ms * 1e-3

    def as_metrics(self, prefix: str = "") -> dict:
        return {f"{prefix}p50_ms": self.p50_ms,
                f"{prefix}p95_ms": self.p95_ms,
                f"{prefix}mean_ms": self.mean_ms,
                f"{prefix}min_ms": self.min_ms}


def stats_from_samples(samples_s: Sequence[float]) -> TimingStats:
    ms = [s * 1e3 for s in samples_s]
    return TimingStats(
        samples_ms=tuple(ms),
        p50_ms=percentile(ms, 50),
        p95_ms=percentile(ms, 95),
        mean_ms=sum(ms) / max(len(ms), 1),
        min_ms=min(ms) if ms else 0.0,
    )


def measure(fn: Callable[[], object], *, repeats: int = 5,
            warmup: int = 1) -> TimingStats:
    """Time ``fn`` (which must block on its own result) repeat times."""
    for _ in range(warmup):
        fn()
    samples: List[float] = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return stats_from_samples(samples)
