"""Super-LIP on TPU pods.

Reproduction + TPU-native extension of:
  "Achieving Super-Linear Speedup across Multi-FPGA for Real-Time DNN
  Inference" (Jiang et al., 2019, DOI 10.1145/3358192).

The paper's contribution — an accurate double-buffered-pipeline analytic
model, a layer partition space ⟨Pb,Pr,Pc,Pm,Pn⟩, and the XFER technique of
sharding *shared* tensors across devices and exchanging them over fast
inter-device links instead of re-reading them from local memory — is
implemented here as a first-class multi-pod JAX framework.

Public surface — the three-stage deployment pipeline (see API.md):

    plan(arch, shape, mesh)  -> ExecutionPlan   # paper Eq. 15 DSE
    ExecutionPlan.compile()  -> Executable      # mesh + NamedShardings + jit
    Executable.serve(...)    -> ServingEngine   # plan-aware continuous batching
    Executable.train(...)    -> TrainDriver     # plan-aware fault-tolerant loop
    deploy(arch, shape, mesh) = plan(...).compile()

plus ``get_arch(id)`` for the architecture registry.
"""

__version__ = "1.1.0"

from repro.api import Executable, deploy, plan  # noqa: E402,F401
from repro.configs import get_arch  # noqa: E402,F401
from repro.core.execution_plan import ExecutionPlan  # noqa: E402,F401

__all__ = ["plan", "deploy", "get_arch", "ExecutionPlan", "Executable",
           "__version__"]
