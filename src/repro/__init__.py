"""Super-LIP on TPU pods.

Reproduction + TPU-native extension of:
  "Achieving Super-Linear Speedup across Multi-FPGA for Real-Time DNN
  Inference" (Jiang et al., 2019, DOI 10.1145/3358192).

The paper's contribution — an accurate double-buffered-pipeline analytic
model, a layer partition space ⟨Pb,Pr,Pc,Pm,Pn⟩, and the XFER technique of
sharding *shared* tensors across devices and exchanging them over fast
inter-device links instead of re-reading them from local memory — is
implemented here as a first-class multi-pod JAX framework.
"""

__version__ = "1.0.0"
