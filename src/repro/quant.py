"""Shared symmetric INT8 quantization — the serving path's bytes lever.

The repo already quantised Adam states (``optim/adamw.py``) and DP
gradients (``runtime/compression.py``) with two ad-hoc copies of the
same round-to-int8 routine; this module is the single implementation
both now route through, extended with the per-channel / per-token modes
the INT8 *serving* path needs (the SNIPPETS exemplar's FP32→INT8
quantize-and-compile pipeline, and the single highest-leverage
bandwidth optimisation both FPGA surveys in PAPERS.md identify).

Contract
--------
``quantize`` is symmetric: ``q = clip(round(x / scale), -127, 127)``
with ``scale = amax / 127`` over the reduction axes. The clip is load-
bearing: fp rounding error at the amax element can produce 127.00...x
which ``round`` takes to 128 — int8 wrap-around to -128 flips the sign
of the largest-magnitude element (the historical ``adamw._quant`` bug).
Quantisation error is bounded by ``scale / 2`` per element inside the
representable range, and ``quantize(dequantize(t))`` is idempotent
(exact round trip of already-quantised values).

Three layouts, one code path:

* per-tensor   — ``axis=None``; scalar f32 scale (optimizer states,
  gradient compression).
* per-channel  — ``axis=<reduced axes>``; the scale keeps the operand's
  rank with reduced axes of extent 1, so ``q * scale`` broadcasts with
  no bookkeeping (serving weights: reduce all but the output-feature
  axis).
* per-token    — a per-channel special case over the head dimension
  (KV-cache rows: scale shaped ``[B, T, G, 1]`` rides next to the int8
  ``[B, T, G, D]`` cache leaf and pages/splices with it structurally).

``QuantConfig`` is the user surface (``ServeConfig(quant=...)``) and the
planner input (``capacity_bytes`` shrinks weight/KV bytes under it).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

PyTree = Any

#: symmetric int8 range bound (‑127..127; -128 is never produced)
Q_MAX = 127.0
#: amax floor so all-zero tensors quantise to scale Q_EPS/127, not 0/0
Q_EPS = 1e-12

Axis = Union[None, int, Tuple[int, ...]]


class QTensor(NamedTuple):
    """Symmetric int8 quantised tensor with an f32 scale.

    ``scale`` is scalar (per-tensor) or keeps ``q``'s rank with the
    reduced axes of extent 1 (per-channel), so ``q * scale`` always
    broadcasts directly."""

    q: jax.Array
    scale: jax.Array

    @property
    def shape(self):
        return self.q.shape

    @property
    def ndim(self):
        return self.q.ndim


def is_qtensor(x) -> bool:
    return isinstance(x, QTensor)


def quantize(x: jax.Array, axis: Axis = None) -> QTensor:
    """Symmetric int8 quantisation over the ``axis`` reduction axes.

    ``axis=None`` → per-tensor (scalar scale); an int or tuple → the
    amax is taken over those axes and the scale keeps rank with extent-1
    reduced axes. The result is always clipped to ±127 (see module
    docstring: unclipped round can wrap the amax element to -128)."""
    x = x.astype(jnp.float32)
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = (jnp.maximum(amax, Q_EPS) / Q_MAX).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -Q_MAX, Q_MAX).astype(jnp.int8)
    return QTensor(q, scale)


def dequantize(t: QTensor, dtype=None) -> jax.Array:
    out = t.q.astype(jnp.float32) * t.scale
    return out if dtype is None else out.astype(dtype)


# ---------------------------------------------------------------------------
# serving-weight quantisation: per-channel over the output-feature axis
# ---------------------------------------------------------------------------

def _weight_axis(x) -> Tuple[int, ...]:
    """Per-channel reduction axes for a weight leaf: everything except
    the trailing output-feature axis."""
    return tuple(range(x.ndim - 1))


def quantize_params(params: PyTree) -> PyTree:
    """Swap every floating matrix-or-higher param leaf for a per-channel
    int8 :class:`QTensor`; vectors (biases, norm scales) and integer
    leaves stay as-is — they are a rounding error of total bytes and
    precision-critical. The pytree *structure* above the leaves is
    unchanged, so param sharding-role trees still line up (the QTensor's
    int8 leaf keeps the original roles; its scale is replicated)."""

    def f(x):
        if (hasattr(x, "ndim") and x.ndim >= 2
                and jnp.issubdtype(x.dtype, jnp.floating)):
            return quantize(x, axis=_weight_axis(x))
        return x

    return jax.tree.map(f, params)


def dequantize_params(params: PyTree, dtype=None) -> PyTree:
    """Inverse of :func:`quantize_params` — called at the top of jitted
    step functions so int8 weights stay HBM-resident and the f32/bf16
    working copy only ever exists transiently inside the step."""
    return jax.tree.map(
        lambda x: dequantize(x, dtype) if is_qtensor(x) else x,
        params, is_leaf=is_qtensor)


def param_qdims(param_dims: PyTree) -> PyTree:
    """Sharding-role tree matching :func:`quantize_params` output: the
    int8 leaf keeps the param's roles, the scale — extent-1 on every
    reduced axis — is replicated."""

    def conv(d):
        if isinstance(d, tuple) and len(d) >= 2:
            return QTensor(q=d, scale=(None,) * len(d))
        return d

    is_dims = lambda x: isinstance(x, tuple) and not isinstance(x, QTensor)
    return jax.tree.map(conv, param_dims, is_leaf=is_dims)


# ---------------------------------------------------------------------------
# KV-cache quantisation: per-token scales over the head dimension
# ---------------------------------------------------------------------------

def quantize_kv(x: jax.Array) -> QTensor:
    """Per-token KV quantisation: ``x [..., G, D]`` → int8 with a
    ``[..., G, 1]`` scale (one scale per token per KV group)."""
    return quantize(x, axis=x.ndim - 1)


def kv_scale_bytes_per_elem(head_dim: int) -> float:
    """Extra bytes/element the f32 per-token scale adds to an int8 KV
    leaf (4 bytes amortised over one head's ``head_dim`` values)."""
    return 4.0 / max(int(head_dim), 1)


# ---------------------------------------------------------------------------
# the user / planner surface
# ---------------------------------------------------------------------------

_MODES = (None, "int8")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """What gets quantised on the serving path.

    ``weights`` — ``"int8"`` stores params as per-channel int8 QTensors
    (HBM-resident; dequantised transiently inside the jitted step).
    ``kv`` — ``"int8"`` stores KV-cache rows as int8 with per-token f32
    scale leaves (``k_scale``/``v_scale``) that ride through splice,
    paging and disaggregation structurally.
    """

    weights: Optional[str] = None
    kv: Optional[str] = None

    def __post_init__(self):
        for name in ("weights", "kv"):
            v = getattr(self, name)
            if v not in _MODES:
                raise ValueError(f"QuantConfig.{name}={v!r}; known: {_MODES}")

    @property
    def enabled(self) -> bool:
        return self.weights is not None or self.kv is not None

    @property
    def quant_kv(self) -> bool:
        return self.kv is not None

    @property
    def quant_weights(self) -> bool:
        return self.weights is not None

    # --- planner bytes model -------------------------------------------
    def param_bytes_per_elem(self, default: float) -> float:
        """Serving-weight bytes/element under this config (int8 payload;
        the per-channel scale is ~4/fan_in bytes/elem — noise)."""
        return 1.0 if self.quant_weights else default

    def kv_bytes_per_elem(self, default: float, head_dim: int = 64) -> float:
        """KV-cache bytes/element: int8 payload + the amortised per-token
        scale (see :func:`kv_scale_bytes_per_elem`)."""
        if not self.quant_kv:
            return default
        return 1.0 + kv_scale_bytes_per_elem(head_dim)


#: canonical full-INT8 serving config
INT8_SERVE = QuantConfig(weights="int8", kv="int8")
