"""Unified deployment API: plan → compile → execute.

The paper's workflow is a pipeline — run the analytic model over the
design space (Eq. 15), pick a partition, then *deploy exactly that
partition* (§5E). This module makes that pipeline first-class::

    import repro

    # stage 1 — DSE: pick the best ShardingPlan + per-layer tiling/ports
    plan = repro.plan("qwen1.5-0.5b", "train_4k")          # auto mesh
    plan = repro.plan(arch_cfg, shape_cfg, mesh)           # explicit mesh

    # stage 2 — compile: build mesh, derive NamedShardings, jit steps
    exe = plan.compile()

    # stage 3 — execute: plan-aware engines
    engine = exe.serve(config=ServeConfig(slots=4, max_len=128))
    driver = exe.train(steps=50, ckpt_dir="/tmp/ckpt")     # TrainDriver

    # or in one call when the defaults are right:
    exe = repro.deploy("qwen1.5-0.5b", "train_4k")

Every arch/shape argument accepts either a registered id string or a
config object; ``mesh`` accepts a live ``jax.sharding.Mesh``, a tuple of
``(axis_name, size)`` pairs, or ``None`` (fit the live device set).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_arch
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.execution_plan import ExecutionPlan
from repro.core.planner import plan_cell
from repro.core.xfer import ShardingCtx
from repro.optim import adamw as OPT

PyTree = Any
MeshLike = Union[None, "jax.sharding.Mesh", Sequence[Tuple[str, int]]]


def _coerce_arch(arch: Union[str, ArchConfig], reduced: bool = False) -> ArchConfig:
    if isinstance(arch, str):
        arch = get_arch(arch)
    return arch.reduced() if reduced else arch


def _coerce_shape(shape: Union[str, ShapeConfig]) -> ShapeConfig:
    if isinstance(shape, str):
        if shape not in SHAPES:
            raise KeyError(f"unknown shape {shape!r}; known: {sorted(SHAPES)}")
        return SHAPES[shape]
    return shape


def _coerce_mesh(mesh: MeshLike, arch: Optional[ArchConfig] = None):
    """-> (mesh_axes, devices, live_mesh). ``arch`` (when known) keeps the
    auto-fitted model axis divisible into the arch's heads."""
    if mesh is None:
        from repro.runtime.elastic import _best_grid
        devices = jax.devices()
        data, model = _best_grid(len(devices), arch)
        return ((("data", data), ("model", model)),
                list(devices[: data * model]), None)
    if isinstance(mesh, jax.sharding.Mesh):
        from repro.launch.mesh import mesh_axes
        return mesh_axes(mesh), list(mesh.devices.flat), mesh
    axes = tuple((str(n), int(s)) for n, s in mesh)
    bad = [(n, s) for n, s in axes if s <= 0]
    if bad:
        raise ValueError(f"mesh axis sizes must be positive, got {bad} in {axes}")
    names = [n for n, _ in axes]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate mesh axis name in {axes}")
    return axes, None, None


def plan(arch: Union[str, ArchConfig], shape: Union[str, ShapeConfig],
         mesh: MeshLike = None, *, reduced: bool = False,
         force_xfer: Optional[bool] = None, quant=None,
         draft: Union[None, str, ArchConfig] = None) -> ExecutionPlan:
    """Stage 1: run the paper's DSE for one cell and wrap the winner.

    The returned :class:`ExecutionPlan` carries the chosen ``ShardingPlan``,
    per-layer ``Tiling``/``Ports``, and the capacity report, and derives the
    ``NamedSharding`` specs that ``compile()`` places tensors with.

    ``quant`` (a :class:`repro.quant.QuantConfig`) informs the capacity
    model when the cell will serve quantised: int8 weights / KV shrink
    per-device HBM residency, which can flip a capacity-infeasible plan
    to feasible (match it to the ``ServeConfig.quant`` you deploy with).

    ``draft`` co-places a speculative-decoding draft model with the
    target (serving shapes only): the capacity report charges both
    models' params + KV footprints to the same devices, and
    ``exe.serve(config=ServeConfig(spec=SpecConfig()))`` resolves its
    draft arch from the plan.
    """
    arch = _coerce_arch(arch, reduced)
    shape = _coerce_shape(shape)
    draft = _coerce_arch(draft, reduced) if draft is not None else None
    axes, devices, live_mesh = _coerce_mesh(mesh, arch)
    report = plan_cell(arch, shape, axes, force_xfer=force_xfer, quant=quant,
                       draft=draft)
    return ExecutionPlan(arch=arch, shape=shape, report=report,
                         mesh_axes=axes, devices=devices, _mesh=live_mesh,
                         draft=draft)


def deploy(arch: Union[str, ArchConfig], shape: Union[str, ShapeConfig],
           mesh: MeshLike = None, *, reduced: bool = False,
           force_xfer: Optional[bool] = None, **compile_kwargs) -> "Executable":
    """plan → compile in one call."""
    return plan(arch, shape, mesh, reduced=reduced,
                force_xfer=force_xfer).compile(**compile_kwargs)


class Executable:
    """Stage 2 output: a plan bound to a live mesh with jitted steps.

    Construction is cheap (mesh + ShardingCtx); jitting happens lazily the
    first time a step builder is asked for, and actual XLA compilation on
    first call as usual.
    """

    def __init__(self, plan: ExecutionPlan, *, dtype=None):
        self.plan = plan
        self.mesh = plan.build_mesh()
        self.ctx: ShardingCtx = plan.ctx(self.mesh)
        if dtype is None:
            dtype = jnp.float32 if jax.default_backend() == "cpu" else jnp.bfloat16
        self.dtype = dtype

    @property
    def arch(self) -> ArchConfig:
        return self.plan.arch

    @property
    def shape(self) -> ShapeConfig:
        return self.plan.shape

    def describe(self) -> str:
        return self.plan.describe()

    # -------------------------- parameters ---------------------------
    def init_params(self, key=None, dtype=None) -> PyTree:
        """Initialise params and place them per the plan's shardings."""
        from repro.models import registry as REG
        if key is None or isinstance(key, int):
            key = jax.random.PRNGKey(key or 0)
        params = REG.init_params(self.arch, key, dtype or self.dtype)
        return self.shard_params(params)

    def shard_params(self, params: PyTree) -> PyTree:
        """device_put with NamedShardings derived from the ShardingPlan."""
        return jax.device_put(params, self.plan.param_shardings(params, self.mesh))

    def shard_opt_state(self, opt_state: PyTree, quantize: bool = False) -> PyTree:
        return jax.device_put(
            opt_state, self.plan.opt_shardings(opt_state, self.mesh, quantize))

    # -------------------------- step builders -------------------------
    def train_step(self, cfg: Optional[OPT.AdamWConfig] = None,
                   lr_schedule=None, accum_steps: int = 1):
        """Jitted plan-aware train step (params, opt, batch) -> (params, opt, metrics)."""
        from repro.models import registry as REG
        cfg = cfg or OPT.AdamWConfig()
        fn = REG.build_train_step(self.arch, cfg, self.ctx, lr_schedule,
                                  accum_steps=accum_steps)
        with self.mesh:
            return jax.jit(fn, donate_argnums=(0, 1))

    def serve_step(self):
        from repro.models import registry as REG
        with self.mesh:
            return jax.jit(REG.build_serve_step(self.arch, self.ctx))

    def prefill_step(self, shape: Optional[ShapeConfig] = None):
        from repro.models import registry as REG
        with self.mesh:
            return jax.jit(REG.build_prefill_step(self.arch, shape or self.shape,
                                                  self.ctx, cache_dtype=self.dtype))

    # -------------------------- stage 3: execute ----------------------
    def serve(self, params: Optional[PyTree] = None, *,
              config: Optional["Any"] = None, on_step=None,
              **legacy_kwargs) -> "Any":
        """Plan-aware :class:`repro.serving.engine.ServingEngine`.

        The serve surface is one typed value — pass a
        :class:`repro.serving.config.ServeConfig`::

            from repro.serving import ServeConfig, PagingConfig, DisaggConfig
            engine = exe.serve(config=ServeConfig(
                slots=4, max_len=128,
                paging=PagingConfig(paged=True),
                disagg=DisaggConfig(prefill_data=2)))

        ``slots``/``max_len`` default to the planned shape's batch/seq;
        the engine exposes the fully-resolved values as ``engine.config``.
        Params are initialised (or re-placed, if given) with the plan's
        NamedShardings before the engine jits its decode step.

        ``config.sampling`` selects on-device token choice (default
        greedy), ``config.lookahead`` the dispatch depth (1 = double-
        buffered, 0 = synchronous), ``config.max_src_len`` bounds enc-dec
        source frames (requests carry ``src_frames`` / vlm
        ``patch_embeds``). ``config.paging`` swaps the dense slot grid
        for the page-pool KV cache (``repro.serving.pages``);
        ``config.disagg`` splits the planned mesh into prefill/decode
        role slices and returns a
        :class:`repro.serving.disagg.DisaggServingEngine` that streams
        admission KV across (``ExecutionPlan.disaggregate``).

        ``on_step`` is the engine's step-timing hook: called after every
        decode step with ``{"step", "wall_s", "tokens"}`` — the probe
        ``repro.bench`` uses to put measured step time next to the plan's
        ``predicted_seconds`` (the paper's model-validation loop).

        The pre-``ServeConfig`` flat kwargs (``slots=, max_len=, paged=,
        ...``) are still accepted — funneled through
        :meth:`ServeConfig.from_kwargs` with a ``DeprecationWarning``.
        """
        import warnings

        from repro.serving.config import ServeConfig
        from repro.serving.engine import ServingEngine
        if config is None:
            if legacy_kwargs:
                warnings.warn(
                    "Executable.serve(slots=..., max_len=..., ...) flat "
                    "kwargs are deprecated; pass "
                    "serve(config=ServeConfig(...))",
                    DeprecationWarning, stacklevel=2)
            config = ServeConfig.from_kwargs(**legacy_kwargs)
        elif legacy_kwargs:
            raise TypeError(
                f"serve() got both config= and flat kwargs "
                f"{sorted(legacy_kwargs)}; put everything in the config")
        config = config.resolve(self.shape)
        if config.spec is not None:
            import dataclasses as _dc

            from repro.models import registry as REG
            from repro.serving.config import SpecConfig  # noqa: F401
            if config.disagg is not None:
                raise NotImplementedError(
                    "speculative decoding does not compose with "
                    "disaggregated serving yet")
            spec = config.spec
            if spec.draft is None:
                if self.plan.draft is None:
                    raise ValueError(
                        "ServeConfig.spec set but no draft arch: pass "
                        "SpecConfig(draft=...) or plan the cell with "
                        "repro.plan(..., draft=...)")
                spec = _dc.replace(spec, draft=self.plan.draft)
                config = _dc.replace(config, spec=spec)
            if params is None:
                params = REG.init_params(
                    self.arch, jax.random.PRNGKey(config.seed), self.dtype)
            if not (isinstance(params, dict)
                    and set(params) == {"target", "draft"}):
                dkey = jax.random.fold_in(
                    jax.random.PRNGKey(config.seed), 1)
                params = {"target": params,
                          "draft": REG.init_params(spec.draft, dkey,
                                                   self.dtype)}
            from repro.serving.engine import ServingEngine
            return self._attach_elastic(
                ServingEngine(self.plan, params, config=config,
                              dtype=self.dtype, on_step=on_step), config)
        if config.disagg is not None:
            # role slices place params on their own meshes; skip the
            # fused-mesh placement and hand the raw tree over
            if config.elastic is not None:
                raise NotImplementedError(
                    "elastic resize does not compose with disaggregated "
                    "serving yet: migrating would re-split the "
                    "prefill/decode role slices")
            from repro.serving.disagg import DisaggServingEngine
            if params is None:
                from repro.models import registry as REG
                params = REG.init_params(
                    self.arch, jax.random.PRNGKey(config.seed), self.dtype)
            return DisaggServingEngine(self.plan, params, config=config,
                                       dtype=self.dtype, on_step=on_step)
        if params is None:
            params = self.init_params(jax.random.PRNGKey(config.seed))
        else:
            params = self.shard_params(params)
        return self._attach_elastic(
            ServingEngine(self.plan, params, config=config,
                          dtype=self.dtype, on_step=on_step), config)

    def _attach_elastic(self, engine, config):
        """Attach the load controller when ``ServeConfig.elastic`` is set:
        the serving loop then drives resizes via ``engine.maybe_resize()``
        (or directly through ``engine.elastic.observe()``)."""
        if config.elastic is not None:
            from repro.runtime.elastic import LoadController
            engine.elastic = LoadController(engine, config.elastic)
        return engine

    def train(self, params: Optional[PyTree] = None,
              opt_state: Optional[PyTree] = None, *,
              steps: int = 20, ckpt_dir: str = "/tmp/repro_ckpt",
              ckpt_every: int = 10, keep: int = 3,
              opt_cfg: Optional[OPT.AdamWConfig] = None,
              lr_schedule=None, accum_steps: int = 1, seed: int = 0,
              pipeline=None, ckpt=None, cfg=None,
              on_failure_rebuild=None) -> "Any":
        """Plan-aware :class:`repro.runtime.driver.TrainDriver`.

        Builds the data pipeline, checkpointer, sharded state and jitted
        step from the plan; call ``.run()`` on the result. ``ckpt`` /
        ``cfg`` override the ``ckpt_dir``/``keep`` and
        ``steps``/``ckpt_every`` conveniences with explicit objects.
        """
        from repro.checkpoint.checkpointer import Checkpointer
        from repro.data.pipeline import TokenPipeline
        from repro.runtime.driver import DriverConfig, TrainDriver
        if opt_cfg is None:
            # honor the capacity side of the DSE: a plan that only fits HBM
            # with int8 Adam states (planner note) must deploy them that way
            from repro.core.planner import INT8_NOTE
            opt_cfg = OPT.AdamWConfig(quantize=INT8_NOTE in self.plan.report.note)
        cfg = cfg or DriverConfig(total_steps=steps, checkpoint_every=ckpt_every)
        if params is None:
            params = self.init_params(jax.random.PRNGKey(seed))
        else:
            params = self.shard_params(params)
        if opt_state is None:
            opt_state = OPT.adamw_init(params, opt_cfg)
        opt_state = self.shard_opt_state(opt_state, opt_cfg.quantize)
        if lr_schedule is None:
            lr_schedule = OPT.cosine_schedule(opt_cfg.lr,
                                              warmup=max(cfg.total_steps // 20, 2),
                                              total=cfg.total_steps)
        step_fn = self.train_step(opt_cfg, lr_schedule, accum_steps)
        pipeline = pipeline or TokenPipeline(self.arch, self.shape, seed=seed)
        ckpt = ckpt or Checkpointer(ckpt_dir, keep=keep)
        return TrainDriver(
            step_fn, params, opt_state, pipeline, ckpt, cfg,
            on_failure_rebuild=on_failure_rebuild, plan=self.plan)
