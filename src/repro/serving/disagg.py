"""Disaggregated prefill/decode serving — two roles, one deployment.

The paper's central move is relieving a saturated local-memory bus by
shifting traffic onto the inter-device links (§4, XFER); the serving
analog implemented here splits the *workload* the same way resources
were split in "Maximizing CNN Accelerator Efficiency Through Resource
Partitioning": one fused mesh becomes two **role-specialised slices**
(``ExecutionPlan.disaggregate``) —

* the **prefill slice** runs the existing batched bucketed prefill
  (the very same :class:`~repro.serving.scheduler.PrefillFactory`
  programs, compiled under the slice's mesh), bursty and compute-bound;
* the **decode slice** runs the fused donated decode step, steady and
  bandwidth-bound.

Finished KV rows (dense splice rows, or the dense rows behind a paged
page chain) stream prefill→decode as an asynchronous cross-mesh
``device_put`` — the runtime analog of the XFER exchange: bytes move
over the interconnect instead of being recomputed from the decode
slice's own compute/memory budget. The scheduler splices an arriving
wave into the decode grid only once every transferred leaf reports
ready (``_Inflight.ready``), so a prefill storm can no longer stall the
decode stream — the property the ``serve_disagg`` bench gates on (p95
decode-step jitter under an admission burst ≤ the fused engine's).

Transferred bytes are accounted like every other transfer in the repo:
:class:`PrefillWorker` books the analytic payload per dispatch
(``kv_xfer_bytes``) and pins each prefill program's **egress shard
bytes** against the compiled HLO's entry outputs
(:meth:`PrefillWorker.verify_hlo`, same tolerance band as
``testing.invariants.check_xfer_accounting``).

Bit-exactness: both sub-plans inherit the fused plan's tp/seq/ep
structure — only the data (batch) axis shrinks — and batch rows are
independent under data parallelism, so prefill rows, admission logits
and decode steps are bit-identical to the fused engine; greedy streams
match token-for-token (``serving_equiv --disagg`` proves it against the
frozen reference).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.execution_plan import DisaggPlan, ExecutionPlan
from repro.core.xfer import tree_shardings
from repro.launch.hlo_analysis import _shape_elems_bytes
from repro.models import registry as REG
from repro.quant import QuantConfig, quantize_params
from repro.serving.config import ServeConfig
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import PrefillFactory, mesh_jit

PyTree = Any

__all__ = ["PrefillWorker", "DisaggServingEngine"]

# same documented band as testing.invariants.check_xfer_accounting: the
# analytic bytes must not exceed what the compiler materialises (modulo
# tolerance), and the compiled form must stay within a small factor of
# the analytic payload (fusion can duplicate or pad, not explode).
XFER_LOWER_TOL = 0.25
XFER_UPPER_FACTOR = 4.0

_ENTRY_RE = re.compile(r"^ENTRY [^\n]*?->\s*(.*?)\s*\{\s*$", re.M)


def _entry_output_bytes(hlo_text: str) -> int:
    """Per-device bytes of the compiled module's entry outputs (the
    prefill program's egress surface)."""
    m = _ENTRY_RE.search(hlo_text)
    if m is None:
        raise ValueError("no ENTRY computation signature in HLO text")
    return _shape_elems_bytes(m.group(1))[1]


@dataclasses.dataclass
class _Signature:
    """One compiled prefill signature on the prefill slice."""
    fn: Any                      # jitted, out_shardings pinned
    abstract: Tuple              # ShapeDtypeStructs of the non-param args
    logical_bytes: int           # full payload (the analytic XFER books)
    shard_bytes: int             # per-device egress (pinned vs HLO)


class PrefillWorker:
    """Executes admission prefill on the prefill slice of a
    disaggregated deployment and streams the results to the decode
    slice.

    The worker compiles the *same* :class:`PrefillFactory` programs the
    fused scheduler uses — under the prefill sub-plan's mesh, with
    ``out_shardings`` pinned from the sub-plan's cache/batch dims so the
    egress bytes per device are analytic. ``dispatch`` is pure dispatch:
    the prefill jit call and the cross-mesh ``device_put`` both return
    immediately; the scheduler polls readiness before splicing.
    """

    def __init__(self, plan: ExecutionPlan, params: PyTree, *,
                 cache_dtype, decode_mesh,
                 quant: Optional[QuantConfig] = None):
        if plan.role != "prefill":
            raise ValueError(f"PrefillWorker needs the role='prefill' "
                             f"sub-plan, got role={plan.role!r}")
        self.plan = plan
        self.arch = plan.arch
        self.mesh = plan.build_mesh()
        self.ctx = plan.ctx(self.mesh)
        self.quant = quant if quant is not None else QuantConfig()
        self.params = jax.device_put(
            params, plan.param_shardings(params, self.mesh))
        if self.quant.quant_weights:
            # the prefill slice holds the same int8 residency the decode
            # engine does; its prefill jits rehydrate transiently
            self.params = mesh_jit(self.mesh, quantize_params)(self.params)
        self.cache_axes = REG.cache_axes(self.arch, cache_dtype,
                                         kv_quant=self.quant.quant_kv)
        self.factory = PrefillFactory(self.arch, self.cache_axes,
                                      cache_dtype, mesh=self.mesh,
                                      quant=self.quant)
        # arriving waves are replicated over the decode slice: every
        # decode device can then splice its own cache shard locally
        self._dst = NamedSharding(decode_mesh, P())
        self._sigs: Dict[Tuple, _Signature] = {}
        self.kv_xfer_bytes = 0
        self.kv_xfer_dispatches = 0

    # ------------------------- signature cache -------------------------
    def _out_dims(self, kind: str) -> Tuple:
        """Logical dim roles of each prefill output (mirrors the output
        tuples built in :meth:`PrefillFactory.build`)."""
        cache_dims = REG.cache_dims(self.arch,
                                    kv_quant=self.quant.quant_kv)
        logits_dims = ("batch", None, None)
        if kind == "encdec":
            return (cache_dims, logits_dims, ("batch", "seq", None))
        return (cache_dims, logits_dims)

    def _signature(self, kind: str, bucket: int, n: int, prefix: int,
                   args: Tuple) -> _Signature:
        key = (kind, bucket, n, prefix)
        sig = self._sigs.get(key)
        if sig is not None:
            return sig
        abstract = tuple(jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args)
        raw = self.factory.build(kind, bucket, n, prefix)
        out_struct = jax.eval_shape(raw, self.params, *abstract)
        out_shardings = tree_shardings(self.ctx, out_struct,
                                       self._out_dims(kind))
        logical = shard = 0
        for leaf, sh in zip(jax.tree.leaves(out_struct),
                            jax.tree.leaves(out_shardings,
                                            is_leaf=lambda x: isinstance(
                                                x, NamedSharding))):
            logical += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
            shard += (int(np.prod(sh.shard_shape(leaf.shape)))
                      * leaf.dtype.itemsize)
        fn = self.factory.get(kind, bucket, n, prefix,
                              out_shardings=out_shardings)
        sig = self._sigs[key] = _Signature(fn=fn, abstract=abstract,
                                           logical_bytes=logical,
                                           shard_bytes=shard)
        return sig

    # ----------------------------- dispatch -----------------------------
    def dispatch(self, kind: str, bucket: int, prefix: int, *,
                 toks: np.ndarray, lens: np.ndarray,
                 frames: Optional[np.ndarray] = None,
                 flens: Optional[np.ndarray] = None,
                 patches: Optional[np.ndarray] = None) -> Tuple:
        """Run one admission group's prefill on the prefill slice and
        start streaming the outputs to the decode slice. Returns the
        transferred output tuple (decode-resident jax arrays, possibly
        still in flight — poll ``is_ready``)."""
        n = int(toks.shape[0])
        if kind == "encdec":
            args = (jnp.asarray(frames), jnp.asarray(flens),
                    jnp.asarray(toks), jnp.asarray(lens))
        elif kind == "vlm":
            args = (jnp.asarray(patches), jnp.asarray(toks),
                    jnp.asarray(lens))
        elif kind == "lm":
            args = (jnp.asarray(toks), jnp.asarray(lens))
        else:
            raise ValueError(
                f"prefill kind {kind!r} cannot run on the prefill slice "
                f"(prefix compute-skip reads decode-resident pools)")
        sig = self._signature(kind, bucket, n, prefix, args)
        outs = sig.fn(self.params, *args)
        moved = jax.device_put(outs, self._dst)
        self.kv_xfer_bytes += sig.logical_bytes
        self.kv_xfer_dispatches += 1
        return moved

    # -------------------------- accounting/HLO --------------------------
    def xfer_stats(self) -> Dict[str, float]:
        return {
            "kv_xfer_bytes": float(self.kv_xfer_bytes),
            "kv_xfer_dispatches": float(self.kv_xfer_dispatches),
            "kv_xfer_signatures": float(len(self._sigs)),
        }

    def verify_hlo(self, *, lower_tol: float = XFER_LOWER_TOL,
                   upper_factor: float = XFER_UPPER_FACTOR) -> Dict:
        """Reconcile the analytic egress bytes of every compiled prefill
        signature against its compiled HLO entry outputs.

        For each signature the per-device egress the accounting predicts
        (``shard_bytes``, derived from the pinned ``out_shardings``) must
        sit inside the repo's documented XFER band of what the compiled
        module actually materialises at its outputs::

            (1 - lower_tol) * analytic <= compiled <= upper_factor * analytic

        Returns ``{key: (analytic, compiled)}``; raises AssertionError
        outside the band. Call after traffic has flowed (signatures
        compile on first dispatch).
        """
        if not self._sigs:
            raise AssertionError("no prefill signatures compiled yet — "
                                 "dispatch traffic before verifying")
        report = {}
        for key, sig in self._sigs.items():
            with self.mesh:
                hlo = sig.fn.lower(self.params,
                                   *sig.abstract).compile().as_text()
            compiled = _entry_output_bytes(hlo)
            analytic = sig.shard_bytes
            assert compiled >= (1 - lower_tol) * analytic, (
                f"disagg xfer {key}: compiled HLO egress {compiled}B below "
                f"analytic {analytic}B (band lower_tol={lower_tol})")
            assert compiled <= upper_factor * analytic, (
                f"disagg xfer {key}: compiled HLO egress {compiled}B "
                f"exceeds {upper_factor}x analytic {analytic}B")
            report[key] = (analytic, compiled)
        return report


class DisaggServingEngine(ServingEngine):
    """The decode-role :class:`ServingEngine` with a
    :class:`PrefillWorker` attached: admissions route to the prefill
    slice, KV streams across, the decode step never waits.

    Construct through the facade::

        exe.serve(config=ServeConfig(..., disagg=DisaggConfig(prefill_data=2)))

    The engine's ``plan`` is the decode sub-plan; ``engine.roles`` holds
    the full :class:`~repro.core.execution_plan.DisaggPlan` (parent +
    both roles with their own ShardingPlans and capacity reports).
    """

    def __init__(self, plan: ExecutionPlan, params: PyTree, *,
                 config: ServeConfig, dtype=jnp.float32, on_step=None):
        if not isinstance(plan, ExecutionPlan):
            raise TypeError("DisaggServingEngine requires an ExecutionPlan "
                            "(legacy arch-first construction has no mesh to "
                            "slice)")
        cfg = config.resolve(plan.shape)
        if cfg.disagg is None:
            raise ValueError("DisaggServingEngine needs config.disagg")
        if cfg.paging.paged and cfg.paging.prefix_cache:
            # prefix compute-skip gathers decode-resident pool pages into
            # the prefill forward — cross-slice reads the split forbids
            cfg = dataclasses.replace(
                cfg, paging=dataclasses.replace(cfg.paging,
                                                prefix_cache=False))
        roles = plan.disaggregate(prefill_data=cfg.disagg.prefill_data,
                                  axis=cfg.disagg.axis)
        self.roles: DisaggPlan = roles
        if params is None:
            params = REG.init_params(plan.arch, jax.random.PRNGKey(cfg.seed),
                                     dtype)
        self.worker = PrefillWorker(roles.prefill, params,
                                    cache_dtype=dtype,
                                    decode_mesh=roles.decode.build_mesh(),
                                    quant=cfg.quant)
        super().__init__(roles.decode, params, config=cfg, dtype=dtype,
                         on_step=on_step)
        self.scheduler.worker = self.worker

    def xfer_stats(self) -> Dict[str, float]:
        """Transferred-KV accounting (see :class:`PrefillWorker`), plus
        how many dispatched waves are still in flight."""
        stats = self.worker.xfer_stats()
        stats["kv_xfer_inflight"] = float(len(self.scheduler.inflight))
        return stats

    def verify_xfer(self, **kw) -> Dict:
        """Reconcile accounted KV-transfer bytes with the compiled HLO
        (see :meth:`PrefillWorker.verify_hlo`)."""
        return self.worker.verify_hlo(**kw)
