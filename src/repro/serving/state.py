"""DecodeState — the serving loop's per-slot bookkeeping as a device pytree.

The old engine kept tokens/positions in host numpy and round-tripped to
the device every step. Everything the decode loop needs per slot now
lives in one pytree that stays device-resident and is threaded through a
donated ``serve_step``:

  tokens     [slots, 1] int32  — current input token per slot (the token
                                 the next step will both emit and consume)
  positions  [slots, 1] int32  — next cache position per slot
  active     [slots]     bool  — slot holds a live request
  emitted    [slots]    int32  — tokens emitted so far (EOS never counts)
  max_new    [slots]    int32  — per-request emission budget
  rng        [slots, 2] uint32 — per-slot PRNG key (sampling)

Enc-dec archs carry two extra leaves (``None`` — an empty pytree node —
for every other family):

  enc_out  [slots, max_src, D] — cached encoder output per slot, written
                                 once at admission and cross-attended by
                                 every decode step
  enc_len  [slots]       int32 — true source length per slot; positions
                                 at-or-beyond it are masked out of the
                                 cross-attention (the row is right-padded)

Paged engines (``serving.pages``) carry two more leaves (``None``
otherwise):

  page_table [slots, M] int32 — physical page per logical position block
                                (M = ceil(max_len / page_size)); 0 is
                                the reserved null page
  seq_len    [slots]    int32 — tokens resident in the slot's pages
                                (prompt length at admission, +1 per
                                decoded token)

Speculative engines (``SpecConfig``) carry three more leaves (``None``
otherwise):

  draft_caches  pytree — the draft model's dense KV grid, threaded
                         through the fused step alongside the target's
                         caches (prefilled at admission, rolled forward
                         by the draft-k loop)
  accepted   [slots] int32 — draft proposals accepted so far (cumulative
                             per occupancy; zeroed at admission)
  proposed   [slots] int32 — draft proposals made so far

Inert slots keep their last token/position so the grid stays a
fixed-shape program — the deterministic-latency property the paper
argues for (§1); ``active`` masks them out of emission and cache writes
never corrupt other slots (per-row ring buffer).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any

_FIELDS = ("tokens", "positions", "active", "emitted", "max_new", "rng",
           "enc_out", "enc_len", "page_table", "seq_len",
           "draft_caches", "accepted", "proposed")


@dataclasses.dataclass
class DecodeState:
    tokens: jax.Array
    positions: jax.Array
    active: jax.Array
    emitted: jax.Array
    max_new: jax.Array
    rng: jax.Array
    enc_out: Optional[jax.Array] = None
    enc_len: Optional[jax.Array] = None
    page_table: Optional[jax.Array] = None
    seq_len: Optional[jax.Array] = None
    draft_caches: Optional[PyTree] = None
    accepted: Optional[jax.Array] = None
    proposed: Optional[jax.Array] = None

    @property
    def slots(self) -> int:
        return self.tokens.shape[0]


jax.tree_util.register_dataclass(DecodeState, data_fields=list(_FIELDS),
                                 meta_fields=[])


def make_decode_state(slots: int, seed: int = 0, *,
                      enc_shape: Optional[tuple] = None,
                      enc_dtype=jnp.float32,
                      table_len: Optional[int] = None,
                      draft_caches: Optional[PyTree] = None) -> DecodeState:
    """Fresh all-inert state; per-slot keys are fold_in(seed_key, slot).

    ``enc_shape=(max_src, d_model)`` allocates the per-slot encoder-output
    grid (enc-dec archs only). ``table_len`` allocates the per-slot page
    table (``ceil(max_len / page_size)`` entries, all null) plus the
    resident-token counter (paged engines only). ``draft_caches`` (a
    freshly-built dense cache grid for the draft model) enables the
    speculative leaves."""
    base = jax.random.PRNGKey(seed)
    keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(jnp.arange(slots))
    enc_out = enc_len = None
    if enc_shape is not None:
        enc_out = jnp.zeros((slots,) + tuple(enc_shape), enc_dtype)
        enc_len = jnp.zeros((slots,), jnp.int32)
    page_table = seq_len = None
    if table_len is not None:
        page_table = jnp.zeros((slots, table_len), jnp.int32)
        seq_len = jnp.zeros((slots,), jnp.int32)
    accepted = proposed = None
    if draft_caches is not None:
        accepted = jnp.zeros((slots,), jnp.int32)
        proposed = jnp.zeros((slots,), jnp.int32)
    return DecodeState(
        tokens=jnp.zeros((slots, 1), jnp.int32),
        positions=jnp.zeros((slots, 1), jnp.int32),
        active=jnp.zeros((slots,), bool),
        emitted=jnp.zeros((slots,), jnp.int32),
        max_new=jnp.ones((slots,), jnp.int32),
        rng=keys,
        enc_out=enc_out, enc_len=enc_len,
        page_table=page_table, seq_len=seq_len,
        draft_caches=draft_caches, accepted=accepted, proposed=proposed,
    )


def active_slots(state: DecodeState) -> list:
    """Host view of the live slot indices (one device read of the
    ``active`` mask). ``ServingEngine.migrate`` uses it to account which
    in-flight rows a plan→plan transfer must physically move."""
    import numpy as np
    return [int(i) for i in np.flatnonzero(np.asarray(state.active))]


def decode_state_dims(enc: bool = False, paged: bool = False,
                      draft_dims: Optional[PyTree] = None) -> DecodeState:
    """Logical sharding roles per field (slot dim is the batch dim).
    ``enc`` / ``paged`` / ``draft_dims`` must mirror whether the state
    carries the enc-dec / paging / speculative leaves so the dims tree
    and the state tree stay structurally equal (``draft_dims`` is the
    draft model's ``registry.cache_dims`` tree)."""
    return DecodeState(
        tokens=("batch", None), positions=("batch", None),
        active=("batch",), emitted=("batch",), max_new=("batch",),
        rng=("batch", None),
        enc_out=("batch", None, None) if enc else None,
        enc_len=("batch",) if enc else None,
        page_table=("batch", None) if paged else None,
        seq_len=("batch",) if paged else None,
        draft_caches=draft_dims,
        accepted=("batch",) if draft_dims is not None else None,
        proposed=("batch",) if draft_dims is not None else None,
    )


def admit_slot(state: DecodeState, slot: jax.Array, token: jax.Array,
               position: jax.Array, max_new: jax.Array,
               rng: jax.Array) -> DecodeState:
    """Write one freshly-prefilled request into ``slot`` (jit-friendly:
    ``slot`` is traced, so admission compiles once per engine)."""

    def put(arr, val):
        val = jnp.asarray(val, arr.dtype).reshape((1,) + arr.shape[1:])
        return jax.lax.dynamic_update_slice(arr, val,
                                            (slot,) + (0,) * (arr.ndim - 1))

    zero = jnp.asarray(0, jnp.int32)
    return DecodeState(
        tokens=put(state.tokens, token),
        positions=put(state.positions, position),
        active=put(state.active, jnp.asarray(True)),
        emitted=put(state.emitted, zero),
        max_new=put(state.max_new, max_new),
        rng=put(state.rng, rng),
        enc_out=state.enc_out, enc_len=state.enc_len,
        page_table=state.page_table, seq_len=state.seq_len,
        draft_caches=state.draft_caches,
        accepted=(None if state.accepted is None
                  else put(state.accepted, zero)),
        proposed=(None if state.proposed is None
                  else put(state.proposed, zero)),
    )


def admit_rows(state: DecodeState, slots: jax.Array, tokens: jax.Array,
               positions: jax.Array, max_new: jax.Array, rng: jax.Array,
               enc_out: Optional[jax.Array] = None,
               enc_len: Optional[jax.Array] = None,
               page_rows: Optional[jax.Array] = None) -> DecodeState:
    """Batched :func:`admit_slot`: write ``n`` freshly-prefilled requests
    at once (``slots [n]`` distinct; the per-bucket admission batch).
    One scatter per field instead of ``n`` chained updates, so a same-
    bucket admission burst is a single device dispatch. Paged engines
    pass ``page_rows [n, M]`` (the slots' freshly-allocated page-table
    rows); the resident-token count starts at the prompt length (==
    ``positions``)."""
    n = slots.shape[0]

    def put(arr, vals):
        return arr.at[slots].set(
            jnp.asarray(vals, arr.dtype).reshape((n,) + arr.shape[1:]))

    zeros = jnp.zeros((n,), jnp.int32)
    return DecodeState(
        tokens=put(state.tokens, tokens),
        positions=put(state.positions, positions),
        active=put(state.active, jnp.ones((n,), bool)),
        emitted=put(state.emitted, zeros),
        max_new=put(state.max_new, max_new),
        rng=put(state.rng, rng),
        enc_out=(state.enc_out if enc_out is None
                 else put(state.enc_out, enc_out)),
        enc_len=(state.enc_len if enc_len is None
                 else put(state.enc_len, enc_len)),
        page_table=(state.page_table if page_rows is None
                    else put(state.page_table, page_rows)),
        seq_len=(state.seq_len if page_rows is None
                 else put(state.seq_len, positions)),
        draft_caches=state.draft_caches,
        accepted=(None if state.accepted is None
                  else put(state.accepted, zeros)),
        proposed=(None if state.proposed is None
                  else put(state.proposed, zeros)),
    )
