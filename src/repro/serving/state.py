"""DecodeState — the serving loop's per-slot bookkeeping as a device pytree.

The old engine kept tokens/positions in host numpy and round-tripped to
the device every step. Everything the decode loop needs per slot now
lives in one pytree that stays device-resident and is threaded through a
donated ``serve_step``:

  tokens     [slots, 1] int32  — current input token per slot (the token
                                 the next step will both emit and consume)
  positions  [slots, 1] int32  — next cache position per slot
  active     [slots]     bool  — slot holds a live request
  emitted    [slots]    int32  — tokens emitted so far (EOS never counts)
  max_new    [slots]    int32  — per-request emission budget
  rng        [slots, 2] uint32 — per-slot PRNG key (sampling)

Inert slots keep their last token/position so the grid stays a
fixed-shape program — the deterministic-latency property the paper
argues for (§1); ``active`` masks them out of emission and cache writes
never corrupt other slots (per-row ring buffer).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

_FIELDS = ("tokens", "positions", "active", "emitted", "max_new", "rng")


@dataclasses.dataclass
class DecodeState:
    tokens: jax.Array
    positions: jax.Array
    active: jax.Array
    emitted: jax.Array
    max_new: jax.Array
    rng: jax.Array

    @property
    def slots(self) -> int:
        return self.tokens.shape[0]


jax.tree_util.register_dataclass(DecodeState, data_fields=list(_FIELDS),
                                 meta_fields=[])


def make_decode_state(slots: int, seed: int = 0) -> DecodeState:
    """Fresh all-inert state; per-slot keys are fold_in(seed_key, slot)."""
    base = jax.random.PRNGKey(seed)
    keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(jnp.arange(slots))
    return DecodeState(
        tokens=jnp.zeros((slots, 1), jnp.int32),
        positions=jnp.zeros((slots, 1), jnp.int32),
        active=jnp.zeros((slots,), bool),
        emitted=jnp.zeros((slots,), jnp.int32),
        max_new=jnp.ones((slots,), jnp.int32),
        rng=keys,
    )


def decode_state_dims() -> DecodeState:
    """Logical sharding roles per field (slot dim is the batch dim)."""
    return DecodeState(
        tokens=("batch", None), positions=("batch", None),
        active=("batch",), emitted=("batch",), max_new=("batch",),
        rng=("batch", None),
    )


def admit_slot(state: DecodeState, slot: jax.Array, token: jax.Array,
               position: jax.Array, max_new: jax.Array,
               rng: jax.Array) -> DecodeState:
    """Write one freshly-prefilled request into ``slot`` (jit-friendly:
    ``slot`` is traced, so admission compiles once per engine)."""

    def put(arr, val):
        val = jnp.asarray(val, arr.dtype).reshape((1,) + arr.shape[1:])
        return jax.lax.dynamic_update_slice(arr, val,
                                            (slot,) + (0,) * (arr.ndim - 1))

    return DecodeState(
        tokens=put(state.tokens, token),
        positions=put(state.positions, position),
        active=put(state.active, jnp.asarray(True)),
        emitted=put(state.emitted, jnp.asarray(0, jnp.int32)),
        max_new=put(state.max_new, max_new),
        rng=put(state.rng, rng),
    )
