"""ServeConfig — the typed serve surface (and its paging/disagg blocks).

``Executable.serve()`` accreted ~11 keyword knobs across the serving PRs
(slots, max_len, eos_id, sampling, lookahead, max_src_len, paged,
page_size, kv_pages, prefix_cache, seed) and disaggregation adds more.
This module consolidates that surface into one frozen dataclass tree::

    exe.serve(config=ServeConfig(slots=4, max_len=128,
                                 paging=PagingConfig(paged=True),
                                 disagg=DisaggConfig(prefill_data=2)))

Bare ``exe.serve(slots=4, ...)`` kwargs still work — they funnel through
:meth:`ServeConfig.from_kwargs` with a single ``DeprecationWarning`` —
and ``engine.config`` exposes the resolved values (defaults filled from
the planned shape, page geometry made concrete).

``None`` fields mean "resolve from context": ``slots``/``max_len`` fall
back to the planned shape's batch/seq, ``sampling`` to greedy,
``page_size``/``kv_pages`` to the pool defaults in ``serving.pages``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.quant import QuantConfig

__all__ = ["PagingConfig", "DisaggConfig", "ElasticConfig", "QuantConfig",
           "SpecConfig", "ServeConfig"]


@dataclasses.dataclass(frozen=True)
class PagingConfig:
    """Paged-KV knobs (see ``serving.pages``). ``page_size`` / ``kv_pages``
    default (``None``) to ``DEFAULT_PAGE_SIZE`` / ``default_kv_pages``."""

    paged: bool = False
    page_size: Optional[int] = None
    kv_pages: Optional[int] = None
    prefix_cache: bool = True


@dataclasses.dataclass(frozen=True)
class DisaggConfig:
    """Disaggregated prefill/decode serving (see ``serving.disagg``).

    The deployment's mesh is split along its data axis into a decode
    slice and a prefill slice: ``prefill_data`` is the number of
    data-axis rows (× the full model axis) the prefill role takes; the
    decode role keeps the rest. ``axis=None`` picks the plan's first
    batch-role axis. Model-parallel structure (tp/seq/ep degree) is
    inherited by both roles, so per-request arithmetic — and therefore
    greedy token streams — stays bit-identical to the fused engine.
    """

    prefill_data: int = 1
    axis: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """Elastic live replan (see ``runtime.elastic.LoadController`` and
    ``ServingEngine.migrate``).

    The load controller consumes the engine's ``step_stats()`` /
    ``prefill_stats()`` telemetry and, when the queue backlog crosses a
    threshold, re-runs the DSE for a different device count and migrates
    the live deployment plan→plan without dropping streams.

    ``grow_queue_depth``: mean queue depth at step dispatch at or above
        which the controller grows onto more devices.
    ``shrink_queue_depth``: mean queue depth at or below which it shrinks
        (freeing devices for other deployments).
    ``shrink_step_p50_ms``: shrink only while the decode step also has
        latency headroom (p50 at or under this bound; ``None`` = ignore).
    ``min_devices`` / ``max_devices``: bounds on the device ladder
        (``None`` max = every visible device).
    ``cooldown_steps``: minimum engine steps between migrations, so one
        burst cannot thrash grow→shrink→grow.
    """

    grow_queue_depth: float = 4.0
    shrink_queue_depth: float = 0.5
    shrink_step_p50_ms: Optional[float] = None
    min_devices: int = 1
    max_devices: Optional[int] = None
    cooldown_steps: int = 50

    def __post_init__(self):
        if self.shrink_queue_depth > self.grow_queue_depth:
            raise ValueError(
                f"ElasticConfig: shrink_queue_depth "
                f"{self.shrink_queue_depth} must not exceed "
                f"grow_queue_depth {self.grow_queue_depth}")
        if int(self.min_devices) < 1:
            raise ValueError("ElasticConfig.min_devices must be >= 1")


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative decoding (see ``registry.build_serve_step(spec=...)``).

    A small draft model autoregressively proposes ``k`` tokens per slot
    per engine step; the target verifies all ``k+1`` positions in one
    batched forward and the longest accepted prefix commits on device.
    Greedy target sampling is bit-exact vs the target-only stream by
    construction; seeded temperature/top-k reuses the per-request PRNG
    keys (one key advance per accepted step, so streams stay invariant
    to the lookahead/plan — the property ``serving_equiv`` certifies).

    ``draft``: the draft :class:`~repro.configs.base.ArchConfig`. May be
        left ``None`` when serving a plan built with
        ``repro.plan(..., draft=...)`` — the plan's co-placed draft is
        used. Pairing rules: the draft must be a dense-attention,
        non-windowed LM sharing the target's vocabulary; it always runs
        full-precision and dense (the target may be paged and/or
        quantized).
    ``k``: proposal depth per step (>= 1).
    """

    draft: Optional[Any] = None
    k: int = 4

    def __post_init__(self):
        if int(self.k) < 1:
            raise ValueError(f"SpecConfig.k must be >= 1, got {self.k}")


# legacy flat-kwarg names accepted by from_kwargs
_FLAT = ("slots", "max_len", "eos_id", "seed", "sampling", "lookahead",
         "max_src_len")
_PAGING = ("paged", "page_size", "kv_pages", "prefix_cache")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """The whole serve surface as one frozen value.

    ``slots``: decode slot count (None -> planned shape's global_batch).
    ``max_len``: per-slot KV length (None -> planned shape's seq_len).
    ``eos_id``: stop token (None -> run to max_new_tokens).
    ``seed``: base PRNG seed for param init + per-slot sampling keys.
    ``sampling``: :class:`repro.serving.sampler.SamplingParams`
        (None -> greedy).
    ``lookahead``: dispatch depth (1 = double-buffered, 0 = synchronous).
    ``max_src_len``: enc-dec per-request source-frame bound
        (None -> max_len).
    ``paging``: nested :class:`PagingConfig`.
    ``disagg``: nested :class:`DisaggConfig`, or None for the fused
        engine.
    ``quant``: nested :class:`repro.quant.QuantConfig` — INT8 serving
        (per-channel int8 weights and/or int8 KV cache with per-token
        scale leaves). The default quantises nothing.
    ``spec``: nested :class:`SpecConfig`, or None for plain decoding.
    ``elastic``: nested :class:`ElasticConfig`, or None for a fixed-size
        deployment. Read by ``Executable.serve`` to attach a
        ``runtime.elastic.LoadController`` to the engine
        (``engine.elastic``).
    """

    slots: Optional[int] = None
    max_len: Optional[int] = None
    eos_id: Optional[int] = None
    seed: int = 0
    sampling: Optional[Any] = None
    lookahead: int = 1
    max_src_len: Optional[int] = None
    paging: PagingConfig = PagingConfig()
    disagg: Optional[DisaggConfig] = None
    quant: QuantConfig = QuantConfig()
    spec: Optional[SpecConfig] = None
    elastic: Optional[ElasticConfig] = None

    @classmethod
    def from_kwargs(cls, **kw) -> "ServeConfig":
        """Build from the legacy flat kwarg surface of ``serve()``
        (``slots=..., paged=..., page_size=...``). Unknown names raise
        ``TypeError`` like a normal signature mismatch would."""
        unknown = (set(kw) - set(_FLAT) - set(_PAGING)
                   - {"disagg", "paging", "quant", "spec", "elastic"})
        if unknown:
            raise TypeError(
                f"serve() got unexpected keyword argument(s) "
                f"{sorted(unknown)}; known: {sorted(_FLAT + _PAGING)} "
                f"(or pass config=ServeConfig(...))")
        paging = kw.pop("paging", None)
        page_kw = {k: kw.pop(k) for k in _PAGING if k in kw}
        if paging is None:
            paging = PagingConfig(**page_kw)
        elif page_kw:
            raise TypeError(f"got both paging= and flat paging kwargs "
                            f"{sorted(page_kw)}")
        return cls(paging=paging, **kw)

    def resolve(self, shape=None) -> "ServeConfig":
        """Fill contextual defaults: ``slots``/``max_len`` from the
        planned ``ShapeConfig`` (when given), ``sampling`` from greedy,
        ``max_src_len`` from ``max_len``. The result is what
        ``engine.config`` exposes."""
        from repro.serving.sampler import GREEDY
        slots, max_len = self.slots, self.max_len
        if slots is None:
            if shape is None:
                raise ValueError("ServeConfig.slots unset and no planned "
                                 "shape to default from")
            slots = shape.global_batch
        if max_len is None:
            if shape is None:
                raise ValueError("ServeConfig.max_len unset and no planned "
                                 "shape to default from")
            max_len = shape.seq_len
        return dataclasses.replace(
            self, slots=int(slots), max_len=int(max_len),
            sampling=self.sampling if self.sampling is not None else GREEDY,
            max_src_len=(self.max_src_len if self.max_src_len is not None
                         else int(max_len)),
            lookahead=max(0, int(self.lookahead)))
