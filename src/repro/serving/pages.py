"""Paged KV cache: a device-resident block pool + prefix reuse.

The dense serving grid allocates one full-``max_len`` KV row per slot, so
device cache memory scales with ``slots × max_len`` regardless of how
many tokens are actually in flight. This module restructures that memory
the way the paper restructures accelerator traffic (§4: move load off
the saturated resource so capacity, not layout, sets the limit): KV
lives in a pool of fixed-size **pages** (``[kv_pages, page_size, G, D]``
per attention layer) and each slot holds an int32 **page table** row
mapping logical position blocks to physical pages. Capacity is then
*tokens in flight*, not slots × max_len — short requests stop paying for
the long tail, and identical prompt prefixes can share physical pages.

Layout
------
Every attention layer's dense cache ``{k [B,T,G,D], v, pos, count}``
becomes a pool pair ``{"kp": [P, ps, G, D], "vp": [P, ps, G, D]}``
(body-stack layers carry a leading repeats axis, mirroring
``models.lm.make_caches``). Page 0 is the reserved **null page**: decode
writes of inactive slots and masked splice writes land there, so the
fixed-shape step never needs a branch — null-page contents are garbage
by construction and never read unmasked. One page table
``[slots, ceil(max_len/page_size)]`` lives in ``DecodeState`` and is
shared by every layer (all layers page identically).

Allocation
----------
Page accounting is refcount-based (prefix sharing aliases pages across
slots). Two mirrored implementations, deliberately:

* :func:`pool_alloc` / :func:`pool_retain` / :func:`pool_release` —
  jitted pure functions over a :class:`PoolState` pytree, the
  device-resident form (donate-friendly, usable inside fused steps).
* :class:`PagePool` — the host mirror the scheduler actually drives:
  admission control needs the allocated page *ids* synchronously for
  Python control flow (grouping, exhaustion queueing, registry keys),
  and a device round-trip per admission would serialise the pipeline.
  The two are equivalence-tested against each other (tests/test_paging).

Exhaustion raises :class:`PagePoolExhausted` naming the waiting rids;
the scheduler catches it and degrades to queueing (requests wait for
pages to free), never crashes.

Prefix reuse
------------
:class:`PrefixRegistry` maps prompt prefixes to refcounted pages at
*token* granularity: every full-page boundary of an admitted prompt is
registered (``tokens[:j·ps] → pages[:j]``), plus one tail entry for the
full prompt (``tokens[:p] → (chain, frontier page, p mod ps)``). A later
prompt reuses the longest registered prefix: matched full pages are
**aliased** into its page table (refcount + 1, zero copy, zero compute),
and a partially-matched frontier page is **copied on write**
(:func:`copy_pages`) before the new request writes its own suffix into
it — the owner keeps decoding into the original. Prefill then computes
only the unmatched suffix against the gathered prefix KV
(:func:`gather_prefix`): admission cost scales with the *new* tokens.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

DEFAULT_PAGE_SIZE = 64

#: Families whose every cache leaf is a full-length attention KV row
#: (window 0): the only layout the page pool replaces. Hybrid (windowed
#: ring), pure-recurrent and enc-dec caches keep their existing layout.
PAGED_FAMILIES = ("dense", "moe", "vlm")


def paged_supported(arch) -> bool:
    return arch.family in PAGED_FAMILIES


def check_paged_supported(arch) -> None:
    if not paged_supported(arch):
        raise ValueError(
            f"paged KV cache supports all-attention families {PAGED_FAMILIES}, "
            f"not {arch.family!r} ({arch.name}): recurrent/windowed/enc-dec "
            f"caches keep their dense layout")


def num_pages_per_slot(max_len: int, page_size: int) -> int:
    """Page-table width: logical position blocks covering ``max_len``."""
    return -(-max_len // page_size)


def default_kv_pages(slots: int, max_len: int, page_size: int) -> int:
    """Dense-equivalent pool size (+1 null page): every slot can hold a
    full ``max_len`` sequence, so the default can never exhaust — callers
    opt into oversubscription by passing a smaller ``kv_pages``."""
    return slots * num_pages_per_slot(max_len, page_size) + 1


class PagePoolExhausted(RuntimeError):
    """An admission needed more free pages than the pool holds.

    ``waiting`` carries the rids whose admission is blocked — the
    scheduler re-queues them (FIFO) and retries as decode slots retire
    and release pages."""

    def __init__(self, msg: str, waiting: Sequence[int] = ()):
        super().__init__(msg)
        self.waiting = list(waiting)


# ---------------------------------------------------------------------------
# refcount accounting — jitted pure functions over a PoolState pytree
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PoolState:
    """Device-resident page accounting: ``refcount [P] int32``. Page 0
    (null) is born with refcount 1 so it is never allocated."""

    refcount: jax.Array

    @property
    def kv_pages(self) -> int:
        return self.refcount.shape[0]


jax.tree_util.register_dataclass(PoolState, data_fields=["refcount"],
                                 meta_fields=[])


def make_pool_state(kv_pages: int) -> PoolState:
    if kv_pages < 2:
        raise ValueError(f"kv_pages must be >= 2 (null page + one usable), "
                         f"got {kv_pages}")
    rc = jnp.zeros((kv_pages,), jnp.int32).at[0].set(1)
    return PoolState(refcount=rc)


@jax.jit
def pool_free_count(state: PoolState) -> jax.Array:
    return jnp.sum((state.refcount == 0).astype(jnp.int32))


def pool_alloc(state: PoolState, n: int) -> Tuple[PoolState, jax.Array]:
    """Take the ``n`` lowest-indexed free pages (refcount 0 → 1).

    Returns ``(state', pages [n] int32)``; positions past the free count
    return the null page 0 (callers check :func:`pool_free_count` — the
    pure function cannot raise). ``n`` is static (one jit per size).
    """

    @jax.jit
    def go(state):
        free = state.refcount == 0
        # stable order: lowest free indices first (argsort of ~free)
        order = jnp.argsort(jnp.where(free, jnp.arange(state.kv_pages),
                                      state.kv_pages).astype(jnp.int32))
        pages = order[:n].astype(jnp.int32)
        enough = jnp.cumsum(free.astype(jnp.int32))[-1] >= jnp.arange(1, n + 1)
        pages = jnp.where(enough, pages, 0)
        rc = state.refcount.at[pages].add(jnp.where(pages > 0, 1, 0))
        return PoolState(refcount=rc), pages

    return go(state)


@jax.jit
def pool_retain(state: PoolState, pages: jax.Array) -> PoolState:
    """refcount += 1 for each page id (null page 0 is a no-op)."""
    inc = jnp.where(pages > 0, 1, 0)
    return PoolState(refcount=state.refcount.at[pages].add(inc))


@jax.jit
def pool_release(state: PoolState, pages: jax.Array) -> PoolState:
    """refcount -= 1 for each page id, clamped at 0 (null page no-op)."""
    dec = jnp.where((pages > 0) & (state.refcount[pages] > 0), -1, 0)
    return PoolState(refcount=state.refcount.at[pages].add(dec))


# ---------------------------------------------------------------------------
# host mirror — the scheduler's synchronous allocator
# ---------------------------------------------------------------------------

class PagePool:
    """Host-side page accounting, bit-compatible with the ``pool_*``
    pure functions (same lowest-free-first policy; equivalence-tested).
    The scheduler needs page *ids* synchronously for admission control,
    so the authoritative refcounts live here and device state only ever
    receives the resulting page tables."""

    def __init__(self, kv_pages: int, page_size: int = DEFAULT_PAGE_SIZE):
        if kv_pages < 2:
            raise ValueError(f"kv_pages must be >= 2 (null page + one "
                             f"usable), got {kv_pages}")
        self.kv_pages = kv_pages
        self.page_size = page_size
        self.refcount = np.zeros((kv_pages,), np.int32)
        self.refcount[0] = 1  # null page: never allocated, never freed

    @property
    def free_pages(self) -> int:
        return int((self.refcount == 0).sum())

    @property
    def used_pages(self) -> int:
        return self.kv_pages - 1 - self.free_pages

    def alloc(self, n: int, waiting: Sequence[int] = ()) -> List[int]:
        """Allocate ``n`` pages (lowest free indices first) or raise
        :class:`PagePoolExhausted` naming the ``waiting`` rids."""
        free = np.flatnonzero(self.refcount == 0)
        if len(free) < n:
            raise PagePoolExhausted(
                f"page pool exhausted: need {n} pages, {len(free)} free "
                f"of {self.kv_pages - 1} (page_size={self.page_size}); "
                f"waiting rids={list(waiting)}", waiting)
        pages = free[:n].tolist()
        self.refcount[pages] = 1
        return pages

    def retain(self, pages: Sequence[int]) -> None:
        for p in pages:
            if p > 0:
                self.refcount[p] += 1

    def release(self, pages: Sequence[int]) -> None:
        for p in pages:
            if p <= 0:
                continue
            if self.refcount[p] <= 0:
                raise AssertionError(
                    f"double free of page {p} (refcount already 0)")
            self.refcount[p] -= 1


# ---------------------------------------------------------------------------
# pool construction (mirrors models.lm.make_caches structurally)
# ---------------------------------------------------------------------------

def _is_kv(node) -> bool:
    return isinstance(node, dict) and "k" in node and "v" in node

def _is_pool(node) -> bool:
    return isinstance(node, dict) and "kp" in node and "vp" in node


def _map_kv(tree, fn):
    """Apply ``fn`` to every dense KV-cache dict in a cache tree."""
    if _is_kv(tree):
        return fn(tree)
    if isinstance(tree, dict):
        return {k: _map_kv(v, fn) for k, v in tree.items()}
    raise ValueError(f"non-attention cache leaf in paged tree: {tree!r}")


#: dense-row leaf -> pool leaf names (scale pools only exist for int8 KV)
_POOL_NAMES = (("k", "kp"), ("v", "vp"), ("k_scale", "kps"), ("v_scale", "vps"))


def make_paged_caches(arch, kv_pages: int, page_size: int,
                      dtype=jnp.bfloat16, kv_quant: bool = False) -> PyTree:
    """Pool tree replacing ``REG.make_caches``: per attention layer
    ``{"kp": [P, ps, G, D], "vp": [P, ps, G, D]}`` (body layers keep the
    leading repeats axis). Page 0 is the null page. ``kv_quant=True``
    makes the payload pools int8 and adds per-token f32 scale pools
    ``{"kps": [P, ps, G, 1], "vps": ...}`` that page identically."""
    from repro.models import registry as REG
    check_paged_supported(arch)
    skeleton = jax.eval_shape(
        lambda: REG.make_caches(arch, 1, page_size, dtype, kv_quant=kv_quant))

    def conv(kv):
        out = {}
        for row_name, pool_name in _POOL_NAMES:
            if row_name not in kv:
                continue
            leaf = kv[row_name]  # [..., 1, ps, G, ·] — swap batch-1 for P
            shape = leaf.shape[:-4] + (kv_pages,) + leaf.shape[-3:]
            out[pool_name] = jnp.zeros(shape, leaf.dtype)
        return out

    return _map_kv(skeleton, conv)


def paged_cache_axes(arch, page_size: int, dtype=jnp.bfloat16,
                     kv_quant: bool = False) -> PyTree:
    """Per-leaf :class:`repro.models.registry.CacheAxes` for a pool tree,
    probed structurally like ``registry.cache_axes``: the axis that
    varies with ``kv_pages`` is the ``page`` axis; pool leaves have no
    batch-slot axis (the page table carries slot identity)."""
    from repro.models.registry import CacheAxes
    probes = [jax.eval_shape(
        lambda p=p: make_paged_caches(arch, p, page_size, dtype, kv_quant))
        for p in (4, 8)]

    def one(a, b):
        diff = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
        assert len(diff) == 1, (a.shape, b.shape)
        return CacheAxes(batch=None, length=None, page=diff[0])

    return jax.tree.map(one, *probes)


# ---------------------------------------------------------------------------
# device splice / gather / copy (jit-friendly pure functions)
# ---------------------------------------------------------------------------

def _pool_scatter(pool: jax.Array, rows: jax.Array, pages: jax.Array,
                  slots: jax.Array) -> jax.Array:
    """Scatter ``rows [n, S, ...]`` into ``pool [(R,) P, ps, ...]`` at
    ``(pages, slots) [n, S]``. A leading repeats axis vmaps."""
    def one(p, r):
        return p.at[pages, slots].set(r.astype(p.dtype))
    if pool.ndim == 4:              # flat pool [P, ps, G, D], rows [n, S, G, D]
        if rows.ndim != 4:
            raise ValueError((pool.shape, rows.shape))
        return one(pool, rows)
    if rows.ndim == 4:              # body stack: [R, P, ps, G, D] vs [n,S,G,D]
        return jax.vmap(one, in_axes=(0, None))(pool, rows)
    return jax.vmap(one)(pool, rows)  # stacked rows too: [R, n, S, G, D]


def splice_pages(pools: PyTree, rows: PyTree, page_rows: jax.Array) -> PyTree:
    """Write batched dense prefill rows into the pool at the positions
    their ``pos`` leaves claim (``-1`` = invalid → routed to the null
    page). ``page_rows [n, M]`` are the slots' page-table rows; the
    bucketed row layout is unchanged — paging is purely a splice-target
    change, prefill compute stays dense."""

    def conv(pool_kv, row_kv):
        ps = pool_kv["kp"].shape[-3]
        pos = row_kv["pos"]
        pos = pos[0] if pos.ndim == 3 else pos  # body stack: pos same per repeat
        valid = pos >= 0
        logical = jnp.maximum(pos, 0)
        pages = jnp.take_along_axis(page_rows, logical // ps, axis=1)
        pages = jnp.where(valid, pages, 0)
        slots = logical % ps
        if "kps" in pool_kv and "k_scale" not in row_kv:
            # fp rows into an int8 pool (shared-prefix suffix prefill
            # returns raw fp rows): quantise at the scatter boundary with
            # the same per-token routine the dense fill uses, so the pool
            # bits match a full quantised prefill exactly
            from repro.quant import quantize_kv
            kq = quantize_kv(row_kv["k"])
            vq = quantize_kv(row_kv["v"])
            row_kv = dict(row_kv, k=kq.q, k_scale=kq.scale,
                          v=vq.q, v_scale=vq.scale)
        return {pool_name: _pool_scatter(pool_kv[pool_name], row_kv[row_name],
                                         pages, slots)
                for row_name, pool_name in _POOL_NAMES
                if pool_name in pool_kv}

    return _zip_kv(pools, rows, conv)


def _zip_kv(pools, rows, fn):
    if _is_pool(pools):
        return fn(pools, rows)
    if isinstance(pools, dict):
        return {k: _zip_kv(v, rows[k], fn) for k, v in pools.items()}
    raise ValueError(f"unexpected pool node: {pools!r}")


def gather_prefix(pools: PyTree, page_rows: jax.Array,
                  prefix_len: jax.Array) -> PyTree:
    """Per-layer shared-prefix KV for a compute-skip suffix prefill:
    gather the first ``K`` table entries' pages into dense
    ``{"pre_k": [n, K·ps, G, D], "pre_v", "pre_len": [n]}`` blocks the
    attention block concatenates ahead of the fresh suffix KV
    (``models.blocks.attn_apply``). ``page_rows`` is ``[n, K]`` —
    already truncated to the page span covering the prefix; entries at
    or beyond ``pre_len`` are garbage and masked by the block."""

    def conv(pool_kv, _):
        def one(p):
            g = p[page_rows]  # [n, K, ps, G, ·]
            return g.reshape(g.shape[0], -1, *g.shape[3:])

        def dense(name):
            # int8 pools dequantise here: the gathered prefix block feeds
            # straight into fp attention concat (blocks.attn_apply)
            g = one(pool_kv[name])
            if f"{name}s" in pool_kv:
                g = g.astype(jnp.float32) * one(pool_kv[f"{name}s"])
            return g

        kp = pool_kv["kp"]
        if kp.ndim == 5:  # body stack
            def dense_r(name):
                g = jax.vmap(one)(pool_kv[name])
                if f"{name}s" in pool_kv:
                    g = g.astype(jnp.float32) * jax.vmap(one)(pool_kv[f"{name}s"])
                return g
            return {"pre_k": dense_r("kp"), "pre_v": dense_r("vp"),
                    "pre_len": jnp.broadcast_to(
                        prefix_len, (kp.shape[0],) + prefix_len.shape)}
        return {"pre_k": dense("kp"), "pre_v": dense("vp"),
                "pre_len": prefix_len}

    return _zip_kv(pools, pools, conv)


def copy_pages(pools: PyTree, dst: jax.Array, src: jax.Array) -> PyTree:
    """Copy whole pages ``src [n] → dst [n]`` in every layer — the
    copy-on-write step for a partially-shared frontier page: the new
    request gets a private copy of the owner's page before writing its
    own suffix into it; the owner keeps decoding into the original."""

    def conv(pool_kv, _):
        def one(p):
            return p.at[dst].set(p[src])
        if pool_kv["kp"].ndim == 5:
            return {name: jax.vmap(one)(p) for name, p in pool_kv.items()}
        return {name: one(p) for name, p in pool_kv.items()}

    return _zip_kv(pools, pools, conv)


# ---------------------------------------------------------------------------
# prefix registry (host-side; token-granularity longest-prefix match)
# ---------------------------------------------------------------------------

class PrefixRegistry:
    """Prompt-prefix → physical-pages cache with refcounted aliasing.

    Entries (all host-side; pages pinned with one registry refcount):

    * ``full``: ``tokens[:j·ps] → (page_0..page_{j-1})`` for every full
      page boundary ``j`` of a registered prompt — aliasable as-is.
    * ``tail``: ``tokens[:p] → (chain, frontier page, p mod ps)`` for the
      full prompt when it ends mid-page — the frontier page is
      copy-on-write for a new sharer (the owner keeps appending to it).

    ``lookup`` returns the longest match at token granularity; ``cap``
    bounds both maps LRU-style (evicted entries drop their refcounts, so
    unreferenced pages return to the pool)."""

    def __init__(self, pool: PagePool, cap: int = 1024):
        self.pool = pool
        self.cap = cap
        self.full: "OrderedDict[bytes, Tuple[int, ...]]" = OrderedDict()
        self.tail: "OrderedDict[bytes, Tuple[Tuple[int, ...], int, int]]" = \
            OrderedDict()
        self.hits = 0
        self.misses = 0

    @staticmethod
    def _key(tokens: np.ndarray) -> bytes:
        return np.ascontiguousarray(tokens, dtype=np.int32).tobytes()

    def register(self, tokens: np.ndarray, pages: Sequence[int]) -> None:
        """Pin an admitted prompt's prefix pages. ``pages`` must cover
        ``ceil(len(tokens)/ps)`` entries of the slot's table."""
        ps = self.pool.page_size
        p = len(tokens)
        k_full, r = divmod(p, ps)
        for j in range(1, k_full + 1):
            self._put_full(self._key(tokens[:j * ps]), tuple(pages[:j]))
        if r and k_full < len(pages):
            self._put_tail(self._key(tokens[:p]),
                           (tuple(pages[:k_full]), int(pages[k_full]), r))

    def _put_full(self, key: bytes, chain: Tuple[int, ...]) -> None:
        if key in self.full:
            self.full.move_to_end(key)
            return
        self.pool.retain(chain)
        self.full[key] = chain
        self._evict()

    def _put_tail(self, key: bytes, entry) -> None:
        if key in self.tail:
            self.tail.move_to_end(key)
            return
        chain, frontier, _ = entry
        self.pool.retain(chain)
        self.pool.retain([frontier])
        self.tail[key] = entry
        self._evict()

    def _evict(self) -> None:
        while len(self.full) + len(self.tail) > self.cap:
            if self.full and (not self.tail or len(self.full) >= len(self.tail)):
                _, chain = self.full.popitem(last=False)
                self.pool.release(chain)
            else:
                _, (chain, frontier, _) = self.tail.popitem(last=False)
                self.pool.release(chain)
                self.pool.release([frontier])

    def evict_unreferenced(self) -> int:
        """Drop entries whose pages are only pinned by the registry —
        the exhaustion fallback that returns cold prefix pages to the
        pool. Nested prefixes of one prompt pin each other's pages, so
        "only the registry" means ``refcount == registry pin count``, not
        ``refcount == 1``. Returns the number of page pins released."""
        pins: Dict[int, int] = {}
        for chain in self.full.values():
            for p in chain:
                pins[p] = pins.get(p, 0) + 1
        for chain, frontier, _ in self.tail.values():
            for p in list(chain) + [frontier]:
                pins[p] = pins.get(p, 0) + 1
        freed = 0

        def try_evict(held):
            nonlocal freed
            if not all(self.pool.refcount[p] == pins.get(p, 0) for p in held):
                return False
            self.pool.release(held)
            for p in held:
                pins[p] -= 1
            freed += len(held)
            return True

        for key in list(self.full):
            if try_evict(list(self.full[key])):
                del self.full[key]
        for key in list(self.tail):
            chain, frontier, _ = self.tail[key]
            if try_evict(list(chain) + [frontier]):
                del self.tail[key]
        return freed

    def lookup(self, tokens: np.ndarray
               ) -> Tuple[int, Tuple[int, ...], Optional[int]]:
        """Longest registered prefix of ``tokens``, capped at
        ``len(tokens) - 1`` (at least one suffix token must run through
        prefill to produce the first logits).

        Returns ``(m, full_chain, frontier)``: ``m`` matched tokens, the
        aliasable full pages covering ``m // ps`` blocks, and — when
        ``m`` ends mid-page — the owner's frontier page to copy-on-write
        (``None`` on a clean page boundary). ``(0, (), None)`` on miss.
        """
        ps = self.pool.page_size
        q = len(tokens)
        best = (0, (), None)
        # tail entries first: they can match at token granularity
        for key, (chain, frontier, r) in self.tail.items():
            t_len = len(chain) * ps + r
            if t_len <= best[0] or t_len > q - 1:
                continue
            if key == self._key(tokens[:t_len]):
                best = (t_len, chain, frontier)
        # full-page boundaries, longest first
        j_max = (q - 1) // ps
        for j in range(j_max, 0, -1):
            if j * ps <= best[0]:
                break
            chain = self.full.get(self._key(tokens[:j * ps]))
            if chain is not None:
                best = (j * ps, chain, None)
                break
        if best[0]:
            self.hits += 1
            # LRU touch
            if best[2] is None:
                self.full.move_to_end(self._key(tokens[:best[0]]))
            else:
                self.tail.move_to_end(self._key(tokens[:best[0]]))
        else:
            self.misses += 1
        return best

    def clear(self) -> None:
        for chain in self.full.values():
            self.pool.release(chain)
        for chain, frontier, _ in self.tail.values():
            self.pool.release(chain)
            self.pool.release([frontier])
        self.full.clear()
        self.tail.clear()
