"""Admission, slot lifecycle, and batched bucketed prefill for the engine.

The scheduler owns everything between "a request arrives" and "its slot
decodes": the FIFO queue, the slot → request map, and the prefill path
that computes cache rows and splices them into the device-resident slot
grid.

Three properties define the admission path:

* **Batched bucketed prefill** — prompts are padded to the next
  power-of-two bucket (≥ ``MIN_BUCKET``) instead of to ``max_len``, and
  *all* waiting requests that land in the same bucket are prefilled as
  one batched forward, spliced with one :func:`splice_rows` call and
  admitted with one state scatter: a same-bucket admission burst of N
  requests costs O(1) device dispatches, not N. One jit compilation per
  (bucket, group size); group size is bounded by the slot count.
* **Every family buckets** — recurrent/hybrid/windowed prefill is
  length-exact under padding (``seq_lens`` mask-carry, see
  ``models.recurrent`` / ``models.blocks._ring_exact_fill``), so the
  bucket length is no longer part of the computation and those archs
  left ``max_len`` alignment. Windowed archs keep a bucket floor of
  ``window`` so a prefill row's ring size equals the grid's. Enc-dec
  archs run the encoder once per admission over frames padded to
  ``max_src_len`` (masked — padded frames contribute exactly zero) and
  cache ``enc_out`` in the slot's :class:`DecodeState` row; vlm archs
  prepend per-request patch embeddings, bucketing on the total
  (prefix + prompt) length. MoE note: routing capacity scales with the
  *batched* token count, so under a dropping capacity factor an MoE
  request's prefill may depend on its bucket companions — same
  contention continuous batching already accepts per decode step.
* **Metadata-driven cache splice** — the batch-slot axis of every cache
  leaf comes from :func:`repro.models.registry.cache_axes` (derived
  structurally from ``make_caches``), not from a runtime shape heuristic
  that mis-matched when a model dim collided with the slot count. The
  splice is a jitted ``dynamic_update_slice`` sweep that donates the
  grid, so admission never rewrites the whole KV grid at Python level.

K/V written by a shorter bucket leave the grid row's tail stale; the
spliced ``pos`` leaves mark it ``-1`` (invalid), which the decode
attention masks — same invariant the ring buffer relies on.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import registry as REG
from repro.quant import QuantConfig, dequantize_params
from repro.serving import pages as PG
from repro.serving import sampler as SMP
from repro.serving.state import DecodeState, admit_rows

PyTree = Any

MIN_BUCKET = 8


class RequestValidationError(ValueError):
    """A request was rejected at ``submit()`` (wrong modality payload for
    the arch family, or prompt + budget exceeding the slot grid)."""


class Request:
    """One serving request.

    The modality payload is explicit per family: ``src_frames``
    ([S_src, D]) are encoder source frames (enc-dec archs — the encoder
    input, *not* resident in the decoder cache row), ``patch_embeds``
    ([P, D]) are vlm patch embeddings (prepended to the prompt's cache
    row). The old ambiguous ``frames=`` kwarg / attribute is kept as a
    deprecated alias; ``submit()`` resolves it to the family's field.
    """

    def __init__(self, rid: int, prompt: np.ndarray, max_new_tokens: int = 16,
                 frames: Optional[np.ndarray] = None, *,
                 src_frames: Optional[np.ndarray] = None,
                 patch_embeds: Optional[np.ndarray] = None,
                 out_tokens: Optional[List[int]] = None,
                 submitted_at: float = 0.0, finished_at: float = 0.0):
        if frames is not None:
            if src_frames is not None or patch_embeds is not None:
                raise RequestValidationError(
                    f"request {rid}: pass src_frames=/patch_embeds= or the "
                    f"deprecated frames=, not both")
            warnings.warn(
                "Request(frames=...) is deprecated: pass src_frames= "
                "(enc-dec source frames) or patch_embeds= (vlm patch "
                "embeddings)", DeprecationWarning, stacklevel=2)
        self.rid = rid
        self.prompt = prompt
        self.max_new_tokens = max_new_tokens
        self.src_frames = src_frames
        self.patch_embeds = patch_embeds
        self._legacy_frames = frames
        self.out_tokens: List[int] = [] if out_tokens is None else out_tokens
        self.submitted_at = submitted_at
        self.finished_at = finished_at

    @property
    def frames(self) -> Optional[np.ndarray]:
        """Deprecated alias: whichever modality payload is set."""
        for v in (self.src_frames, self.patch_embeds, self._legacy_frames):
            if v is not None:
                return v
        return None

    def _resolve_payload(self, family: str) -> None:
        """Route a legacy ``frames=`` payload to the family's field
        (called by ``submit()``, where the arch family is known)."""
        if self._legacy_frames is not None:
            if family == "encdec":
                self.src_frames = self._legacy_frames
            else:
                self.patch_embeds = self._legacy_frames
            self._legacy_frames = None

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens

    def __repr__(self) -> str:
        return (f"Request(rid={self.rid}, prompt_len={len(self.prompt)}, "
                f"max_new_tokens={self.max_new_tokens})")


def _bucketable(arch: ArchConfig) -> bool:
    """True when prefill length is free to vary per request. Since
    prefill went length-exact (recurrent mask-carry, windowed ring-exact
    fill, masked encoder), every registered family qualifies; the hook
    stays for archs whose prefill state could still depend on the padded
    length."""
    return True


def bucket_floor(arch: ArchConfig, max_len: int,
                 min_bucket: int = MIN_BUCKET) -> int:
    """Smallest admissible bucket: windowed archs must build prefill rows
    whose ring size equals the grid's (``min(bucket, window)`` ==
    ``min(max_len, window)``), so their floor is the window."""
    win = arch.window if arch.family == "hybrid" else 0
    return max(min_bucket, min(win, max_len)) if win else min_bucket


def bucket_len(prompt_len: int, max_len: int, *, aligned: bool = False,
               min_bucket: int = MIN_BUCKET) -> int:
    """Power-of-two bucket ≥ prompt_len, clamped to ``max_len``."""
    if aligned:
        return max_len
    b = min_bucket
    while b < prompt_len:
        b *= 2
    return min(b, max_len)


def _leaf_key(path) -> Optional[str]:
    return getattr(path[-1], "key", None) if path else None


def mesh_jit(mesh, fn, **kw):
    """jit ``fn`` under the plan's mesh context when one is bound (the
    single place the serving package enters a mesh to compile)."""
    if mesh is not None:
        with mesh:
            return jax.jit(fn, **kw)
    return jax.jit(fn, **kw)


def splice_row(grid: PyTree, row: PyTree, slot, axes: PyTree) -> PyTree:
    """Write a batch-1 prefill row into ``grid`` at ``slot``.

    ``axes`` is the :func:`repro.models.registry.cache_axes` tree: the
    batch axis is explicit per leaf (never guessed from shapes). Rows may
    be shorter than the grid on their length axis (bucketed prefill);
    ``pos`` leaves are padded with ``-1`` so the stale K/V tail of the
    grid row stays masked, other leaves leave the tail untouched.
    Jit-friendly: ``slot`` may be a traced scalar.
    """

    def one(path, g, r, ax):
        if ax.batch is None or g.ndim == 0:
            return g
        r = r.astype(g.dtype)
        if ax.length is not None and r.shape[ax.length] < g.shape[ax.length]:
            if _leaf_key(path) == "pos":
                pad = [(0, 0)] * r.ndim
                pad[ax.length] = (0, g.shape[ax.length] - r.shape[ax.length])
                r = jnp.pad(r, pad, constant_values=-1)
        starts = [0] * g.ndim
        starts[ax.batch] = slot
        return jax.lax.dynamic_update_slice(g, r, tuple(starts))

    return jax.tree_util.tree_map_with_path(one, grid, row, axes)


def splice_rows(grid: PyTree, rows: PyTree, slots: jax.Array,
                axes: PyTree) -> PyTree:
    """Batched :func:`splice_row`: write ``n`` stacked prefill rows into
    ``grid`` at ``slots`` ([n] int32, distinct). The per-row update sweep
    is unrolled inside one jit, so a same-bucket admission burst is a
    single splice dispatch regardless of its size."""
    n = int(slots.shape[0])

    def row_i(i):
        def take(r, ax):
            if ax.batch is None or not hasattr(r, "ndim") or r.ndim == 0:
                return r
            return jax.lax.dynamic_slice_in_dim(r, i, 1, axis=ax.batch)
        return jax.tree.map(take, rows, axes)

    for i in range(n):
        grid = splice_row(grid, row_i(i), slots[i], axes)
    return grid


def invalidate_padding(row: PyTree, true_len, axes: PyTree) -> PyTree:
    """Mark ``pos`` entries at-or-beyond the true prompt length invalid
    (``-1``) — the in-bucket analog of the splice's tail padding.
    ``true_len`` is a scalar, or ``[n]`` for a stacked batch of rows
    (broadcast along each leaf's batch axis).

    The mask compares the stored position *value*, not the ring index:
    windowed caches keep the last ``window`` positions, so index ``i``
    does not hold position ``i`` there. For full-length caches the two
    coincide (prefill stores position ``i`` at index ``i``); already
    invalid entries (``-1``) stay invalid either way."""

    def one(path, leaf, ax):
        if _leaf_key(path) != "pos" or ax.length is None:
            return leaf
        lens = jnp.asarray(true_len)
        if lens.ndim and ax.batch is not None:
            shape = [1] * leaf.ndim
            shape[ax.batch] = lens.shape[0]
            lens = lens.reshape(shape)
        return jnp.where(leaf < lens, leaf, -1)

    return jax.tree_util.tree_map_with_path(one, row, axes)


class _Inflight:
    """One dispatched prefill→decode admission wave: the worker's
    transferred outputs plus the host bookkeeping needed to splice them
    (``ready()`` is the non-blocking all-leaves-arrived check)."""

    def __init__(self, *, kind, outs, group, slots, lens, max_new,
                 rids=None, flens=None, page_rows=None, dispatch_wall=0.0):
        self.kind = kind
        self.outs = outs
        self.group = group
        self.slots = slots
        self.lens = lens
        self.max_new = max_new
        self.rids = rids
        self.flens = flens
        self.page_rows = page_rows
        self.dispatch_wall = dispatch_wall

    def ready(self) -> bool:
        try:
            return all(leaf.is_ready() for leaf in jax.tree.leaves(self.outs))
        except AttributeError:  # runtime without is_ready: sync splice
            return True


class PrefillFactory:
    """Builds (and caches jits of) the batched bucketed prefill step,
    keyed ``(kind, bucket, n, prefix)``.

    Factored out of the :class:`Scheduler` so a disaggregated
    deployment's ``PrefillWorker`` (``serving.disagg``) can compile the
    *same* prefill programs under its own prefill-slice mesh: the
    arithmetic is identical, only the mesh (and therefore the sharding
    of the same logical computation) differs.

    kind "lm":     (params, tokens [n,B], lens [n])
    kind "vlm":    (params, patches [n,P,D], tokens [n,B-P], lens [n])
    kind "encdec": (params, frames [n,max_src,D], flens [n],
                    tokens [n,B], lens [n]) — also returns enc_out
    ``lens`` counts the prefix; every returned row is length-exact for
    its row's true length (mask-carry / ring-exact fill / invalidated
    pos tail).
    """

    def __init__(self, arch: ArchConfig, cache_axes: PyTree, cache_dtype,
                 mesh=None, quant: Optional[QuantConfig] = None):
        self.arch = arch
        self.cache_axes = cache_axes
        self.cache_dtype = cache_dtype
        self.mesh = mesh
        self.quant = quant if quant is not None else QuantConfig()
        self._fns: Dict[Tuple, Callable] = {}

    def build(self, kind: str, bucket: int, n: int,
              prefix: int = 0) -> Callable:
        """The raw (unjitted) prefill callable for one signature."""
        from repro.models import encdec as ED
        from repro.models import lm as LM
        arch, axes, dtype = self.arch, self.cache_axes, self.cache_dtype
        qkv, qw = self.quant.quant_kv, self.quant.quant_weights

        def last_hidden(hidden, lens):
            return jax.vmap(lambda h, l: jax.lax.dynamic_slice_in_dim(
                h, l - 1, 1, axis=0))(hidden, lens)

        if kind == "encdec":
            def prefill(params, frames, flens, tokens, lens):
                params = dequantize_params(params) if qw else params
                enc_out = ED.encode(arch, params, frames, enc_lens=flens)
                caches = ED.make_caches(arch, n, bucket, dtype, kv_quant=qkv)
                hidden, rows = ED.decode(arch, params, tokens, enc_out,
                                         caches=caches, enc_lens=flens)
                logits = last_hidden(hidden, lens) @ params["unembed"]
                return invalidate_padding(rows, lens, axes), logits, enc_out
        elif kind == "vlm":
            def prefill(params, patches, tokens, lens):
                params = dequantize_params(params) if qw else params
                caches = REG.make_caches(arch, n, bucket, dtype, kv_quant=qkv)
                hidden, rows = LM.forward(arch, params, tokens, caches=caches,
                                          prefix_embeds=patches, seq_lens=lens)
                logits = LM.logits_fn(arch, params, last_hidden(hidden, lens))
                return invalidate_padding(rows, lens, axes), logits
        else:
            def prefill(params, tokens, lens):
                params = dequantize_params(params) if qw else params
                caches = REG.make_caches(arch, n, bucket, dtype, kv_quant=qkv)
                hidden, rows = LM.forward(arch, params, tokens, caches=caches,
                                          seq_lens=lens)
                logits = LM.logits_fn(arch, params, last_hidden(hidden, lens))
                return invalidate_padding(rows, lens, axes), logits

        return prefill

    def get(self, kind: str, bucket: int, n: int, prefix: int = 0,
            **jit_kw) -> Callable:
        """Cached ``mesh_jit`` of :meth:`build` (``jit_kw`` — e.g.
        ``out_shardings`` — applies on first build of a signature)."""
        key = (kind, bucket, n, prefix)
        fn = self._fns.get(key)
        if fn is None:
            fn = self._fns[key] = mesh_jit(
                self.mesh, self.build(kind, bucket, n, prefix), **jit_kw)
        return fn


class Scheduler:
    """Host-side slot lifecycle; all device mutation goes through jits.

    The engine threads ``(caches, state)`` through :meth:`admit`; the
    scheduler never holds device buffers itself, so donation stays linear
    (exactly one live reference to the grid at any time).

    When a :attr:`worker` (``serving.disagg.PrefillWorker``) is attached,
    admission is **routed to the prefill role**: :meth:`admit` dispatches
    each admission group to the worker (which runs the same bucketed
    prefill on the prefill mesh slice and streams the results over) and
    returns immediately; arriving KV is spliced into the decode grid by
    :meth:`admit` on a later call, only once every transferred leaf
    reports ready — the fused decode step never waits on a prefill.
    """

    def __init__(self, arch: ArchConfig, *, slots: int, max_len: int,
                 cache_dtype, mesh=None, sampling: SMP.SamplingParams = SMP.GREEDY,
                 min_bucket: int = MIN_BUCKET,
                 max_src_len: Optional[int] = None,
                 paged: bool = False, page_size: int = PG.DEFAULT_PAGE_SIZE,
                 kv_pages: Optional[int] = None, prefix_cache: bool = True,
                 quant: Optional[QuantConfig] = None, seed: int = 0,
                 spec_draft: Optional[ArchConfig] = None):
        self.arch = arch
        self.slots = slots
        self.max_len = max_len
        # per-request sampling keys are fold_in(PRNGKey(seed), rid): a
        # request's stochastic token stream is a function of (seed, rid)
        # alone — independent of admission timing, slot assignment,
        # lookahead depth, and the plan (the invariance serving_equiv's
        # sampled mode certifies)
        self.seed = seed
        self.max_src_len = max_src_len if max_src_len is not None else max_len
        self.cache_dtype = cache_dtype
        self.mesh = mesh
        self.sampling = sampling
        self.quant = quant if quant is not None else QuantConfig()
        self.min_bucket = bucket_floor(arch, max_len, min_bucket)
        self.aligned = not _bucketable(arch)
        self.cache_axes = REG.cache_axes(arch, cache_dtype,
                                         kv_quant=self.quant.quant_kv)
        self.paged = paged
        self.page_size = page_size
        self.pool: Optional[PG.PagePool] = None
        self.registry: Optional[PG.PrefixRegistry] = None
        self.slot_pages: Dict[int, List[int]] = {}
        if paged:
            PG.check_paged_supported(arch)
            self.table_len = PG.num_pages_per_slot(max_len, page_size)
            if kv_pages is None:
                kv_pages = PG.default_kv_pages(slots, max_len, page_size)
            self.pool = PG.PagePool(kv_pages, page_size)
            # MoE routing capacity couples batch rows, so a compute-skip
            # suffix prefill would perturb its bucket companions — MoE
            # pages its KV but does not prefix-share.
            if prefix_cache and arch.family != "moe":
                self.registry = PG.PrefixRegistry(self.pool)
            self._matches: Dict[int, Tuple[int, Tuple[int, ...],
                                           Optional[int]]] = {}
        self.queue: List[Request] = []
        self.active: Dict[int, Optional[Request]] = {i: None for i in range(slots)}
        self.prefill_factory = PrefillFactory(arch, self.cache_axes,
                                              cache_dtype, mesh=mesh,
                                              quant=self.quant)
        # speculative decoding: the draft model's prompt KV is prefilled
        # at admission too (full prompt, always dense and full-precision,
        # bucketed on its own) and spliced into state.draft_caches
        self.draft = spec_draft
        self.draft_axes = self.draft_factory = None
        if spec_draft is not None:
            self.draft_axes = REG.cache_axes(spec_draft, cache_dtype)
            self.draft_factory = PrefillFactory(spec_draft, self.draft_axes,
                                                cache_dtype, mesh=mesh)
        # disagg: attached by DisaggServingEngine; admissions then route
        # to the prefill role and splice on arrival (see _integrate)
        self.worker = None
        self.inflight: deque = deque()
        self._prefill_fns: Dict[Tuple, Callable] = {}
        self._splice_fns: Dict[Tuple, Callable] = {}
        self._admit_fns: Dict[Tuple, Callable] = {}
        # prefill telemetry: host wall per admission (dispatch + splice
        # enqueue — the serving loop's critical-path cost; the prefill
        # compute itself overlaps the running decode grid). Batched
        # admission attributes a dispatch's wall evenly to its requests
        # and additionally records per-dispatch wall and batch size.
        self.prefill_times = deque(maxlen=4096)
        self.prefill_prompt_lens = deque(maxlen=4096)
        self.prefill_dispatch_times = deque(maxlen=4096)
        self.prefill_batch_sizes = deque(maxlen=4096)

    # ------------------------------ queue ------------------------------
    def submit(self, req: Request) -> None:
        req._resolve_payload(self.arch.family)
        if self.arch.family == "encdec":
            if req.patch_embeds is not None:
                raise RequestValidationError(
                    f"request {req.rid}: patch_embeds is a vlm payload; "
                    f"encdec arch {self.arch.name} takes src_frames")
            if req.src_frames is None:
                raise RequestValidationError(
                    f"request {req.rid}: encdec arch {self.arch.name} needs "
                    f"source frames ([S_src, {self.arch.d_model}]) to encode")
            if len(req.src_frames) > self.max_src_len:
                raise RequestValidationError(
                    f"request {req.rid}: {len(req.src_frames)} source frames "
                    f"exceed max_src_len {self.max_src_len}")
        elif req.src_frames is not None:
            raise RequestValidationError(
                f"request {req.rid}: src_frames is an encdec payload; "
                f"{self.arch.family} arch {self.arch.name} takes "
                f"patch_embeds")
        if self.draft is not None and req.patch_embeds is not None:
            raise RequestValidationError(
                f"request {req.rid}: speculative serving drafts token "
                f"prompts only; patch_embeds are unsupported with a "
                f"draft model")
        total = len(req.prompt) + self._prefix_len(req)
        if total > self.max_len:
            raise RequestValidationError(
                f"request {req.rid}: prompt length {total} (incl. prefix) "
                f"exceeds max_len {self.max_len}")
        if total + req.max_new_tokens > self.max_len:
            raise RequestValidationError(
                f"request {req.rid}: prompt {total} + max_new_tokens "
                f"{req.max_new_tokens} exceeds max_len {self.max_len} "
                f"(the slot's KV row holds prompt and decoded tokens)")
        req.submitted_at = time.time()
        self.queue.append(req)

    def _prefix_len(self, req: Request) -> int:
        """Prefix tokens the prompt's cache row must also hold (vlm patch
        embeddings ride in the decoder grid; encdec frames do not)."""
        if req.patch_embeds is not None:
            return len(req.patch_embeds)
        return 0

    def has_active(self) -> bool:
        return any(r is not None for r in self.active.values())

    # -------------------------- jit factories --------------------------
    def _jit(self, fn, **kw):
        return mesh_jit(self.mesh, fn, **kw)

    def rebind_mesh(self, mesh) -> None:
        """Re-home the scheduler on a new mesh (live plan→plan migration,
        see ``ServingEngine.migrate``). Host bookkeeping — queue, active
        slots, page pool, prefix registry, rid→key seeding — is
        mesh-independent and survives untouched; the cached
        prefill/splice/admit jits were compiled under the old mesh
        context, so they are dropped and rebuild lazily on the new one."""
        if self.worker is not None:
            raise NotImplementedError(
                "rebind_mesh on a disaggregated scheduler: migrating a "
                "two-role deployment would re-split the prefill/decode "
                "slices; migrate the fused engine instead")
        self.mesh = mesh
        self.prefill_factory.mesh = mesh
        self.prefill_factory._fns.clear()
        if self.draft_factory is not None:
            self.draft_factory.mesh = mesh
            self.draft_factory._fns.clear()
        self._prefill_fns.clear()
        self._splice_fns.clear()
        self._admit_fns.clear()

    def _get_prefill(self, kind: str, bucket: int, n: int,
                     prefix: int = 0) -> Callable:
        """Batched prefill step for ``n`` same-bucket requests (see
        :class:`PrefillFactory` for the per-kind signatures)."""
        return self.prefill_factory.get(kind, bucket, n, prefix)

    def _get_splice(self, n: int) -> Callable:
        fn = self._splice_fns.get(n)
        if fn is None:
            axes = self.cache_axes
            fn = self._splice_fns[n] = self._jit(
                lambda grid, rows, slots: splice_rows(grid, rows, slots, axes),
                donate_argnums=(0,))
        return fn

    def _admit_keys(self, rids: jax.Array) -> jax.Array:
        """Per-request sampling keys: ``fold_in(PRNGKey(seed), rid)``.
        Keying on the request id (not the slot) makes a sampled stream
        reproducible whatever slot, step, or plan the request lands on."""
        base = jax.random.PRNGKey(self.seed)
        return jax.vmap(lambda r: jax.random.fold_in(base, r))(rids)

    def _get_draft_splice(self, n: int) -> Callable:
        key = ("draft_splice", n)
        fn = self._splice_fns.get(key)
        if fn is None:
            axes = self.draft_axes
            fn = self._splice_fns[key] = self._jit(
                lambda grid, rows, slots: splice_rows(grid, rows, slots, axes),
                donate_argnums=(0,))
        return fn

    def _get_admit(self, n: int, enc: bool) -> Callable:
        key = (n, enc)
        fn = self._admit_fns.get(key)
        if fn is None:
            sampling = self.sampling
            admit_keys = self._admit_keys

            def admit(state, slots, rids, logits, positions, max_new,
                      enc_out=None, enc_len=None):
                rng, toks = SMP.sample(logits[:, -1], admit_keys(rids),
                                       sampling)
                return admit_rows(state, slots, toks, positions, max_new,
                                  rng, enc_out=enc_out, enc_len=enc_len)

            if enc:
                fn = self._jit(admit, donate_argnums=(0,))
            else:
                fn = self._jit(lambda state, slots, rids, logits, positions,
                               max_new: admit(state, slots, rids, logits,
                                              positions, max_new),
                               donate_argnums=(0,))
            self._admit_fns[key] = fn
        return fn

    # ------------------------- paged jit factories ----------------------
    def _get_page_splice(self, n: int) -> Callable:
        key = ("page_splice", n)
        fn = self._splice_fns.get(key)
        if fn is None:
            fn = self._splice_fns[key] = self._jit(
                PG.splice_pages, donate_argnums=(0,))
        return fn

    def _get_copy(self, n: int) -> Callable:
        key = ("page_copy", n)
        fn = self._splice_fns.get(key)
        if fn is None:
            fn = self._splice_fns[key] = self._jit(
                PG.copy_pages, donate_argnums=(0,))
        return fn

    def _get_admit_paged(self, n: int) -> Callable:
        key = (n, "paged")
        fn = self._admit_fns.get(key)
        if fn is None:
            sampling = self.sampling
            admit_keys = self._admit_keys

            def admit(state, slots, rids, logits, positions, max_new,
                      page_rows):
                rng, toks = SMP.sample(logits[:, -1], admit_keys(rids),
                                       sampling)
                return admit_rows(state, slots, toks, positions, max_new,
                                  rng, page_rows=page_rows)

            fn = self._admit_fns[key] = self._jit(admit, donate_argnums=(0,))
        return fn

    def _get_prefill_shared(self, bucket: int, n: int, span: int) -> Callable:
        """Compute-skip suffix prefill: gather the ``span`` prefix pages
        per row into dense KV blocks (``pages.gather_prefix``) and run
        only the suffix tokens through the stack, queries positioned at
        ``m..m+bucket-1`` (``models.blocks._shared_prefix_attention``).
        Returned rows carry absolute ``pos`` values, so the ordinary
        paged splice routes them past the shared region."""
        key = ("lm_shared", bucket, n, span)
        fn = self._prefill_fns.get(key)
        if fn is not None:
            return fn
        from repro.models import lm as LM
        arch, axes = self.arch, self.cache_axes
        qw = self.quant.quant_weights

        def prefill(params, pools, page_rows, m_arr, tokens, lens):
            params = dequantize_params(params) if qw else params
            pre = PG.gather_prefix(pools, page_rows, m_arr)
            positions = m_arr[:, None] + jnp.broadcast_to(
                jnp.arange(bucket, dtype=jnp.int32)[None], (n, bucket))
            hidden, rows = LM.forward(arch, params, tokens, caches=pre,
                                      positions=positions, seq_lens=lens)
            suf_lens = lens - m_arr
            last = jax.vmap(lambda h, l: jax.lax.dynamic_slice_in_dim(
                h, l - 1, 1, axis=0))(hidden, suf_lens)
            logits = LM.logits_fn(arch, params, last)
            return invalidate_padding(rows, lens, axes), logits

        fn = self._prefill_fns[key] = self._jit(prefill)
        return fn

    # ------------------------- page accounting --------------------------
    def _alloc_slot_pages(self, req: Request):
        """Reserve the physical pages one admission needs: fresh pages
        covering prompt + decode budget, with any matched prefix aliased
        (refcount+1) ahead of them. Returns ``(row [table_len] int32,
        owned pages, (cow_dst, cow_src) | None)``; raises
        :class:`pages.PagePoolExhausted` when the pool cannot satisfy.
        """
        total = len(req.prompt) + self._prefix_len(req)
        need = -(-(total + req.max_new_tokens) // self.page_size)
        waiting = [req.rid] + [r.rid for r in self.queue]
        row = np.zeros((self.table_len,), np.int32)
        match = self._matches.get(req.rid) if self.registry else None
        if match is not None and match[0]:
            m, chain, frontier = match
            j = len(chain)
            fresh = self.pool.alloc(need - j, waiting=waiting)
            self.pool.retain(chain)
            row[:j] = chain
            row[j:need] = fresh
            owned = list(chain) + fresh
            # mid-page match: the sharer's suffix continues inside the
            # owner's frontier page, so it writes into a private copy
            cow = (fresh[0], frontier) if frontier is not None else None
            return row, owned, cow
        pages = self.pool.alloc(need, waiting=waiting)
        row[:need] = pages
        return row, pages, None

    def release_slot(self, slot: int) -> None:
        """Return a retired slot's pages to the pool (refcount−1; pages
        still pinned by the prefix registry or a sharer stay resident)."""
        pages = self.slot_pages.pop(slot, None)
        if pages is not None:
            self.pool.release(pages)

    # ---------------------------- admission ----------------------------
    def _group_key(self, req: Request) -> Tuple[str, int, int]:
        total = len(req.prompt) + self._prefix_len(req)
        bucket = bucket_len(total, self.max_len, aligned=self.aligned,
                            min_bucket=self.min_bucket)
        if self.arch.family == "encdec":
            return ("encdec", bucket, 0)
        if req.patch_embeds is not None:
            return ("vlm", bucket, len(req.patch_embeds))
        if self.registry is not None:
            m, chain, frontier = self.registry.lookup(
                np.asarray(req.prompt, np.int32))
            if m:
                # compute-skip admission: only the unmatched suffix runs
                # through prefill, bucketed on its own length. The third
                # key component is the shared prefix length, so every
                # group member gathers the same page span.
                self._matches[req.rid] = (m, chain, frontier)
                suf_bucket = bucket_len(total - m, self.max_len,
                                        aligned=self.aligned,
                                        min_bucket=self.min_bucket)
                return ("lm_shared", suf_bucket, m)
        return ("lm", bucket, 0)

    def _marshal_frames(self, group):
        """Host-side [n, max_src, D] frame grid + true lengths (encdec)."""
        n = len(group)
        frames = np.zeros((n, self.max_src_len, self.arch.d_model),
                          np.float32)
        flens = np.zeros((n,), np.int32)
        for i, (req, _) in enumerate(group):
            flens[i] = len(req.src_frames)
            frames[i, :flens[i]] = req.src_frames
        return frames, flens

    def _integrate(self, caches, state: DecodeState):
        """Splice arrived prefill→decode transfers into the grid.

        Waves integrate in dispatch order, and only once **every**
        transferred leaf reports ready (non-blocking ``is_ready``), so
        the fused decode step the engine dispatches right after never
        data-depends on an in-flight transfer — a prefill storm on the
        other slice cannot stall the decode stream. The slots were
        reserved at dispatch; until the splice lands they are device-
        inactive and the serve step treats them as inert rows.
        """
        while self.inflight:
            inf = self.inflight[0]
            if not inf.ready():
                break
            self.inflight.popleft()
            t0 = time.perf_counter()
            n = len(inf.group)
            slots_j = jnp.asarray(inf.slots)
            lens_j = jnp.asarray(inf.lens)
            max_new_j = jnp.asarray(inf.max_new)
            rids_j = jnp.asarray(inf.rids)
            rows, logits = inf.outs[0], inf.outs[1]
            if self.paged:
                page_rows_j = jnp.asarray(inf.page_rows)
                caches = self._get_page_splice(n)(caches, rows, page_rows_j)
                state = self._get_admit_paged(n)(
                    state, slots_j, rids_j, logits, lens_j, max_new_j,
                    page_rows_j)
            elif inf.kind == "encdec":
                caches = self._get_splice(n)(caches, rows, slots_j)
                state = self._get_admit(n, enc=True)(
                    state, slots_j, rids_j, logits, lens_j, max_new_j,
                    inf.outs[2], jnp.asarray(inf.flens))
            else:
                caches = self._get_splice(n)(caches, rows, slots_j)
                state = self._get_admit(n, enc=False)(
                    state, slots_j, rids_j, logits, lens_j, max_new_j)
            wall = time.perf_counter() - t0
            self.prefill_dispatch_times.append(wall + inf.dispatch_wall)
            self.prefill_batch_sizes.append(n)
            for req, _ in inf.group:
                self.prefill_times.append((wall + inf.dispatch_wall) / n)
                self.prefill_prompt_lens.append(len(req.prompt))
        return caches, state

    def admit(self, params, caches, state: DecodeState):
        """Fill free slots from the queue; returns updated (caches, state).

        All waiting requests that land in the same bucket become one
        batched prefill + one batched splice + one state scatter — O(1)
        dispatches per bucket, however many requests arrived. Pure
        dispatch: the work is enqueued on the device stream and overlaps
        the in-flight decode step — the serving-loop analog of the
        paper's §4.3 transfer/compute overlap.

        With a disagg :attr:`worker` attached the group's prefill runs on
        the prefill slice instead and this call only *dispatches* (and
        integrates previously-arrived waves); see :meth:`_integrate`.

        Speculative engines pass ``params`` as ``{"target", "draft"}``:
        every admission additionally prefills the draft model over the
        **full** prompt (dense, full-precision, bucketed on its own —
        even for prefix-shared groups whose target prefill is
        suffix-only) and splices the rows into ``state.draft_caches``.
        """
        dparams = None
        if self.draft is not None:
            dparams = params["draft"]
            params = params["target"]
        if self.worker is not None:
            caches, state = self._integrate(caches, state)
        free = [s for s, occ in self.active.items() if occ is None]
        take = min(len(free), len(self.queue))
        if take == 0:
            return caches, state
        pairs = list(zip(self.queue[:take], free))
        del self.queue[:take]
        if self.paged:
            self._matches.clear()
        groups: Dict[Tuple[str, int, int], List[Tuple[Request, int]]] = {}
        for req, slot in pairs:
            groups.setdefault(self._group_key(req), []).append((req, slot))

        admitted: set = set()
        exhausted = False
        for (kind, bucket, prefix), group in sorted(groups.items()):
            if exhausted:
                break
            page_rows_np: List[np.ndarray] = []
            owned_list: List[List[int]] = []
            cows: List[Optional[Tuple[int, int]]] = []
            if self.paged:
                kept = []
                for req, slot in group:
                    try:
                        row, owned, cow = self._alloc_slot_pages(req)
                    except PG.PagePoolExhausted:
                        # degrade to queueing: un-admitted requests go
                        # back to the queue head and wait for retiring
                        # slots (or a registry eviction) to free pages
                        exhausted = True
                        if self.registry is not None:
                            self.registry.evict_unreferenced()
                        break
                    kept.append((req, slot))
                    page_rows_np.append(row)
                    owned_list.append(owned)
                    cows.append(cow)
                group = kept
                if not group:
                    continue
            t0 = time.perf_counter()
            n = len(group)
            width = bucket if kind == "lm_shared" else bucket - prefix
            toks = np.zeros((n, width), np.int32)
            lens = np.zeros((n,), np.int32)
            slots_arr = np.zeros((n,), np.int32)
            max_new = np.zeros((n,), np.int32)
            rids_arr = np.zeros((n,), np.int32)
            for i, (req, slot) in enumerate(group):
                s = len(req.prompt)
                if kind == "lm_shared":  # suffix tokens only; lens = total
                    toks[i, :s - prefix] = req.prompt[prefix:]
                    lens[i] = s
                else:
                    toks[i, :s] = req.prompt
                    lens[i] = s + prefix if kind == "vlm" else s
                slots_arr[i] = slot
                max_new[i] = req.max_new_tokens
                rids_arr[i] = req.rid
            if self.worker is not None:
                # disagg: run this group's prefill on the prefill slice;
                # the outputs stream over asynchronously and splice in a
                # later _integrate call. Slots are reserved host-side now
                # (device-inactive until the splice lands).
                frames = flens = patches = None
                if kind == "encdec":
                    frames, flens = self._marshal_frames(group)
                elif kind == "vlm":
                    patches = np.stack([req.patch_embeds for req, _ in group]
                                       ).astype(np.float32)
                outs = self.worker.dispatch(kind, bucket, prefix, toks=toks,
                                            lens=lens, frames=frames,
                                            flens=flens, patches=patches)
                self.inflight.append(_Inflight(
                    kind=kind, outs=outs, group=list(group), slots=slots_arr,
                    lens=lens, max_new=max_new, rids=rids_arr, flens=flens,
                    page_rows=(np.stack(page_rows_np) if self.paged
                               else None),
                    dispatch_wall=time.perf_counter() - t0))
                for i, (req, slot) in enumerate(group):
                    self.active[slot] = req
                    admitted.add(req.rid)
                    if self.paged:
                        self.slot_pages[slot] = owned_list[i]
                continue
            slots_j = jnp.asarray(slots_arr)
            lens_j = jnp.asarray(lens)
            rids_j = jnp.asarray(rids_arr)
            if kind == "lm_shared":
                page_rows_j = jnp.asarray(np.stack(page_rows_np))
                cow_pairs = [c for c in cows if c is not None]
                if cow_pairs:
                    dst = jnp.asarray([d for d, _ in cow_pairs], jnp.int32)
                    src = jnp.asarray([s_ for _, s_ in cow_pairs], jnp.int32)
                    caches = self._get_copy(len(cow_pairs))(caches, dst, src)
                span = -(-prefix // self.page_size)
                m_arr = jnp.full((n,), prefix, jnp.int32)
                rows, logits = self._get_prefill_shared(bucket, n, span)(
                    params, caches, page_rows_j[:, :span], m_arr,
                    jnp.asarray(toks), lens_j)
                caches = self._get_page_splice(n)(caches, rows, page_rows_j)
                state = self._get_admit_paged(n)(
                    state, slots_j, rids_j, logits, lens_j,
                    jnp.asarray(max_new), page_rows_j)
            elif kind == "encdec":
                frames, flens = self._marshal_frames(group)
                rows, logits, enc_out = self._get_prefill(
                    kind, bucket, n)(params, jnp.asarray(frames),
                                     jnp.asarray(flens), jnp.asarray(toks),
                                     lens_j)
                caches = self._get_splice(n)(caches, rows, slots_j)
                state = self._get_admit(n, enc=True)(
                    state, slots_j, rids_j, logits, lens_j,
                    jnp.asarray(max_new), enc_out, jnp.asarray(flens))
            else:
                if kind == "vlm":
                    patches = np.stack([req.patch_embeds for req, _ in group]
                                       ).astype(np.float32)
                    rows, logits = self._get_prefill(kind, bucket, n, prefix)(
                        params, jnp.asarray(patches), jnp.asarray(toks),
                        lens_j)
                else:
                    rows, logits = self._get_prefill(kind, bucket, n)(
                        params, jnp.asarray(toks), lens_j)
                if self.paged:
                    # prefill compute stays dense and bucketed — paging
                    # only redirects the splice target to the page pool
                    page_rows_j = jnp.asarray(np.stack(page_rows_np))
                    caches = self._get_page_splice(n)(caches, rows,
                                                      page_rows_j)
                    state = self._get_admit_paged(n)(
                        state, slots_j, rids_j, logits, lens_j,
                        jnp.asarray(max_new), page_rows_j)
                else:
                    caches = self._get_splice(n)(caches, rows, slots_j)
                    state = self._get_admit(n, enc=False)(
                        state, slots_j, rids_j, logits, lens_j,
                        jnp.asarray(max_new))
            if self.draft is not None:
                # draft prompt KV: full-prompt dense prefill at the
                # group's full-length bucket (a prefix-shared group's
                # target prefill is suffix-only, the draft's never is),
                # spliced into the state's draft grid
                dbucket = bucket_len(int(lens.max()), self.max_len,
                                     min_bucket=MIN_BUCKET)
                dtoks = np.zeros((n, dbucket), np.int32)
                for i, (req, _) in enumerate(group):
                    dtoks[i, :len(req.prompt)] = req.prompt
                drows, _ = self.draft_factory.get("lm", dbucket, n)(
                    dparams, jnp.asarray(dtoks), lens_j)
                state = dataclasses.replace(
                    state, draft_caches=self._get_draft_splice(n)(
                        state.draft_caches, drows, slots_j))
            for i, (req, slot) in enumerate(group):
                self.active[slot] = req
                admitted.add(req.rid)
                if self.paged:
                    self.slot_pages[slot] = owned_list[i]
                    if self.registry is not None and req.patch_embeds is None:
                        total = len(req.prompt)
                        cover = -(-total // self.page_size)
                        self.registry.register(
                            np.asarray(req.prompt, np.int32),
                            page_rows_np[i][:cover].tolist())
            wall = time.perf_counter() - t0
            self.prefill_dispatch_times.append(wall)
            self.prefill_batch_sizes.append(n)
            for req, _ in group:
                self.prefill_times.append(wall / n)
                self.prefill_prompt_lens.append(len(req.prompt))
        leftover = [req for req, _ in pairs if req.rid not in admitted]
        if leftover:  # pool exhausted mid-wave: requeue in arrival order
            self.queue[:0] = leftover
        return caches, state

    def reset_stats(self) -> None:
        self.prefill_times.clear()
        self.prefill_prompt_lens.clear()
        self.prefill_dispatch_times.clear()
        self.prefill_batch_sizes.clear()
