"""Admission, slot lifecycle, and bucketed prefill for the serving engine.

The scheduler owns everything between "a request arrives" and "its slot
decodes": the FIFO queue, the slot → request map, and the prefill path
that computes a one-row cache and splices it into the device-resident
slot grid.

Three things changed versus the old monolithic engine:

* **Bucketed prefill** — prompts are padded to the next power-of-two
  bucket (≥ ``MIN_BUCKET``) instead of to ``max_len``, so a 12-token
  prompt pays a 16-token forward, not a ``max_len``-token one. One jit
  compilation per bucket (log₂ many), not per prompt length. Archs with
  recurrent state (rglru/mlstm/slstm blocks) still pad to ``max_len``:
  their prefill state integrates the padded tail, so the bucket length
  is part of the computation, and aligning it keeps prefill identical to
  the pre-refactor engine (see ``_bucketable``).
* **Metadata-driven cache splice** — the batch-slot axis of every cache
  leaf comes from :func:`repro.models.registry.cache_axes` (derived
  structurally from ``make_caches``), not from a runtime shape heuristic
  that mis-matched when a model dim collided with the slot count. The
  splice is a jitted ``dynamic_update_slice`` that donates the grid, so
  admission never rewrites the whole KV grid at Python level.
* **Device-side admission** — the first sampled token goes straight into
  the :class:`~repro.serving.state.DecodeState` on device (one jitted
  update); the old per-admission ``int(argmax(...))`` host sync is gone.

K/V written by a shorter bucket leave the grid row's tail stale; the
spliced ``pos`` leaves mark it ``-1`` (invalid), which the decode
attention masks — same invariant the ring buffer relies on.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import registry as REG
from repro.serving import sampler as SMP
from repro.serving.state import DecodeState, admit_slot

PyTree = Any

MIN_BUCKET = 8


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    finished_at: float = 0.0

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens


def _bucketable(arch: ArchConfig) -> bool:
    """True when prefill length is free to vary per request: every block
    is plain attention and no sliding window truncates the cache. Archs
    with recurrent state integrate the padded tail into their prefill
    state, and windowed caches change ring geometry with length — both
    pin the bucket to ``max_len``."""
    if arch.family == "encdec":
        return False
    from repro.models import lm as LM
    prefix, repeats, suffix = LM.stack_structure(arch)
    kinds = set(prefix) | set(suffix) | (set(LM._pattern(arch)) if repeats else set())
    # the window check is defensive: today only `hybrid` archs get
    # windowed caches, but a windowed cache row built at bucket length
    # would have a different ring geometry than the max_len grid
    return (kinds <= {"attn"} and arch.family != "hybrid"
            and not getattr(arch, "window", 0))


def bucket_len(prompt_len: int, max_len: int, *, aligned: bool,
               min_bucket: int = MIN_BUCKET) -> int:
    """Power-of-two bucket ≥ prompt_len, clamped to ``max_len``."""
    if aligned:
        return max_len
    b = min_bucket
    while b < prompt_len:
        b *= 2
    return min(b, max_len)


def _leaf_key(path) -> Optional[str]:
    return getattr(path[-1], "key", None) if path else None


def mesh_jit(mesh, fn, **kw):
    """jit ``fn`` under the plan's mesh context when one is bound (the
    single place the serving package enters a mesh to compile)."""
    if mesh is not None:
        with mesh:
            return jax.jit(fn, **kw)
    return jax.jit(fn, **kw)


def splice_row(grid: PyTree, row: PyTree, slot, axes: PyTree) -> PyTree:
    """Write a batch-1 prefill row into ``grid`` at ``slot``.

    ``axes`` is the :func:`repro.models.registry.cache_axes` tree: the
    batch axis is explicit per leaf (never guessed from shapes). Rows may
    be shorter than the grid on their length axis (bucketed prefill);
    ``pos`` leaves are padded with ``-1`` so the stale K/V tail of the
    grid row stays masked, other leaves leave the tail untouched.
    Jit-friendly: ``slot`` may be a traced scalar.
    """

    def one(path, g, r, ax):
        if ax.batch is None or g.ndim == 0:
            return g
        r = r.astype(g.dtype)
        if ax.length is not None and r.shape[ax.length] < g.shape[ax.length]:
            if _leaf_key(path) == "pos":
                pad = [(0, 0)] * r.ndim
                pad[ax.length] = (0, g.shape[ax.length] - r.shape[ax.length])
                r = jnp.pad(r, pad, constant_values=-1)
        starts = [0] * g.ndim
        starts[ax.batch] = slot
        return jax.lax.dynamic_update_slice(g, r, tuple(starts))

    return jax.tree_util.tree_map_with_path(one, grid, row, axes)


def invalidate_padding(row: PyTree, true_len, axes: PyTree) -> PyTree:
    """Mark ``pos`` entries at-or-beyond the true prompt length invalid
    (``-1``) — the in-bucket analog of the splice's tail padding.

    The mask compares the stored position *value*, not the ring index:
    windowed caches keep the last ``window`` positions, so index ``i``
    does not hold position ``i`` there. For full-length caches the two
    coincide (prefill stores position ``i`` at index ``i``); already
    invalid entries (``-1``) stay invalid either way."""

    def one(path, leaf, ax):
        if _leaf_key(path) != "pos" or ax.length is None:
            return leaf
        return jnp.where(leaf < true_len, leaf, -1)

    return jax.tree_util.tree_map_with_path(one, row, axes)


class Scheduler:
    """Host-side slot lifecycle; all device mutation goes through jits.

    The engine threads ``(caches, state)`` through :meth:`admit`; the
    scheduler never holds device buffers itself, so donation stays linear
    (exactly one live reference to the grid at any time).
    """

    def __init__(self, arch: ArchConfig, *, slots: int, max_len: int,
                 cache_dtype, mesh=None, sampling: SMP.SamplingParams = SMP.GREEDY,
                 min_bucket: int = MIN_BUCKET):
        self.arch = arch
        self.slots = slots
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.mesh = mesh
        self.sampling = sampling
        self.min_bucket = min_bucket
        self.aligned = not _bucketable(arch)
        self.cache_axes = REG.cache_axes(arch, cache_dtype)
        self.queue: List[Request] = []
        self.active: Dict[int, Optional[Request]] = {i: None for i in range(slots)}
        self._prefill_fns: Dict[int, Callable] = {}
        self._splice_fn: Optional[Callable] = None
        self._admit_fn: Optional[Callable] = None
        # prefill telemetry: host wall per admission (dispatch + splice
        # enqueue — the serving loop's critical-path cost; the prefill
        # compute itself overlaps the running decode grid)
        self.prefill_times = deque(maxlen=4096)
        self.prefill_prompt_lens = deque(maxlen=4096)

    # ------------------------------ queue ------------------------------
    def submit(self, req: Request) -> None:
        if len(req.prompt) > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} exceeds "
                f"max_len {self.max_len}")
        req.submitted_at = time.time()
        self.queue.append(req)

    def has_active(self) -> bool:
        return any(r is not None for r in self.active.values())

    # -------------------------- jit factories --------------------------
    def _jit(self, fn, **kw):
        return mesh_jit(self.mesh, fn, **kw)

    def _get_prefill(self, bucket: int) -> Callable:
        fn = self._prefill_fns.get(bucket)
        if fn is None:
            from repro.models import lm as LM
            axes = self.cache_axes

            def prefill(params, tokens, true_len):
                caches = REG.make_caches(self.arch, 1, bucket, self.cache_dtype)
                hidden, row = LM.forward(self.arch, params, tokens,
                                         caches=caches)
                h_last = jax.lax.dynamic_slice_in_dim(hidden, true_len - 1, 1,
                                                      axis=1)
                logits = LM.logits_fn(self.arch, params, h_last)
                return invalidate_padding(row, true_len, axes), logits

            fn = self._prefill_fns[bucket] = self._jit(prefill)
        return fn

    def _get_splice(self) -> Callable:
        if self._splice_fn is None:
            axes = self.cache_axes
            self._splice_fn = self._jit(
                lambda grid, row, slot: splice_row(grid, row, slot, axes),
                donate_argnums=(0,))
        return self._splice_fn

    def _get_admit(self) -> Callable:
        if self._admit_fn is None:
            sampling = self.sampling

            def admit(state, slot, logits, position, max_new):
                key = jax.lax.dynamic_index_in_dim(state.rng, slot, axis=0,
                                                   keepdims=False)
                rng, tok = SMP.sample(logits[:, -1], key[None], sampling)
                return admit_slot(state, slot, tok[0], position, max_new,
                                  rng[0])

            self._admit_fn = self._jit(admit, donate_argnums=(0,))
        return self._admit_fn

    # ---------------------------- admission ----------------------------
    def admit(self, params, caches, state: DecodeState):
        """Fill free slots from the queue; returns updated (caches, state).

        Pure dispatch: prefill, splice and state update are enqueued on
        the device stream and overlap the in-flight decode step — the
        serving-loop analog of the paper's §4.3 transfer/compute overlap.
        """
        for slot, occupant in self.active.items():
            if occupant is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            t0 = time.perf_counter()
            s = len(req.prompt)
            bucket = bucket_len(s, self.max_len, aligned=self.aligned,
                                min_bucket=self.min_bucket)
            toks = np.zeros((1, bucket), np.int32)
            toks[0, :s] = req.prompt
            row, logits = self._get_prefill(bucket)(
                params, jnp.asarray(toks), jnp.int32(s))
            caches = self._get_splice()(caches, row, jnp.int32(slot))
            state = self._get_admit()(state, jnp.int32(slot), logits,
                                      jnp.int32(s), jnp.int32(req.max_new_tokens))
            self.active[slot] = req
            self.prefill_times.append(time.perf_counter() - t0)
            self.prefill_prompt_lens.append(s)
        return caches, state

    def reset_stats(self) -> None:
        self.prefill_times.clear()
        self.prefill_prompt_lens.clear()
