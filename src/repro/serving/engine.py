"""Batched serving engine: slot-based KV cache + continuous-batching admission.

Real-time inference is the paper's target regime (ultra-low batch,
deterministic latency). The engine keeps a fixed grid of batch slots; each
slot holds one request's progress. Admission fills free slots between
decode steps (continuous batching); the decode step itself is one jitted
``serve_step`` over the whole grid, so device work is a fixed-shape
program — the deterministic-latency property the paper argues FPGAs (and
TPUs) have over GPUs (§1).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.execution_plan import ExecutionPlan
from repro.models import registry as REG


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    finished_at: float = 0.0

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens


class ServingEngine:
    """Plan-aware construction takes an :class:`ExecutionPlan` first::

        engine = ServingEngine(plan, params, slots=4, max_len=128)

    which places params and the cache grid with the plan's NamedShardings
    and jits the decode step under the plan's mesh. Passing an
    ``ArchConfig`` first is the original (unsharded) construction and
    remains supported.
    """

    def __init__(self, arch, params, *, slots: int, max_len: int,
                 ctx=None, eos_id: Optional[int] = None, dtype=jnp.float32,
                 on_step: Optional[Callable[[Dict[str, float]], None]] = None):
        self.plan: Optional[ExecutionPlan] = None
        self.mesh = None
        if isinstance(arch, ExecutionPlan):
            self.plan = arch
            exe = self.plan.compile()
            arch = self.plan.arch
            ctx = exe.ctx if ctx is None else ctx
            self.mesh = exe.mesh
        self.arch: ArchConfig = arch
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.caches = REG.make_caches(arch, slots, max_len, dtype)
        if self.plan is not None:
            params = jax.device_put(
                params, self.plan.param_shardings(params, self.mesh))
            self.caches = jax.device_put(
                self.caches, self.plan.cache_shardings(self.caches, self.mesh))
            with self.mesh:
                self.serve_step = jax.jit(REG.build_serve_step(arch, ctx))
        else:
            self.serve_step = jax.jit(REG.build_serve_step(arch, ctx))
        self.params = params
        self.active: Dict[int, Optional[Request]] = {i: None for i in range(slots)}
        self.positions = np.zeros((slots, 1), np.int32)
        self.tokens = np.zeros((slots, 1), np.int32)
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        # per-slot prefill (single-row) jitted once
        self._prefill_cache_fn = None
        # step-timing hooks (repro.bench serve scenarios read these):
        # wall seconds per decode step and tokens emitted per step, plus
        # wall seconds per request prefill (the admission-path latency the
        # prefill_latency bench scenario gates on).
        # Bounded deques: stats cover a sliding window of the most recent
        # steps so a long-lived engine's telemetry cannot grow unbounded.
        from collections import deque
        self.on_step = on_step
        self.step_times = deque(maxlen=4096)
        self.step_token_counts = deque(maxlen=4096)
        self.prefill_times = deque(maxlen=4096)
        self.prefill_prompt_lens = deque(maxlen=4096)

    # ---------------------------- admission ----------------------------
    def submit(self, req: Request):
        req.submitted_at = time.time()
        self.queue.append(req)

    def _admit(self):
        for slot, occupant in self.active.items():
            if occupant is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            self._prefill_slot(slot, req)
            self.active[slot] = req

    def _prefill_slot(self, slot: int, req: Request):
        """Prefill one request and splice its cache into the slot grid.

        Prompts are right-padded to ``max_len`` (one compilation); the
        next-token logits are taken at the true last prompt position, and
        padded cache slots are invalidated. Note: recurrent-state archs
        (rglru/xlstm) need length-aligned prompts — their prefill state is
        computed over the padded tail; attention archs are exact.
        """
        t0 = time.perf_counter()
        s = len(req.prompt)
        if self._prefill_cache_fn is None:
            from repro.models import lm as LM
            dtype = jax.tree.leaves(self.caches)[0].dtype

            def prefill(params, tokens, last_idx):
                caches = REG.make_caches(self.arch, 1, self.max_len, dtype)
                hidden, caches = LM.forward(self.arch, params, tokens,
                                            caches=caches)
                h_last = jax.lax.dynamic_slice_in_dim(hidden, last_idx, 1, axis=1)
                return caches, LM.logits_fn(self.arch, params, h_last)

            self._prefill_cache_fn = jax.jit(prefill)
        toks = np.zeros((1, self.max_len), np.int32)
        toks[0, :s] = req.prompt
        row_cache, logits = self._prefill_cache_fn(
            self.params, jnp.asarray(toks), jnp.int32(s - 1))
        # mark cache slots beyond the true prompt length invalid (pos = -1)
        def fix_pos(path, leaf):
            key = getattr(path[-1], "key", None)
            if key == "pos" and leaf.ndim >= 1 and leaf.shape[-1] == self.max_len:
                rng = jnp.arange(self.max_len)
                return jnp.where(rng[None, :] < s if leaf.ndim == 2 else rng < s,
                                 leaf, -1)
            return leaf
        row_cache = jax.tree_util.tree_map_with_path(fix_pos, row_cache)
        # row_cache leaves have batch dim 1 at the same position as grid's slots
        self.caches = jax.tree.map(_splice_leaf(slot, self.slots), self.caches, row_cache)
        self.tokens[slot, 0] = int(jnp.argmax(logits[0, -1]))  # device sync
        self.positions[slot, 0] = s
        self.prefill_times.append(time.perf_counter() - t0)
        self.prefill_prompt_lens.append(s)

    # ---------------------------- decode loop ----------------------------
    def step(self):
        t0 = time.perf_counter()
        self._admit()
        batch = {"tokens": jnp.asarray(self.tokens),
                 "positions": jnp.asarray(self.positions)}
        next_tok, self.caches = self.serve_step(self.params, self.caches, batch)
        next_np = np.asarray(next_tok)  # forces device sync
        emitted = 0
        freed = False
        for slot, req in self.active.items():
            if req is None:
                continue
            tok = int(self.tokens[slot, 0])
            if self.eos_id is not None and tok == self.eos_id:
                # EOS straight out of prefill: stop before emitting anything.
                self._finish(slot, req)
                freed = True
                continue
            req.out_tokens.append(tok)
            emitted += 1
            nxt = int(next_np[slot])
            if req.done or (self.eos_id is not None and nxt == self.eos_id):
                # EOS is a stop signal, not an output token: it neither
                # enters out_tokens nor counts toward max_new_tokens, and it
                # is detected the step it is generated (no extra decode).
                self._finish(slot, req)
                freed = True
                continue
            self.tokens[slot, 0] = nxt
            self.positions[slot, 0] += 1
        if freed and self.queue:
            # re-admit into the slots freed above so the next decode step
            # runs at full occupancy (no idle-slot bubble).
            self._admit()
        wall = time.perf_counter() - t0
        self.step_times.append(wall)
        self.step_token_counts.append(emitted)
        if self.on_step is not None:
            self.on_step({"step": len(self.step_times) - 1,
                          "wall_s": wall, "tokens": emitted})

    # ------------------------- step-timing hooks -------------------------
    def reset_step_stats(self):
        """Drop recorded step/prefill timings (e.g. after a jit warmup pass)."""
        self.step_times.clear()
        self.step_token_counts.clear()
        self.prefill_times.clear()
        self.prefill_prompt_lens.clear()

    def step_stats(self) -> Dict[str, float]:
        """p50/p95 decode-step wall time and aggregate token throughput."""
        from repro.core.stats import percentile
        ms = [t * 1e3 for t in self.step_times]
        total_s = sum(self.step_times)
        toks = sum(self.step_token_counts)
        return {
            "steps": float(len(ms)),
            "step_p50_ms": percentile(ms, 50),
            "step_p95_ms": percentile(ms, 95),
            "step_mean_ms": (sum(ms) / len(ms)) if ms else 0.0,
            "tokens": float(toks),
            "tokens_per_s": toks / total_s if total_s > 0 else 0.0,
        }

    def prefill_stats(self) -> Dict[str, float]:
        """p50/p95 per-request prefill wall time (admission path)."""
        from repro.core.stats import percentile
        ms = [t * 1e3 for t in self.prefill_times]
        lens = list(self.prefill_prompt_lens)
        return {
            "prefills": float(len(ms)),
            "prefill_p50_ms": percentile(ms, 50),
            "prefill_p95_ms": percentile(ms, 95),
            "prefill_mean_ms": (sum(ms) / len(ms)) if ms else 0.0,
            "prompt_tokens": float(sum(lens)),
            "prefill_tokens_per_s": (sum(lens) / (sum(self.prefill_times) or 1.0)
                                     if ms else 0.0),
        }

    def _finish(self, slot: int, req: Request):
        req.finished_at = time.time()
        self.completed.append(req)
        self.active[slot] = None

    def run_until_drained(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(r is not None for r in self.active.values())) \
                and steps < max_steps:
            self.step()
            steps += 1
        return steps


def _splice_leaf(slot: int, slots: int):
    def f(grid, row):
        if not hasattr(grid, "ndim") or grid.ndim == 0:
            return grid
        # find the batch axis: the axis where grid has `slots` and row has 1
        for ax in range(grid.ndim):
            if grid.shape[ax] == slots and ax < row.ndim and row.shape[ax] == 1:
                idx = [slice(None)] * grid.ndim
                idx[ax] = slot
                return grid.at[tuple(idx)].set(jnp.take(row, 0, axis=ax))
        return grid
    return f
