"""Device-resident serving engine: lookahead dispatch over a slot grid.

The engine is the thin top of the ``serving`` package (see also
``state.py`` / ``sampler.py`` / ``scheduler.py``): it wires the plan, the
fused jitted ``serve_step`` (donated caches + :class:`DecodeState`, see
``models.registry.build_serve_step``), and the scheduler together, and
runs **one-step-lookahead dispatch** — the serving-loop analog of the
paper's §4.3 tile double buffering. Step *N+1* is dispatched before step
*N*'s per-step record is read back, so the host's Python bookkeeping
overlaps the device's decode compute instead of serialising with it:

    step N:    [retire N-2] [admit] [dispatch N] ──┐ device runs N
    step N+1:  [retire N-1] [admit] [dispatch N+1] ┘ host never waits

Public surface (unchanged from the monolithic engine): construct with an
:class:`~repro.core.execution_plan.ExecutionPlan` first, then
``submit`` / ``step`` / ``run_until_drained`` and the ``step_stats`` /
``prefill_stats`` telemetry hooks. The old ``ServingEngine(arch, ...)``
construction still works but is deprecated (it routes through the same
scheduler, unsharded).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.execution_plan import ExecutionPlan
from repro.models import registry as REG
from repro.quant import dequantize_params, quantize_params
from repro.serving.config import PagingConfig, ServeConfig
from repro.serving.pages import DEFAULT_PAGE_SIZE as PG_DEFAULT
from repro.serving.sampler import GREEDY, SamplingParams
from repro.serving.scheduler import Request, Scheduler, mesh_jit
from repro.serving.state import DecodeState, decode_state_dims, make_decode_state

__all__ = ["ServingEngine", "Request", "SamplingParams", "DecodeState",
           "IncompleteDrainError", "MigrationReport", "ServeConfig"]


@dataclasses.dataclass(frozen=True)
class MigrationReport:
    """One plan→plan live migration (``ServingEngine.migrate``).

    Byte fields follow the disagg transfer accounting: ``*_moved_bytes``
    are the logical bytes of leaves whose sharding actually changed
    (a leaf equivalently placed on both plans is a no-op ``device_put``
    and counts as kept); ``dst_shard_bytes`` is the analytic per-device
    total the destination placement implies, reconciled against
    ``actual_shard_bytes`` read back from the committed arrays within the
    disagg tolerance band."""

    from_axes: tuple
    to_axes: tuple
    stall_s: float             # wall from migrate() entry to transfer done
    flushed_records: int       # lookahead records retired before the move
    active_slots: int          # in-flight streams carried across
    drained_slots: int         # of those, slots whose rows physically moved
    params_moved_bytes: int
    caches_moved_bytes: int
    state_moved_bytes: int
    logical_bytes: int         # Σ global bytes over params + caches + state
    moved_bytes: int           # Σ logical bytes that physically moved
    dst_shard_bytes: int       # analytic bytes landed across all devices
    actual_shard_bytes: int    # committed bytes read back after the put
    verified: bool


class IncompleteDrainError(RuntimeError):
    """``run_until_drained`` hit ``max_steps`` with requests in flight."""

    def __init__(self, msg: str, unfinished: List[int]):
        super().__init__(msg)
        self.unfinished = unfinished


def _record_ready(rec) -> bool:
    """True when every leaf of a step record has finished on device
    (non-blocking; conservatively False if the runtime lacks is_ready)."""
    try:
        return all(leaf.is_ready() for leaf in jax.tree.leaves(rec))
    except AttributeError:
        return False


class ServingEngine:
    """Plan-aware construction takes an :class:`ExecutionPlan` first::

        engine = ServingEngine(plan, params,
                               config=ServeConfig(slots=4, max_len=128))

    which places params, the cache grid and the decode state with the
    plan's NamedShardings and jits the fused decode step under the plan's
    mesh. ``sampling`` selects on-device token choice (default greedy);
    ``lookahead`` is the dispatch depth (1 = double-buffered, 0 =
    synchronous like the old engine).

    Passing an ``ArchConfig`` first is the legacy (unsharded)
    construction: still supported, now with a ``DeprecationWarning``.
    """

    def __init__(self, arch, params, *, config: Optional[ServeConfig] = None,
                 slots: Optional[int] = None, max_len: Optional[int] = None,
                 ctx=None, eos_id: Optional[int] = None, dtype=jnp.float32,
                 on_step: Optional[Callable[[Dict[str, float]], None]] = None,
                 sampling: Optional[SamplingParams] = None,
                 lookahead: Optional[int] = None, seed: Optional[int] = None,
                 max_src_len: Optional[int] = None,
                 paged: Optional[bool] = None,
                 page_size: Optional[int] = None,
                 kv_pages: Optional[int] = None,
                 prefix_cache: Optional[bool] = None):
        import dataclasses as _dc
        if config is None:
            if slots is None or max_len is None:
                raise TypeError("ServingEngine needs config=ServeConfig(...) "
                                "or explicit slots=/max_len=")
            config = ServeConfig(
                slots=slots, max_len=max_len, eos_id=eos_id,
                seed=0 if seed is None else seed, sampling=sampling,
                lookahead=1 if lookahead is None else lookahead,
                max_src_len=max_src_len,
                paging=PagingConfig(
                    paged=bool(paged), page_size=page_size, kv_pages=kv_pages,
                    prefix_cache=(True if prefix_cache is None
                                  else prefix_cache)))
        elif any(v is not None for v in (slots, max_len, eos_id, sampling,
                                         lookahead, seed, max_src_len, paged,
                                         page_size, kv_pages, prefix_cache)):
            raise TypeError("ServingEngine: pass either config= or the flat "
                            "serve kwargs, not both")
        config = config.resolve()
        slots, max_len = config.slots, config.max_len
        seed = config.seed
        spec = config.spec
        if spec is not None and config.disagg is not None:
            raise NotImplementedError(
                "speculative decoding does not compose with disaggregated "
                "serving yet: the draft's prompt KV would have to stream "
                "across role slices alongside the target's")
        self.plan: Optional[ExecutionPlan] = None
        self.mesh = None
        if isinstance(arch, ExecutionPlan):
            self.plan = arch
            exe = self.plan.compile()
            arch = self.plan.arch
            ctx = exe.ctx if ctx is None else ctx
            self.mesh = exe.mesh
        else:
            warnings.warn(
                "ServingEngine(arch, ...) construction is deprecated; plan "
                "the cell and use ExecutionPlan.compile().serve(...) (or "
                "pass the ExecutionPlan first) so params and caches are "
                "placed with the plan's shardings",
                DeprecationWarning, stacklevel=2)
        self.arch: ArchConfig = arch
        self.slots = slots
        self.max_len = max_len
        self.max_src_len = config.max_src_len
        self.eos_id = config.eos_id
        self.sampling = config.sampling
        self.lookahead = config.lookahead
        paged = config.paging.paged
        self.paged = paged
        self.quant = config.quant
        if spec is not None and spec.draft is None:
            draft = self.plan.draft if self.plan is not None else None
            if draft is None:
                raise ValueError(
                    "ServeConfig.spec set but no draft arch: pass "
                    "SpecConfig(draft=...) or plan the cell with "
                    "repro.plan(..., draft=...)")
            spec = _dc.replace(spec, draft=draft)
            config = _dc.replace(config, spec=spec)
        self.spec = spec
        if spec is not None and not (isinstance(params, dict)
                                     and set(params) == {"target", "draft"}):
            raise TypeError(
                "speculative serving takes params as "
                "{'target': <target tree>, 'draft': <draft tree>} "
                "(Executable.serve builds the pair for you)")
        is_encdec = arch.family == "encdec"
        if paged:
            from repro.serving import pages as PG
            PG.check_paged_supported(arch)
            self.page_size = config.paging.page_size or PG.DEFAULT_PAGE_SIZE
            self.kv_pages = (config.paging.kv_pages
                             if config.paging.kv_pages is not None else
                             PG.default_kv_pages(slots, max_len,
                                                 self.page_size))
            table_len = PG.num_pages_per_slot(max_len, self.page_size)
            self.caches = PG.make_paged_caches(arch, self.kv_pages,
                                               self.page_size, dtype,
                                               kv_quant=self.quant.quant_kv)
        else:
            self.page_size = config.paging.page_size
            self.kv_pages = config.paging.kv_pages
            table_len = None
            self.caches = REG.make_caches(arch, slots, max_len, dtype,
                                          kv_quant=self.quant.quant_kv)
        # the resolved surface (page geometry made concrete) — what
        # `engine.config` exposes
        self.config: ServeConfig = _dc.replace(
            config, paging=_dc.replace(config.paging,
                                       page_size=self.page_size,
                                       kv_pages=self.kv_pages))
        # speculative decoding: the draft's dense KV grid rides inside the
        # DecodeState (threaded through the donated fused step alongside
        # the target caches); the draft always runs dense + full-precision
        draft_caches = draft_dims = None
        if spec is not None:
            draft_caches = REG.make_caches(spec.draft, slots, max_len, dtype)
            draft_dims = REG.cache_dims(spec.draft)
        self.state = make_decode_state(
            slots, seed,
            enc_shape=(self.max_src_len, arch.d_model) if is_encdec else None,
            enc_dtype=dtype, table_len=table_len, draft_caches=draft_caches)
        if self.plan is not None:
            from repro.core.xfer import tree_shardings
            if spec is not None:
                # target params take the plan's shardings; the draft is
                # small by construction and stays replicated (its dims
                # resolve under the same ctx — non-dividing axes drop)
                params = {"target": jax.device_put(
                    params["target"],
                    self.plan.param_shardings(params["target"], self.mesh)),
                    "draft": params["draft"]}
            else:
                params = jax.device_put(
                    params, self.plan.param_shardings(params, self.mesh))
            if not paged:
                # page pools have no slot axis, so the plan's dense cache
                # shardings don't apply; the jitted step lets the compiler
                # place them (gathered reads are resharded on the fly)
                self.caches = jax.device_put(
                    self.caches,
                    self.plan.cache_shardings(self.caches, self.mesh))
            self.state = jax.device_put(
                self.state, tree_shardings(self.plan.ctx(self.mesh),
                                           self.state,
                                           decode_state_dims(
                                               enc=is_encdec, paged=paged,
                                               draft_dims=draft_dims)))
        if self.quant.quant_weights:
            # int8 weights stay HBM-resident; every step (prefill and
            # decode alike) rehydrates a transient fp working copy inside
            # its own jit. Quantising on device keeps the placed shardings
            # (the QTensor's int8 leaf inherits the param's placement).
            # Spec engines quantise only the target: a draft cheap enough
            # to speculate with gains nothing from int8 residency.
            if spec is not None:
                params = dict(params, target=mesh_jit(
                    self.mesh, quantize_params)(params["target"]))
            else:
                params = mesh_jit(self.mesh, quantize_params)(params)
        self.params = params
        step_fn = REG.build_serve_step(arch, ctx, sampling=self.sampling,
                                       eos_id=self.eos_id, paged=paged,
                                       spec=spec)
        if self.quant.quant_weights:
            inner_step = step_fn
            if spec is not None:
                step_fn = (lambda params, caches, state:
                           inner_step({"target":
                                       dequantize_params(params["target"]),
                                       "draft": params["draft"]},
                                      caches, state))
            else:
                step_fn = (lambda params, caches, state:
                           inner_step(dequantize_params(params), caches,
                                      state))
        # caches and state are donated: the per-step KV-grid copy the old
        # engine paid (fresh output buffers every step) goes away.
        self._serve_step = mesh_jit(self.mesh, step_fn, donate_argnums=(1, 2))
        self.scheduler = Scheduler(arch, slots=slots, max_len=max_len,
                                   cache_dtype=dtype, mesh=self.mesh,
                                   sampling=self.sampling,
                                   max_src_len=self.max_src_len,
                                   paged=paged,
                                   page_size=(self.page_size if paged
                                              else PG_DEFAULT),
                                   kv_pages=self.kv_pages,
                                   prefix_cache=self.config.paging.prefix_cache,
                                   quant=self.quant, seed=seed,
                                   spec_draft=(spec.draft if spec is not None
                                               else None))
        self.completed: List[Request] = []
        self._pending: deque = deque()  # dispatched, unread step records
        # elastic serving: migrate() appends a MigrationReport per resize;
        # Executable.serve attaches a runtime.elastic.LoadController here
        # when ServeConfig.elastic is set (see maybe_resize())
        self.migrations: List[MigrationReport] = []
        self.elastic = None
        # step-timing hooks (repro.bench serve scenarios read these):
        # wall seconds per step() call and tokens retired per call, plus
        # host admission-path wall per prefill. Bounded deques: telemetry
        # covers a sliding window so long-lived engines stay bounded.
        self.on_step = on_step
        self.step_times = deque(maxlen=4096)
        self.step_token_counts = deque(maxlen=4096)
        # queue backlog per step() call, and per-retire commit accounting
        # (emitted tokens vs active slot-steps — the speculative
        # acceptance telemetry; exactly 1.0 on a non-spec engine except
        # for EOS-at-prefill slots)
        self.queue_depths = deque(maxlen=4096)
        self.retired_emits = deque(maxlen=4096)
        self.retired_active = deque(maxlen=4096)

    # ------------------------- queue / slot views -------------------------
    @property
    def queue(self) -> List[Request]:
        return self.scheduler.queue

    @property
    def active(self) -> Dict[int, Optional[Request]]:
        return self.scheduler.active

    @property
    def prefill_times(self):
        return self.scheduler.prefill_times

    @property
    def prefill_prompt_lens(self):
        return self.scheduler.prefill_prompt_lens

    def submit(self, req: Request):
        self.scheduler.submit(req)

    def unfinished(self) -> List[int]:
        """rids still queued or decoding (including unretired records)."""
        rids = [r.rid for r in self.queue]
        rids += [r.rid for r in self.active.values() if r is not None]
        return rids

    # ---------------------------- decode loop ----------------------------
    def step(self):
        """One serving-loop iteration: retire the record(s) that fell out
        of the lookahead window, admit into the freed slots, dispatch the
        next fused decode step."""
        t0 = time.perf_counter()
        self.queue_depths.append(len(self.queue))
        emitted = 0
        while len(self._pending) > self.lookahead:
            emitted += self._retire_one()
        # opportunistic early retire: a record whose device work already
        # completed costs nothing to read now, and freeing its finished
        # slots one step earlier avoids idle-slot decode steps under
        # churn. Records still inside the lookahead window are only ever
        # read when ready — the loop never blocks here.
        while self._pending and _record_ready(self._pending[0]):
            emitted += self._retire_one()
        self.caches, self.state = self.scheduler.admit(
            self.params, self.caches, self.state)
        state, caches, record = self._serve_step(self.params, self.caches,
                                                 self.state)
        self.state, self.caches = state, caches
        self._pending.append(record)
        if self.lookahead == 0:
            while self._pending:
                emitted += self._retire_one()
        wall = time.perf_counter() - t0
        self.step_times.append(wall)
        self.step_token_counts.append(emitted)
        if self.on_step is not None:
            self.on_step({"step": len(self.step_times) - 1,
                          "wall_s": wall, "tokens": emitted})

    def _retire_one(self) -> int:
        """Read one step record back (the only host↔device sync in the
        loop) and apply it: append emitted tokens, free finished slots.

        Speculative steps return 2-D ``token``/``emit`` ([slots, k+1] —
        up to ``k+1`` commits per slot per step); the plain step's 1-D
        record is handled as the single-column case."""
        rec = self._pending.popleft()
        token = np.asarray(rec["token"])
        emit = np.asarray(rec["emit"])
        finished = np.asarray(rec["finished"])
        if token.ndim == 1:
            token = token[:, None]
            emit = emit[:, None]
        # emit.any(1) | finished == active-at-dispatch (an active slot
        # either emits or finishes without emitting: EOS at prefill)
        self.retired_emits.append(int(emit.sum()))
        self.retired_active.append(int((emit.any(axis=1) | finished).sum()))
        count = 0
        for slot, req in self.active.items():
            if req is None:
                continue
            for j in range(token.shape[1]):
                if emit[slot, j]:
                    req.out_tokens.append(int(token[slot, j]))
                    count += 1
            if finished[slot]:
                req.finished_at = time.time()
                self.completed.append(req)
                self.active[slot] = None
                if self.paged:
                    self.scheduler.release_slot(slot)
        return count

    def _flush(self) -> int:
        count = 0
        while self._pending:
            count += self._retire_one()
        return count

    # ----------------------- elastic live migration -----------------------
    def migrate(self, new_plan: ExecutionPlan, *,
                verify: bool = True) -> MigrationReport:
        """Live plan→plan migration: move this deployment onto
        ``new_plan``'s mesh without dropping streams.

        The resharded transfer is *derived* from the two plans'
        ``NamedSharding``\\ s (``core.execution_plan.reshard_transfer``):
        params, the KV cache grid and the in-flight :class:`DecodeState`
        are ``device_put`` onto the destination placements — a leaf whose
        placement is equivalent on both plans does not physically move,
        so only the slots whose pages/rows must move are drained through
        the transfer. Host bookkeeping (queue, active slot map, page
        pool, prefix registry, per-request PRNG seeding) is
        mesh-independent and carries over untouched; the fused step and
        the scheduler's prefill/splice/admit jits are rebuilt lazily on
        the new mesh. Greedy token streams are bit-exact across the move
        (the plan-invariance property ``serving_equiv --replan``
        certifies).

        ``verify`` reconciles the analytic destination shard bytes
        against the committed arrays within the disagg transfer band
        (``serving.disagg.XFER_LOWER_TOL`` / ``XFER_UPPER_FACTOR``) and
        raises on a mismatch. Returns the :class:`MigrationReport`
        (also appended to ``self.migrations``).
        """
        import dataclasses as _dc
        from jax.sharding import NamedSharding, PartitionSpec
        from repro.core.execution_plan import reshard_transfer
        from repro.core.xfer import tree_shardings
        from repro.serving.state import active_slots as _active_slots

        if self.plan is None:
            raise ValueError(
                "migrate() needs a plan-constructed engine (build with "
                "repro.plan(...).compile().serve(...)); the deprecated "
                "ServingEngine(arch, ...) construction has no source plan")
        if self.scheduler.worker is not None:
            raise NotImplementedError(
                "migrating a disaggregated deployment would re-split the "
                "prefill/decode role slices; migrate the fused engine")
        if new_plan.arch != self.arch:
            raise ValueError(
                f"migrate() cannot change the architecture: engine serves "
                f"{self.arch.name}, new plan is {new_plan.arch.name}")
        t0 = time.perf_counter()
        # read back every dispatched-but-unread record first: host
        # bookkeeping must be current before rows move, and old-mesh
        # record buffers must not be read after their grid is donated on
        # the new mesh
        flushed = len(self._pending)
        self._flush()
        exe = new_plan.compile()
        new_mesh = exe.mesh
        ctx = exe.ctx
        in_flight = _active_slots(self.state)
        is_encdec = self.arch.family == "encdec"
        draft_dims = (REG.cache_dims(self.spec.draft)
                      if self.spec is not None else None)
        repl = lambda tree: jax.tree.map(
            lambda _: NamedSharding(new_mesh, PartitionSpec()), tree)

        # --- params: destination shardings from the new plan. int8
        # weights dequantize first (symmetric per-channel int8
        # round-trips exactly: the max-magnitude channel maps back to
        # ±127, so requantizing on the new mesh reproduces the same
        # ints), are placed as fp, and requantize under the new mesh —
        # the construction order, so int8 leaves inherit the placement.
        params = self.params
        requant = self.quant.quant_weights
        if requant:
            if self.spec is not None:
                params = dict(params, target=mesh_jit(
                    self.mesh, dequantize_params)(params["target"]))
            else:
                params = mesh_jit(self.mesh, dequantize_params)(params)
        if self.spec is not None:
            params_dst = {
                "target": new_plan.param_shardings(params["target"], new_mesh),
                "draft": repl(params["draft"])}
        else:
            params_dst = new_plan.param_shardings(params, new_mesh)
        # --- caches: dense grids take the plan's cache shardings; paged
        # pools have no slot axis (the jitted step lets the compiler
        # place them), so they cross replicated
        caches_dst = (repl(self.caches) if self.paged
                      else new_plan.cache_shardings(self.caches, new_mesh))
        state_dst = tree_shardings(
            new_plan.ctx(new_mesh), self.state,
            decode_state_dims(enc=is_encdec, paged=self.paged,
                              draft_dims=draft_dims))

        xp = reshard_transfer(params, params_dst)
        xc = reshard_transfer(self.caches, caches_dst)
        xs = reshard_transfer(self.state, state_dst)

        params = jax.device_put(params, params_dst)
        if requant:
            if self.spec is not None:
                params = dict(params, target=mesh_jit(
                    new_mesh, quantize_params)(params["target"]))
            else:
                params = mesh_jit(new_mesh, quantize_params)(params)
        self.params = params
        self.caches = jax.device_put(self.caches, caches_dst)
        self.state = jax.device_put(self.state, state_dst)
        jax.block_until_ready((self.params, self.caches, self.state))

        # --- reconcile: bytes actually committed across the new mesh vs
        # the analytic per-device shard bytes the placements imply (the
        # disagg verify_xfer band; shard-exact modulo padding)
        n_dev = int(np.prod(list(new_mesh.shape.values())))
        analytic = (xp.dst_shard_bytes + xc.dst_shard_bytes
                    + xs.dst_shard_bytes) * n_dev
        actual = sum(
            sum(s.data.nbytes for s in leaf.addressable_shards)
            for leaf in jax.tree.leaves(
                (self.params, self.caches, self.state))
            if hasattr(leaf, "addressable_shards"))
        from repro.serving.disagg import XFER_LOWER_TOL, XFER_UPPER_FACTOR
        verified = ((1.0 - XFER_LOWER_TOL) * analytic <= actual
                    <= XFER_UPPER_FACTOR * analytic)
        if verify and not verified:
            raise RuntimeError(
                f"migrate(): committed bytes {actual} outside the "
                f"[{1.0 - XFER_LOWER_TOL:.2f}x, {XFER_UPPER_FACTOR:.1f}x] "
                f"band of analytic {analytic} "
                f"({dict(self.plan.mesh_axes)} -> {dict(new_plan.mesh_axes)})")

        # --- resume the fused step on the new mesh; scheduler host state
        # survives, its jits rebuild lazily under the new mesh context
        step_fn = REG.build_serve_step(self.arch, ctx, sampling=self.sampling,
                                       eos_id=self.eos_id, paged=self.paged,
                                       spec=self.spec)
        if requant:
            inner_step = step_fn
            if self.spec is not None:
                step_fn = (lambda params, caches, state:
                           inner_step({"target":
                                       dequantize_params(params["target"]),
                                       "draft": params["draft"]},
                                      caches, state))
            else:
                step_fn = (lambda params, caches, state:
                           inner_step(dequantize_params(params), caches,
                                      state))
        self._serve_step = mesh_jit(new_mesh, step_fn, donate_argnums=(1, 2))
        self.scheduler.rebind_mesh(new_mesh)
        from_axes = tuple(self.plan.mesh_axes)
        self.plan = new_plan
        self.mesh = new_mesh
        report = MigrationReport(
            from_axes=from_axes, to_axes=tuple(new_plan.mesh_axes),
            stall_s=time.perf_counter() - t0,
            flushed_records=flushed,
            active_slots=len(in_flight),
            drained_slots=(len(in_flight)
                           if (xc.moved_leaves or xs.moved_leaves) else 0),
            params_moved_bytes=xp.moved_bytes,
            caches_moved_bytes=xc.moved_bytes,
            state_moved_bytes=xs.moved_bytes,
            logical_bytes=xp.logical_bytes + xc.logical_bytes
            + xs.logical_bytes,
            moved_bytes=xp.moved_bytes + xc.moved_bytes + xs.moved_bytes,
            dst_shard_bytes=analytic, actual_shard_bytes=actual,
            verified=verified)
        self.migrations.append(report)
        return report

    def maybe_resize(self):
        """One elastic-controller tick (no-op without
        ``ServeConfig(elastic=...)``): lets the attached
        ``runtime.elastic.LoadController`` act on the current telemetry.
        Returns the :class:`MigrationReport` when a resize happened."""
        if self.elastic is None:
            return None
        return self.elastic.observe()

    def migration_stats(self) -> Dict[str, float]:
        """Resize telemetry: count, stall percentiles, bytes moved."""
        from repro.core.stats import percentile
        stalls = [m.stall_s * 1e3 for m in self.migrations]
        return {
            "migrations": float(len(self.migrations)),
            "migration_stall_p50_ms": percentile(stalls, 50),
            "migration_stall_max_ms": max(stalls) if stalls else 0.0,
            "migration_moved_bytes": float(sum(m.moved_bytes
                                               for m in self.migrations)),
            "migration_logical_bytes": float(sum(m.logical_bytes
                                                 for m in self.migrations)),
        }

    def run_until_drained(self, max_steps: int = 10_000, *,
                          on_incomplete: str = "raise") -> int:
        """Step until every submitted request completed; returns the step
        count. Hitting ``max_steps`` with requests still in flight raises
        :class:`IncompleteDrainError` naming the unfinished rids (pass
        ``on_incomplete="warn"`` to degrade to a warning) — a hang must
        surface in tests and benches, not truncate silently.

        Step/prefill telemetry is reset on entry: ``step_stats()`` /
        ``prefill_stats()`` after a drain describe exactly that drain,
        however many drains the engine already ran."""
        if on_incomplete not in ("raise", "warn"):
            raise ValueError(f"on_incomplete must be 'raise' or 'warn', "
                             f"got {on_incomplete!r}")
        self.reset_step_stats()
        steps = 0
        while (self.queue or self.scheduler.has_active()) and steps < max_steps:
            self.step()
            steps += 1
            if not self.queue and not self.scheduler.has_active():
                self._flush()  # retire the trailing lookahead records
        if self.queue or self.scheduler.has_active():
            self._flush()
        if self.queue or self.scheduler.has_active():
            rids = self.unfinished()
            msg = (f"run_until_drained: {len(rids)} request(s) still in "
                   f"flight after {steps} steps (max_steps={max_steps}): "
                   f"rids={rids}")
            if on_incomplete == "raise":
                raise IncompleteDrainError(msg, rids)
            warnings.warn(msg, RuntimeWarning, stacklevel=2)
        return steps

    # ------------------------- step-timing hooks -------------------------
    def reset_step_stats(self):
        """Drop recorded step/prefill timings (e.g. after a jit warmup pass)."""
        self.step_times.clear()
        self.step_token_counts.clear()
        self.queue_depths.clear()
        self.retired_emits.clear()
        self.retired_active.clear()
        self.scheduler.reset_stats()

    def step_stats(self) -> Dict[str, float]:
        """p50/p95 decode-step wall time and aggregate token throughput.

        ``queue_depth`` is the mean backlog observed at step dispatch;
        ``accepted_tokens_mean`` is committed tokens per active slot-step
        (1.0 for plain decoding, up to ``k+1`` under speculation — the
        speedup lever). Speculative engines additionally report
        ``draft_acceptance``: accepted / proposed draft tokens over the
        currently-resident requests (device counters, zeroed at
        admission)."""
        from repro.core.stats import percentile
        ms = [t * 1e3 for t in self.step_times]
        total_s = sum(self.step_times)
        toks = sum(self.step_token_counts)
        qd = list(self.queue_depths)
        emits = sum(self.retired_emits)
        actives = sum(self.retired_active)
        stats = {
            "steps": float(len(ms)),
            "step_p50_ms": percentile(ms, 50),
            "step_p95_ms": percentile(ms, 95),
            "step_mean_ms": (sum(ms) / len(ms)) if ms else 0.0,
            "tokens": float(toks),
            "tokens_per_s": toks / total_s if total_s > 0 else 0.0,
            "queue_depth": (sum(qd) / len(qd)) if qd else 0.0,
            "accepted_tokens_mean": (emits / actives) if actives else 0.0,
        }
        if self.spec is not None and self.state.accepted is not None:
            acc = float(np.asarray(self.state.accepted).sum())
            prop = float(np.asarray(self.state.proposed).sum())
            stats["draft_acceptance"] = acc / prop if prop else 0.0
        return stats

    def prefill_stats(self) -> Dict[str, float]:
        """p50/p95 per-request admission wall time (host critical path:
        bucketed prefill dispatch + cache splice + state update; the
        prefill compute itself overlaps the in-flight decode step).

        Batched admission telemetry rides along: ``prefill_dispatches``
        counts device dispatch groups since the last reset (a same-bucket
        burst of N requests is **one** dispatch), ``admit_p50_ms`` /
        ``admit_p95_ms`` are per-dispatch wall percentiles, and
        ``prefill_batch_mean`` is the mean requests-per-dispatch.
        ``prefix_hit_rate`` is the fraction of prefix-registry lookups
        that aliased shared pages (0.0 on non-paged engines)."""
        from repro.core.stats import percentile
        sched = self.scheduler
        ms = [t * 1e3 for t in self.prefill_times]
        lens = list(self.prefill_prompt_lens)
        disp_ms = [t * 1e3 for t in sched.prefill_dispatch_times]
        sizes = list(sched.prefill_batch_sizes)
        reg = sched.registry
        looked = (reg.hits + reg.misses) if reg is not None else 0
        return {
            "prefix_hit_rate": (reg.hits / looked) if looked else 0.0,
            "prefills": float(len(ms)),
            "prefill_p50_ms": percentile(ms, 50),
            "prefill_p95_ms": percentile(ms, 95),
            "prefill_mean_ms": (sum(ms) / len(ms)) if ms else 0.0,
            "prompt_tokens": float(sum(lens)),
            "prefill_tokens_per_s": (sum(lens) / (sum(self.prefill_times) or 1.0)
                                     if ms else 0.0),
            "prefill_dispatches": float(len(disp_ms)),
            "admit_p50_ms": percentile(disp_ms, 50),
            "admit_p95_ms": percentile(disp_ms, 95),
            "prefill_batch_mean": (sum(sizes) / len(sizes)) if sizes else 0.0,
        }
