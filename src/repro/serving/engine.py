"""Device-resident serving engine: lookahead dispatch over a slot grid.

The engine is the thin top of the ``serving`` package (see also
``state.py`` / ``sampler.py`` / ``scheduler.py``): it wires the plan, the
fused jitted ``serve_step`` (donated caches + :class:`DecodeState`, see
``models.registry.build_serve_step``), and the scheduler together, and
runs **one-step-lookahead dispatch** — the serving-loop analog of the
paper's §4.3 tile double buffering. Step *N+1* is dispatched before step
*N*'s per-step record is read back, so the host's Python bookkeeping
overlaps the device's decode compute instead of serialising with it:

    step N:    [retire N-2] [admit] [dispatch N] ──┐ device runs N
    step N+1:  [retire N-1] [admit] [dispatch N+1] ┘ host never waits

Public surface (unchanged from the monolithic engine): construct with an
:class:`~repro.core.execution_plan.ExecutionPlan` first, then
``submit`` / ``step`` / ``run_until_drained`` and the ``step_stats`` /
``prefill_stats`` telemetry hooks. The old ``ServingEngine(arch, ...)``
construction still works but is deprecated (it routes through the same
scheduler, unsharded).
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.execution_plan import ExecutionPlan
from repro.models import registry as REG
from repro.quant import dequantize_params, quantize_params
from repro.serving.config import PagingConfig, ServeConfig
from repro.serving.pages import DEFAULT_PAGE_SIZE as PG_DEFAULT
from repro.serving.sampler import GREEDY, SamplingParams
from repro.serving.scheduler import Request, Scheduler, mesh_jit
from repro.serving.state import DecodeState, decode_state_dims, make_decode_state

__all__ = ["ServingEngine", "Request", "SamplingParams", "DecodeState",
           "IncompleteDrainError", "ServeConfig"]


class IncompleteDrainError(RuntimeError):
    """``run_until_drained`` hit ``max_steps`` with requests in flight."""

    def __init__(self, msg: str, unfinished: List[int]):
        super().__init__(msg)
        self.unfinished = unfinished


def _record_ready(rec) -> bool:
    """True when every leaf of a step record has finished on device
    (non-blocking; conservatively False if the runtime lacks is_ready)."""
    try:
        return all(leaf.is_ready() for leaf in jax.tree.leaves(rec))
    except AttributeError:
        return False


class ServingEngine:
    """Plan-aware construction takes an :class:`ExecutionPlan` first::

        engine = ServingEngine(plan, params,
                               config=ServeConfig(slots=4, max_len=128))

    which places params, the cache grid and the decode state with the
    plan's NamedShardings and jits the fused decode step under the plan's
    mesh. ``sampling`` selects on-device token choice (default greedy);
    ``lookahead`` is the dispatch depth (1 = double-buffered, 0 =
    synchronous like the old engine).

    Passing an ``ArchConfig`` first is the legacy (unsharded)
    construction: still supported, now with a ``DeprecationWarning``.
    """

    def __init__(self, arch, params, *, config: Optional[ServeConfig] = None,
                 slots: Optional[int] = None, max_len: Optional[int] = None,
                 ctx=None, eos_id: Optional[int] = None, dtype=jnp.float32,
                 on_step: Optional[Callable[[Dict[str, float]], None]] = None,
                 sampling: Optional[SamplingParams] = None,
                 lookahead: Optional[int] = None, seed: Optional[int] = None,
                 max_src_len: Optional[int] = None,
                 paged: Optional[bool] = None,
                 page_size: Optional[int] = None,
                 kv_pages: Optional[int] = None,
                 prefix_cache: Optional[bool] = None):
        import dataclasses as _dc
        if config is None:
            if slots is None or max_len is None:
                raise TypeError("ServingEngine needs config=ServeConfig(...) "
                                "or explicit slots=/max_len=")
            config = ServeConfig(
                slots=slots, max_len=max_len, eos_id=eos_id,
                seed=0 if seed is None else seed, sampling=sampling,
                lookahead=1 if lookahead is None else lookahead,
                max_src_len=max_src_len,
                paging=PagingConfig(
                    paged=bool(paged), page_size=page_size, kv_pages=kv_pages,
                    prefix_cache=(True if prefix_cache is None
                                  else prefix_cache)))
        elif any(v is not None for v in (slots, max_len, eos_id, sampling,
                                         lookahead, seed, max_src_len, paged,
                                         page_size, kv_pages, prefix_cache)):
            raise TypeError("ServingEngine: pass either config= or the flat "
                            "serve kwargs, not both")
        config = config.resolve()
        slots, max_len = config.slots, config.max_len
        seed = config.seed
        self.plan: Optional[ExecutionPlan] = None
        self.mesh = None
        if isinstance(arch, ExecutionPlan):
            self.plan = arch
            exe = self.plan.compile()
            arch = self.plan.arch
            ctx = exe.ctx if ctx is None else ctx
            self.mesh = exe.mesh
        else:
            warnings.warn(
                "ServingEngine(arch, ...) construction is deprecated; plan "
                "the cell and use ExecutionPlan.compile().serve(...) (or "
                "pass the ExecutionPlan first) so params and caches are "
                "placed with the plan's shardings",
                DeprecationWarning, stacklevel=2)
        self.arch: ArchConfig = arch
        self.slots = slots
        self.max_len = max_len
        self.max_src_len = config.max_src_len
        self.eos_id = config.eos_id
        self.sampling = config.sampling
        self.lookahead = config.lookahead
        paged = config.paging.paged
        self.paged = paged
        self.quant = config.quant
        is_encdec = arch.family == "encdec"
        if paged:
            from repro.serving import pages as PG
            PG.check_paged_supported(arch)
            self.page_size = config.paging.page_size or PG.DEFAULT_PAGE_SIZE
            self.kv_pages = (config.paging.kv_pages
                             if config.paging.kv_pages is not None else
                             PG.default_kv_pages(slots, max_len,
                                                 self.page_size))
            table_len = PG.num_pages_per_slot(max_len, self.page_size)
            self.caches = PG.make_paged_caches(arch, self.kv_pages,
                                               self.page_size, dtype,
                                               kv_quant=self.quant.quant_kv)
        else:
            self.page_size = config.paging.page_size
            self.kv_pages = config.paging.kv_pages
            table_len = None
            self.caches = REG.make_caches(arch, slots, max_len, dtype,
                                          kv_quant=self.quant.quant_kv)
        # the resolved surface (page geometry made concrete) — what
        # `engine.config` exposes
        self.config: ServeConfig = _dc.replace(
            config, paging=_dc.replace(config.paging,
                                       page_size=self.page_size,
                                       kv_pages=self.kv_pages))
        self.state = make_decode_state(
            slots, seed,
            enc_shape=(self.max_src_len, arch.d_model) if is_encdec else None,
            enc_dtype=dtype, table_len=table_len)
        if self.plan is not None:
            from repro.core.xfer import tree_shardings
            params = jax.device_put(
                params, self.plan.param_shardings(params, self.mesh))
            if not paged:
                # page pools have no slot axis, so the plan's dense cache
                # shardings don't apply; the jitted step lets the compiler
                # place them (gathered reads are resharded on the fly)
                self.caches = jax.device_put(
                    self.caches,
                    self.plan.cache_shardings(self.caches, self.mesh))
            self.state = jax.device_put(
                self.state, tree_shardings(self.plan.ctx(self.mesh),
                                           self.state,
                                           decode_state_dims(enc=is_encdec,
                                                             paged=paged)))
        if self.quant.quant_weights:
            # int8 weights stay HBM-resident; every step (prefill and
            # decode alike) rehydrates a transient fp working copy inside
            # its own jit. Quantising on device keeps the placed shardings
            # (the QTensor's int8 leaf inherits the param's placement).
            params = mesh_jit(self.mesh, quantize_params)(params)
        self.params = params
        step_fn = REG.build_serve_step(arch, ctx, sampling=self.sampling,
                                       eos_id=self.eos_id, paged=paged)
        if self.quant.quant_weights:
            inner_step = step_fn
            step_fn = (lambda params, caches, state:
                       inner_step(dequantize_params(params), caches, state))
        # caches and state are donated: the per-step KV-grid copy the old
        # engine paid (fresh output buffers every step) goes away.
        self._serve_step = mesh_jit(self.mesh, step_fn, donate_argnums=(1, 2))
        self.scheduler = Scheduler(arch, slots=slots, max_len=max_len,
                                   cache_dtype=dtype, mesh=self.mesh,
                                   sampling=self.sampling,
                                   max_src_len=self.max_src_len,
                                   paged=paged,
                                   page_size=(self.page_size if paged
                                              else PG_DEFAULT),
                                   kv_pages=self.kv_pages,
                                   prefix_cache=self.config.paging.prefix_cache,
                                   quant=self.quant)
        self.completed: List[Request] = []
        self._pending: deque = deque()  # dispatched, unread step records
        # step-timing hooks (repro.bench serve scenarios read these):
        # wall seconds per step() call and tokens retired per call, plus
        # host admission-path wall per prefill. Bounded deques: telemetry
        # covers a sliding window so long-lived engines stay bounded.
        self.on_step = on_step
        self.step_times = deque(maxlen=4096)
        self.step_token_counts = deque(maxlen=4096)

    # ------------------------- queue / slot views -------------------------
    @property
    def queue(self) -> List[Request]:
        return self.scheduler.queue

    @property
    def active(self) -> Dict[int, Optional[Request]]:
        return self.scheduler.active

    @property
    def prefill_times(self):
        return self.scheduler.prefill_times

    @property
    def prefill_prompt_lens(self):
        return self.scheduler.prefill_prompt_lens

    def submit(self, req: Request):
        self.scheduler.submit(req)

    def unfinished(self) -> List[int]:
        """rids still queued or decoding (including unretired records)."""
        rids = [r.rid for r in self.queue]
        rids += [r.rid for r in self.active.values() if r is not None]
        return rids

    # ---------------------------- decode loop ----------------------------
    def step(self):
        """One serving-loop iteration: retire the record(s) that fell out
        of the lookahead window, admit into the freed slots, dispatch the
        next fused decode step."""
        t0 = time.perf_counter()
        emitted = 0
        while len(self._pending) > self.lookahead:
            emitted += self._retire_one()
        # opportunistic early retire: a record whose device work already
        # completed costs nothing to read now, and freeing its finished
        # slots one step earlier avoids idle-slot decode steps under
        # churn. Records still inside the lookahead window are only ever
        # read when ready — the loop never blocks here.
        while self._pending and _record_ready(self._pending[0]):
            emitted += self._retire_one()
        self.caches, self.state = self.scheduler.admit(
            self.params, self.caches, self.state)
        state, caches, record = self._serve_step(self.params, self.caches,
                                                 self.state)
        self.state, self.caches = state, caches
        self._pending.append(record)
        if self.lookahead == 0:
            while self._pending:
                emitted += self._retire_one()
        wall = time.perf_counter() - t0
        self.step_times.append(wall)
        self.step_token_counts.append(emitted)
        if self.on_step is not None:
            self.on_step({"step": len(self.step_times) - 1,
                          "wall_s": wall, "tokens": emitted})

    def _retire_one(self) -> int:
        """Read one step record back (the only host↔device sync in the
        loop) and apply it: append emitted tokens, free finished slots."""
        rec = self._pending.popleft()
        token = np.asarray(rec["token"])
        emit = np.asarray(rec["emit"])
        finished = np.asarray(rec["finished"])
        count = 0
        for slot, req in self.active.items():
            if req is None:
                continue
            if emit[slot]:
                req.out_tokens.append(int(token[slot]))
                count += 1
            if finished[slot]:
                req.finished_at = time.time()
                self.completed.append(req)
                self.active[slot] = None
                if self.paged:
                    self.scheduler.release_slot(slot)
        return count

    def _flush(self) -> int:
        count = 0
        while self._pending:
            count += self._retire_one()
        return count

    def run_until_drained(self, max_steps: int = 10_000, *,
                          on_incomplete: str = "raise") -> int:
        """Step until every submitted request completed; returns the step
        count. Hitting ``max_steps`` with requests still in flight raises
        :class:`IncompleteDrainError` naming the unfinished rids (pass
        ``on_incomplete="warn"`` to degrade to a warning) — a hang must
        surface in tests and benches, not truncate silently."""
        if on_incomplete not in ("raise", "warn"):
            raise ValueError(f"on_incomplete must be 'raise' or 'warn', "
                             f"got {on_incomplete!r}")
        steps = 0
        while (self.queue or self.scheduler.has_active()) and steps < max_steps:
            self.step()
            steps += 1
            if not self.queue and not self.scheduler.has_active():
                self._flush()  # retire the trailing lookahead records
        if self.queue or self.scheduler.has_active():
            self._flush()
        if self.queue or self.scheduler.has_active():
            rids = self.unfinished()
            msg = (f"run_until_drained: {len(rids)} request(s) still in "
                   f"flight after {steps} steps (max_steps={max_steps}): "
                   f"rids={rids}")
            if on_incomplete == "raise":
                raise IncompleteDrainError(msg, rids)
            warnings.warn(msg, RuntimeWarning, stacklevel=2)
        return steps

    # ------------------------- step-timing hooks -------------------------
    def reset_step_stats(self):
        """Drop recorded step/prefill timings (e.g. after a jit warmup pass)."""
        self.step_times.clear()
        self.step_token_counts.clear()
        self.scheduler.reset_stats()

    def step_stats(self) -> Dict[str, float]:
        """p50/p95 decode-step wall time and aggregate token throughput."""
        from repro.core.stats import percentile
        ms = [t * 1e3 for t in self.step_times]
        total_s = sum(self.step_times)
        toks = sum(self.step_token_counts)
        return {
            "steps": float(len(ms)),
            "step_p50_ms": percentile(ms, 50),
            "step_p95_ms": percentile(ms, 95),
            "step_mean_ms": (sum(ms) / len(ms)) if ms else 0.0,
            "tokens": float(toks),
            "tokens_per_s": toks / total_s if total_s > 0 else 0.0,
        }

    def prefill_stats(self) -> Dict[str, float]:
        """p50/p95 per-request admission wall time (host critical path:
        bucketed prefill dispatch + cache splice + state update; the
        prefill compute itself overlaps the in-flight decode step).

        Batched admission telemetry rides along: ``prefill_dispatches``
        counts device dispatch groups since the last reset (a same-bucket
        burst of N requests is **one** dispatch), ``admit_p50_ms`` /
        ``admit_p95_ms`` are per-dispatch wall percentiles, and
        ``prefill_batch_mean`` is the mean requests-per-dispatch.
        ``prefix_hit_rate`` is the fraction of prefix-registry lookups
        that aliased shared pages (0.0 on non-paged engines)."""
        from repro.core.stats import percentile
        sched = self.scheduler
        ms = [t * 1e3 for t in self.prefill_times]
        lens = list(self.prefill_prompt_lens)
        disp_ms = [t * 1e3 for t in sched.prefill_dispatch_times]
        sizes = list(sched.prefill_batch_sizes)
        reg = sched.registry
        looked = (reg.hits + reg.misses) if reg is not None else 0
        return {
            "prefix_hit_rate": (reg.hits / looked) if looked else 0.0,
            "prefills": float(len(ms)),
            "prefill_p50_ms": percentile(ms, 50),
            "prefill_p95_ms": percentile(ms, 95),
            "prefill_mean_ms": (sum(ms) / len(ms)) if ms else 0.0,
            "prompt_tokens": float(sum(lens)),
            "prefill_tokens_per_s": (sum(lens) / (sum(self.prefill_times) or 1.0)
                                     if ms else 0.0),
            "prefill_dispatches": float(len(disp_ms)),
            "admit_p50_ms": percentile(disp_ms, 50),
            "admit_p95_ms": percentile(disp_ms, 95),
            "prefill_batch_mean": (sum(sizes) / len(sizes)) if sizes else 0.0,
        }
