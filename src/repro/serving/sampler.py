"""On-device token selection: greedy / temperature / top-k.

Sampling is folded into the jitted ``serve_step`` (see
``models.registry.build_serve_step``) so the chosen token never
round-trips to the host — the readback the old engine paid every step is
deferred behind one-step-lookahead dispatch instead.

``SamplingParams`` is a frozen (hashable) dataclass so step builders can
close over it: one jit compilation per sampling configuration.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

METHODS = ("greedy", "temperature", "top_k")


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """How ``serve_step`` turns last-token logits into the next token.

    greedy       — argmax (deterministic; the default, bit-exact with the
                   pre-refactor engine)
    temperature  — softmax sample of ``logits / temperature``
    top_k        — restrict to the ``top_k`` largest logits, then
                   temperature-sample
    """

    method: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0

    def __post_init__(self):
        if self.method not in METHODS:
            raise ValueError(f"unknown sampling method {self.method!r}; "
                             f"known: {METHODS}")
        if self.method != "greedy" and self.temperature <= 0:
            raise ValueError(f"temperature must be > 0, got {self.temperature}")
        if self.method == "top_k" and self.top_k <= 0:
            raise ValueError(f"top_k must be > 0, got {self.top_k}")


GREEDY = SamplingParams()


def sample(logits: jax.Array, rng: jax.Array,
           sp: SamplingParams) -> Tuple[jax.Array, jax.Array]:
    """(rng', tokens): pick one token per row of ``logits [S, V]``.

    ``rng [S, 2]`` holds one PRNG key per slot; greedy leaves it
    untouched (and costs no RNG work), stochastic methods split each key
    and return the carried halves.
    """
    if sp.method == "greedy":
        return rng, jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def one(key, row):
        key, sub = jax.random.split(key)
        row = row.astype(jnp.float32) / sp.temperature
        if sp.method == "top_k":
            # Mask by the *indices* top_k returns, not a >= threshold:
            # when logits tie at the k-th value a threshold keeps every
            # tied candidate (> k survivors). top_k already breaks ties
            # (lowest index wins), so exactly k candidates remain.
            _, idx = jax.lax.top_k(row, sp.top_k)
            keep = jnp.zeros(row.shape, bool).at[idx].set(True)
            row = jnp.where(keep, row, -jnp.inf)
        return key, jax.random.categorical(sub, row).astype(jnp.int32)

    # Partitionable threefry ONLY around the sampling ops: the default
    # lowering's random bits depend on how XLA shards the categorical
    # (vocab-sharded logits under a tp plan draw different gumbels than
    # the same key unsharded), which would make a seeded stream depend on
    # the execution plan. Counter-based bits are sharding-invariant, so
    # one (seed, rid) key yields one stream on any mesh. Scoped here so
    # param-init streams elsewhere keep their historical values.
    with jax.threefry_partitionable(True):
        return jax.vmap(one)(rng, logits)
