"""Device-resident serving runtime (see API.md "Serving runtime").

Layers:
  state.py      DecodeState pytree — per-slot bookkeeping, on device
  sampler.py    SamplingParams + on-device greedy/temperature/top-k
  scheduler.py  admission, slot lifecycle, bucketed prefill + splice
  engine.py     ServingEngine — one-step-lookahead dispatch loop
"""
from repro.serving.engine import (  # noqa: F401
    IncompleteDrainError, Request, ServingEngine)
from repro.serving.sampler import GREEDY, SamplingParams  # noqa: F401
from repro.serving.scheduler import Scheduler  # noqa: F401
from repro.serving.state import DecodeState, make_decode_state  # noqa: F401
