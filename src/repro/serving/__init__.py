"""Device-resident serving runtime (see API.md "Serving runtime").

Layers:
  config.py     ServeConfig / PagingConfig / DisaggConfig / SpecConfig —
                the typed serve surface
  state.py      DecodeState pytree — per-slot bookkeeping, on device
  sampler.py    SamplingParams + on-device greedy/temperature/top-k
  scheduler.py  admission, slot lifecycle, bucketed prefill + splice
  engine.py     ServingEngine — one-step-lookahead dispatch loop
  pages.py      paged KV cache: page pools, prefix registry
  disagg.py     disaggregated prefill/decode: PrefillWorker + engine
"""
from repro.serving.config import (  # noqa: F401
    DisaggConfig, ElasticConfig, PagingConfig, QuantConfig, ServeConfig,
    SpecConfig)
from repro.serving.engine import (  # noqa: F401
    IncompleteDrainError, MigrationReport, Request, ServingEngine)
from repro.serving.sampler import GREEDY, SamplingParams  # noqa: F401
from repro.serving.scheduler import (  # noqa: F401
    RequestValidationError, Scheduler)
from repro.serving.state import DecodeState, make_decode_state  # noqa: F401
