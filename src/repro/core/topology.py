"""2-D torus organisation + bandwidth feasibility — paper §4.4.

The paper organises ``Pm`` columns × ``Pb·Pr·Pc`` rows of devices on a
2-D torus: columns share (and XFER-distribute) weights, rows share IFMs.
A TPU pod slice *is* that torus; mesh axis "model" plays the column role
and ("pod","data") the row role.

Eq. 22 feasibility: per-device outgoing traffic of one pipeline beat,
``D_row + D_col ≤ NB · Lat1`` — the exchanges must hide behind the beat.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core import hw
from repro.core.partition import PartitionFactors
from repro.core.perf_model import Tiling


@dataclasses.dataclass(frozen=True)
class TorusSpec:
    rows: int  # weight-shared degree (Pb*Pr*Pc)
    cols: int  # Pm
    hw_spec: hw.HardwareSpec = dataclasses.field(default_factory=lambda: hw.V5E)

    @property
    def num_devices(self) -> int:
        return self.rows * self.cols

    def links_per_device(self) -> int:
        # 2-D torus: 2 in + 2 out per dimension with wraparound
        return 4

    def xfer_feasible(self, tiling: Tiling, layer_k: int, lat1_seconds: float,
                      bpe: int = 2, ifm_shared: bool = True,
                      weight_shared: bool = True) -> Tuple[bool, float, float]:
        """Paper Eq. 22 with ICI constants.

        D_row: IFM bytes each device forwards along its row ring per beat;
        D_col: weight bytes along its column ring. Both must complete within
        Lat1 at NB bytes/s per direction.
        """
        b_i = tiling.Tn * tiling.Tr * tiling.Tc * bpe
        b_w = tiling.Tm * tiling.Tn * layer_k * layer_k * bpe
        d_row = (self.cols - 1) * b_i / self.cols if (ifm_shared and self.cols > 1) else 0.0
        d_col = (self.rows - 1) * b_w / self.rows if (weight_shared and self.rows > 1) else 0.0
        nb = self.hw_spec.ici_bandwidth_per_link  # one direction, per paper
        need = d_row + d_col
        budget = nb * lat1_seconds
        return need <= budget, need, budget

    def exchange_time(self, bytes_row: float, bytes_col: float) -> float:
        """Time to complete both ring exchanges (they use disjoint links)."""
        nb = self.hw_spec.ici_bandwidth_per_link
        t_row = (self.cols - 1) / self.cols * bytes_row / nb if self.cols > 1 else 0.0
        t_col = (self.rows - 1) / self.rows * bytes_col / nb if self.rows > 1 else 0.0
        return max(t_row, t_col)


def torus_for(factors: PartitionFactors) -> TorusSpec:
    return TorusSpec(rows=factors.weight_shared_degree, cols=factors.Pm * factors.Pn)
