"""Tiny numpy-free statistics helpers shared across layers.

Lives in ``core`` so both the serving runtime (step-timing hooks) and the
benchmark subsystem can use it without either depending on the other.
"""
from __future__ import annotations

from typing import Sequence


def percentile(samples: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile (q in [0, 100])."""
    if not samples:
        return 0.0
    xs = sorted(samples)
    if len(xs) == 1:
        return xs[0]
    pos = (len(xs) - 1) * q / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac
