"""ExecutionPlan — the planner's decision as a deployable artifact.

This is the object that closes the loop the paper draws between its
analytic model (Eq. 8–15) and the multi-device datapath (§5E): the DSE
output (``ShardingPlan``, per-layer ``Tiling``/``Ports``, capacity report)
plus everything needed to *execute* it — derived ``NamedSharding`` specs
for params / optimizer states / caches / batches, and ``compile()`` which
builds the mesh and jits the step functions.

Three-stage pipeline (see ``repro.api``)::

    plan = repro.plan("qwen1.5-0.5b", "decode_32k", mesh)   # DSE
    exe = plan.compile()                                    # mesh + jit
    engine = exe.serve(slots=4, max_len=128)                # plan-aware run

The class lives in ``core`` because it is pure planning data + spec
derivation; the heavyweight compile step is delegated to
``repro.api.Executable`` via a lazy import so ``core`` keeps zero
dependencies on launch/serving/runtime at import time.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.perf_model import Ports, Tiling
from repro.core.planner import PlanReport, ShardingPlan
from repro.core.xfer import ShardingCtx, tree_shardings

PyTree = Any


@dataclasses.dataclass
class ExecutionPlan:
    """Planner DSE output bound to one (arch × shape × mesh) cell."""

    arch: ArchConfig
    shape: ShapeConfig
    report: PlanReport
    mesh_axes: Tuple[Tuple[str, int], ...]
    # concrete devices backing the mesh (None -> resolve at compile time)
    devices: Optional[Sequence] = None
    _mesh: Any = dataclasses.field(default=None, repr=False)      # reuse if given
    _exe: Any = dataclasses.field(default=None, repr=False)       # compile() cache
    _exe_kwargs: Any = dataclasses.field(default=None, repr=False)

    # ------------------------------------------------------------------
    # planner-facing views
    # ------------------------------------------------------------------
    @property
    def sharding_plan(self) -> ShardingPlan:
        return self.report.plan

    @property
    def predicted_seconds(self) -> float:
        return self.report.predicted_seconds

    @property
    def hbm_bytes_per_device(self) -> float:
        return self.report.hbm_bytes_per_device

    @property
    def feasible(self) -> bool:
        return self.report.feasible and self.report.fits_hbm

    @property
    def layer_choices(self) -> Tuple[Tuple[str, Tiling, Ports], ...]:
        """Winning per-layer ⟨tiling, ports⟩ from the accelerator-level DSE."""
        return self.report.layer_choices

    @property
    def num_devices(self) -> int:
        n = 1
        for _, s in self.mesh_axes:
            n *= s
        return n

    def describe(self) -> str:
        return (f"{self.arch.name} × {self.shape.name} on "
                f"{'x'.join(str(s) for _, s in self.mesh_axes)} "
                f"[{self.sharding_plan.describe()}] "
                f"predicted={self.predicted_seconds * 1e3:.1f}ms "
                f"hbm={self.hbm_bytes_per_device / 2**30:.2f}GB"
                + (f" ({self.report.note})" if self.report.note else ""))

    # ------------------------------------------------------------------
    # sharding derivation: ShardingPlan -> NamedSharding pytrees
    # ------------------------------------------------------------------
    def build_mesh(self):
        """Materialise the planned mesh over concrete devices."""
        if self._mesh is not None:
            return self._mesh
        import jax
        from repro.launch.mesh import make_mesh
        shape = tuple(s for _, s in self.mesh_axes)
        names = tuple(n for n, _ in self.mesh_axes)
        devices = self.devices
        if devices is None:
            avail = jax.devices()
            if self.num_devices > len(avail):
                raise ValueError(
                    f"plan targets {self.num_devices} devices "
                    f"({dict(self.mesh_axes)}) but only {len(avail)} exist; "
                    f"re-plan with repro.plan(arch, shape) to auto-fit, or "
                    f"pass explicit devices")
            devices = avail[: self.num_devices]
        self._mesh = make_mesh(shape, names, devices=devices)
        return self._mesh

    def ctx(self, mesh=None) -> ShardingCtx:
        """The logical-dim resolver every model function consumes."""
        return ShardingCtx(mesh if mesh is not None else self.build_mesh(),
                           self.sharding_plan)

    def param_shardings(self, params: PyTree, mesh=None) -> PyTree:
        from repro.models import registry as REG
        return tree_shardings(self.ctx(mesh), params, REG.param_dims(self.arch))

    def opt_shardings(self, opt_state: PyTree, mesh=None,
                      quantize: bool = False) -> PyTree:
        from repro.models import registry as REG
        from repro.optim import adamw as OPT
        return tree_shardings(self.ctx(mesh), opt_state,
                              OPT.opt_state_dims(REG.param_dims(self.arch), quantize))

    def cache_shardings(self, caches: PyTree, mesh=None) -> PyTree:
        from repro.models import registry as REG
        return tree_shardings(self.ctx(mesh), caches, REG.cache_dims(self.arch))

    def batch_shardings(self, batch: PyTree, mesh=None) -> PyTree:
        from repro.models import registry as REG
        return tree_shardings(self.ctx(mesh), batch,
                              REG.input_dims(self.arch, self.shape))

    # ------------------------------------------------------------------
    # stage 2: compile
    # ------------------------------------------------------------------
    def compile(self, **kwargs) -> "Any":
        """Build the mesh, derive shardings, jit the step functions.

        Returns a :class:`repro.api.Executable` (cached: compiling the same
        plan twice returns the same object).
        """
        from repro.api import Executable
        if self._exe is not None:
            if kwargs != self._exe_kwargs:
                # different build options must not hand back the cached
                # Executable — build a fresh one (uncached) instead
                return Executable(self, **kwargs)
            return self._exe
        self._exe = Executable(self, **kwargs)
        self._exe_kwargs = kwargs
        return self._exe
