"""ExecutionPlan — the planner's decision as a deployable artifact.

This is the object that closes the loop the paper draws between its
analytic model (Eq. 8–15) and the multi-device datapath (§5E): the DSE
output (``ShardingPlan``, per-layer ``Tiling``/``Ports``, capacity report)
plus everything needed to *execute* it — derived ``NamedSharding`` specs
for params / optimizer states / caches / batches, and ``compile()`` which
builds the mesh and jits the step functions.

Three-stage pipeline (see ``repro.api``)::

    plan = repro.plan("qwen1.5-0.5b", "decode_32k", mesh)   # DSE
    exe = plan.compile()                                    # mesh + jit
    engine = exe.serve(config=ServeConfig(slots=4, max_len=128))  # plan-aware

The class lives in ``core`` because it is pure planning data + spec
derivation; the heavyweight compile step is delegated to
``repro.api.Executable`` via a lazy import so ``core`` keeps zero
dependencies on launch/serving/runtime at import time.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.perf_model import Ports, Tiling
from repro.core.planner import PlanReport, ShardingPlan, evaluate_plan
from repro.core.xfer import ShardingCtx, tree_shardings

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ReshardTransfer:
    """Analytic byte accounting for moving one pytree between two plans'
    ``NamedSharding``\\ s (the plan→plan analog of the disagg
    ``PrefillWorker`` signature accounting): per-leaf, the *logical*
    (global) bytes, whether the leaf physically moves (its current
    sharding is not equivalent to the destination's — a leaf that keeps
    an identical layout on identical devices is a no-op ``device_put``),
    and the per-device destination shard bytes the transfer must land.
    """

    logical_bytes: int        # Σ global array bytes over all leaves
    moved_bytes: int          # logical bytes of leaves that change sharding
    kept_bytes: int           # logical bytes of leaves that stay put
    dst_shard_bytes: int      # Σ per-device shard bytes on the destination
    moved_leaves: int
    kept_leaves: int


def reshard_transfer(tree: PyTree, dst_shardings: PyTree) -> ReshardTransfer:
    """Derive the transfer a ``device_put(tree, dst_shardings)`` implies.

    ``dst_shardings`` mirrors ``tree`` with a ``NamedSharding`` per leaf
    (e.g. ``plan.param_shardings(...)`` of the *destination* plan). The
    source sharding is read off each leaf's committed placement; leaves
    without one (host arrays) always count as moved.
    """
    import jax
    import numpy as np

    leaves = jax.tree.leaves(tree)
    dsts = jax.tree.leaves(
        dst_shardings,
        is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
    if len(leaves) != len(dsts):
        raise ValueError(f"reshard_transfer: {len(leaves)} leaves vs "
                         f"{len(dsts)} destination shardings")
    logical = moved = kept = shard = 0
    moved_n = kept_n = 0
    for leaf, dst in zip(leaves, dsts):
        nbytes = int(np.prod(leaf.shape, dtype=np.int64)) * leaf.dtype.itemsize
        logical += nbytes
        shard += (int(np.prod(dst.shard_shape(tuple(leaf.shape)),
                              dtype=np.int64)) * leaf.dtype.itemsize)
        src = getattr(leaf, "sharding", None)
        stays = False
        if src is not None:
            try:
                stays = src.is_equivalent_to(dst, leaf.ndim)
            except Exception:
                stays = src == dst
        if stays:
            kept += nbytes
            kept_n += 1
        else:
            moved += nbytes
            moved_n += 1
    return ReshardTransfer(logical_bytes=logical, moved_bytes=moved,
                           kept_bytes=kept, dst_shard_bytes=shard,
                           moved_leaves=moved_n, kept_leaves=kept_n)


@dataclasses.dataclass
class ExecutionPlan:
    """Planner DSE output bound to one (arch × shape × mesh) cell."""

    arch: ArchConfig
    shape: ShapeConfig
    report: PlanReport
    mesh_axes: Tuple[Tuple[str, int], ...]
    # concrete devices backing the mesh (None -> resolve at compile time)
    devices: Optional[Sequence] = None
    # "fused" (the whole mesh runs prefill+decode) or a disaggregated
    # slice: "prefill" / "decode" (see disaggregate())
    role: str = "fused"
    # co-placed speculative-decoding draft arch (repro.plan(draft=...));
    # its params + KV footprint is charged in the capacity report and
    # ServeConfig.spec resolves its draft arch from here
    draft: Optional[ArchConfig] = None
    _mesh: Any = dataclasses.field(default=None, repr=False)      # reuse if given
    _exe: Any = dataclasses.field(default=None, repr=False)       # compile() cache
    _exe_kwargs: Any = dataclasses.field(default=None, repr=False)

    # ------------------------------------------------------------------
    # planner-facing views
    # ------------------------------------------------------------------
    @property
    def sharding_plan(self) -> ShardingPlan:
        return self.report.plan

    @property
    def predicted_seconds(self) -> float:
        return self.report.predicted_seconds

    @property
    def hbm_bytes_per_device(self) -> float:
        return self.report.hbm_bytes_per_device

    @property
    def feasible(self) -> bool:
        return self.report.feasible and self.report.fits_hbm

    @property
    def layer_choices(self) -> Tuple[Tuple[str, Tiling, Ports], ...]:
        """Winning per-layer ⟨tiling, ports⟩ from the accelerator-level DSE."""
        return self.report.layer_choices

    @property
    def num_devices(self) -> int:
        n = 1
        for _, s in self.mesh_axes:
            n *= s
        return n

    def describe(self) -> str:
        return (f"{self.arch.name} × {self.shape.name} on "
                f"{'x'.join(str(s) for _, s in self.mesh_axes)} "
                + (f"role={self.role} " if self.role != "fused" else "")
                + f"[{self.sharding_plan.describe()}] "
                f"predicted={self.predicted_seconds * 1e3:.1f}ms "
                f"hbm={self.hbm_bytes_per_device / 2**30:.2f}GB"
                + (f" ({self.report.note})" if self.report.note else ""))

    # ------------------------------------------------------------------
    # disaggregation: one fused plan -> prefill + decode role sub-plans
    # ------------------------------------------------------------------
    def disaggregate(self, prefill_data: int = 1,
                     axis: Optional[str] = None) -> "DisaggPlan":
        """Split this plan's mesh along its data axis into two role
        sub-plans over **disjoint** device slices: a bursty compute-bound
        ``prefill`` slice (``prefill_data`` data-axis rows × the full
        model axis) and a steady bandwidth-bound ``decode`` slice (the
        remaining rows) — the serving analog of the paper's resource
        partitioning argument (two smaller specialised partitions beat
        one fused design).

        Both sub-plans **inherit the parent's ShardingPlan structure**
        (same tp/seq/ep axis roles and degrees — only the data axis
        shrinks), so per-request arithmetic on either slice is
        bit-identical to the fused deployment; each is re-scored with
        :func:`repro.core.planner.evaluate_plan` on its own mesh for its
        own capacity report. The decode slice keeps the leading device
        block so single-role deployments stay on the same hardware.
        """
        names = [n for n, _ in self.mesh_axes]
        sizes = dict(self.mesh_axes)
        if axis is None:
            axis = next((n for n in names
                         if n in self.sharding_plan.batch_axes), None)
            if axis is None:
                raise ValueError(
                    f"plan {self.sharding_plan.describe()!r} has no "
                    f"batch-role mesh axis to split for disaggregation")
        if axis not in sizes:
            raise ValueError(f"unknown mesh axis {axis!r}; have {names}")
        d = sizes[axis]
        if not 1 <= prefill_data < d:
            raise ValueError(
                f"prefill_data={prefill_data} must leave both roles at "
                f"least one {axis!r} row (axis size {d})")
        import jax
        import numpy as np
        devices = (list(self.devices) if self.devices is not None
                   else list(jax.devices()))
        if len(devices) < self.num_devices:
            raise ValueError(
                f"disaggregate needs {self.num_devices} devices, "
                f"have {len(devices)}")
        grid = np.array(devices[: self.num_devices], dtype=object).reshape(
            [s for _, s in self.mesh_axes])
        ai = names.index(axis)
        dec_rows = d - prefill_data
        dec_dev = np.take(grid, range(dec_rows), axis=ai).ravel().tolist()
        pre_dev = np.take(grid, range(dec_rows, d), axis=ai).ravel().tolist()

        def sub(role: str, rows: int, devs) -> "ExecutionPlan":
            sub_axes = tuple((n, rows if n == axis else s)
                             for n, s in self.mesh_axes)
            sub_plan = dataclasses.replace(self.sharding_plan,
                                           mesh_axes=sub_axes)
            sub_shape = ShapeConfig(f"{self.shape.name}/{role}",
                                    self.shape.seq_len,
                                    self.shape.global_batch, role)
            report = evaluate_plan(self.arch, sub_shape, sub_plan)
            return ExecutionPlan(arch=self.arch, shape=sub_shape,
                                 report=report, mesh_axes=sub_axes,
                                 devices=devs, role=role)

        return DisaggPlan(parent=self, axis=axis,
                          prefill=sub("prefill", prefill_data, pre_dev),
                          decode=sub("decode", dec_rows, dec_dev))

    # ------------------------------------------------------------------
    # sharding derivation: ShardingPlan -> NamedSharding pytrees
    # ------------------------------------------------------------------
    def build_mesh(self):
        """Materialise the planned mesh over concrete devices."""
        if self._mesh is not None:
            return self._mesh
        import jax
        from repro.launch.mesh import make_mesh
        shape = tuple(s for _, s in self.mesh_axes)
        names = tuple(n for n, _ in self.mesh_axes)
        devices = self.devices
        if devices is None:
            avail = jax.devices()
            if self.num_devices > len(avail):
                raise ValueError(
                    f"plan targets {self.num_devices} devices "
                    f"({dict(self.mesh_axes)}) but only {len(avail)} exist; "
                    f"re-plan with repro.plan(arch, shape) to auto-fit, or "
                    f"pass explicit devices")
            devices = avail[: self.num_devices]
        self._mesh = make_mesh(shape, names, devices=devices)
        return self._mesh

    def ctx(self, mesh=None) -> ShardingCtx:
        """The logical-dim resolver every model function consumes."""
        return ShardingCtx(mesh if mesh is not None else self.build_mesh(),
                           self.sharding_plan)

    def param_shardings(self, params: PyTree, mesh=None) -> PyTree:
        from repro.models import registry as REG
        return tree_shardings(self.ctx(mesh), params, REG.param_dims(self.arch))

    def opt_shardings(self, opt_state: PyTree, mesh=None,
                      quantize: bool = False) -> PyTree:
        from repro.models import registry as REG
        from repro.optim import adamw as OPT
        return tree_shardings(self.ctx(mesh), opt_state,
                              OPT.opt_state_dims(REG.param_dims(self.arch), quantize))

    def cache_shardings(self, caches: PyTree, mesh=None) -> PyTree:
        from repro.models import registry as REG
        # the dims tree must mirror the cache tree: int8 KV caches carry
        # extra scale leaves, detected structurally off the caches given
        return tree_shardings(
            self.ctx(mesh), caches,
            REG.cache_dims(self.arch,
                           kv_quant=REG.caches_quantized(caches)))

    def batch_shardings(self, batch: PyTree, mesh=None) -> PyTree:
        from repro.models import registry as REG
        return tree_shardings(self.ctx(mesh), batch,
                              REG.input_dims(self.arch, self.shape))

    # ------------------------------------------------------------------
    # stage 2: compile
    # ------------------------------------------------------------------
    def compile(self, **kwargs) -> "Any":
        """Build the mesh, derive shardings, jit the step functions.

        Returns a :class:`repro.api.Executable` (cached: compiling the same
        plan twice returns the same object).
        """
        from repro.api import Executable
        if self._exe is not None:
            if kwargs != self._exe_kwargs:
                # different build options must not hand back the cached
                # Executable — build a fresh one (uncached) instead
                return Executable(self, **kwargs)
            return self._exe
        self._exe = Executable(self, **kwargs)
        self._exe_kwargs = kwargs
        return self._exe


@dataclasses.dataclass
class DisaggPlan:
    """The two-role split of one fused deployment (``disaggregate()``):
    ``prefill`` and ``decode`` are ordinary :class:`ExecutionPlan`\\ s
    over disjoint device slices of the parent's mesh, each compilable on
    its own. ``axis`` is the data axis that was split."""

    parent: ExecutionPlan
    prefill: ExecutionPlan
    decode: ExecutionPlan
    axis: str

    def describe(self) -> str:
        return (f"disagg[{self.axis}] "
                f"decode<{self.decode.describe()}> "
                f"prefill<{self.prefill.describe()}>")
