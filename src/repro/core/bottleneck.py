"""Performance-bottleneck detection — paper §3 ③ Corollary 1.

Given a layer + design parameters, name the dominating term and suggest the
XFER move that relieves it (paper Table 4 "Bound" column + §4.3).
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.core.layer_model import ConvLayer
from repro.core.partition import PartitionFactors
from repro.core.perf_model import LayerLatency, Ports, TilePipelineModel, Tiling


@dataclasses.dataclass(frozen=True)
class Diagnosis:
    layer: str
    bottleneck: str  # OFM | IFM | weights | compute | link | reduce
    latency: LayerLatency
    suggestion: str


_SUGGESTIONS = {
    "weights": ("weight-shared XFER: shard weights over the Pb·Pr·Pc group and "
                "exchange over ICI (Eq. 16-17); or raise Wp share of HBM"),
    "IFM": ("IFM-shared XFER: raise Pm and distribute the IFM over the TP group "
            "(Eq. 19-20); or raise Ip share of HBM"),
    "OFM": "raise Op share of HBM, or increase Tn so OFM writes amortise (Eq. 13)",
    "compute": "fully utilised — scale out (more devices), the goal state of P1",
    "link": "link-bound: widen torus axis / reduce exchange degree (Eq. 22 violated)",
    "reduce": "partial-sum bound: lower Pn or fuse reduce-scatter with next layer",
}


def diagnose(layer: ConvLayer, tiling: Tiling, ports: Ports,
             factors: PartitionFactors = PartitionFactors(),
             xfer: bool = False, domain: str = "seconds",
             model: TilePipelineModel | None = None) -> Diagnosis:
    model = model or TilePipelineModel()
    fn = model.seconds if domain == "seconds" else model.cycles
    lat = fn(layer, tiling, ports, factors, xfer)
    b = lat.bottleneck
    return Diagnosis(layer.name, b, lat, _SUGGESTIONS.get(b, ""))


def diagnose_model(layers: List[ConvLayer], tiling: Tiling, ports: Ports,
                   factors: PartitionFactors = PartitionFactors(),
                   xfer: bool = False, domain: str = "seconds") -> List[Diagnosis]:
    return [diagnose(l, tiling, ports, factors, xfer, domain) for l in layers]
