"""The paper's accurate analytic model (§3 Eqs. 8–14, §4.3 Eqs. 16–21).

Two domains are provided:

* **cycle domain** (`TilePipelineModel.cycles`): the paper's formulation
  verbatim — AXI-stream port counts ⟨Ip, Wp, Op⟩, latencies in clock
  cycles. Used by the paper-parity benchmarks (Tables 1/3/4, Figs 3/14/15).
* **time domain** (`TilePipelineModel.seconds`): the TPU v5e adaptation —
  ports become fractions of HBM bandwidth, the MAC array becomes the MXU,
  inter-FPGA links become ICI rings. Used by the planner and the roofline
  report.

The model's defining property (the paper's Challenge 1): the pipeline is a
**max over concurrent streams**, not a sum of aggregate costs — a design
under both classical roofs can still stall on its slowest stream.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core import hw
from repro.core.layer_model import ConvLayer
from repro.core.partition import PartitionFactors


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Calibratable constants for the time-domain model.

    The paper validates its analytic model against measured runs (<3%
    error); the TPU/CPU adaptation does the same by scaling the three
    roofs to the *effective* rates the host actually achieves.  A scale of
    1.0 means "the hardware hits its datasheet roof"; real machines sit
    below that, and ``repro.bench.calibrate`` fits these from measured
    runs (time = uncalibrated_time / scale).

    ``overhead_s`` is a fixed per-layer dispatch/launch cost added to the
    assembled total — the term that dominates tiny layers.
    """

    flops_scale: float = 1.0  # effective fraction of peak MXU/ALU rate
    hbm_scale: float = 1.0    # effective fraction of peak memory bandwidth
    ici_scale: float = 1.0    # effective fraction of peak interconnect bw
    overhead_s: float = 0.0   # per-layer fixed dispatch overhead (seconds)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Calibration":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: float(v) for k, v in d.items() if k in known})

    @property
    def identity(self) -> bool:
        return (self.flops_scale == 1.0 and self.hbm_scale == 1.0
                and self.ici_scale == 1.0 and self.overhead_s == 0.0)


IDENTITY_CALIBRATION = Calibration()


@dataclasses.dataclass(frozen=True)
class Tiling:
    """Paper ②-1 loop tiling ⟨Tm, Tn, Tr, Tc⟩ (BlockSpec block shape)."""

    Tm: int
    Tn: int
    Tr: int
    Tc: int = 1

    def clamp(self, layer: ConvLayer, p: PartitionFactors) -> "Tiling":
        _, R, C, M, N = _device_dims(layer, p)
        return Tiling(
            Tm=max(1, min(self.Tm, M)),
            Tn=max(1, min(self.Tn, N)),
            Tr=max(1, min(self.Tr, R)),
            Tc=max(1, min(self.Tc, C)),
        )


@dataclasses.dataclass(frozen=True)
class Ports:
    """Paper ②-2 ⟨Ip, Wp, Op⟩ — AXI streams (cycle domain) or HBM bandwidth
    fractions (time domain, normalised to sum ≤ 1)."""

    Ip: float = 2
    Wp: float = 2
    Op: float = 2
    b2b: float = 8  # inter-device link width (elements/cycle — cycle domain)

    def normalized(self) -> "Ports":
        s = self.Ip + self.Wp + self.Op
        return Ports(self.Ip / s, self.Wp / s, self.Op / s, self.b2b)


@dataclasses.dataclass(frozen=True)
class LayerLatency:
    """All terms of Eqs. 8–14 for one layer on one device, plus bottleneck."""

    t_comp: float
    t_ifm: float
    t_wei: float
    t_ofm: float
    t_link_w: float  # Eq. 17 weight exchange over links (XFER)
    t_link_i: float  # Eq. 19 IFM exchange over links (XFER)
    t_reduce: float  # Pn>1 partial-sum reduce (TPU extension)
    lat1: float      # Eq. 12/18/21
    lat2: float      # Eq. 13
    total: float     # Eq. 14
    trip_outer: int
    trip_inner: int

    @property
    def bottleneck(self) -> str:
        # Paper Corollary 1, extended with link/reduce terms.
        if self.lat2 > self.trip_inner * self.lat1 + 1e-12:
            return "OFM"
        terms = {
            "compute": self.t_comp,
            "IFM": self.t_ifm,
            "weights": self.t_wei,
            "link": max(self.t_link_w, self.t_link_i),
            "reduce": self.t_reduce,
        }
        return max(terms, key=terms.get)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // max(b, 1))


def _device_dims(layer: ConvLayer, p: PartitionFactors):
    """Per-device ⟨B,R,C,M,N⟩ after partitioning, honouring LM semantics.

    tokens_folded: batch rows fold into R (weights streamed once per token
    block); the weight-shared factors Pb·Pr·Pc jointly divide the tokens.
    pm_on_batch: Pm (TP) shards the batch·heads dim, not output channels.
    """
    if layer.tokens_folded:
        tokens = layer.B * layer.R * layer.C
        wsd = p.Pb * p.Pr * p.Pc
        B = 1
        R = _ceil_div(tokens, wsd)
        C = 1
        M = _ceil_div(layer.M, p.Pm)
        N = _ceil_div(layer.N, p.Pn)
    elif layer.pm_on_batch:
        B = _ceil_div(layer.B, p.Pb * p.Pm)
        R = _ceil_div(layer.R, p.Pr)
        C = _ceil_div(layer.C, p.Pc)
        M = layer.M
        N = _ceil_div(layer.N, p.Pn)
    else:
        B = _ceil_div(layer.B, p.Pb)
        R = _ceil_div(layer.R, p.Pr)
        C = _ceil_div(layer.C, p.Pc)
        M = _ceil_div(layer.M, p.Pm)
        N = _ceil_div(layer.N, p.Pn)
    return B, R, C, M, N


@dataclasses.dataclass
class TilePipelineModel:
    """Evaluate Eqs. 8–14 (+ XFER Eqs. 16–21) for a layer/partition/tiling."""

    hw_spec: hw.HardwareSpec = dataclasses.field(default_factory=lambda: hw.V5E)
    # Fitted by repro.bench.calibrate; identity = datasheet roofs.
    calib: Calibration = dataclasses.field(default_factory=Calibration)

    # ---------------- cycle domain (paper-faithful) ----------------
    def cycles(self, layer: ConvLayer, t: Tiling, ports: Ports,
               p: PartitionFactors = PartitionFactors(),
               xfer: bool = False) -> LayerLatency:
        t = t.clamp(layer, p)
        K = layer.K
        # per-device dims after partitioning (paper §4.2)
        B, R, C, M, N = _device_dims(layer, p)

        t_comp = K * K * t.Tr * t.Tc  # Eq. 11 (Tm×Tn MACs/cycle)
        t_ifm = t.Tn * t.Tr * t.Tc / ports.Ip  # Eq. 8
        t_ofm = t.Tm * t.Tr * t.Tc / ports.Op  # Eq. 10
        wsd, isd = p.weight_shared_degree, p.ifm_shared_degree
        if layer.weighted and xfer and wsd > 1:
            t_wei = t.Tm * t.Tn * K * K / (ports.Wp * wsd)      # Eq. 16
            t_link_w = t.Tm * t.Tn * K * K / (ports.b2b * wsd)  # Eq. 17
        else:
            t_wei = (t.Tm * t.Tn * K * K / ports.Wp) if layer.weighted else 0.0  # Eq. 9
            t_link_w = 0.0
        if xfer and isd > 1:
            t_ifm = t_ifm / isd                                  # Eq. 20 (corrected: IFM tile size)
            t_link_i = t.Tn * t.Tr * t.Tc / (ports.b2b * isd)    # Eq. 19 (corrected)
        else:
            t_link_i = 0.0
        t_reduce = 0.0
        if p.Pn > 1:
            # partial-sum exchange per OFM tile over links (TPU extension)
            t_reduce = 2 * t.Tm * t.Tr * t.Tc * (p.Pn - 1) / (ports.b2b * p.Pn)

        return self._assemble(layer, t, B, R, C, M, N, t_comp, t_ifm, t_wei,
                              t_ofm, t_link_w, t_link_i, t_reduce)

    # ---------------- time domain (TPU v5e) ----------------
    def seconds(self, layer: ConvLayer, t: Tiling, ports: Optional[Ports] = None,
                p: PartitionFactors = PartitionFactors(),
                xfer: bool = False, dtype: str = "bfloat16") -> LayerLatency:
        """Same pipeline algebra with physical units.

        Streams share the HBM bus: ports are fractions of `hbm_bandwidth`
        (Eq. 7 analogue: ΣBITs·ports ≤ W  →  Σφ ≤ 1). The MAC array is the
        MXU; link terms use the ICI ring bandwidth of the axis carrying the
        exchange.
        """
        ports = (ports or Ports()).normalized()
        t = t.clamp(layer, p)
        bpe = layer.bytes_per_elem
        K = layer.K
        s = self.hw_spec
        c = self.calib
        B, R, C, M, N = _device_dims(layer, p)

        flops_tile = 2.0 * K * K * t.Tr * t.Tc * t.Tm * t.Tn
        # MXU efficiency: contraction/output dims below the systolic array
        # size waste lanes (paper Eqs. 1–2 analogue).
        eff = min(t.Tm / s.mxu_dim, 1.0) * min(t.Tn / s.mxu_dim, 1.0)
        eff = max(eff, 1e-3) if (t.Tm < s.mxu_dim or t.Tn < s.mxu_dim) else 1.0
        t_comp = flops_tile / (s.matmul_flops_per_s(dtype) * eff * c.flops_scale)

        bw = s.hbm_bandwidth * c.hbm_scale
        t_ifm = t.Tn * t.Tr * t.Tc * bpe / (ports.Ip * bw)
        t_ofm = t.Tm * t.Tr * t.Tc * bpe / (ports.Op * bw)
        wsd, isd = p.weight_shared_degree, p.ifm_shared_degree
        ici = s.ici_axis_bandwidth() * c.ici_scale
        if layer.weighted and xfer and wsd > 1:
            wtile = t.Tm * t.Tn * K * K * bpe
            t_wei = wtile / (ports.Wp * bw * wsd)                       # Eq. 16
            t_link_w = wtile * (wsd - 1) / wsd / ici                    # Eq. 17 (ring)
        else:
            t_wei = (t.Tm * t.Tn * K * K * bpe / (ports.Wp * bw)) if layer.weighted else 0.0
            t_link_w = 0.0
        if xfer and isd > 1:
            itile = t.Tn * t.Tr * t.Tc * bpe
            t_ifm = t_ifm / isd                                          # Eq. 20
            t_link_i = itile * (isd - 1) / isd / ici                     # Eq. 19
        else:
            t_link_i = 0.0
        t_reduce = 0.0
        if p.Pn > 1:
            otile = t.Tm * t.Tr * t.Tc * bpe
            t_reduce = 2.0 * otile * (p.Pn - 1) / p.Pn / ici

        return self._assemble(layer, t, B, R, C, M, N, t_comp, t_ifm, t_wei,
                              t_ofm, t_link_w, t_link_i, t_reduce,
                              overhead=c.overhead_s)

    def calibrated(self, calib: Calibration) -> "TilePipelineModel":
        """A copy of this model with fitted constants applied."""
        return dataclasses.replace(self, calib=calib)

    # ---------------- shared pipeline algebra (Eqs. 12–14) ----------------
    @staticmethod
    def _assemble(layer, t, B, R, C, M, N, t_comp, t_ifm, t_wei, t_ofm,
                  t_link_w, t_link_i, t_reduce, overhead: float = 0.0) -> LayerLatency:
        trip_inner = _ceil_div(N, t.Tn)                      # loop C
        trip_outer = B * _ceil_div(R, t.Tr) * _ceil_div(C, t.Tc) * _ceil_div(M, t.Tm)
        lat1 = max(t_comp, t_ifm, t_wei, t_link_w, t_link_i)  # Eq. 12/18/21
        lat2 = max(trip_inner * lat1 + t_reduce, t_ofm)       # Eq. 13
        total = trip_outer * lat2 + (t_ofm + lat1) + overhead  # Eq. 14 (+dispatch)
        return LayerLatency(
            t_comp=t_comp, t_ifm=t_ifm, t_wei=t_wei, t_ofm=t_ofm,
            t_link_w=t_link_w, t_link_i=t_link_i, t_reduce=t_reduce,
            lat1=lat1, lat2=lat2, total=total,
            trip_outer=trip_outer, trip_inner=trip_inner,
        )

    # ---------------- resource constraints (paper Eqs. 1–7) ----------------
    def vmem_ok(self, layer: ConvLayer, t: Tiling, bpe: int = 2) -> bool:
        """Eqs. 3–6: double-buffered IFM/OFM/WEI tiles must fit on-chip."""
        k = layer.K
        need = 2 * bpe * (t.Tn * t.Tr * t.Tc + t.Tm * t.Tr * t.Tc + t.Tm * t.Tn * k * k)
        return need <= self.hw_spec.vmem_bytes

    def bram_usage(self, layer: ConvLayer, t: Tiling, bits: int = 16) -> int:
        """Paper Eqs. 3–5 (18Kb BRAM blocks) for parity benchmarks.

        Empirical note (validated against paper Table 4): the paper's Eq. 5
        carries a ×2 double-buffer factor, but its *reported* 16-bit designs
        only match with single-buffered weights (design C ⟨64,20⟩: 40+128+
        64·20·1 = 1448 = their figure exactly), while 32-bit designs match
        with the ×2 (design A ⟨8,32⟩: 64+16+2·8·32 = 592 = their figure).
        We reproduce the reported accounting.
        """
        k = layer.K
        wfac = 2 if bits == 32 else 1
        b_i = 2 * t.Tn * math.ceil(t.Tr * t.Tc * bits / 18432)
        b_o = 2 * t.Tm * math.ceil(t.Tr * t.Tc * bits / 18432)
        b_w = wfac * t.Tm * t.Tn * math.ceil(k * k * bits / 18432)
        return b_i + b_o + b_w

    def dsp_usage(self, t: Tiling, bits: int = 16) -> int:
        """Paper Eqs. 1–2: one MAC = 1 DSP (16b fixed) or 5 DSPs (32b float)."""
        per_mac = 1 if bits == 16 else 5
        return per_mac * t.Tm * t.Tn
