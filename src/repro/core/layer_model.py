"""Layer workload descriptors — paper §3 ① generalised to the LM zoo.

The paper describes one CNN layer as ``L = ⟨B, M, N, R, C, K⟩``. Every
dense-algebra op in an LM is expressible in exactly that vocabulary:

* a matmul ``Y[B·S, M] = X[B·S, N] @ W[N, M]`` is a 1×1 convolution with the
  sequence as the spatial extent: ``⟨B, M, N, R=S, C=1, K=1⟩``.  The paper's
  spatial partitions ``Pr``/``Pc`` therefore become sequence partitions.
* attention score/value contractions are batched matmuls with no weights.
* MoE expert MLPs are matmuls whose effective row count is the routed
  token share.

``arch_layers()`` lowers an :class:`~repro.configs.base.ArchConfig` into a
list of descriptors consumed by the analytic model and the planner.
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    """Paper §3 ①: L = ⟨B, M, N, R, C, K⟩ (+ dtype width and a tag).

    ``weighted=False`` marks ops with no weight operand (attention SDPA):
    XFER weight distribution does not apply, but spatial/batch/head
    partitions do.
    ``count`` collapses repeated identical layers (scan over depth).
    """

    name: str
    B: int
    M: int
    N: int
    R: int
    C: int
    K: int = 1
    bytes_per_elem: int = 2  # bf16
    weighted: bool = True
    count: int = 1
    # collective bytes this op *inherently* moves per device set (e.g. MoE
    # all-to-all), independent of the partition scheme:
    intrinsic_collective_bytes: float = 0.0
    # LM matmuls: batch folds into the row (token) dim, so weights are
    # streamed once per token block, not once per batch element (the
    # paper's loop order F is outermost only for CNNs with per-image reuse).
    tokens_folded: bool = False
    # attention score/value contractions: the "weight" operand is the K/V
    # activation (per batch·head), so Pm (TP) partitions the *batch* (heads)
    # and XFER weight distribution does not apply.
    pm_on_batch: bool = False
    xferable: bool = True

    # ---- aggregate workload (full layer, no tiling/partition) ----
    @property
    def macs(self) -> int:
        return self.B * self.M * self.N * self.R * self.C * self.K * self.K

    @property
    def flops(self) -> int:
        return 2 * self.macs

    @property
    def ifm_elems(self) -> int:
        return self.B * self.N * self.R * self.C  # stride-1, K-halo ignored

    @property
    def ofm_elems(self) -> int:
        return self.B * self.M * self.R * self.C

    @property
    def wei_elems(self) -> int:
        return self.M * self.N * self.K * self.K if self.weighted else 0

    @property
    def ifm_bytes(self) -> int:
        return self.ifm_elems * self.bytes_per_elem

    @property
    def ofm_bytes(self) -> int:
        return self.ofm_elems * self.bytes_per_elem

    @property
    def wei_bytes(self) -> int:
        return self.wei_elems * self.bytes_per_elem

    def arithmetic_intensity(self) -> float:
        return self.flops / max(1, self.ifm_bytes + self.ofm_bytes + self.wei_bytes)


# ---------------------------------------------------------------------------
# AlexNet conv layers (paper Tables 1/3/4 vehicle) — for benchmark parity.
# ---------------------------------------------------------------------------

def alexnet_layers(batch: int = 1) -> List[ConvLayer]:
    return [
        ConvLayer("conv1", batch, 96, 3, 55, 55, 11),
        ConvLayer("conv2", batch, 256, 48, 27, 27, 5),
        ConvLayer("conv3", batch, 384, 256, 13, 13, 3),
        ConvLayer("conv4", batch, 384, 192, 13, 13, 3),
        ConvLayer("conv5", batch, 256, 192, 13, 13, 3),
    ]


# ---------------------------------------------------------------------------
# LM architectures → descriptor lists
# ---------------------------------------------------------------------------

def _attn_descriptors(arch: ArchConfig, B: int, S: int, kv_len: int, tag: str,
                      count: int, window: int = 0) -> List[ConvLayer]:
    d, qd, kvd = arch.d_model, arch.q_dim, arch.kv_dim
    eff_kv = min(kv_len, window) if window else kv_len
    out = [
        ConvLayer(f"{tag}.qkv", B, qd + 2 * kvd, d, S, 1, count=count,
                  tokens_folded=True),
        # SDPA: two batched matmuls over heads; the K/V operand plays the
        # "weight" role (streamed from HBM per head) but is not XFERable.
        ConvLayer(f"{tag}.scores", B * arch.num_heads, eff_kv, arch.head_dim, S, 1,
                  count=count, pm_on_batch=True, xferable=False),
        ConvLayer(f"{tag}.values", B * arch.num_heads, arch.head_dim, eff_kv, S, 1,
                  count=count, pm_on_batch=True, xferable=False),
        ConvLayer(f"{tag}.out", B, d, qd, S, 1, count=count, tokens_folded=True),
    ]
    return out


def _mlp_descriptors(arch: ArchConfig, B: int, S: int, d_ff: int, tag: str,
                     count: int) -> List[ConvLayer]:
    if d_ff == 0 or arch.mlp == "none":
        return []
    d = arch.d_model
    gates = 2 if arch.mlp in ("swiglu", "geglu") else 1
    return [
        ConvLayer(f"{tag}.mlp_up", B, gates * d_ff, d, S, 1, count=count, tokens_folded=True),
        ConvLayer(f"{tag}.mlp_down", B, d, d_ff, S, 1, count=count, tokens_folded=True),
    ]


def _recurrent_descriptors(arch: ArchConfig, B: int, S: int, kind: str, tag: str,
                           count: int) -> List[ConvLayer]:
    d = arch.d_model
    if kind == "rglru":
        w = arch.lru_width or d
        return [
            ConvLayer(f"{tag}.in_proj", B, 2 * w, d, S, 1, count=count, tokens_folded=True),
            ConvLayer(f"{tag}.gates", B, 2 * w, w // max(arch.num_heads, 1), S, 1, count=count, tokens_folded=True),
            ConvLayer(f"{tag}.scan", B, 1, 1, S, w, weighted=False, count=count),  # elementwise recurrence
            ConvLayer(f"{tag}.out_proj", B, d, w, S, 1, count=count, tokens_folded=True),
        ]
    if kind == "mlstm":
        w = 2 * d
        hd = w // max(arch.num_heads, 1)
        return [
            ConvLayer(f"{tag}.up_proj", B, 2 * w, d, S, 1, count=count, tokens_folded=True),
            ConvLayer(f"{tag}.qkv", B, 3 * hd * arch.num_heads, w, S, 1, count=count, tokens_folded=True),
            ConvLayer(f"{tag}.mem", B * arch.num_heads, hd, hd, S, 1, count=count, pm_on_batch=True, xferable=False),
            ConvLayer(f"{tag}.down_proj", B, d, w, S, 1, count=count, tokens_folded=True),
        ]
    if kind == "slstm":
        return [
            ConvLayer(f"{tag}.gates4", B, 4 * d, d, S, 1, count=count, tokens_folded=True),
            ConvLayer(f"{tag}.rec4", B, 4 * d, d // max(arch.num_heads, 1), S, 1, count=count, tokens_folded=True),
        ]
    raise ValueError(kind)


def arch_layers(arch: ArchConfig, shape: ShapeConfig) -> List[ConvLayer]:
    """Lower (arch, shape) to descriptors of the per-step workload.

    train: full forward over ``seq_len`` (bwd modelled as 2× fwd by callers);
    prefill: forward over ``seq_len``; decode: S=1 with kv_len=seq_len.
    """
    B = shape.global_batch
    if shape.kind in ("train", "prefill"):
        S, kv = shape.seq_len, shape.seq_len
    else:  # decode: one new token against a cache of seq_len
        S, kv = 1, shape.seq_len

    out: List[ConvLayer] = []
    d = arch.d_model

    if arch.family == "encdec":
        src = shape.seq_len
        tgt = S if shape.kind == "decode" else max(shape.seq_len // 8, 1)
        if shape.kind != "decode":  # decode reuses the cached encoder output
            out += _attn_descriptors(arch, B, src, src, "enc.attn", arch.enc_layers)
            out += _mlp_descriptors(arch, B, src, arch.d_ff, "enc", arch.enc_layers)
        out += _attn_descriptors(arch, B, tgt, tgt if shape.kind != "decode" else kv, "dec.self",
                                 arch.dec_layers)
        out += _attn_descriptors(arch, B, tgt, src, "dec.cross", arch.dec_layers)
        out += _mlp_descriptors(arch, B, tgt, arch.d_ff, "dec", arch.dec_layers)
        out.append(ConvLayer("unembed", B, arch.vocab_size, d, tgt, 1, tokens_folded=True))
        return out

    # group layers by kind so identical ones collapse into `count`
    kinds = arch.layer_kinds()
    from collections import Counter
    kind_counts = Counter(kinds)
    for kind, count in sorted(kind_counts.items()):
        if kind == "attn":
            n_moe = 0
            if arch.family == "moe":
                n_moe = max(0, count - arch.first_dense_layers)
                n_dense = count - n_moe
            else:
                n_dense = count
            win = arch.window if arch.family == "hybrid" else 0
            out += _attn_descriptors(arch, B, S, kv, "attn", count, window=win)
            if n_dense and arch.d_ff:
                out += _mlp_descriptors(arch, B, S, arch.d_ff, "dense", n_dense)
            if n_moe:
                ff = arch.moe_d_ff or arch.d_ff
                gates = 2 if arch.mlp in ("swiglu", "geglu") else 1
                tokens = B * S
                routed = tokens * arch.top_k
                out.append(ConvLayer("moe.router", B, arch.num_experts, d, S, 1, count=n_moe, tokens_folded=True))
                # routed experts: total rows = tokens*top_k spread over experts
                out.append(ConvLayer("moe.up", 1, gates * ff, d, routed, 1, count=n_moe, tokens_folded=True,
                                     intrinsic_collective_bytes=2 * routed * d * 2))
                out.append(ConvLayer("moe.down", 1, d, ff, routed, 1, count=n_moe, tokens_folded=True))
                if arch.num_shared_experts:
                    out += _mlp_descriptors(arch, B, S, ff * arch.num_shared_experts,
                                            "moe.shared", n_moe)
        else:
            out += _recurrent_descriptors(arch, B, S, kind, kind, count)
            if arch.d_ff:
                out += _mlp_descriptors(arch, B, S, arch.d_ff, f"{kind}.mlp", count)

    out.append(ConvLayer("unembed", B, arch.vocab_size, d, S, 1, tokens_folded=True))
    return out


def dataclasses_replace_dff(arch: ArchConfig, ff: int) -> ArchConfig:
    import dataclasses as _dc
    return _dc.replace(arch, d_ff=ff)


def total_flops(layers: List[ConvLayer], backward: bool = False) -> float:
    f = sum(l.flops * l.count for l in layers)
    return f * 3 if backward else f


def model_flops_estimate(arch: ArchConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference), N = active params."""
    n = arch.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n * tokens
