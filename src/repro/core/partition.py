"""Partition factors ⟨Pb, Pr, Pc, Pm, Pn⟩ — paper §4.2.

Share-class taxonomy (paper Fig. 7):
  * ``Pb``/``Pr``/``Pc`` (batch / rows / cols) — **weight-shared**: every
    partition needs the whole weight tensor. On an LM: DP (batch) and SP
    (sequence — the spatial extent).
  * ``Pm`` (OFM channels) — **IFM-shared**: every partition needs the whole
    input activation. On an LM: TP column-parallel (features/heads/experts/
    vocab).
  * ``Pn`` (IFM channels) — **OFM-shared**: partitions produce partial sums.
    The paper rejects it (P3: partial sums would move through CPU-managed
    DRAM); on TPU the reduction is one fused reduce-scatter on ICI, so we
    admit it with its collective cost (DESIGN.md §7.1).

XFER (paper §4.3) applies to the *shared* tensor of the chosen class: shard
it over the partitions and exchange over inter-device links instead of
re-reading it from local memory.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple


@dataclasses.dataclass(frozen=True)
class PartitionFactors:
    Pb: int = 1  # batch (DP)
    Pr: int = 1  # rows = sequence (SP)
    Pc: int = 1  # cols (second spatial dim; 1 for LMs, used for CNN parity)
    Pm: int = 1  # OFM channels (TP column-parallel / heads / experts / vocab)
    Pn: int = 1  # IFM channels (TP row-parallel)

    @property
    def total(self) -> int:
        return self.Pb * self.Pr * self.Pc * self.Pm * self.Pn

    @property
    def weight_shared_degree(self) -> int:
        """#devices that need the same weight shard (paper Eq. 16 divisor)."""
        return self.Pb * self.Pr * self.Pc

    @property
    def ifm_shared_degree(self) -> int:
        return self.Pm

    @property
    def ofm_shared_degree(self) -> int:
        return self.Pn

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)

    def validate(self, B: int, R: int, C: int, M: int, N: int) -> bool:
        """A factor may not exceed the dimension it splits."""
        return (self.Pb <= max(B, 1) and self.Pr <= max(R, 1) and
                self.Pc <= max(C, 1) and self.Pm <= max(M, 1) and
                self.Pn <= max(N, 1))


def factorizations(n: int, dims: int) -> Iterator[Tuple[int, ...]]:
    """All ordered tuples of `dims` positive ints whose product is n."""
    if dims == 1:
        yield (n,)
        return
    for d in _divisors(n):
        for rest in factorizations(n // d, dims - 1):
            yield (d,) + rest


def _divisors(n: int):
    return [d for d in range(1, n + 1) if n % d == 0]


def enumerate_partitions(num_devices: int, B: int, R: int, C: int, M: int, N: int,
                         allow_pn: bool = True) -> Iterator[PartitionFactors]:
    """Paper §4.2/§4.4: all 2-D-array organisations of `num_devices`.

    `allow_pn=False` reproduces the paper's original space (OFM-shared
    rejected by P3).
    """
    seen = set()
    for fb, fr, fc, fm, fn in factorizations(num_devices, 5):
        if not allow_pn and fn > 1:
            continue
        p = PartitionFactors(fb, fr, fc, fm, fn)
        if p in seen:
            continue
        seen.add(p)
        if p.validate(B, R, C, M, N):
            yield p


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """A partition mapped onto named mesh axes.

    ``axis_map``: partition dim → mesh axis name(s). The paper's 2-D torus
    organisation (§4.4: Pm columns × Pb·Pr·Pc rows) becomes the ("data",
    "model") mesh: weight-shared factors on "data"(, "pod"), Pm/Pn on
    "model".
    ``xfer``: distribute shared tensors + exchange over ICI (paper §4.3);
    ``False`` = the paper's replicate-shared-data baseline (Fig. 7f/g).
    """

    factors: PartitionFactors
    axis_map: Dict[str, Tuple[str, ...]]  # e.g. {"Pb": ("pod","data"), "Pm": ("model",)}
    xfer: bool = True

    def axes_for(self, dim: str) -> Tuple[str, ...]:
        return self.axis_map.get(dim, ())
