"""TPU v5e hardware constants — the roofline terms are expressed in these.

The paper's platform constants (ZCU102: DSP count, BRAM count, memory-bus
width W, inter-FPGA NB) map to the TPU quantities below; see DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """One accelerator chip + its torus links."""

    name: str = "tpu-v5e"
    # Compute roof (paper: DSP array size, Eqs. 1-2).
    peak_flops_bf16: float = 197e12  # FLOP/s
    peak_flops_f32: float = 197e12 / 4
    # Memory-bus roof (paper: off-chip DDR via AXI, width W, Eq. 7).
    hbm_bandwidth: float = 819e9  # bytes/s
    hbm_bytes: int = 16 * 2**30  # capacity per chip
    # On-chip buffer (paper: BRAM count B, Eq. 6).
    vmem_bytes: int = 128 * 2**20
    # Inter-device links (paper: SFP+/Aurora, NB in Eq. 22).
    ici_bandwidth_per_link: float = 50e9  # bytes/s, per direction
    ici_links_per_axis: int = 2  # torus: +1/-1 neighbours on each mesh axis
    ici_hop_latency: float = 1e-6  # per-hop launch/forward latency (s)
    # Systolic array geometry (paper: Tm x Tn MAC array).
    mxu_dim: int = 128
    # Lane/sublane tiling for non-matmul ops.
    lane: int = 128
    sublane: int = 8

    def matmul_flops_per_s(self, dtype: str = "bfloat16") -> float:
        return self.peak_flops_bf16 if dtype in ("bfloat16", "bf16") else self.peak_flops_f32

    def ici_axis_bandwidth(self, wraparound: bool = True) -> float:
        """Bi-directional ring bandwidth available along one torus axis."""
        n = self.ici_links_per_axis if wraparound else 1
        return self.ici_bandwidth_per_link * n


V5E = HardwareSpec()

# Collective cost helpers (ring algorithms on a torus axis). These are the
# TPU analogues of the paper's Eq. 17/19 link terms and are used by both the
# analytic model (core/perf_model.py) and the planner feasibility check
# (core/topology.py, paper Eq. 22).


def _lat(axis_size: int, hw: HardwareSpec) -> float:
    """Ring-collective launch latency: (P-1) store-and-forward hops."""
    return (axis_size - 1) * hw.ici_hop_latency


def all_gather_time(bytes_per_device: float, axis_size: int, hw: HardwareSpec = V5E) -> float:
    """Ring all-gather of a tensor sharded over `axis_size` devices.

    Each device receives (P-1)/P of the full tensor over the axis ring.
    `bytes_per_device` is the *shard* each device holds.
    """
    if axis_size <= 1:
        return 0.0
    total = bytes_per_device * axis_size
    return total * (axis_size - 1) / axis_size / hw.ici_axis_bandwidth() + _lat(axis_size, hw)


def reduce_scatter_time(bytes_full: float, axis_size: int, hw: HardwareSpec = V5E) -> float:
    if axis_size <= 1:
        return 0.0
    return bytes_full * (axis_size - 1) / axis_size / hw.ici_axis_bandwidth() + _lat(axis_size, hw)


def all_reduce_time(bytes_full: float, axis_size: int, hw: HardwareSpec = V5E) -> float:
    # ring all-reduce = reduce-scatter + all-gather
    if axis_size <= 1:
        return 0.0
    return (2.0 * bytes_full * (axis_size - 1) / axis_size / hw.ici_axis_bandwidth()
            + 2.0 * _lat(axis_size, hw))


def all_to_all_time(bytes_full: float, axis_size: int, hw: HardwareSpec = V5E) -> float:
    if axis_size <= 1:
        return 0.0
    # each device keeps 1/P, sends (P-1)/P spread over the ring; on a torus
    # ring the bisection limits this to ~bytes/4 per direction per hop-chain.
    return (bytes_full * (axis_size - 1) / axis_size / hw.ici_axis_bandwidth()
            + _lat(axis_size, hw))
