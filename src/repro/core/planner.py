"""Design-space exploration — paper Eq. 15 (INLP → enumerative search).

Two search layers, mirroring Figure 1:

* ①–③ accelerator design space: tiling ⟨Tm,Tn,Tr,Tc⟩ × port split
  ⟨Ip,Wp,Op⟩ per layer, constrained by VMEM (Eqs. 3–6) and MXU geometry
  (Eqs. 1–2).
* ④–⑥ multi-device design space: partition factors ⟨Pb,Pr,Pc,Pm,Pn⟩
  mapped onto the named mesh axes, XFER on/off, constrained by torus
  bandwidth (Eq. 22).

Uniform partition factors across layers (paper §4.5 P3 — keeps the residual
stream in-situ); tiling/ports are free per layer (XLA recompiles per op at
zero cost, DESIGN.md §7.2).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence, Tuple

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import hw
from repro.core.layer_model import ConvLayer, arch_layers
from repro.core.partition import PartitionFactors
from repro.core.perf_model import LayerLatency, Ports, TilePipelineModel, Tiling

_TILINGS = [
    Tiling(128, 128, 256), Tiling(128, 128, 1024), Tiling(128, 128, 4096),
    Tiling(256, 256, 256), Tiling(256, 256, 1024),
    Tiling(512, 512, 128), Tiling(128, 512, 512), Tiling(512, 128, 512),
    Tiling(1024, 1024, 512), Tiling(1024, 1024, 1024),
    Tiling(2048, 2048, 512), Tiling(2048, 1024, 1024), Tiling(4096, 2048, 256),
]
_PORTS = [Ports(2, 2, 2), Ports(4, 8, 4), Ports(1, 1, 6), Ports(6, 1, 1),
          Ports(1, 6, 1), Ports(4, 1, 3), Ports(3, 1, 4)]

# Capacity rule shared with testing/invariants.py: a plan "fits" when its
# residency stays under this fraction of per-chip HBM (fragmentation +
# runtime headroom), retrying trains with int8 Adam states (note below).
HBM_HEADROOM = 0.92
INT8_NOTE = "requires int8 Adam states"
#: serving analog of the int8-Adam retry: the cell only fits with the
#: INT8 serving path, so the DSE selected it automatically.
AUTO_QUANT_NOTE = "auto-selected int8 serving quantization"


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """Mesh-axis role assignment — the output of the multi-device DSE.

    This is the JAX-facing form of the paper's 2-D torus organisation
    (§4.4): ``tp_axes`` play the column (IFM-shared / Pm) role; ``batch_axes``
    + ``seq_axes`` play the row (weight-shared / Pb·Pr·Pc) role; ``xfer``
    chooses between replicating the shared weights (paper Fig. 7 baseline)
    and distributing + exchanging them over ICI (paper Fig. 8 XFER).
    """

    mesh_axes: Tuple[Tuple[str, int], ...]  # ordered (name, size)
    batch_axes: Tuple[str, ...] = ()
    seq_axes: Tuple[str, ...] = ()
    tp_axes: Tuple[str, ...] = ("model",)
    xfer: bool = True
    ep_axes: Tuple[str, ...] = ()  # expert-parallel axes (subset of tp_axes)

    def axis_size(self, name: str) -> int:
        return dict(self.mesh_axes)[name]

    def degree(self, axes: Sequence[str]) -> int:
        d = 1
        for a in axes:
            d *= self.axis_size(a)
        return d

    @property
    def factors(self) -> PartitionFactors:
        return PartitionFactors(
            Pb=self.degree(self.batch_axes),
            Pr=self.degree(self.seq_axes),
            Pc=1,
            Pm=self.degree(self.tp_axes),
            Pn=1,
        )

    @property
    def num_devices(self) -> int:
        return self.degree([n for n, _ in self.mesh_axes])

    def describe(self) -> str:
        f = self.factors
        return (f"Pb={f.Pb}({'+'.join(self.batch_axes) or '-'}) "
                f"Pr={f.Pr}({'+'.join(self.seq_axes) or '-'}) "
                f"Pm={f.Pm}({'+'.join(self.tp_axes) or '-'}) "
                f"xfer={'on' if self.xfer else 'off'}"
                + (f" ep={'+'.join(self.ep_axes)}" if self.ep_axes else ""))


@dataclasses.dataclass(frozen=True)
class PlanReport:
    plan: ShardingPlan
    predicted_seconds: float
    per_layer: Tuple[Tuple[str, float, str], ...]  # (name, seconds, bottleneck)
    feasible: bool  # Eq. 22: XFER exchanges hide behind the pipeline beat
    hbm_bytes_per_device: float = 0.0
    fits_hbm: bool = True
    note: str = ""
    # accelerator-level DSE output (paper ①–③): the winning ⟨Tm,Tn,Tr,Tc⟩ ×
    # ⟨Ip,Wp,Op⟩ per layer, consumed by ExecutionPlan for deployment.
    layer_choices: Tuple[Tuple[str, Tiling, Ports], ...] = ()


def capacity_bytes(arch: ArchConfig, shape: ShapeConfig, plan: ShardingPlan,
                   hw_spec: Optional[hw.HardwareSpec] = None,
                   opt_bytes_per_param: float = 8.0,
                   quant=None, draft: Optional[ArchConfig] = None) -> float:
    """Per-device HBM residency estimate — the capacity side of the DSE.

    The paper's Eq. 6 bounds on-chip BRAM; the pod-scale analogue bounds
    per-chip HBM: params (+ optimizer states for training, + KV cache for
    decode, + remat'd activations). This is what makes XFER weight
    distribution *mandatory* for large-model training on 16 GB chips even
    when the pure-time model is indifferent (DESIGN.md §7.4).

    ``quant`` (a :class:`repro.quant.QuantConfig`) shrinks the serving-path
    bytes: int8 weights drop params to 1 B/elem, int8 KV drops the cache to
    ``1 + 4/head_dim`` B/elem (payload + amortised per-token f32 scale).

    ``draft`` (speculative decoding) co-places a second, smaller model on
    the same mesh: its params + KV rows are resident alongside the
    target's, so the draft footprint is added recursively (full precision
    — quantization applies to the target only).
    """
    bpe = 2  # bf16
    param_bpe = quant.param_bytes_per_elem(bpe) if quant is not None else bpe
    kv_bpe = (quant.kv_bytes_per_elem(bpe, arch.head_dim)
              if quant is not None else bpe)
    f = plan.factors
    p_total = arch.param_count() * param_bpe
    tp = max(f.Pm * f.Pn, 1)
    wsd = max(f.weight_shared_degree, 1)
    if arch.family == "moe":
        # Expert weights shard E over the EP axes only (E rarely divides the
        # full TP degree) plus their input dim over the XFER group; the rest
        # of the params shard over full TP (matches models/blocks.attn_dims).
        ep_deg = max(plan.degree(plan.ep_axes), 1)
        if ep_deg and arch.num_experts % ep_deg != 0:
            ep_deg = 1
        ff = arch.moe_d_ff or arch.d_ff
        gates = 3 if arch.mlp in ("swiglu", "geglu") else 2
        n_moe = sum(1 for i in range(arch.num_layers)
                    if i >= arch.first_dense_layers and arch.block_kind(i) == "attn")
        expert_total = n_moe * arch.num_experts * gates * arch.d_model * ff * bpe
        rest_total = max(p_total - expert_total, 0)
        params_dev = (expert_total / ep_deg / (wsd if plan.xfer else 1)
                      + rest_total / tp / (wsd if plan.xfer else 1))
    else:
        params_dev = p_total / tp / (wsd if plan.xfer else 1)
    total = params_dev
    B, S = shape.global_batch, shape.seq_len
    b_loc = max(B // max(f.Pb, 1), 1)
    s_loc = max(S // max(f.Pr, 1), 1)
    if shape.kind == "train":
        # ZeRO-1: optimizer states (m, v) always shard over the full
        # weight-sharing group; gradients shard like params after
        # reduce-scatter (+ one live layer during backward).
        opt_dev = arch.param_count() * opt_bytes_per_param / tp / wsd
        grads_dev = p_total / tp / (wsd if plan.xfer else 1)
        # remat: per-layer saved residual stream, sequence-parallel over the
        # tp axis as well (Megatron-SP; DESIGN.md beyond-paper §SP).
        resid = arch.num_layers * b_loc * s_loc * arch.d_model * bpe / tp
        work = b_loc * s_loc * max(3 * (arch.d_ff or 2 * arch.d_model) // max(tp, 1),
                                   arch.d_model) * bpe * 2
        # chunked-CE logits working set (vocab sharded over tp)
        logits = b_loc * min(s_loc, 512) * (arch.vocab_size // max(tp, 1)) * 4
        total += opt_dev + grads_dev + resid + work + logits
    else:
        # KV cache (attention archs) / recurrent state (ssm/hybrid)
        kinds = arch.layer_kinds()
        n_attn = sum(1 for k in kinds if k == "attn")
        eff = min(S, arch.window) if arch.window else S
        kv = n_attn * 2 * b_loc * eff * arch.kv_dim * kv_bpe / max(tp if arch.kv_dim % tp == 0 else 1, 1)
        state = (len(kinds) - n_attn) * b_loc * max(arch.lru_width, 2 * arch.d_model) * 4
        act = b_loc * max(s_loc if shape.kind == "prefill" else 1, 1) * arch.d_model * bpe * 4
        total += kv + state + act
    if draft is not None and shape.kind != "train":
        total += capacity_bytes(draft, shape, plan, hw_spec)
    return total


def _layer_best(model: TilePipelineModel, layer: ConvLayer, p: PartitionFactors,
                xfer: bool) -> Tuple[float, LayerLatency, Tiling, Ports]:
    best = None
    for t in _TILINGS:
        tc = t.clamp(layer, p)
        if not model.vmem_ok(layer, tc, layer.bytes_per_elem):
            continue
        for ports in _PORTS:
            lat = model.seconds(layer, tc, ports, p, xfer=xfer and layer.weighted)
            if best is None or lat.total < best[0]:
                best = (lat.total, lat, tc, ports)
    if best is None:  # fall back to smallest tiling even if VMEM-tight
        tc = Tiling(128, 128, 128).clamp(layer, p)
        lat = model.seconds(layer, tc, _PORTS[0], p, xfer=xfer and layer.weighted)
        best = (lat.total, lat, tc, _PORTS[0])
    return best


def evaluate_plan(arch: ArchConfig, shape: ShapeConfig, plan: ShardingPlan,
                  model: Optional[TilePipelineModel] = None,
                  quant=None, draft: Optional[ArchConfig] = None) -> PlanReport:
    """Score a plan with the analytic model.

    Structure (paper's pipeline-of-maxes, applied at three levels):
      tile level   — Eqs. 8–14: HBM streams vs MXU inside one layer;
      layer level  — XFER weight gathers prefetched one layer ahead
                     overlap the previous layer's compute: the effective
                     cost is ``max(layer, gather)`` (paper Fig. 6 at layer
                     granularity);
      step level   — TP activation collectives sit on the critical path
                     (summed); gradient sync overlaps the backward scan
                     (``max(bwd, sync)``).
    """
    model = model or TilePipelineModel()
    s = model.hw_spec
    p = plan.factors
    tp = max(p.Pm * p.Pn, 1)
    wsd = max(p.weight_shared_degree, 1)
    layers = arch_layers(arch, shape)
    rows: List[Tuple[str, float, str]] = []
    choices: List[Tuple[str, Tiling, Ports]] = []
    feasible = True
    fwd = 0.0
    xfer_gather = 0.0   # ICI: weight all-gathers (paper Eq. 17 at layer level)
    act_coll = 0.0      # ICI: TP activation ag/rs pairs (Megatron-style)
    moe_a2a = 0.0       # ICI: MoE token all-to-all
    wei_bytes_dev = 0.0
    for layer in layers:
        sec, lat, tiling, ports = _layer_best(model, layer, p, xfer=False)
        fwd += sec * layer.count
        rows.append((layer.name, sec * layer.count, lat.bottleneck))
        choices.append((layer.name, tiling, ports))
        if layer.weighted and layer.xferable:
            wb_dev = layer.wei_bytes / tp
            wei_bytes_dev += wb_dev * layer.count
            if plan.xfer and wsd > 1:
                xfer_gather += layer.count * hw.all_gather_time(wb_dev / wsd, wsd, s)
        # Eq. 22 at layer granularity: the weight exchange for this layer
        # must hide behind the layer's own pipeline time (D_col ≤ NB·Lat).
        # Exposure is captured by the step-level max(); `feasible` only
        # reports whether the overlap holds (paper's constraint).
        if (plan.xfer and wsd > 1 and layer.weighted and layer.xferable):
            need = layer.wei_bytes / tp * (wsd - 1) / wsd
            budget = s.ici_axis_bandwidth() * sec
            feasible = feasible and (need <= budget)
        if layer.intrinsic_collective_bytes:
            moe_a2a += layer.count * hw.all_to_all_time(
                layer.intrinsic_collective_bytes / max(p.total, 1), tp, s)
    # TP activation collectives: ag+rs pair per projection boundary.
    if tp > 1:
        bpe = 2
        b_loc = max(shape.global_batch // max(p.Pb, 1), 1)
        s_loc = (max(shape.seq_len // max(p.Pr, 1), 1)
                 if shape.kind in ("train", "prefill") else 1)
        act_bytes = b_loc * s_loc * arch.d_model * bpe
        n_blocks = arch.num_layers + (arch.dec_layers if arch.family == "encdec" else 0)
        act_coll = n_blocks * 2 * (hw.all_gather_time(act_bytes / tp, tp, s)
                                   + hw.reduce_scatter_time(act_bytes, tp, s))

    if shape.kind == "train":
        bwd = 2.0 * fwd
        if plan.xfer and wsd > 1:
            # ZeRO-3: re-gather weights in bwd + reduce-scatter grads
            sync = xfer_gather + sum(
                hw.reduce_scatter_time(l.wei_bytes / tp, wsd, s) * l.count
                for l in layers if l.weighted and l.xferable)
        else:
            sync = hw.all_reduce_time(wei_bytes_dev, wsd, s) if wsd > 1 else 0.0
        total = max(fwd, xfer_gather) + max(bwd, sync) + act_coll * 3 + moe_a2a * 3
    else:
        total = max(fwd, xfer_gather) + act_coll + moe_a2a
        # decode cannot hide the gather behind a tiny step: if gather
        # exceeds compute the difference is exposed (modelled by the max).
    cap = capacity_bytes(arch, shape, plan, s, quant=quant, draft=draft)
    fits = cap <= HBM_HEADROOM * s.hbm_bytes
    note = ""
    if not fits and shape.kind == "train":
        # retry with blockwise-int8 Adam states (optim/adamw.py quantized=True)
        cap8 = capacity_bytes(arch, shape, plan, s, opt_bytes_per_param=2.0)
        if cap8 <= HBM_HEADROOM * s.hbm_bytes:
            cap, fits, note = cap8, True, INT8_NOTE
    return PlanReport(plan, total, tuple(rows), feasible,
                      hbm_bytes_per_device=cap, fits_hbm=fits, note=note,
                      layer_choices=tuple(choices))


def candidate_plans(arch: ArchConfig, shape: ShapeConfig,
                    mesh_axes: Sequence[Tuple[str, int]]) -> List[ShardingPlan]:
    """Enumerate axis-role assignments valid for (arch, shape)."""
    mesh_axes = tuple(mesh_axes)
    names = [n for n, _ in mesh_axes]
    sizes = dict(mesh_axes)
    data_like = [n for n in names if n != "model"]
    plans: List[ShardingPlan] = []

    B, S = shape.global_batch, shape.seq_len
    seq_shardable = shape.kind in ("train", "prefill")

    # every subset split of data-like axes between batch and seq roles
    for k in range(len(data_like) + 1):
        for batch_set in itertools.combinations(data_like, k):
            seq_set = tuple(n for n in data_like if n not in batch_set)
            pb = 1
            for n in batch_set:
                pb *= sizes[n]
            pr = 1
            for n in seq_set:
                pr *= sizes[n]
            if B % pb != 0 or B < pb:
                continue
            if seq_set and (not seq_shardable or S % pr != 0):
                # decode: seq axis can still host extra TP (weight-stationary)
                for xfer in (False, True):
                    plans.append(ShardingPlan(
                        mesh_axes, batch_axes=batch_set, seq_axes=(),
                        tp_axes=tuple(seq_set) + ("model",), xfer=xfer,
                        ep_axes=("model",) if arch.family == "moe" else ()))
                continue
            for xfer in (False, True):
                plans.append(ShardingPlan(
                    mesh_axes, batch_axes=batch_set, seq_axes=seq_set,
                    tp_axes=("model",), xfer=xfer,
                    ep_axes=("model",) if arch.family == "moe" else ()))
    # dedupe (ep_axes included: MoE plans differing only in expert-parallel
    # assignment are distinct candidates)
    uniq = {}
    for p in plans:
        uniq[(p.batch_axes, p.seq_axes, p.tp_axes, p.xfer, p.ep_axes)] = p
    return list(uniq.values())


def plan_cell(arch: ArchConfig, shape: ShapeConfig,
              mesh_axes: Sequence[Tuple[str, int]],
              force_xfer: Optional[bool] = None,
              quant=None, draft: Optional[ArchConfig] = None) -> PlanReport:
    """Pick the best plan for one (arch × shape × mesh) cell — Eq. 15.

    ``quant`` threads the serving quantisation config into the capacity
    model (int8 weights / KV shrink per-device residency — a plan that is
    capacity-infeasible in bf16 can fit under INT8 serving). When a
    serving cell fits *only* quantized, the DSE retries with
    :data:`repro.quant.INT8_SERVE` automatically instead of discarding
    the cell; the winning report's note records the auto-selection
    (:data:`AUTO_QUANT_NOTE`).

    ``draft`` adds a co-placed speculative-decoding draft model to the
    capacity side (both footprints must fit the same mesh).
    """
    reports = []
    for plan in candidate_plans(arch, shape, mesh_axes):
        if force_xfer is not None and plan.xfer != force_xfer:
            continue
        reports.append(evaluate_plan(arch, shape, plan, quant=quant,
                                     draft=draft))
    ok = [r for r in reports if r.feasible and r.fits_hbm]
    if ok:
        best = min(ok, key=lambda r: r.predicted_seconds)
        # tie-break within 3%: prefer the lower-HBM (XFER) plan — capacity
        # headroom is worth a rounding error of predicted time.
        near = [r for r in ok if r.predicted_seconds <= 1.03 * best.predicted_seconds]
        return min(near, key=lambda r: r.hbm_bytes_per_device)
    if quant is None and shape.kind != "train":
        # serving analog of the int8-Adam retry: re-plan the cell under
        # INT8 serving before giving up on capacity.
        from repro.quant import INT8_SERVE
        retry = plan_cell(arch, shape, mesh_axes, force_xfer,
                          quant=INT8_SERVE, draft=draft)
        if retry.feasible and retry.fits_hbm:
            return dataclasses.replace(
                retry, note=(retry.note + "; " if retry.note else "")
                + AUTO_QUANT_NOTE)
    # constraints too strict — least-infeasible first, then time
    best = min(reports, key=lambda r: (r.hbm_bytes_per_device, r.predicted_seconds))
    return dataclasses.replace(best, note=(best.note + "; " if best.note else "")
                               + "capacity-infeasible on this mesh; best-effort")
