"""XFER — the paper's §4.3 technique as JAX shardings + collectives.

Baseline (paper Fig. 7 f/g): the *shared* tensor of a partition scheme is
replicated — every device re-reads all of it from its own memory (HBM).

XFER (paper Fig. 8): the shared tensor is *distributed* across the sharing
group; each device reads 1/P from HBM and receives the rest over the
inter-device links (ICI all-gather). For LM weights under DP/SP this is
ZeRO-3/FSDP-style weight gathering; the paper's tile-level double buffering
becomes a **one-layer-ahead weight prefetch** inside the scan
(:func:`scan_layers`), so the gather of layer *i+1* has no data dependence
on layer *i*'s compute and the XLA latency-hiding scheduler overlaps them.

All sharding decisions flow through :class:`ShardingCtx`, which turns
logical dim names into `PartitionSpec`s with divisibility checking, so the
same model code runs on a 1-device CPU test, a 256-chip pod, or a
multi-pod mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.planner import ShardingPlan

PyTree = Any


def explicit_spmd_enabled() -> bool:
    """Gate for the explicit shard_map paths (attention locality, EP
    all-to-all, flash-decoding). Default on; set REPRO_EXPLICIT_SPMD=0 to
    measure the pure-GSPMD baseline (§Perf before/after)."""
    import os
    return os.environ.get("REPRO_EXPLICIT_SPMD", "1") != "0"


def _fits(size: int, axes: Sequence[str], axis_sizes: Dict[str, int]) -> Tuple[str, ...]:
    """Longest prefix of `axes` whose product divides `size`."""
    out = []
    prod = 1
    for a in axes:
        if size % (prod * axis_sizes[a]) == 0:
            out.append(a)
            prod *= axis_sizes[a]
        else:
            break
    return tuple(out)


@dataclasses.dataclass
class ShardingCtx:
    """Logical-dim → mesh-axis resolution for one plan.

    Logical dims:
      batch, seq         activation data dims (weight-shared partitions)
      tp                 IFM-shared partition (paper Pm): heads/ff/vocab/experts
      xfer               weight input-dim distribution (paper Fig. 8) — only
                         populated when plan.xfer is on
      ep                 expert dim
      none               explicit replication
    """

    mesh: Optional[Mesh]
    plan: ShardingPlan

    def __post_init__(self):
        self.axis_sizes = dict(self.plan.mesh_axes)
        self.roles: Dict[str, Tuple[str, ...]] = {
            "batch": self.plan.batch_axes,
            "seq": self.plan.seq_axes,
            # residual-stream sequence dim: SP over the tp axis as well
            # (Megatron-SP; keeps remat'd activations 1/tp per device)
            "sp": self.plan.seq_axes + tuple(
                a for a in self.plan.tp_axes if a not in self.plan.seq_axes),
            "tp": self.plan.tp_axes,
            "xfer": (self.plan.batch_axes + self.plan.seq_axes) if self.plan.xfer else (),
            # optimizer states always shard over the weight-sharing group
            # (ZeRO-1), independent of whether params do (XFER):
            "zero": self.plan.batch_axes + self.plan.seq_axes,
            "ep": self.plan.ep_axes,
            "none": (),
        }

    # ---- spec construction ----
    def spec(self, shape: Sequence[int], dims: Sequence[Optional[str]]) -> P:
        """PartitionSpec for `shape` with logical role per dim (None = replicated).

        Axes that do not divide the dim are dropped (degrade to replication),
        and an axis is used at most once across dims.
        """
        used: set = set()
        parts = []
        for size, role in zip(shape, dims):
            if role is None or role == "none":
                parts.append(None)
                continue
            cand = tuple(a for a in self.roles.get(role, ()) if a not in used)
            ax = _fits(size, cand, self.axis_sizes)
            used.update(ax)
            if not ax:
                parts.append(None)
            elif len(ax) == 1:
                parts.append(ax[0])
            else:
                parts.append(ax)
        return P(*parts)

    def sharding(self, shape: Sequence[int], dims: Sequence[Optional[str]]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(shape, dims))

    # ---- activation constraints (the paper's "keep data in-situ", §4.5) ----
    def constrain(self, x: jax.Array, *dims: Optional[str]) -> jax.Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(x.shape, dims)))

    # ---- XFER weight gather (Fig. 8: receive remote shards over ICI) ----
    def gather_params(self, params: PyTree, specs: PyTree) -> PyTree:
        """All-gather the xfer-distributed dims of a layer's params.

        `specs`: pytree of dim-role tuples matching `params`. The gathered
        form drops the "xfer" role (weights whole on each device of the
        sharing group) but keeps "tp"/"ep" (the IFM-shared partition stays).
        """
        if self.mesh is None or not self.plan.xfer:
            return params

        def gather(leaf, dims):
            g = tuple(None if d == "xfer" else d for d in dims)
            return jax.lax.with_sharding_constraint(
                leaf, NamedSharding(self.mesh, self.spec(leaf.shape, g)))

        return jax.tree.map(gather, params, specs,
                            is_leaf=lambda x: isinstance(x, jnp.ndarray))


def _is_dims(x) -> bool:
    return isinstance(x, tuple) and all(i is None or isinstance(i, str) for i in x)


def tree_shardings(ctx: ShardingCtx, value_tree: PyTree, dims_tree: PyTree) -> PyTree:
    """Resolve a parallel tree of logical-dim tuples into NamedShardings.

    The dims tree mirrors the value tree but holds role tuples at leaf
    positions (tuples are themselves pytrees, so the two trees are
    flattened independently with a custom is_leaf and zipped).
    """
    vals, treedef = jax.tree.flatten(value_tree)
    dims, _ = jax.tree.flatten(dims_tree, is_leaf=_is_dims)
    if len(vals) != len(dims):
        raise ValueError(f"dims tree mismatch: {len(vals)} values vs {len(dims)} dim tuples")
    out = []
    for v, d in zip(vals, dims):
        if not _is_dims(d):
            raise ValueError(f"bad dims entry {d!r}")
        shape = v.shape
        d = tuple(d)[: len(shape)] + (None,) * (len(shape) - len(d))
        out.append(NamedSharding(ctx.mesh, ctx.spec(shape, d)))
    return jax.tree.unflatten(treedef, out)


def null_ctx(plan: Optional[ShardingPlan] = None) -> ShardingCtx:
    """A no-mesh ctx for CPU smoke tests: every constraint is identity."""
    plan = plan or ShardingPlan(mesh_axes=(("data", 1), ("model", 1)),
                                batch_axes=("data",), tp_axes=("model",), xfer=False)
    return ShardingCtx(mesh=None, plan=plan)


# ---------------------------------------------------------------------------
# Layer scan with one-layer-ahead XFER prefetch (paper's double buffer,
# lifted from tile level to layer level — DESIGN.md §7.3).
# ---------------------------------------------------------------------------

def scan_layers(layer_fn: Callable[[PyTree, PyTree], PyTree],
                stacked_params: PyTree,
                x: PyTree,
                ctx: Optional[ShardingCtx] = None,
                specs: Optional[PyTree] = None,
                prefetch: bool = True,
                unroll: int = 1) -> PyTree:
    """Apply ``layer_fn`` over the leading (layer) axis of ``stacked_params``.

    With ``prefetch`` and an XFER plan, iteration *i* issues the all-gather
    for layer *i*'s weights while *computing layer i-1*: the two have no
    data dependence, so compute hides the ICI exchange (paper Fig. 3/6 —
    `Lat1 = max(tComp, tW_b2b)` instead of their sum).
    """
    leaves = jax.tree.leaves(stacked_params)
    num_layers = leaves[0].shape[0]

    use_prefetch = (prefetch and ctx is not None and ctx.mesh is not None
                    and ctx.plan.xfer and specs is not None and num_layers > 1)

    if not use_prefetch:
        def body(carry, p):
            return layer_fn(p, carry), None
        x, _ = jax.lax.scan(body, x, stacked_params, unroll=unroll)
        return x

    first = jax.tree.map(lambda a: a[0], stacked_params)
    rest = jax.tree.map(lambda a: a[1:], stacked_params)
    g0 = ctx.gather_params(first, specs)

    def body(carry, p_next):
        h, g = carry
        g_next = ctx.gather_params(p_next, specs)  # prefetch: no dep on h
        h = layer_fn(g, h)
        return (h, g_next), None

    (x, g_last), _ = jax.lax.scan(body, (x, g0), rest, unroll=unroll)
    return layer_fn(g_last, x)
