"""Super-LIP core: analytic model, partition planner, XFER sharding."""
from repro.core.hw import V5E, HardwareSpec  # noqa: F401
from repro.core.layer_model import ConvLayer, alexnet_layers, arch_layers  # noqa: F401
from repro.core.partition import MeshPlan, PartitionFactors, enumerate_partitions  # noqa: F401
from repro.core.perf_model import LayerLatency, Ports, TilePipelineModel, Tiling  # noqa: F401
from repro.core.bottleneck import Diagnosis, diagnose, diagnose_model  # noqa: F401
from repro.core.topology import TorusSpec, torus_for  # noqa: F401
from repro.core.planner import PlanReport, ShardingPlan, candidate_plans, evaluate_plan, plan_cell  # noqa: F401
from repro.core.xfer import ShardingCtx, null_ctx, scan_layers  # noqa: F401
