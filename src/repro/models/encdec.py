"""Encoder-decoder stack (SeamlessM4T-medium backbone).

The audio frontend is a STUB per the brief: the encoder consumes
precomputed frame embeddings ``frames [B, S_src, D]`` (``input_specs()``
provides them). Encoder blocks are bidirectional; decoder blocks are
causal self-attention + cross-attention over the encoder output.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.xfer import ShardingCtx, scan_layers
from repro.models import blocks as B
from repro.models import layers as L


def init_params(arch: ArchConfig, key, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, 6)

    def stack_init(k, n, cross):
        def one(kk):
            return B.attn_init(kk, arch, dtype, cross=cross)
        return jax.vmap(one)(jax.random.split(k, n))

    return {
        "embed": L.dense_init(ks[0], (arch.vocab_size, arch.d_model), 1, dtype),
        "enc_body": stack_init(ks[1], arch.enc_layers, cross=False),
        "enc_norm": jnp.zeros((arch.d_model,), dtype),
        "dec_body": stack_init(ks[2], arch.dec_layers, cross=True),
        "final_norm": jnp.zeros((arch.d_model,), dtype),
        "unembed": L.dense_init(ks[3], (arch.d_model, arch.vocab_size), 0, dtype),
    }


def param_dims(arch: ArchConfig) -> Dict:
    enc = B.attn_dims(arch, cross=False)
    dec = B.attn_dims(arch, cross=True)
    add_l = lambda tree: jax.tree.map(lambda d: (None,) + tuple(d), tree,
                                      is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embed": ("tp", "xfer"),
        "enc_body": add_l(enc),
        "enc_norm": (None,),
        "dec_body": add_l(dec),
        "final_norm": (None,),
        "unembed": ("xfer", "tp"),
    }


def make_caches(arch: ArchConfig, batch: int, length: int, dtype=jnp.bfloat16,
                kv_quant: bool = False) -> Dict:
    one = B.make_kv_cache(arch, batch, length, dtype, kv_quant=kv_quant)
    stack = jax.tree.map(
        lambda leaf: jnp.broadcast_to(leaf[None], (arch.dec_layers,) + leaf.shape), one)
    return {"dec_body": stack}


def cache_dims(arch: ArchConfig, kv_quant: bool = False) -> Dict:
    kv = {"k": (None, "batch", "tp", None, None), "v": (None, "batch", "tp", None, None),
          "pos": (None, "batch", "tp"), "count": (None,)}
    if kv_quant:
        kv["k_scale"] = kv["k"][:-1] + (None,)
        kv["v_scale"] = kv["v"][:-1] + (None,)
    return {"dec_body": kv}


def encode(arch: ArchConfig, params: Dict, frames: jax.Array,
           ctx: Optional[ShardingCtx] = None, remat: bool = False,
           enc_lens: Optional[jax.Array] = None) -> jax.Array:
    """frames: [B, S_src, D] stub embeddings -> encoder output [B, S_src, D].

    ``enc_lens`` ([B] int32): true per-row frame count of a right-padded
    batch. The bidirectional encoder attention masks keys at-or-beyond it,
    so a valid position's output is bit-equal to encoding the unpadded
    frames — the property the serving scheduler's per-slot ``enc_out``
    admission relies on (requests with different source lengths share one
    padded encoder call).
    """
    b, s, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = frames
    if ctx is not None:
        x = ctx.constrain(x, "batch", "seq", None)

    def block(p, h):
        def fn(p_, h_):
            return B.attn_apply(arch, p_, h_, ctx, positions=pos, causal=False,
                                seq_lens=enc_lens)[0]
        if remat:
            fn = jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
        return fn(p, h)

    x = scan_layers(block, params["enc_body"], x, ctx=ctx,
                    specs=B.attn_dims(arch, cross=False))
    return L.rms_norm(x, params["enc_norm"])


def decode(arch: ArchConfig, params: Dict, tokens: jax.Array, enc_out: jax.Array,
           ctx: Optional[ShardingCtx] = None, *,
           caches: Optional[Dict] = None,
           positions: Optional[jax.Array] = None,
           enc_lens: Optional[jax.Array] = None,
           remat: bool = False) -> Tuple[jax.Array, Optional[Dict]]:
    """``enc_lens`` masks right-padded ``enc_out`` rows out of every
    cross-attention (serving threads it per slot through DecodeState)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = L.embed_tokens(params["embed"], tokens, ctx)
    x = x * jnp.asarray(arch.d_model ** 0.5, x.dtype)

    def block(p, h, cache=None):
        def fn(p_, h_, cache_):
            return B.attn_apply(arch, p_, h_, ctx, positions=positions,
                                causal=True, enc=enc_out, enc_lens=enc_lens,
                                cache=cache_)
        if remat:
            fn = jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
        return fn(p, h, cache)

    new_caches = None
    if caches is None:
        x = scan_layers(lambda p, h: block(p, h)[0], params["dec_body"], x,
                        ctx=ctx, specs=B.attn_dims(arch, cross=True))
    else:
        def body(h, xs):
            p, c = xs
            h, c2 = block(p, h, c)
            return h, c2

        x, body_caches = jax.lax.scan(body, x, (params["dec_body"], caches["dec_body"]))
        new_caches = {"dec_body": body_caches}
    return L.rms_norm(x, params["final_norm"]), new_caches


def loss_fn(arch: ArchConfig, params: Dict, frames: jax.Array, tokens: jax.Array,
            labels: jax.Array, ctx=None, mask=None) -> jax.Array:
    enc_out = encode(arch, params, frames, ctx, remat=True)
    hidden, _ = decode(arch, params, tokens, enc_out, ctx, remat=True)
    return L.cross_entropy_chunked(params["unembed"], hidden, labels, mask=mask, ctx=ctx)
