"""Model zoo: unified LM stack + encoder-decoder, per-arch step builders."""
from repro.models import registry  # noqa: F401
