"""Transformer blocks: GQA attention (+dense MLP or MoE), with KV caches.

Every block exposes three functions:
  ``*_init(key, arch, ...) -> params``          (pytree of arrays)
  ``*_dims(arch, ...) -> roles``                 (matching pytree of logical
                                                  sharding roles, see
                                                  core/xfer.ShardingCtx)
  ``*_apply(arch, params, x, ctx, ...) -> (x, cache')``
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.quant import quantize_kv


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------

def make_kv_cache(arch: ArchConfig, batch: int, length: int, dtype=jnp.bfloat16,
                  window: int = 0, kv_quant: bool = False) -> dict:
    """``kv_quant=True`` stores K/V as int8 with per-token f32 scale
    leaves (``k_scale``/``v_scale`` ``[B, t, G, 1]``, one scale per token
    per KV group). The scales are ordinary cache leaves: they splice,
    page and shard structurally alongside the payload they describe."""
    t = min(length, window) if window else length
    g, d = arch.num_kv_heads, arch.head_dim
    cache = {
        "k": jnp.zeros((batch, t, g, d), jnp.int8 if kv_quant else dtype),
        "v": jnp.zeros((batch, t, g, d), jnp.int8 if kv_quant else dtype),
        "pos": jnp.full((batch, t), -1, jnp.int32),  # -1 = invalid slot
        "count": jnp.zeros((), jnp.int32),
    }
    if kv_quant:
        cache["k_scale"] = jnp.zeros((batch, t, g, 1), jnp.float32)
        cache["v_scale"] = jnp.zeros((batch, t, g, 1), jnp.float32)
    return cache


def kv_quantized(cache: dict) -> bool:
    return "k_scale" in cache or "kps" in cache


def _kv_leaves(cache: dict, k: jax.Array, v: jax.Array):
    """Fresh fp K/V → the cache's storage leaves: ``[(name, value)]``
    pairs matching the dict layout (int8 payload + per-token scales for
    quantised caches). Per-token quantisation commutes with any
    gather/slice/pad along the length axis, so fill paths can quantise
    first and reuse their fp indexing untouched."""
    if "k_scale" not in cache:
        return [("k", k.astype(cache["k"].dtype)),
                ("v", v.astype(cache["v"].dtype))]
    kq, vq = quantize_kv(k), quantize_kv(v)
    return [("k", kq.q), ("k_scale", kq.scale),
            ("v", vq.q), ("v_scale", vq.scale)]


def _kv_read(cache: dict, name: str, dtype) -> jax.Array:
    """Cache leaf → attention operand (dequantised for int8 caches)."""
    x = cache[name]
    scale = cache.get(f"{name}_scale")
    if scale is None:
        return x
    return (x.astype(jnp.float32) * scale).astype(dtype)


# Paged decode read-path implementation (see serving/pages.py):
# "gather" reads pages with a jnp gather and runs the same attention the
# dense grid runs (bit-exact with it when page_size divides max_len);
# "kernel" dispatches the Pallas paged-attention kernel
# (kernels/paged_attention.py — interpret mode off-TPU). Overridable for
# experiments, like lm.set_remat_policy.
_PAGED_ATTN_IMPL = "gather"


def set_paged_attention_impl(impl: str) -> None:
    global _PAGED_ATTN_IMPL
    if impl not in ("gather", "kernel"):
        raise ValueError(f"paged attention impl must be 'gather' or "
                         f"'kernel', got {impl!r}")
    _PAGED_ATTN_IMPL = impl


def _paged_decode_attention(ctx, q, k, v, cache: dict,
                            page_table: jax.Array, positions: jax.Array,
                            causal: bool):
    """Decode (S≥1) against a paged pool: write the new KV into the
    slot's frontier page(s), then attend over the slot's page list.

    The gather path materialises ``[B, M·ps, G, D]`` keys through the
    page table and runs the *same* attention the dense grid runs —
    positions beyond the frontier map to the null page or to a not-yet-
    written tail and are masked exactly like the dense grid's stale
    ``pos=-1`` entries, so the two layouts are bit-identical when
    ``page_size`` divides ``max_len`` (equal kv extent per shard).

    S>1 is the speculative verify: positions are the contiguous range
    ``p..p+k`` per row, every slot of which is (over)written before the
    gathered read, so stale entries from a previous partially-accepted
    verify can never be read. Positions at or beyond the table extent
    (speculative overshoot past a slot's budget) are redirected to the
    null page and masked from the read."""
    b, s = q.shape[0], q.shape[1]
    ps = cache["kp"].shape[-3]
    m = page_table.shape[1]
    t = m * ps
    pos = positions  # [B, S]
    page = jnp.take_along_axis(page_table, jnp.clip(pos // ps, 0, m - 1),
                               axis=1)
    page = jnp.where(pos < t, page, 0)  # overshoot → null page
    slot = pos % ps

    def write(pool, new):
        # inactive slots carry a zeroed (null-page) table row, so their
        # writes collide harmlessly on page 0's garbage
        return pool.at[page, slot].set(new.astype(pool.dtype))

    quant = "kps" in cache
    if quant:
        kq, vq = quantize_kv(k), quantize_kv(v)
        new_cache = {"kp": write(cache["kp"], kq.q),
                     "kps": write(cache["kps"], kq.scale),
                     "vp": write(cache["vp"], vq.q),
                     "vps": write(cache["vps"], vq.scale)}
    else:
        new_cache = {"kp": write(cache["kp"], k), "vp": write(cache["vp"], v)}
    if _PAGED_ATTN_IMPL == "kernel" and s == 1:
        from repro.kernels.paged_attention import paged_attention
        o = paged_attention(q[:, 0], new_cache["kp"], new_cache["vp"],
                            page_table, pos[:, 0] + 1,
                            k_scale=new_cache.get("kps"),
                            v_scale=new_cache.get("vps"))[:, None]
        return o, new_cache

    def flat(name):
        x = new_cache[name][page_table]  # [B, M, ps, G, ·]
        x = x.reshape(b, t, *x.shape[3:])
        if quant:
            s_ = new_cache[f"{name}s"][page_table].reshape(b, t, *x.shape[2:-1] + (1,))
            x = (x.astype(jnp.float32) * s_).astype(q.dtype)
        return x

    kf, vf = flat("kp"), flat("vp")
    kv_pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    kv_valid = kv_pos <= pos[:, -1][:, None]
    o = L.decode_attention_sharded(ctx, q, kf, vf, positions, kv_pos,
                                   kv_valid, causal=causal)
    return o, new_cache


def _shared_prefix_attention(ctx, q, k, v, cache: dict, positions, seq_lens):
    """Compute-skip suffix prefill: queries at positions ``m..`` attend
    the gathered shared-prefix KV (``pre_k/pre_v``, valid below
    ``pre_len``) concatenated ahead of the fresh suffix KV. The valid
    kv set per query is identical to a full-prompt prefill — padding
    (the gathered region's tail and the suffix bucket's tail) is masked
    to exact zeros, so the suffix hidden states match the full prefill
    bit-for-bit."""
    b, s = q.shape[0], q.shape[1]
    pre_k, pre_v, pre_len = cache["pre_k"], cache["pre_v"], cache["pre_len"]
    lp = pre_k.shape[1]
    k_cat = jnp.concatenate([pre_k.astype(k.dtype), k], axis=1)
    v_cat = jnp.concatenate([pre_v.astype(v.dtype), v], axis=1)
    pre_pos = jnp.broadcast_to(jnp.arange(lp, dtype=jnp.int32)[None], (b, lp))
    kv_pos = jnp.concatenate([pre_pos, positions], axis=1)
    pre_valid = pre_pos < pre_len[:, None]
    suf_valid = (jnp.arange(s, dtype=jnp.int32)[None]
                 < (seq_lens - pre_len)[:, None])
    kv_valid = jnp.concatenate([pre_valid, suf_valid], axis=1)
    return L.attention_sharded(ctx, q, k_cat, v_cat, positions, kv_pos,
                               kv_valid, causal=True)


def _cache_write(cache: dict, k_new, v_new, pos_new):
    """Ring-buffer write of one token (decode step).

    Slot = position mod cache length, **per batch row**, so continuous
    batching can hold requests at different positions in one grid.
    """
    t = cache["k"].shape[1]
    slot = (pos_new[:, 0] % t).astype(jnp.int32)  # [B]

    def wr(c, u):
        # per-row rank inside the vmap: start indices must cover c_.ndim
        return jax.vmap(lambda c_, u_, i: jax.lax.dynamic_update_slice(
            c_, u_.astype(c_.dtype), (i,) + (0,) * (c_.ndim - 1)))(c, u, slot)

    out = dict(cache)
    for name, u in _kv_leaves(cache, k_new, v_new):
        out[name] = wr(cache[name], u)
    out["pos"] = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(c, u, (i,)))(
        cache["pos"], pos_new, slot)
    out["count"] = cache["count"] + 1
    return out


def _cache_write_many(cache: dict, k_new, v_new, pos_new):
    """Append-mode write of S tokens per row (speculative draft/verify).

    Non-windowed caches only: the slot is the position itself (no ring
    wrap — a wrap inside one multi-token write would clobber live
    context). Writes at positions beyond the cache extent are dropped
    (OOB scatter with ``mode="drop"``); a slot's stale entries above its
    accept frontier always store a position greater than any future
    query position below them, and every verify rewrites the full
    ``p..p+k`` range before the in-step read, so stale data is never
    attended.
    """
    b, s = pos_new.shape
    t = cache["k"].shape[1]
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    slot = jnp.where(pos_new >= 0, pos_new, t)  # negative → dropped too
    out = dict(cache)
    for name, u in _kv_leaves(cache, k_new, v_new):
        out[name] = cache[name].at[rows, slot].set(
            u.astype(cache[name].dtype), mode="drop")
    out["pos"] = cache["pos"].at[rows, slot].set(pos_new, mode="drop")
    out["count"] = cache["count"] + s
    return out


# ---------------------------------------------------------------------------
# attention block (pre-norm attn + pre-norm MLP/MoE)
# ---------------------------------------------------------------------------

def attn_init(key, arch: ArchConfig, dtype=jnp.float32, moe: bool = False,
              d_ff: Optional[int] = None, cross: bool = False) -> dict:
    ks = jax.random.split(key, 12)
    d, qd, kvd = arch.d_model, arch.q_dim, arch.kv_dim
    p = {
        "ln1": jnp.zeros((d,), dtype),
        "wq": L.dense_init(ks[0], (d, qd), 0, dtype),
        "wk": L.dense_init(ks[1], (d, kvd), 0, dtype),
        "wv": L.dense_init(ks[2], (d, kvd), 0, dtype),
        "wo": L.dense_init(ks[3], (qd, d), 0, dtype),
    }
    if arch.qkv_bias:
        p["bq"] = jnp.zeros((qd,), dtype)
        p["bk"] = jnp.zeros((kvd,), dtype)
        p["bv"] = jnp.zeros((kvd,), dtype)
    if cross:
        p["ln_x"] = jnp.zeros((d,), dtype)
        p["xwq"] = L.dense_init(ks[8], (d, qd), 0, dtype)
        p["xwk"] = L.dense_init(ks[9], (d, kvd), 0, dtype)
        p["xwv"] = L.dense_init(ks[10], (d, kvd), 0, dtype)
        p["xwo"] = L.dense_init(ks[11], (qd, d), 0, dtype)
    ff = d_ff if d_ff is not None else arch.d_ff
    if ff and arch.mlp != "none":
        p["ln2"] = jnp.zeros((d,), dtype)
        if moe:
            p["router"] = L.dense_init(ks[4], (d, arch.num_experts), 0, dtype)
            ks2 = jax.random.split(ks[5], 3)
            eff = arch.moe_d_ff or arch.d_ff
            gates = arch.mlp in ("swiglu", "geglu")
            p["moe"] = {
                "w_gate": L.dense_init(ks2[0], (arch.num_experts, d, eff), 1, dtype),
                "w_up": L.dense_init(ks2[1], (arch.num_experts, d, eff), 1, dtype),
                "w_down": L.dense_init(ks2[2], (arch.num_experts, eff, d), 1, dtype),
            } if gates else {
                "w_up": L.dense_init(ks2[1], (arch.num_experts, d, eff), 1, dtype),
                "w_down": L.dense_init(ks2[2], (arch.num_experts, eff, d), 1, dtype),
            }
            if arch.num_shared_experts:
                p["shared"] = L.mlp_init(ks[6], d, (arch.moe_d_ff or arch.d_ff) * arch.num_shared_experts,
                                         arch.mlp, dtype)
        else:
            p["mlp"] = L.mlp_init(ks[7], d, ff, arch.mlp, dtype)
    return p


def attn_dims(arch: ArchConfig, moe: bool = False, d_ff: Optional[int] = None,
              cross: bool = False) -> dict:
    d = {
        "ln1": (None,),
        "wq": ("xfer", "tp"), "wk": ("xfer", "tp"), "wv": ("xfer", "tp"),
        "wo": ("tp", "xfer"),
    }
    if arch.qkv_bias:
        d["bq"] = ("tp",)
        d["bk"] = ("tp",)
        d["bv"] = ("tp",)
    if cross:
        d.update({"ln_x": (None,), "xwq": ("xfer", "tp"), "xwk": ("xfer", "tp"),
                  "xwv": ("xfer", "tp"), "xwo": ("tp", "xfer")})
    ff = d_ff if d_ff is not None else arch.d_ff
    if ff and arch.mlp != "none":
        d["ln2"] = (None,)
        if moe:
            d["router"] = ("xfer", None)
            gates = arch.mlp in ("swiglu", "geglu")
            d["moe"] = ({"w_gate": ("ep", "xfer", None), "w_up": ("ep", "xfer", None),
                         "w_down": ("ep", None, "xfer")} if gates else
                        {"w_up": ("ep", "xfer", None), "w_down": ("ep", None, "xfer")})
            if arch.num_shared_experts:
                d["shared"] = L.mlp_dims(arch.mlp)
        else:
            d["mlp"] = L.mlp_dims(arch.mlp)
    return d


def _project_qkv(arch: ArchConfig, p: dict, h: jax.Array, ctx, prefix: str = "w"):
    b, s, _ = h.shape
    q = h @ p[f"{prefix}q"]
    k = h @ p[f"{prefix}k"]
    v = h @ p[f"{prefix}v"]
    if arch.qkv_bias and prefix == "w":
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, arch.num_heads, arch.head_dim)
    k = k.reshape(b, s, arch.num_kv_heads, arch.head_dim)
    v = v.reshape(b, s, arch.num_kv_heads, arch.head_dim)
    if ctx is not None:
        q = ctx.constrain(q, "batch", "seq", "tp", None)
        k = ctx.constrain(k, "batch", "seq", "tp", None)
        v = ctx.constrain(v, "batch", "seq", "tp", None)
    return q, k, v


def _ring_exact_fill(cache: dict, k, v, seq_lens: jax.Array, s: int) -> dict:
    """Length-exact prefill fill of a (possibly windowed) ring cache.

    Index ``i`` of a ring of size ``t`` must hold the newest position
    ``p ≡ i (mod t)`` below the true length — i.e. the last
    ``min(len, t)`` positions of the *unpadded* prompt, not of the padded
    bucket. The plain suffix fill keeps the last ``t`` positions of the
    padded sequence instead, which evicts real context whenever the
    prompt is shorter than the bucket; per-row gather by true length
    makes the fill identical for every padded length ≥ the prompt.
    """
    t = cache["k"].shape[1]
    ring = jnp.arange(t)[None, :]  # [1, t]
    last = seq_lens[:, None] - 1
    pos = last - jnp.mod(last - ring, t)  # [B, t], pos ≡ ring (mod t)
    valid = pos >= 0
    idx = jnp.clip(pos, 0, s - 1)
    out = dict(cache)
    for name, u in _kv_leaves(cache, k, v):
        out[name] = jnp.take_along_axis(
            u, idx[:, :, None, None], axis=1).astype(cache[name].dtype)
    out["pos"] = jnp.where(valid, pos, -1)
    out["count"] = jnp.asarray(s, jnp.int32)
    return out


def attn_apply(arch: ArchConfig, p: dict, x: jax.Array, ctx=None, *,
               positions: jax.Array, cache: Optional[dict] = None,
               window: int = 0, prefix_len: Optional[jax.Array] = None,
               causal: bool = True, moe: bool = False,
               enc: Optional[jax.Array] = None,
               enc_lens: Optional[jax.Array] = None,
               seq_lens: Optional[jax.Array] = None,
               page_table: Optional[jax.Array] = None,
               deterministic_router: bool = True,
               append: bool = False
               ) -> Tuple[jax.Array, Optional[dict]]:
    """Self-attention + MLP/MoE block.

    full mode (cache is None or being filled): x is [B,S,D];
    decode mode (cache with count>0 and S==1): ring-buffer cache update.

    ``append=True`` (speculative decoding) treats a filled cache as an
    append target for S≥1 fresh positions per row instead of a prefill
    fill: the new KV is scattered at its positions (non-windowed caches
    only — see :func:`_cache_write_many`) and attention runs over the
    whole cache exactly like the decode path. The paged pool handles
    append natively (frontier writes are position-addressed already).

    ``seq_lens`` ([B] int32) marks the true per-row length of a
    right-padded batch: keys at-or-beyond it are masked out of attention
    (only observable for non-causal use — causal masking already hides a
    padded tail from valid queries) and, for windowed caches, the prefill
    fill gathers the last ``window`` positions *before* the true length
    instead of the padded bucket's suffix (see :func:`_ring_exact_fill`).

    Paged modes (``serving.pages``), keyed by the cache dict's shape:
    a pool pair ``{"kp", "vp"}`` plus ``page_table`` ([B, M] int32)
    selects the paged decode path; a gathered shared-prefix block
    ``{"pre_k", "pre_v", "pre_len"}`` selects the compute-skip suffix
    prefill, whose returned cache is the dense suffix row the scheduler
    splices into pages.
    """
    b, s, d = x.shape
    h = L.rms_norm(x, p["ln1"])
    q, k, v = _project_qkv(arch, p, h, ctx)
    q = L.rope(q, positions, arch.rope_theta)
    k = L.rope(k, positions, arch.rope_theta)
    kv_valid_in = (jnp.arange(s)[None, :] < seq_lens[:, None]
                   if seq_lens is not None and s > 1 else None)

    new_cache = None
    if cache is not None and "kp" in cache:
        if page_table is None:
            raise ValueError("paged KV pool given without a page_table")
        o, new_cache = _paged_decode_attention(ctx, q, k, v, cache,
                                               page_table, positions, causal)
    elif cache is not None and "pre_k" in cache:
        o = _shared_prefix_attention(ctx, q, k, v, cache, positions, seq_lens)
        new_cache = {"k": k, "v": v, "pos": positions,
                     "count": jnp.asarray(s, jnp.int32)}
    elif cache is not None and append:
        new_cache = _cache_write_many(cache, k, v, positions)
        kv_valid = new_cache["pos"] >= 0
        o = L.decode_attention_sharded(ctx, q,
                                       _kv_read(new_cache, "k", q.dtype),
                                       _kv_read(new_cache, "v", q.dtype),
                                       positions, new_cache["pos"], kv_valid,
                                       causal=causal, window=window,
                                       prefix_len=prefix_len)
    elif cache is not None and s == 1:
        new_cache = _cache_write(cache, k, v, positions)
        kv_valid = new_cache["pos"] >= 0
        o = L.decode_attention_sharded(ctx, q,
                                       _kv_read(new_cache, "k", q.dtype),
                                       _kv_read(new_cache, "v", q.dtype),
                                       positions, new_cache["pos"], kv_valid,
                                       causal=causal, window=window,
                                       prefix_len=prefix_len)
    else:
        o = L.attention_sharded(ctx, q, k, v, positions, positions,
                                kv_valid_in, causal=causal, window=window,
                                prefix_len=prefix_len)
        if cache is not None:  # prefill: fill the cache with the suffix
            t = cache["k"].shape[1]
            if seq_lens is not None and window:
                new_cache = _ring_exact_fill(cache, k, v, seq_lens, s)
            elif s >= t:
                new_cache = dict(cache)
                for name, u in _kv_leaves(cache, k, v):
                    new_cache[name] = u[:, -t:]
                new_cache["pos"] = positions[:, -t:]
                new_cache["count"] = jnp.asarray(s, jnp.int32)
            else:
                pad = t - s
                new_cache = dict(cache)
                for name, u in _kv_leaves(cache, k, v):
                    new_cache[name] = jnp.pad(
                        u, ((0, 0), (0, pad)) + ((0, 0),) * (u.ndim - 2))
                new_cache["pos"] = jnp.pad(positions, ((0, 0), (0, pad)),
                                           constant_values=-1)
                new_cache["count"] = jnp.asarray(s, jnp.int32)
    o = o.reshape(b, s, arch.q_dim)
    x = x + o @ p["wo"]
    if ctx is not None:
        x = ctx.constrain(x, "batch", "sp", None)

    if enc is not None:
        x = cross_attn_apply(arch, p, x, enc, ctx, enc_lens=enc_lens)
        if ctx is not None:
            x = ctx.constrain(x, "batch", "sp", None)

    if "ln2" in p:
        h = L.rms_norm(x, p["ln2"])
        if moe:
            y = moe_apply(arch, p, h, ctx)
        else:
            y = L.mlp_apply(p["mlp"], h, arch.mlp, ctx)
        x = x + y
        if ctx is not None:
            x = ctx.constrain(x, "batch", "sp", None)
    return x, new_cache


# ---------------------------------------------------------------------------
# cross-attention (enc-dec decoder)
# ---------------------------------------------------------------------------

def cross_attn_apply(arch: ArchConfig, p: dict, x: jax.Array, enc: jax.Array,
                     ctx=None, enc_lens: Optional[jax.Array] = None) -> jax.Array:
    """Decoder cross-attention over encoder output. ``enc_lens`` ([B]
    int32) masks right-padded encoder positions out of the keys — the
    per-slot encoder-length mask the serving runtime threads through
    ``DecodeState`` (padded ``enc_out`` rows contribute exactly zero)."""
    b, s, d = x.shape
    t = enc.shape[1]
    h = L.rms_norm(x, p["ln_x"])
    q = (h @ p["xwq"]).reshape(b, s, arch.num_heads, arch.head_dim)
    k = (enc @ p["xwk"]).reshape(b, t, arch.num_kv_heads, arch.head_dim)
    v = (enc @ p["xwv"]).reshape(b, t, arch.num_kv_heads, arch.head_dim)
    if ctx is not None:
        q = ctx.constrain(q, "batch", "seq", "tp", None)
        k = ctx.constrain(k, "batch", "seq", "tp", None)
        v = ctx.constrain(v, "batch", "seq", "tp", None)
    qp = jnp.zeros((b, s), jnp.int32)
    kp = jnp.zeros((b, t), jnp.int32)
    kv_valid = (jnp.arange(t)[None, :] < enc_lens[:, None]
                if enc_lens is not None else None)
    o = L.attention(q, k, v, qp, kp, kv_valid, causal=False)
    return x + o.reshape(b, s, arch.q_dim) @ p["xwo"]


# ---------------------------------------------------------------------------
# MoE (capacity-based, sort + scatter dispatch — GShard/Switch style)
# ---------------------------------------------------------------------------

def moe_apply(arch: ArchConfig, p: dict, h: jax.Array, ctx=None,
              capacity_factor: float = 0.0) -> jax.Array:
    """Dispatch wrapper: explicit shard_map all-to-all when the mesh allows
    (§Perf iteration: GSPMD's handling of the scatter/gather dispatch
    degenerates into full-buffer all-gathers — observed 185 s of collective
    time on deepseek train_4k; the explicit EP path moves only the routed
    tokens, twice, over the model axis)."""
    from repro.core.xfer import explicit_spmd_enabled
    if (ctx is not None and ctx.mesh is not None and h.shape[1] > 1
            and explicit_spmd_enabled()):
        ep_axes = ctx.plan.ep_axes or ctx.plan.tp_axes
        ep = ctx.plan.degree(ep_axes)
        if (len(ep_axes) == 1 and ep > 1 and arch.num_experts % ep == 0):
            return _moe_apply_sharded(arch, p, h, ctx, ep_axes[0],
                                      capacity_factor or arch.moe_capacity_factor)
    return _moe_apply_dense(arch, p, h, ctx, capacity_factor)


def _local_dispatch(arch: ArchConfig, hf: jax.Array, router: jax.Array,
                    cap: int):
    """Per-device top-k routing into an [E, cap, D] buffer. Returns
    (buffer, combine metadata)."""
    t, d = hf.shape
    e, k = arch.num_experts, arch.top_k
    logits = (hf @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    eid = idx.reshape(-1)
    order = jnp.argsort(eid, stable=True)
    eid_s = eid[order]
    rank = jnp.arange(t * k) - jnp.searchsorted(eid_s, eid_s, side="left")
    keep = rank < cap
    dest = jnp.where(keep, eid_s * cap + rank, e * cap)
    src_tok = order // k
    buf = jnp.zeros((e * cap, d), hf.dtype).at[dest].set(hf[src_tok], mode="drop")
    meta = (dest, keep, src_tok, gate_vals.reshape(-1)[order])
    return buf.reshape(e, cap, d), meta


def _local_combine(meta, out: jax.Array, t: int) -> jax.Array:
    dest, keep, src_tok, gv_sorted = meta
    e_cap, d = out.reshape(-1, out.shape[-1]).shape[0], out.shape[-1]
    out_flat = out.reshape(-1, d)
    contrib = jnp.where(keep[:, None], out_flat[jnp.minimum(dest, e_cap - 1)], 0.0)
    return jnp.zeros((t, d), out.dtype).at[src_tok].add(
        (contrib * gv_sorted[:, None]).astype(out.dtype))


def _expert_ffn(arch: ArchConfig, moe_p: dict, buf: jax.Array) -> jax.Array:
    if "w_gate" in moe_p:
        act = jax.nn.silu if arch.mlp == "swiglu" else (
            lambda u: jax.nn.gelu(u, approximate=True))
        inner = act(jnp.einsum("ecd,edf->ecf", buf, moe_p["w_gate"])) * \
            jnp.einsum("ecd,edf->ecf", buf, moe_p["w_up"])
    else:
        inner = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", buf, moe_p["w_up"])))
    return jnp.einsum("ecf,efd->ecd", inner, moe_p["w_down"])


def _moe_apply_sharded(arch: ArchConfig, p: dict, h: jax.Array, ctx,
                       axis: str, capacity_factor: float) -> jax.Array:
    """GShard-style EP: local top-k dispatch → all-to-all over the expert
    axis → local expert FFNs → reverse all-to-all → local combine."""
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map

    b, s, d = h.shape
    e, k = arch.num_experts, arch.top_k
    wsd = max(ctx.plan.degree(ctx.plan.batch_axes + ctx.plan.seq_axes), 1)
    t_loc = max(b * s // wsd, 1)
    cap = max(int(math.ceil(t_loc * k / e * capacity_factor)), 1)

    moe_p = p["moe"]
    has_gate = "w_gate" in moe_p

    def local(h_loc, router, *weights):
        bl, sl, _ = h_loc.shape
        hf = h_loc.reshape(bl * sl, d)
        buf, meta = _local_dispatch(arch, hf, router, cap)  # [E, cap, D]
        # route: every device sends each expert-owner its slice of tokens
        buf = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=1,
                                 tiled=True)  # [E/ep, cap*ep, D]
        names = ("w_gate", "w_up", "w_down") if has_gate else ("w_up", "w_down")
        out = _expert_ffn(arch, dict(zip(names, weights)), buf)
        out = jax.lax.all_to_all(out, axis, split_axis=1, concat_axis=0,
                                 tiled=True)  # [E, cap, D]
        y = _local_combine(meta, out, bl * sl)
        return y.reshape(bl, sl, d)

    hs = ctx.spec(h.shape, ("batch", "seq", None))
    rs = P(*([None] * p["router"].ndim))
    # expert weights: E sharded over the EP axis, other dims gathered at entry
    ws = P(axis, None, None)
    wnames = ("w_gate", "w_up", "w_down") if has_gate else ("w_up", "w_down")
    kwargs = dict(mesh=ctx.mesh, in_specs=(hs, rs) + (ws,) * len(wnames),
                  out_specs=hs)
    try:
        fn = shard_map(local, check_vma=False, **kwargs)
    except TypeError:  # pragma: no cover
        fn = shard_map(local, check_rep=False, **kwargs)
    y = fn(h, p["router"], *(moe_p[n] for n in wnames))
    if "shared" in p:
        y = y + L.mlp_apply(p["shared"], h, arch.mlp, ctx)
    return y


def _moe_apply_dense(arch: ArchConfig, p: dict, h: jax.Array, ctx=None,
                     capacity_factor: float = 0.0) -> jax.Array:
    capacity_factor = capacity_factor or arch.moe_capacity_factor
    b, s, d = h.shape
    t = b * s
    e, k = arch.num_experts, arch.top_k
    hf = h.reshape(t, d)

    logits = (hf @ p["router"]).astype(jnp.float32)  # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)  # [T,k]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = max(int(math.ceil(t * k / e * capacity_factor)), 1)
    eid = idx.reshape(-1)  # [T*k]
    order = jnp.argsort(eid, stable=True)
    eid_s = eid[order]
    # rank within expert group
    first = jnp.searchsorted(eid_s, eid_s, side="left")
    rank = jnp.arange(t * k) - first
    keep = rank < cap
    dest = jnp.where(keep, eid_s * cap + rank, e * cap)  # overflow -> dropped
    src_tok = order // k

    buf = jnp.zeros((e * cap, d), h.dtype).at[dest].set(hf[src_tok], mode="drop")
    buf = buf.reshape(e, cap, d)
    if ctx is not None:
        buf = ctx.constrain(buf, "ep", None, None)

    if "w_gate" in p["moe"]:
        act = jax.nn.silu if arch.mlp == "swiglu" else (lambda u: jax.nn.gelu(u, approximate=True))
        inner = act(jnp.einsum("ecd,edf->ecf", buf, p["moe"]["w_gate"])) * \
            jnp.einsum("ecd,edf->ecf", buf, p["moe"]["w_up"])
    else:
        inner = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", buf, p["moe"]["w_up"])))
    out = jnp.einsum("ecf,efd->ecd", inner, p["moe"]["w_down"])
    if ctx is not None:
        out = ctx.constrain(out, "ep", None, None)

    out_flat = out.reshape(e * cap, d)
    contrib = jnp.where(keep[:, None], out_flat[jnp.minimum(dest, e * cap - 1)], 0.0)
    gv_sorted = gate_vals.reshape(-1)[order]
    y = jnp.zeros((t, d), h.dtype).at[src_tok].add(
        (contrib * gv_sorted[:, None]).astype(h.dtype))
    y = y.reshape(b, s, d)
    if "shared" in p:
        y = y + L.mlp_apply(p["shared"], h, arch.mlp, ctx)
    return y
