"""Shared model primitives (pure JAX): norms, RoPE, GQA attention, MLPs.

Attention uses a query-block online-softmax formulation for long sequences
(the same algorithm the Pallas kernel in ``kernels/flash_attention.py``
implements for TPU), so a 32k-token prefill never materialises an S×S
score matrix — essential for both CPU smoke tests and compile-time memory
analysis on the dry-run.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis] if isinstance(in_axis, int) else 1
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] (int). Rotates pairs (d, d+D/2)."""
    b, s, h, d = x.shape
    half = d // 2
    freq = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, :, None] * freq[None, None, :]  # [B,S,half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, causal / local-window / prefix-bidirectional / cross)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _mask(q_pos, kv_pos, kv_valid, causal: bool, window: int, prefix_len):
    """[B, Sq, Skv] boolean allow-mask from position metadata."""
    qp = q_pos[:, :, None]
    kp = kv_pos[:, None, :]
    m = kv_valid[:, None, :]
    if causal:
        c = kp <= qp
        if prefix_len is not None:
            c = c | (kp < prefix_len[:, None, None])  # prefix-LM: bidirectional prefix
        m = m & c
    if window:
        m = m & (qp - kp < window)
    return m


def _attend_block(q, k, v, mask):
    """One (q-block × full-kv) online-softmax pass. q:[B,Sq,H,D] k,v:[B,T,G,D].

    Pure-jnp oracle of kernels/flash_attention.py. Everything inside the
    "flashattn" scope stays in VMEM on the TPU kernel path; the HLO
    analyzer (launch/hlo_analysis.py) accounts its traffic separately.

    Comm-friendly conventions (§Perf iteration 1): inputs stay in their
    storage dtype with f32 MXU accumulation (`preferred_element_type`), so
    any GSPMD resharding of the score/probability tensors moves bf16, and
    the softmax normalisation happens in the grouped [B,G,rep,…] layout so
    no reshape crosses the head-sharded dim boundary.
    """
    b, sq, h, d = q.shape
    t, g = k.shape[1], k.shape[2]
    rep = h // g
    scale = jnp.asarray(1.0 / math.sqrt(d), q.dtype)
    qg = (q * scale).reshape(b, sq, g, rep, d)
    scores = jnp.einsum("bsgrd,btgd->bgrst", qg, k,
                        preferred_element_type=jnp.float32)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    m = jnp.maximum(m, NEG_INF)  # rows with no valid kv stay finite
    p = jnp.exp(scores - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bgrst,btgd->bsgrd", p.astype(q.dtype), v,
                   preferred_element_type=jnp.float32)
    # normalise in grouped layout (no cross-shard reshape), then flatten
    o = o / jnp.maximum(l[..., 0].transpose(0, 3, 1, 2)[..., None], 1e-30)
    return (o.astype(q.dtype).reshape(b, sq, h, d),
            m[..., 0], l[..., 0])  # m,l: [B,G,rep,Sq]


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              q_pos: jax.Array, kv_pos: jax.Array,
              kv_valid: Optional[jax.Array] = None,
              causal: bool = True, window: int = 0,
              prefix_len: Optional[jax.Array] = None,
              q_block: int = 1024) -> jax.Array:
    """GQA attention. q:[B,Sq,H,D]; k,v:[B,T,G,D]; positions int32.

    For Sq > q_block, scans over query blocks (the kv axis is processed in
    one shot per block — the flash kernel tiles it further on TPU).
    """
    b, sq, h, d = q.shape
    t = k.shape[1]
    if kv_valid is None:
        kv_valid = jnp.ones((b, t), dtype=bool)

    if sq <= q_block:
        with jax.named_scope("flashattn"):
            mask = _mask(q_pos, kv_pos, kv_valid, causal, window, prefix_len)
            o, _, _ = _attend_block(q, k, v, mask)
            return o

    nb = sq // q_block
    assert sq % q_block == 0, f"seq {sq} not divisible by q_block {q_block}"

    def body(_, inputs):
        qb, qpb = inputs
        with jax.named_scope("flashattn"):
            mask = _mask(qpb, kv_pos, kv_valid, causal, window, prefix_len)
            o, _, _ = _attend_block(qb, k, v, mask)
            return None, o

    qs = q.reshape(b, nb, q_block, h, d).transpose(1, 0, 2, 3, 4)
    qps = q_pos.reshape(b, nb, q_block).transpose(1, 0, 2)
    _, out = jax.lax.scan(body, None, (qs, qps))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)


def attention_sharded(ctx, q, k, v, q_pos, kv_pos, kv_valid=None, *,
                      causal=True, window=0, prefix_len=None, q_block=1024):
    """Attention with explicitly local per-device compute (§Perf iter. 2).

    GSPMD left alone reshards the score/probability tensors inside the
    attention body (observed: GB-scale all-gathers per layer in the
    backward). On TPU the flash kernel runs entirely on-device, so we make
    that structure explicit: ``shard_map`` over (batch, heads); inside, the
    plain jnp attention runs on local shards with **zero** collectives.
    GQA KV heads are broadcast to the full head count first when the KV
    head count does not divide the TP degree (the Pallas kernel indexes
    instead of broadcasting — DESIGN.md §7).

    Falls back to the GSPMD path for decode (s==1) and for head counts not
    divisible by the TP degree (e.g. phi3's 40 heads on 16-way TP).
    """
    from repro.core.xfer import explicit_spmd_enabled
    if (ctx is None or ctx.mesh is None or q.shape[1] == 1
            or not explicit_spmd_enabled()):
        return attention(q, k, v, q_pos, kv_pos, kv_valid, causal=causal,
                         window=window, prefix_len=prefix_len, q_block=q_block)
    try:
        from jax import shard_map  # jax >= 0.6
    except ImportError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map

    b, s, h, d = q.shape
    t, g = k.shape[1], k.shape[2]
    tp = ctx.plan.degree(ctx.plan.tp_axes)
    if tp > 1 and h % tp != 0:
        return attention(q, k, v, q_pos, kv_pos, kv_valid, causal=causal,
                         window=window, prefix_len=prefix_len, q_block=q_block)
    if tp > 1 and g % tp != 0:
        k = jnp.repeat(k, h // g, axis=2)  # broadcast KV to full heads
        v = jnp.repeat(v, h // g, axis=2)
        g = h
    if kv_valid is None:
        kv_valid = jnp.ones((b, t), dtype=bool)
    if prefix_len is None:
        prefix_len = jnp.full((b,), -1, jnp.int32)  # <0: no prefix override

    qs = ctx.spec(q.shape, ("batch", "seq", "tp", None))
    ks = ctx.spec(k.shape, ("batch", None, "tp", None))
    ps = ctx.spec(q_pos.shape, ("batch", "seq"))
    kp = ctx.spec(kv_pos.shape, ("batch", None))
    kvd = ctx.spec(kv_valid.shape, ("batch", None))
    pls = ctx.spec(prefix_len.shape, ("batch",))

    def local(q_, k_, v_, qp_, kp_, kvv_, pl_):
        # prefix_len < 0 encodes "no prefix override"; clamping to 0 makes
        # the prefix clause vacuous (kp < 0 never holds), matching None.
        return attention(q_, k_, v_, qp_, kp_, kvv_, causal=causal,
                         window=window, prefix_len=jnp.maximum(pl_, 0),
                         q_block=min(q_block, q_.shape[1]))

    kwargs = dict(mesh=ctx.mesh, in_specs=(qs, ks, ks, ps, kp, kvd, pls),
                  out_specs=qs)
    try:
        fn = shard_map(local, check_vma=False, **kwargs)  # jax >= 0.8
    except TypeError:  # pragma: no cover
        fn = shard_map(local, check_rep=False, **kwargs)
    return fn(q, k, v, q_pos, kv_pos, kv_valid, prefix_len)


def decode_attention_sharded(ctx, q, k, v, q_pos, kv_pos, kv_valid, *,
                             causal=True, window=0, prefix_len=None):
    """Flash-decoding (§Perf iteration: decode cell).

    The KV cache's head dim rarely divides the TP degree (GQA kv=8 on
    16-way TP; MQA kv=1), so head-sharding the cache is impossible and
    GSPMD falls back to replicating + all-gathering the entire cache every
    step (observed: 68 GB of cache movement per decoded token). Instead the
    cache is sharded over its *sequence* dim; each device computes partial
    attention (o, m, l) over its chunk and the partials merge with a
    log-sum-exp weighted psum over the TP axis — two tiny collectives of
    [B,H,D] instead of the cache.
    """
    from repro.core.xfer import explicit_spmd_enabled
    if ctx is None or ctx.mesh is None or not explicit_spmd_enabled():
        return attention(q, k, v, q_pos, kv_pos, kv_valid, causal=causal,
                         window=window, prefix_len=prefix_len)
    try:
        from jax import shard_map
    except ImportError:  # pragma: no cover
        from jax.experimental.shard_map import shard_map

    b, s, h, d = q.shape
    t, g = k.shape[1], k.shape[2]
    tp_axes = ctx.plan.tp_axes
    tp = ctx.plan.degree(tp_axes)
    if tp <= 1 or t % tp != 0 or s != 1:
        return attention(q, k, v, q_pos, kv_pos, kv_valid, causal=causal,
                         window=window, prefix_len=prefix_len)
    if prefix_len is None:
        prefix_len = jnp.full((b,), -1, jnp.int32)

    qs = ctx.spec(q.shape, ("batch", None, None, None))
    ks = ctx.spec(k.shape, ("batch", "tp", None, None))
    pqs = ctx.spec(q_pos.shape, ("batch", None))
    pks = ctx.spec(kv_pos.shape, ("batch", "tp"))
    kvs = ctx.spec(kv_valid.shape, ("batch", "tp"))
    pls = ctx.spec(prefix_len.shape, ("batch",))
    used = ks[1]  # axes actually sharding the cache seq dim
    axis_names = tuple(used) if isinstance(used, tuple) else (used,) if used else ()
    if not axis_names:
        return attention(q, k, v, q_pos, kv_pos, kv_valid, causal=causal,
                         window=window, prefix_len=prefix_len)

    def local(q_, k_, v_, qp_, kp_, kvv_, pl_):
        bl, _, hl, _ = q_.shape
        with jax.named_scope("flashattn"):
            mask = _mask(qp_, kp_, kvv_, causal, window, jnp.maximum(pl_, 0))
            o, m, l = _attend_block(q_, k_, v_, mask)  # o normalised by local l
            # undo local normalisation -> weighted partials, merge over axis
            lq = l.reshape(bl, hl, 1).transpose(0, 2, 1)[..., None]  # [B,1,H,1]
            mq = m.reshape(bl, hl, 1).transpose(0, 2, 1)[..., None]
            m_star = jax.lax.pmax(mq, axis_names)
            w = jnp.exp(mq - m_star) * lq
            num = jax.lax.psum((o.astype(jnp.float32) * w), axis_names)
            den = jax.lax.psum(w, axis_names)
            return (num / jnp.maximum(den, 1e-30)).astype(q_.dtype)

    kwargs = dict(mesh=ctx.mesh,
                  in_specs=(qs, ks, ks, pqs, pks, kvs, pls), out_specs=qs)
    try:
        fn = shard_map(local, check_vma=False, **kwargs)
    except TypeError:  # pragma: no cover
        fn = shard_map(local, check_rep=False, **kwargs)
    return fn(q, k, v, q_pos, kv_pos, kv_valid, prefix_len)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_apply(p: dict, x: jax.Array, kind: str, ctx=None) -> jax.Array:
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else (lambda u: jax.nn.gelu(u, approximate=True))
        g = x @ p["w_gate"]
        u = x @ p["w_up"]
        if ctx is not None:
            g = ctx.constrain(g, "batch", "seq", "tp")
            u = ctx.constrain(u, "batch", "seq", "tp")
        h = act(g) * u
    elif kind == "relu2":
        h = x @ p["w_up"]
        if ctx is not None:
            h = ctx.constrain(h, "batch", "seq", "tp")
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(kind)
    return h @ p["w_down"]


def mlp_init(key, d_model: int, d_ff: int, kind: str, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff), 0, dtype),
            "w_up": dense_init(ks[1], (d_model, d_ff), 0, dtype),
            "w_down": dense_init(ks[2], (d_ff, d_model), 0, dtype),
        }
    return {
        "w_up": dense_init(ks[0], (d_model, d_ff), 0, dtype),
        "w_down": dense_init(ks[1], (d_ff, d_model), 0, dtype),
    }


def mlp_dims(kind: str) -> dict:
    """Logical sharding roles per param (leading layer-stack dim added by stack)."""
    if kind in ("swiglu", "geglu"):
        return {"w_gate": ("xfer", "tp"), "w_up": ("xfer", "tp"), "w_down": ("tp", "xfer")}
    return {"w_up": ("xfer", "tp"), "w_down": ("tp", "xfer")}


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------

def embed_tokens(embed: jax.Array, tokens: jax.Array, ctx=None) -> jax.Array:
    x = jnp.take(embed, tokens, axis=0)
    if ctx is not None:
        x = ctx.constrain(x, "batch", "seq", None)
    return x


def unembed_logits(w: jax.Array, x: jax.Array, ctx=None) -> jax.Array:
    logits = x @ w  # [B,S,V]
    if ctx is not None:
        logits = ctx.constrain(logits, "batch", "seq", "tp")
    return logits


def cross_entropy_chunked(unembed_w: jax.Array, x: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None, ctx=None,
                          chunk: int = 512) -> jax.Array:
    """Mean CE over tokens, computing logits in sequence chunks so the
    [B, S, V] tensor never materialises (vocab up to 257k)."""
    b, s, d = x.shape
    if mask is None:
        mask = jnp.ones((b, s), dtype=jnp.float32)
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    nb = s // chunk

    def body(carry, inp):
        xc, yc, mc = inp
        logits = (xc @ unembed_w).astype(jnp.float32)
        if ctx is not None:
            logits = ctx.constrain(logits, "batch", "seq", "tp")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        loss = jnp.sum((lse - gold) * mc)
        return carry + loss, None

    xs = x.reshape(b, nb, chunk, d).transpose(1, 0, 2, 3)
    ys = labels.reshape(b, nb, chunk).transpose(1, 0, 2)
    ms = mask.reshape(b, nb, chunk).transpose(1, 0, 2)
    total, _ = jax.lax.scan(body, jnp.float32(0.0), (xs, ys, ms))
    return total / jnp.maximum(jnp.sum(mask), 1.0)
