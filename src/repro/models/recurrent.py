"""Recurrent blocks: RG-LRU (RecurrentGemma/Griffin), mLSTM and sLSTM (xLSTM).

Train/prefill use parallel forms (associative scan for RG-LRU, decay-biased
chunked attention for mLSTM, time scan for sLSTM); decode uses O(1)
recurrent state updates. The two forms are numerically cross-checked by
property tests (tests/test_recurrent_parity.py).

**Pad-free prefill** (``seq_lens``): every parallel form accepts a per-row
true length for right-padded batches and stops integrating the padded
tail into the recurrent state — RG-LRU forces identity scan elements
``(a, b) = (1, 0)`` on padded steps, mLSTM forces identity gates
``(log f, i) = (0, -1e30)`` so padded steps carry zero weight in the
state fold, and sLSTM carries the previous state through masked steps.
The resulting state is bit-equal to running the unpadded prompt, for
*any* padded length — which is what lets the serving scheduler prefill
recurrent archs at power-of-two buckets instead of ``max_len``.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

_LRU_C = 8.0

_NEG = -1e30  # log-space "never": exp(_NEG - finite) underflows to exactly 0


def _valid_mask(seq_lens: Optional[jax.Array], s: int) -> Optional[jax.Array]:
    """[B, S] bool — True where the position is below the row's true
    length; None when no per-row lengths were given (nothing padded)."""
    if seq_lens is None:
        return None
    return jnp.arange(s)[None, :] < seq_lens[:, None]


# ---------------------------------------------------------------------------
# RG-LRU block (Griffin recurrent block: in-proj → conv1d → RG-LRU → gate)
# ---------------------------------------------------------------------------

def rglru_init(key, arch: ArchConfig, dtype=jnp.float32) -> dict:
    d = arch.d_model
    w = arch.lru_width or d
    heads = arch.num_heads
    hw = w // heads
    cw = arch.conv1d_width or 4
    ks = jax.random.split(key, 8)
    p = {
        "ln1": jnp.zeros((d,), dtype),
        "w_in": L.dense_init(ks[0], (d, 2 * w), 0, dtype),
        "conv_w": L.dense_init(ks[1], (cw, w), 0, dtype),
        "conv_b": jnp.zeros((w,), dtype),
        # block-diagonal per-head input/recurrence gates
        "gate_w": L.dense_init(ks[2], (heads, hw, 2 * hw), 1, dtype),
        "gate_b": jnp.zeros((heads, 2 * hw), dtype),
        "a_param": jnp.linspace(0.9, 0.999, w).astype(dtype),  # Λ init
        "w_out": L.dense_init(ks[3], (w, d), 0, dtype),
    }
    if arch.d_ff and arch.mlp != "none":
        p["ln2"] = jnp.zeros((d,), dtype)
        p["mlp"] = L.mlp_init(ks[4], d, arch.d_ff, arch.mlp, dtype)
    return p


def rglru_dims(arch: ArchConfig) -> dict:
    d = {
        "ln1": (None,),
        "w_in": ("xfer", "tp"),
        "conv_w": (None, "tp"),
        "conv_b": ("tp",),
        "gate_w": ("tp", None, None),
        "gate_b": ("tp", None),
        "a_param": ("tp",),
        "w_out": ("tp", "xfer"),
    }
    if arch.d_ff and arch.mlp != "none":
        d["ln2"] = (None,)
        d["mlp"] = L.mlp_dims(arch.mlp)
    return d


def make_rglru_state(arch: ArchConfig, batch: int, dtype=jnp.float32) -> dict:
    w = arch.lru_width or arch.d_model
    cw = arch.conv1d_width or 4
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cw - 1, w), dtype)}


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array],
                 seq_lens: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. x:[B,S,W], w:[cw,W]. Returns (y, new_state).

    ``seq_lens`` makes the carried state length-exact for right-padded
    rows: the window of the last ``cw-1`` *real* inputs is
    ``xp[len : len+cw-1]`` (``xp`` index ``i`` holds input ``i-(cw-1)``),
    instead of the padded tail the suffix slice would keep.
    """
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # [B, S+cw-1, W]
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(cw))
    if cw <= 1:
        return y + b, state
    if seq_lens is None:
        return y + b, xp[:, -(cw - 1):, :]
    new_state = jax.vmap(
        lambda row, l: jax.lax.dynamic_slice_in_dim(row, l, cw - 1, axis=0)
    )(xp, seq_lens)
    return y + b, new_state


def _rglru_gates(p: dict, xr: jax.Array, heads: int):
    b, s, w = xr.shape
    hw = w // heads
    xh = xr.reshape(b, s, heads, hw)
    g = jnp.einsum("bshd,hde->bshe", xh, p["gate_w"]) + p["gate_b"]
    r, i = jnp.split(g.reshape(b, s, 2 * w), 2, axis=-1)
    r, i = jax.nn.sigmoid(r.astype(jnp.float32)), jax.nn.sigmoid(i.astype(jnp.float32))
    log_a = -_LRU_C * jax.nn.softplus(p["a_param"].astype(jnp.float32)) * r
    gated_x = xr.astype(jnp.float32) * i
    scale = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return log_a, scale * gated_x


def rglru_apply(arch: ArchConfig, p: dict, x: jax.Array, ctx=None, *,
                state: Optional[dict] = None,
                seq_lens: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Optional[dict]]:
    b, s, d = x.shape
    h = L.rms_norm(x, p["ln1"])
    u = h @ p["w_in"]
    if ctx is not None:
        u = ctx.constrain(u, "batch", "seq", "tp")
    y_branch, xr = jnp.split(u, 2, axis=-1)

    conv_state = state["conv"] if state is not None else None
    xr, new_conv = _causal_conv(xr, p["conv_w"], p["conv_b"], conv_state,
                                seq_lens=None if s == 1 else seq_lens)
    log_a, bx = _rglru_gates(p, xr, arch.num_heads)

    if s == 1 and state is not None:  # decode step
        a = jnp.exp(log_a[:, 0])
        h_new = a * state["h"] + bx[:, 0]
        seq = h_new[:, None, :]
        new_state = {"h": h_new, "conv": new_conv}
    else:
        valid = _valid_mask(seq_lens, s)
        if valid is not None:
            # padded steps become scan identities (a, b) = (1, 0): the
            # carried h past the true length is exactly h_{len-1}
            log_a = jnp.where(valid[:, :, None], log_a, 0.0)
            bx = jnp.where(valid[:, :, None], bx, 0.0)
        a = jnp.exp(log_a)
        if state is not None:
            bx = bx.at[:, 0].add(a[:, 0] * state["h"])

        def comb(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        _, seq = jax.lax.associative_scan(comb, (a, bx), axis=1)
        new_state = ({"h": seq[:, -1], "conv": new_conv}
                     if state is not None else None)

    out = (seq.astype(x.dtype) * jax.nn.gelu(y_branch, approximate=True)) @ p["w_out"]
    x = x + out
    if ctx is not None:
        x = ctx.constrain(x, "batch", "sp", None)
    if "ln2" in p:
        x = x + L.mlp_apply(p["mlp"], L.rms_norm(x, p["ln2"]), arch.mlp, ctx)
        if ctx is not None:
            x = ctx.constrain(x, "batch", "sp", None)
    return x, new_state


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM): matrix memory, decay-biased attention parallel form
# ---------------------------------------------------------------------------

def mlstm_init(key, arch: ArchConfig, dtype=jnp.float32) -> dict:
    d = arch.d_model
    w = 2 * d  # expansion factor 2
    heads = arch.num_heads
    ks = jax.random.split(key, 8)
    return {
        "ln1": jnp.zeros((d,), dtype),
        "w_up": L.dense_init(ks[0], (d, 2 * w), 0, dtype),
        "wq": L.dense_init(ks[1], (w, w), 0, dtype),
        "wk": L.dense_init(ks[2], (w, w), 0, dtype),
        "wv": L.dense_init(ks[3], (w, w), 0, dtype),
        "w_i": L.dense_init(ks[4], (w, heads), 0, dtype),
        "w_f": L.dense_init(ks[5], (w, heads), 0, dtype),
        "b_i": jnp.zeros((heads,), dtype),
        "b_f": jnp.full((heads,), 3.0, dtype),  # forget-gate bias: remember
        "ln_inner": jnp.zeros((w,), dtype),
        "w_down": L.dense_init(ks[6], (w, d), 0, dtype),
    }


def mlstm_dims(arch: ArchConfig) -> dict:
    return {
        "ln1": (None,), "w_up": ("xfer", "tp"),
        "wq": ("xfer", "tp"), "wk": ("xfer", "tp"), "wv": ("xfer", "tp"),
        "w_i": ("xfer", "tp"), "w_f": ("xfer", "tp"),
        "b_i": ("tp",), "b_f": ("tp",),
        "ln_inner": ("tp",), "w_down": ("tp", "xfer"),
    }


def make_mlstm_state(arch: ArchConfig, batch: int) -> dict:
    w = 2 * arch.d_model
    heads = arch.num_heads
    hd = w // heads
    return {"C": jnp.zeros((batch, heads, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, heads, hd), jnp.float32),
            "m": jnp.full((batch, heads), -1e30, jnp.float32)}


def _mlstm_qkvif(arch: ArchConfig, p: dict, u: jax.Array):
    b, s, w = u.shape
    heads = arch.num_heads
    hd = w // heads
    q = (u @ p["wq"]).reshape(b, s, heads, hd)
    k = (u @ p["wk"]).reshape(b, s, heads, hd) / math.sqrt(hd)
    v = (u @ p["wv"]).reshape(b, s, heads, hd)
    it = (u @ p["w_i"] + p["b_i"]).astype(jnp.float32)  # [B,S,H]
    ft = (u @ p["w_f"] + p["b_f"]).astype(jnp.float32)
    return q, k, v, it, ft


def mlstm_apply(arch: ArchConfig, p: dict, x: jax.Array, ctx=None, *,
                state: Optional[dict] = None,
                seq_lens: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Optional[dict]]:
    b, s, d = x.shape
    h0 = L.rms_norm(x, p["ln1"])
    up = h0 @ p["w_up"]
    if ctx is not None:
        up = ctx.constrain(up, "batch", "seq", "tp")
    u, z = jnp.split(up, 2, axis=-1)  # mixer input, output gate branch
    q, k, v, it, ft = _mlstm_qkvif(arch, p, u)
    heads = arch.num_heads
    hd = u.shape[-1] // heads

    if s == 1 and state is not None:  # recurrent decode
        logf = jax.nn.log_sigmoid(ft[:, 0])  # [B,H]
        m_new = jnp.maximum(logf + state["m"], it[:, 0])
        fs = jnp.exp(logf + state["m"] - m_new)[..., None]
        is_ = jnp.exp(it[:, 0] - m_new)[..., None]
        kf = k[:, 0].transpose(0, 2, 1).astype(jnp.float32)  # [B,hd? no
        k1 = k[:, 0].astype(jnp.float32)  # [B,H,hd]
        v1 = v[:, 0].astype(jnp.float32)
        C = fs[..., None] * state["C"] + is_[..., None] * (k1[..., :, None] * v1[..., None, :])
        n = fs * state["n"] + is_ * k1
        q1 = q[:, 0].astype(jnp.float32)
        num = jnp.einsum("bhkv,bhk->bhv", C, q1)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q1)),
                          jnp.exp(-m_new))[..., None]
        hseq = (num / den).reshape(b, 1, heads * hd)
        new_state = {"C": C, "n": n, "m": m_new}
    else:
        # chunkwise parallel form: intra-chunk decay-biased attention +
        # cross-chunk recurrent state (keeps memory O(S·Q), not O(S²)).
        st0 = state if state is not None else make_mlstm_state(arch, b)
        logf = jax.nn.log_sigmoid(ft)  # [B,S,H]
        valid = _valid_mask(seq_lens, s)
        if valid is not None:
            # identity gates on padded steps: forget=1 (log f = 0) keeps
            # the cumulative decay F flat past the true length, and the
            # _NEG input gate gives the step weight exp(_NEG - m) == 0 in
            # the state fold — padded k/v never enter (C, n, m)
            logf = jnp.where(valid[..., None], logf, 0.0)
            it = jnp.where(valid[..., None], it, _NEG)
        chunk = min(s, 1024)
        while s % chunk:
            chunk -= 1
        nb = s // chunk

        def chunk_body(carry, inp):
            # "flashattn" scope: VMEM-resident in the mlstm Pallas kernel
            qc, kc, vc, ic, fc = inp  # [B,Q,H,*]
            F = jnp.cumsum(fc, axis=1)  # [B,Q,H]
            Ft = F.transpose(0, 2, 1)  # [B,H,Q]
            it_t = ic.transpose(0, 2, 1)
            bias = Ft[:, :, :, None] - Ft[:, :, None, :] + it_t[:, :, None, :]
            causal = jnp.tril(jnp.ones((chunk, chunk), bool))
            bias = jnp.where(causal[None, None], bias, -jnp.inf)
            w_state = Ft + carry["m"][:, :, None]  # [B,H,Q]
            m_i = jnp.maximum(jnp.max(bias, axis=-1), w_state)
            m_i = jnp.maximum(m_i, -1e30)
            dmat = jnp.exp(bias - m_i[..., None])
            qf, kf, vf = (t.astype(jnp.float32) for t in (qc, kc, vc))
            scores = jnp.einsum("bqhd,bthd->bhqt", qf, kf) * dmat
            s_coef = jnp.exp(w_state - m_i)  # [B,H,Q]
            num = (jnp.einsum("bhqt,bthd->bqhd", scores, vf)
                   + jnp.einsum("bhq,bhkv,bqhk->bqhv", s_coef, carry["C"], qf))
            den = (jnp.einsum("bhqt->bhq", scores)
                   + s_coef * jnp.einsum("bhk,bqhk->bhq", carry["n"], qf))
            den = jnp.maximum(jnp.abs(den), jnp.exp(-m_i)).transpose(0, 2, 1)
            out = num / den[..., None]  # [B,Q,H,hd]
            nxt = _mlstm_suffix_state(arch, carry, kc, vc, ic, fc)
            return nxt, out

        def rs(t):  # [B,S,...] -> [nb,B,Q,...]
            return t.reshape(b, nb, chunk, *t.shape[2:]).transpose(1, 0, 2, *range(3, t.ndim + 1))

        st2, outs = jax.lax.scan(
            chunk_body, st0, (rs(q), rs(k), rs(v), rs(it), rs(logf)))
        hseq = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, heads * hd)
        new_state = st2 if state is not None else None

    hseq = L.rms_norm(hseq.astype(x.dtype), p["ln_inner"])
    out = (hseq * jax.nn.silu(z)) @ p["w_down"]
    x = x + out
    if ctx is not None:
        x = ctx.constrain(x, "batch", "sp", None)
    return x, new_state


def _mlstm_suffix_state(arch, state, k, v, it, logf):
    """Fold a full sequence into the recurrent state (prefill → decode)."""
    b, s, heads, hd = k.shape
    F = jnp.cumsum(logf, axis=1)  # [B,S,H]
    Fe = F[:, -1][:, None]  # [B,1,H]
    w_log = (Fe - F + it)  # weight of step t in final state (log)
    m_new = jnp.maximum(jnp.max(w_log, axis=1), Fe[:, 0] + state["m"])  # [B,H]
    wts = jnp.exp(w_log - m_new[:, None, :])  # [B,S,H]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    C = jnp.einsum("bsh,bshk,bshv->bhkv", wts, kf, vf)
    n = jnp.einsum("bsh,bshk->bhk", wts, kf)
    carry = jnp.exp(Fe[:, 0] + state["m"] - m_new)
    C = C + carry[..., None, None] * state["C"]
    n = n + carry[..., None] * state["n"]
    return {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM): scalar memory, strictly sequential scan
# ---------------------------------------------------------------------------

def slstm_init(key, arch: ArchConfig, dtype=jnp.float32) -> dict:
    d = arch.d_model
    heads = arch.num_heads
    hd = d // heads
    ks = jax.random.split(key, 4)
    return {
        "ln1": jnp.zeros((d,), dtype),
        "w": L.dense_init(ks[0], (d, 4 * d), 0, dtype),
        "r": L.dense_init(ks[1], (heads, hd, 4 * hd), 1, dtype),
        "b": jnp.zeros((4 * d,), dtype),
        "w_out": L.dense_init(ks[2], (d, d), 0, dtype),
    }


def slstm_dims(arch: ArchConfig) -> dict:
    return {"ln1": (None,), "w": ("xfer", "tp"), "r": ("tp", None, None),
            "b": ("tp",), "w_out": ("xfer", "tp")}


def make_slstm_state(arch: ArchConfig, batch: int) -> dict:
    d = arch.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, d), -1e30, jnp.float32)}


def _slstm_step(arch: ArchConfig, p: dict, state: dict, xt: jax.Array):
    """One timestep. xt: [B, 4D] pre-activations from the input proj."""
    b = xt.shape[0]
    d = arch.d_model
    heads = arch.num_heads
    hd = d // heads
    hprev = state["h"].reshape(b, heads, hd).astype(xt.dtype)
    rec = jnp.einsum("bhd,hde->bhe", hprev, p["r"]).reshape(b, 4 * d)
    pre = (xt + rec + p["b"]).astype(jnp.float32)
    i_, f_, z_, o_ = jnp.split(pre, 4, axis=-1)
    m_new = jnp.maximum(f_ + state["m"], i_)
    ip = jnp.exp(i_ - m_new)
    fp = jnp.exp(f_ + state["m"] - m_new)
    c = fp * state["c"] + ip * jnp.tanh(z_)
    n = fp * state["n"] + ip
    h = jax.nn.sigmoid(o_) * c / jnp.maximum(n, 1e-6)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_apply(arch: ArchConfig, p: dict, x: jax.Array, ctx=None, *,
                state: Optional[dict] = None,
                seq_lens: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Optional[dict]]:
    b, s, d = x.shape
    h0 = L.rms_norm(x, p["ln1"])
    pre = h0 @ p["w"]  # [B,S,4D]
    if ctx is not None:
        pre = ctx.constrain(pre, "batch", "seq", "tp")
    st = state if state is not None else make_slstm_state(arch, b)

    if s == 1:
        st2 = _slstm_step(arch, p, st, pre[:, 0])
        seq = st2["h"][:, None].astype(x.dtype)
        new_state = st2 if state is not None else None
    else:
        valid = _valid_mask(seq_lens, s)

        def body(carry, inp):
            xt, vt = inp
            nxt = _slstm_step(arch, p, carry, xt)
            if vt is not None:
                # mask-carry: padded steps pass the state (incl. h, which
                # feeds the recurrence matrix) through untouched
                nxt = jax.tree.map(
                    lambda n, c: jnp.where(vt[:, None], n, c), nxt, carry)
            return nxt, nxt["h"]

        xs = (pre.transpose(1, 0, 2),
              valid.transpose(1, 0) if valid is not None else None)
        if valid is None:
            st2, hs = jax.lax.scan(lambda c, xt: body(c, (xt, None)), st, xs[0])
        else:
            st2, hs = jax.lax.scan(body, st, xs)
        seq = hs.transpose(1, 0, 2).astype(x.dtype)
        new_state = st2 if state is not None else None

    x = x + seq @ p["w_out"]
    if ctx is not None:
        x = ctx.constrain(x, "batch", "sp", None)
    return x, new_state
