"""Unified decoder-only LM stack covering dense / MoE / hybrid / SSM / VLM.

The layer stack is organised as
  prefix  — unrolled leading layers (e.g. DeepSeekMoE's dense first layer)
  body    — `repeats` copies of the arch's block pattern, stacked and
            scanned (keeps HLO size O(pattern), not O(layers))
  suffix  — unrolled trailing layers (pattern remainder, e.g.
            RecurrentGemma's 26 = 8×(r,r,a) + (r,r))

Under an XFER plan the body scan prefetches the next repeat's weights one
step ahead (core.xfer.scan_layers) — the paper's double-buffering at layer
granularity.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.xfer import ShardingCtx, scan_layers
from repro.models import blocks as B
from repro.models import layers as L
from repro.models import recurrent as R

PyTree = Any

# Remat policy (§Perf iteration 4): save no-batch-dim dot outputs (layer
# weights' products) but recompute everything else — cheaper backward
# recompute traffic than nothing_saveable at ~1 activation per matmul of
# extra residency. Overridable for experiments.
_REMAT_POLICY = jax.checkpoint_policies.nothing_saveable


def set_remat_policy(policy):
    global _REMAT_POLICY
    _REMAT_POLICY = policy


def _pattern(arch: ArchConfig) -> Tuple[str, ...]:
    return arch.block_pattern or ("attn",)


def stack_structure(arch: ArchConfig) -> Tuple[List[str], int, List[str]]:
    """(prefix kinds, body repeats, suffix kinds)."""
    pat = _pattern(arch)
    n = arch.num_layers
    prefix = []
    if arch.family == "moe" and arch.first_dense_layers:
        prefix = ["attn"] * arch.first_dense_layers  # dense MLP layers
        n -= arch.first_dense_layers
    repeats, rem = divmod(n, len(pat))
    suffix = list(pat[:rem])
    return prefix, repeats, suffix


def _block_init(kind: str, key, arch: ArchConfig, dtype, moe: bool):
    if kind == "attn":
        return B.attn_init(key, arch, dtype, moe=moe,
                           d_ff=arch.d_ff if not moe else None)
    if kind == "rglru":
        return R.rglru_init(key, arch, dtype)
    if kind == "mlstm":
        return R.mlstm_init(key, arch, dtype)
    if kind == "slstm":
        return R.slstm_init(key, arch, dtype)
    raise ValueError(kind)


def _block_dims(kind: str, arch: ArchConfig, moe: bool):
    if kind == "attn":
        return B.attn_dims(arch, moe=moe, d_ff=arch.d_ff if not moe else None)
    if kind == "rglru":
        return R.rglru_dims(arch)
    if kind == "mlstm":
        return R.mlstm_dims(arch)
    if kind == "slstm":
        return R.slstm_dims(arch)
    raise ValueError(kind)


def _block_cache(kind: str, arch: ArchConfig, batch: int, length: int, dtype,
                 kv_quant: bool = False):
    if kind == "attn":
        win = arch.window if arch.family == "hybrid" else 0
        return B.make_kv_cache(arch, batch, length, dtype, window=win,
                               kv_quant=kv_quant)
    if kind == "rglru":
        return R.make_rglru_state(arch, batch, dtype)
    if kind == "mlstm":
        return R.make_mlstm_state(arch, batch)
    if kind == "slstm":
        return R.make_slstm_state(arch, batch)
    raise ValueError(kind)


def _block_apply(kind: str, arch: ArchConfig, p: PyTree, x, ctx, *,
                 positions, cache, prefix_len, moe: bool, seq_lens=None,
                 page_table=None, append: bool = False):
    if kind == "attn":
        win = arch.window if arch.family == "hybrid" else 0
        return B.attn_apply(arch, p, x, ctx, positions=positions, cache=cache,
                            window=win, prefix_len=prefix_len, moe=moe,
                            seq_lens=seq_lens, page_table=page_table,
                            append=append)
    if kind == "rglru":
        return R.rglru_apply(arch, p, x, ctx, state=cache, seq_lens=seq_lens)
    if kind == "mlstm":
        return R.mlstm_apply(arch, p, x, ctx, state=cache, seq_lens=seq_lens)
    if kind == "slstm":
        return R.slstm_apply(arch, p, x, ctx, state=cache, seq_lens=seq_lens)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# params / dims / caches
# ---------------------------------------------------------------------------

def init_params(arch: ArchConfig, key, dtype=jnp.float32) -> Dict:
    prefix, repeats, suffix = stack_structure(arch)
    moe = arch.family == "moe"
    keys = jax.random.split(key, 4 + len(prefix) + len(suffix))
    params: Dict[str, Any] = {
        "embed": L.dense_init(keys[0], (arch.vocab_size, arch.d_model), 1, dtype),
        "final_norm": jnp.zeros((arch.d_model,), dtype),
    }
    if not arch.tie_embeddings:
        params["unembed"] = L.dense_init(keys[1], (arch.d_model, arch.vocab_size), 0, dtype)
    for i, kind in enumerate(prefix):
        params[f"prefix{i}"] = _block_init(kind, keys[4 + i], arch, dtype, moe=False)
    pat = _pattern(arch)
    if repeats:
        def one_repeat(k):
            ks = jax.random.split(k, len(pat))
            return {f"b{j}_{kind}": _block_init(kind, ks[j], arch, dtype, moe)
                    for j, kind in enumerate(pat)}
        params["body"] = jax.vmap(one_repeat)(jax.random.split(keys[2], repeats))
    for i, kind in enumerate(suffix):
        params[f"suffix{i}"] = _block_init(kind, keys[4 + len(prefix) + i], arch, dtype, moe)
    return params


def param_dims(arch: ArchConfig) -> Dict:
    """Logical sharding roles matching init_params' tree."""
    prefix, repeats, suffix = stack_structure(arch)
    moe = arch.family == "moe"
    dims: Dict[str, Any] = {
        "embed": ("tp", "xfer"),
        "final_norm": (None,),
    }
    if not arch.tie_embeddings:
        dims["unembed"] = ("xfer", "tp")
    for i, kind in enumerate(prefix):
        dims[f"prefix{i}"] = _block_dims(kind, arch, moe=False)
    pat = _pattern(arch)
    if repeats:
        body = {f"b{j}_{kind}": _block_dims(kind, arch, moe)
                for j, kind in enumerate(pat)}
        dims["body"] = jax.tree.map(lambda d: (None,) + tuple(d), body,
                                    is_leaf=lambda x: isinstance(x, tuple))
    for i, kind in enumerate(suffix):
        dims[f"suffix{i}"] = _block_dims(kind, arch, moe)
    return dims


def body_dims_unstacked(arch: ArchConfig) -> Dict:
    pat = _pattern(arch)
    moe = arch.family == "moe"
    return {f"b{j}_{kind}": _block_dims(kind, arch, moe)
            for j, kind in enumerate(pat)}


def make_caches(arch: ArchConfig, batch: int, length: int, dtype=jnp.bfloat16,
                kv_quant: bool = False) -> Dict:
    prefix, repeats, suffix = stack_structure(arch)
    caches: Dict[str, Any] = {}
    for i, kind in enumerate(prefix):
        caches[f"prefix{i}"] = _block_cache(kind, arch, batch, length, dtype,
                                            kv_quant)
    pat = _pattern(arch)
    if repeats:
        def stack(*ts):
            return jnp.stack(ts) if repeats > 1 else ts[0][None]
        one = {f"b{j}_{kind}": _block_cache(kind, arch, batch, length, dtype,
                                            kv_quant)
               for j, kind in enumerate(pat)}
        caches["body"] = jax.tree.map(
            lambda leaf: jnp.broadcast_to(leaf[None], (repeats,) + leaf.shape), one)
    for i, kind in enumerate(suffix):
        caches[f"suffix{i}"] = _block_cache(kind, arch, batch, length, dtype,
                                            kv_quant)
    return caches


def cache_dims(arch: ArchConfig, kv_quant: bool = False) -> Dict:
    """Sharding roles for cache trees (kv: batch + tp over kv heads)."""
    prefix, repeats, suffix = stack_structure(arch)

    def kv_roles(kind):
        if kind == "attn":
            from repro.core.xfer import explicit_spmd_enabled
            if explicit_spmd_enabled():
                # cache sharded over its sequence dim (flash-decoding
                # partials; kv-head counts rarely divide the TP degree)
                roles = {"k": ("batch", "tp", None, None),
                         "v": ("batch", "tp", None, None),
                         "pos": ("batch", "tp"), "count": ()}
            else:
                roles = {"k": ("batch", None, "tp", None),
                         "v": ("batch", None, "tp", None),
                         "pos": ("batch", None), "count": ()}
            if kv_quant:
                # scales ride the same batch/length layout as the payload
                roles["k_scale"] = roles["k"][:-1] + (None,)
                roles["v_scale"] = roles["v"][:-1] + (None,)
            return roles
        if kind == "rglru":
            return {"h": ("batch", "tp"), "conv": ("batch", None, "tp")}
        if kind == "mlstm":
            return {"C": ("batch", "tp", None, None), "n": ("batch", "tp", None),
                    "m": ("batch", "tp")}
        return {"c": ("batch", "tp"), "n": ("batch", "tp"), "h": ("batch", "tp"),
                "m": ("batch", "tp")}

    dims: Dict[str, Any] = {}
    for i, kind in enumerate(prefix):
        dims[f"prefix{i}"] = kv_roles(kind)
    pat = _pattern(arch)
    if repeats:
        body = {f"b{j}_{kind}": kv_roles(kind) for j, kind in enumerate(pat)}
        dims["body"] = jax.tree.map(lambda d: (None,) + tuple(d), body,
                                    is_leaf=lambda x: isinstance(x, tuple))
    for i, kind in enumerate(suffix):
        dims[f"suffix{i}"] = kv_roles(kind)
    return dims


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def forward(arch: ArchConfig, params: Dict, tokens: jax.Array,
            ctx: Optional[ShardingCtx] = None, *,
            caches: Optional[Dict] = None,
            positions: Optional[jax.Array] = None,
            prefix_embeds: Optional[jax.Array] = None,
            seq_lens: Optional[jax.Array] = None,
            page_table: Optional[jax.Array] = None,
            remat: bool = False,
            append: bool = False) -> Tuple[jax.Array, Optional[Dict]]:
    """Returns (hidden [B,S,D] after final norm, updated caches or None).

    ``append=True`` (speculative decoding): ``caches`` is a *filled*
    grid and the S fresh tokens per row are scattered at ``positions``
    instead of re-filling from scratch — attention-only archs, see
    ``blocks.attn_apply``.

    ``prefix_embeds``: modality-frontend stub output ([B, P, D]) prepended
    to the token embeddings (vlm/audio archs); attended bidirectionally.

    ``seq_lens`` ([B] int32, prefix included): true per-row length of a
    right-padded batch. Recurrent/windowed blocks then produce
    length-exact caches (the padded tail never enters the carried state
    — see ``models.recurrent``), which is what lets the serving
    scheduler prefill every arch family at power-of-two buckets.

    ``page_table`` ([B, M] int32): paged decode — ``caches`` is then the
    page-pool tree (``serving.pages.make_paged_caches``) shared by all
    slots, and the table maps each row's logical position blocks to
    physical pages.
    """
    prefix, repeats, suffix = stack_structure(arch)
    moe = arch.family == "moe"
    pat = _pattern(arch)

    x = L.embed_tokens(params["embed"], tokens, ctx)
    x = x * jnp.asarray(arch.d_model ** 0.5, x.dtype)
    prefix_len = None
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        if ctx is not None:
            x = ctx.constrain(x, "batch", "seq", None)
        prefix_len = jnp.full((x.shape[0],), prefix_embeds.shape[1], jnp.int32)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    new_caches: Dict[str, Any] = {}

    def apply_one(kind, p, h, cache, moe_block=None):
        use_moe = (moe and kind == "attn") if moe_block is None else moe_block

        def fn(p_, h_, cache_):
            return _block_apply(kind, arch, p_, h_, ctx, positions=positions,
                                prefix_len=prefix_len, moe=use_moe,
                                cache=cache_, seq_lens=seq_lens,
                                page_table=page_table, append=append)
        if remat:
            fn = jax.checkpoint(fn, policy=_REMAT_POLICY)
        return fn(p, h, cache)

    for i, kind in enumerate(prefix):
        x, c = apply_one(kind, params[f"prefix{i}"], x,
                         None if caches is None else caches[f"prefix{i}"],
                         moe_block=False)
        if caches is not None:
            new_caches[f"prefix{i}"] = c

    if repeats:
        def pattern_body(p_rep, h, cache_rep=None):
            outs = {}
            for j, kind in enumerate(pat):
                key = f"b{j}_{kind}"
                h, c = apply_one(kind, p_rep[key], h,
                                 None if cache_rep is None else cache_rep[key])
                if cache_rep is not None:
                    outs[key] = c
            return h, outs

        if caches is None:
            x = scan_layers(lambda p, h: pattern_body(p, h)[0], params["body"], x,
                            ctx=ctx, specs=body_dims_unstacked(arch))
        else:
            def body(h, xs):
                p_rep, cache_rep = xs
                h, outs = pattern_body(p_rep, h, cache_rep)
                return h, outs

            x, body_caches = jax.lax.scan(body, x, (params["body"], caches["body"]))
            new_caches["body"] = body_caches

    for i, kind in enumerate(suffix):
        x, c = apply_one(kind, params[f"suffix{i}"], x,
                         None if caches is None else caches[f"suffix{i}"])
        if caches is not None:
            new_caches[f"suffix{i}"] = c

    x = L.rms_norm(x, params["final_norm"])
    return x, (new_caches if caches is not None else None)


def unembed_matrix(arch: ArchConfig, params: Dict) -> jax.Array:
    return params["embed"].T if arch.tie_embeddings else params["unembed"]


def logits_fn(arch: ArchConfig, params: Dict, hidden: jax.Array, ctx=None) -> jax.Array:
    return L.unembed_logits(unembed_matrix(arch, params), hidden, ctx)


def loss_fn(arch: ArchConfig, params: Dict, tokens: jax.Array, labels: jax.Array,
            ctx=None, mask: Optional[jax.Array] = None,
            prefix_embeds: Optional[jax.Array] = None) -> jax.Array:
    hidden, _ = forward(arch, params, tokens, ctx, prefix_embeds=prefix_embeds,
                        remat=True)
    if prefix_embeds is not None:  # loss only on the text tail
        hidden = hidden[:, prefix_embeds.shape[1]:]
    return L.cross_entropy_chunked(unembed_matrix(arch, params), hidden, labels,
                                   mask=mask, ctx=ctx)
