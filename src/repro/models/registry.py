"""Arch registry: step builders + input specs for every (arch × shape) cell.

The three step kinds (DESIGN.md §5):
  train_step(params, opt_state, batch)          -> (params, opt_state, metrics)
  prefill_step(params, batch)                   -> (caches, last_logits)
  serve_step(params, caches, batch)             -> (next_token, caches)

``input_specs(arch, shape)`` returns ShapeDtypeStructs for the batch — the
dry-run lowers against these without allocating (modality frontends are
stubs: audio frames / vision patches arrive as precomputed embeddings).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.xfer import ShardingCtx
from repro.models import encdec as ED
from repro.models import lm as LM
from repro.optim import adamw as OPT

PyTree = Any

DEC_FRAC = 8  # enc-dec: decoder target length = seq_len // DEC_FRAC


# ---------------------------------------------------------------------------
# params / dims / caches dispatch
# ---------------------------------------------------------------------------

def init_params(arch: ArchConfig, key, dtype=jnp.float32) -> PyTree:
    if arch.family == "encdec":
        return ED.init_params(arch, key, dtype)
    return LM.init_params(arch, key, dtype)


def param_dims(arch: ArchConfig) -> PyTree:
    if arch.family == "encdec":
        return ED.param_dims(arch)
    return LM.param_dims(arch)


def make_caches(arch: ArchConfig, batch: int, length: int, dtype=jnp.bfloat16) -> PyTree:
    if arch.family == "encdec":
        return ED.make_caches(arch, batch, length, dtype)
    return LM.make_caches(arch, batch, length, dtype)


def cache_dims(arch: ArchConfig) -> PyTree:
    if arch.family == "encdec":
        return ED.cache_dims(arch)
    return LM.cache_dims(arch)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(arch: ArchConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if arch.family == "encdec":
        if shape.kind == "train":
            T = max(S // DEC_FRAC, 1)
            return {"frames": sds((B, S, arch.d_model), dtype),
                    "tokens": sds((B, T), i32), "labels": sds((B, T), i32)}
        if shape.kind == "prefill":
            return {"frames": sds((B, S, arch.d_model), dtype),
                    "tokens": sds((B, max(S // DEC_FRAC, 1)), i32)}
        return {"tokens": sds((B, 1), i32), "positions": sds((B, 1), i32),
                "enc_out": sds((B, S, arch.d_model), dtype)}
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    text = S
    if arch.frontend == "vision_patches" and shape.kind in ("train", "prefill"):
        out["patches"] = sds((B, arch.frontend_tokens, arch.d_model), dtype)
        text = S - arch.frontend_tokens
    if shape.kind == "train":
        out["tokens"] = sds((B, text), i32)
        out["labels"] = sds((B, text), i32)
    elif shape.kind == "prefill":
        out["tokens"] = sds((B, text), i32)
    else:  # decode
        out["tokens"] = sds((B, 1), i32)
        out["positions"] = sds((B, 1), i32)
    return out


def input_dims(arch: ArchConfig, shape: ShapeConfig) -> Dict[str, tuple]:
    """Logical sharding roles for each batch input."""
    d: Dict[str, tuple] = {}
    for k, v in input_specs(arch, shape).items():
        if k in ("tokens", "labels", "positions"):
            d[k] = ("batch", "seq")[: len(v.shape)] if len(v.shape) == 2 else ("batch",)
            d[k] = ("batch", "seq") if shape.kind != "decode" else ("batch", None)
        elif k in ("frames", "patches", "enc_out"):
            d[k] = ("batch", "seq", None)
    return d


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_train_step(arch: ArchConfig, cfg: OPT.AdamWConfig,
                     ctx: Optional[ShardingCtx] = None,
                     lr_schedule: Optional[Callable] = None,
                     accum_steps: int = 1) -> Callable:
    """Train step; with ``accum_steps > 1`` the batch is split into equal
    microbatches along the batch dim and gradients are averaged before the
    single optimizer update (distributed-optimization trick: holds the
    global batch while shrinking per-step activation memory by the factor)."""
    schedule = lr_schedule or (lambda step: jnp.asarray(cfg.lr, jnp.float32))

    def loss(params, batch):
        if arch.family == "encdec":
            return ED.loss_fn(arch, params, batch["frames"], batch["tokens"],
                              batch["labels"], ctx)
        return LM.loss_fn(arch, params, batch["tokens"], batch["labels"], ctx,
                          prefix_embeds=batch.get("patches"))

    def grads_of(params, batch):
        if accum_steps <= 1:
            return jax.value_and_grad(loss)(params, batch)

        def split(x):
            b = x.shape[0]
            assert b % accum_steps == 0, (b, accum_steps)
            return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            lsum, gsum = carry
            lval, g = jax.value_and_grad(loss)(params, mb)
            return (lsum + lval,
                    jax.tree.map(jnp.add, gsum, g)), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (lsum, gsum), _ = jax.lax.scan(body, (jnp.float32(0.0), zeros), micro)
        inv = 1.0 / accum_steps
        return lsum * inv, jax.tree.map(lambda g: g * inv, gsum)

    def train_step(params, opt_state, batch):
        lval, grads = grads_of(params, batch)
        lr = schedule(opt_state["step"])
        params, opt_state, info = OPT.adamw_update(params, grads, opt_state, cfg, lr)
        metrics = {"loss": lval, "lr": lr, **info}
        return params, opt_state, metrics

    return train_step


def build_prefill_step(arch: ArchConfig, shape: ShapeConfig,
                       ctx: Optional[ShardingCtx] = None,
                       cache_dtype=jnp.bfloat16) -> Callable:
    B, S = shape.global_batch, shape.seq_len

    def prefill_step(params, batch):
        if arch.family == "encdec":
            enc_out = ED.encode(arch, params, batch["frames"], ctx)
            caches = ED.make_caches(arch, B, S, cache_dtype)
            hidden, caches = ED.decode(arch, params, batch["tokens"], enc_out,
                                       ctx, caches=caches)
            logits = hidden[:, -1:] @ params["unembed"]
            return caches, logits, enc_out
        caches = LM.make_caches(arch, B, S, cache_dtype)
        hidden, caches = LM.forward(arch, params, batch["tokens"], ctx,
                                    caches=caches,
                                    prefix_embeds=batch.get("patches"))
        logits = LM.logits_fn(arch, params, hidden[:, -1:], ctx)
        return caches, logits

    return prefill_step


def build_serve_step(arch: ArchConfig, ctx: Optional[ShardingCtx] = None) -> Callable:
    def serve_step(params, caches, batch):
        if arch.family == "encdec":
            hidden, caches = ED.decode(arch, params, batch["tokens"],
                                       batch["enc_out"], ctx, caches=caches,
                                       positions=batch["positions"])
            logits = hidden @ params["unembed"]
        else:
            hidden, caches = LM.forward(arch, params, batch["tokens"], ctx,
                                        caches=caches,
                                        positions=batch["positions"])
            logits = LM.logits_fn(arch, params, hidden, ctx)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, caches

    return serve_step


def build_step(arch: ArchConfig, shape: ShapeConfig,
               ctx: Optional[ShardingCtx] = None,
               opt_cfg: Optional[OPT.AdamWConfig] = None) -> Callable:
    if shape.kind == "train":
        return build_train_step(arch, opt_cfg or OPT.AdamWConfig(), ctx)
    if shape.kind == "prefill":
        return build_prefill_step(arch, shape, ctx)
    return build_serve_step(arch, ctx)
