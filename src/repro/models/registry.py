"""Arch registry: step builders + input specs for every (arch × shape) cell.

The three step kinds (DESIGN.md §5):
  train_step(params, opt_state, batch)          -> (params, opt_state, metrics)
  prefill_step(params, batch)                   -> (caches, last_logits)
  serve_step(params, caches, batch)             -> (next_token, caches)

``build_serve_step`` has a second, state-threaded form for the serving
runtime (``sampling=`` given): the decode step consumes a device-resident
:class:`repro.serving.state.DecodeState`, folds on-device sampling into
the same jit, and returns a small per-step record instead of forcing the
host to read the token grid back every step:

  serve_step(params, caches, state)  -> (state', caches', record)

``input_specs(arch, shape)`` returns ShapeDtypeStructs for the batch — the
dry-run lowers against these without allocating (modality frontends are
stubs: audio frames / vision patches arrive as precomputed embeddings).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.xfer import ShardingCtx
from repro.models import encdec as ED
from repro.models import lm as LM
from repro.optim import adamw as OPT

PyTree = Any

DEC_FRAC = 8  # enc-dec: decoder target length = seq_len // DEC_FRAC


# ---------------------------------------------------------------------------
# params / dims / caches dispatch
# ---------------------------------------------------------------------------

def init_params(arch: ArchConfig, key, dtype=jnp.float32) -> PyTree:
    if arch.family == "encdec":
        return ED.init_params(arch, key, dtype)
    return LM.init_params(arch, key, dtype)


def param_dims(arch: ArchConfig) -> PyTree:
    if arch.family == "encdec":
        return ED.param_dims(arch)
    return LM.param_dims(arch)


def make_caches(arch: ArchConfig, batch: int, length: int, dtype=jnp.bfloat16,
                kv_quant: bool = False) -> PyTree:
    if arch.family == "encdec":
        return ED.make_caches(arch, batch, length, dtype, kv_quant=kv_quant)
    return LM.make_caches(arch, batch, length, dtype, kv_quant=kv_quant)


def cache_dims(arch: ArchConfig, kv_quant: bool = False) -> PyTree:
    if arch.family == "encdec":
        return ED.cache_dims(arch, kv_quant=kv_quant)
    return LM.cache_dims(arch, kv_quant=kv_quant)


def caches_quantized(caches: PyTree) -> bool:
    """Structural probe: does this cache tree carry int8 KV scale leaves
    (``k_scale`` / paged ``kps``)? Used to derive matching dims trees
    without threading a flag through every call site."""
    def walk(node):
        if not isinstance(node, dict):
            return False
        if "k_scale" in node or "kps" in node:
            return True
        return any(walk(v) for v in node.values())

    return walk(caches)


@dataclasses.dataclass(frozen=True)
class CacheAxes:
    """Which axis of one cache leaf is the batch-slot axis and which (if
    any) scales with the cache length. Deliberately NOT a registered
    pytree: it is carried as a leaf in a tree parallel to the cache.

    ``page`` is the pool axis of a *paged* cache leaf (scales with
    ``kv_pages``, see ``serving.pages.paged_cache_axes``); pool leaves
    have no batch-slot axis — the page table carries slot identity."""

    batch: Optional[int]
    length: Optional[int]
    page: Optional[int] = None


def cache_axes(arch: ArchConfig, dtype=jnp.bfloat16,
               kv_quant: bool = False) -> PyTree:
    """Per-leaf :class:`CacheAxes` metadata, derived from ``make_caches``.

    The axes are found structurally — ``eval_shape`` the cache skeleton at
    two batch sizes and two lengths and diff the leaf shapes — so the
    metadata can never drift from the constructor, and cache-splicing code
    need not guess the batch axis from runtime shapes (the old heuristic
    mis-matched when a model dim collided with the slot count).

    Leaves whose shape depends on neither (e.g. the scalar ``count``) get
    ``CacheAxes(None, None)``; windowed KV caches whose length saturates at
    the window report ``length=None`` at probe sizes beyond the window.
    ``kv_quant=True`` probes the int8 layout, so the per-token scale
    leaves get their own (identical batch/length) axes entries — splice
    and admit then handle them with zero special cases.
    """
    probes = [jax.eval_shape(lambda b=b, l=l: make_caches(arch, b, l, dtype,
                                                          kv_quant=kv_quant))
              for b, l in ((2, 16), (3, 16), (2, 32))]

    def one(base, bdiff, ldiff):
        b_ax = [i for i, (p, q) in enumerate(zip(base.shape, bdiff.shape))
                if p != q]
        l_ax = [i for i, (p, q) in enumerate(zip(base.shape, ldiff.shape))
                if p != q]
        assert len(b_ax) <= 1 and len(l_ax) <= 1, (base.shape, b_ax, l_ax)
        return CacheAxes(batch=b_ax[0] if b_ax else None,
                         length=l_ax[0] if l_ax else None)

    return jax.tree.map(one, *probes)


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(arch: ArchConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if arch.family == "encdec":
        if shape.kind == "train":
            T = max(S // DEC_FRAC, 1)
            return {"frames": sds((B, S, arch.d_model), dtype),
                    "tokens": sds((B, T), i32), "labels": sds((B, T), i32)}
        if shape.kind == "prefill":
            return {"frames": sds((B, S, arch.d_model), dtype),
                    "tokens": sds((B, max(S // DEC_FRAC, 1)), i32)}
        return {"tokens": sds((B, 1), i32), "positions": sds((B, 1), i32),
                "enc_out": sds((B, S, arch.d_model), dtype)}
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    text = S
    if arch.frontend == "vision_patches" and shape.kind in ("train", "prefill"):
        out["patches"] = sds((B, arch.frontend_tokens, arch.d_model), dtype)
        text = S - arch.frontend_tokens
    if shape.kind == "train":
        out["tokens"] = sds((B, text), i32)
        out["labels"] = sds((B, text), i32)
    elif shape.kind == "prefill":
        out["tokens"] = sds((B, text), i32)
    else:  # decode
        out["tokens"] = sds((B, 1), i32)
        out["positions"] = sds((B, 1), i32)
    return out


def input_dims(arch: ArchConfig, shape: ShapeConfig) -> Dict[str, tuple]:
    """Logical sharding roles for each batch input."""
    d: Dict[str, tuple] = {}
    for k, v in input_specs(arch, shape).items():
        if k in ("tokens", "labels", "positions"):
            d[k] = ("batch", "seq")[: len(v.shape)] if len(v.shape) == 2 else ("batch",)
            d[k] = ("batch", "seq") if shape.kind != "decode" else ("batch", None)
        elif k in ("frames", "patches", "enc_out"):
            d[k] = ("batch", "seq", None)
    return d


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_train_step(arch: ArchConfig, cfg: OPT.AdamWConfig,
                     ctx: Optional[ShardingCtx] = None,
                     lr_schedule: Optional[Callable] = None,
                     accum_steps: int = 1) -> Callable:
    """Train step; with ``accum_steps > 1`` the batch is split into equal
    microbatches along the batch dim and gradients are averaged before the
    single optimizer update (distributed-optimization trick: holds the
    global batch while shrinking per-step activation memory by the factor)."""
    schedule = lr_schedule or (lambda step: jnp.asarray(cfg.lr, jnp.float32))

    def loss(params, batch):
        if arch.family == "encdec":
            return ED.loss_fn(arch, params, batch["frames"], batch["tokens"],
                              batch["labels"], ctx)
        return LM.loss_fn(arch, params, batch["tokens"], batch["labels"], ctx,
                          prefix_embeds=batch.get("patches"))

    def grads_of(params, batch):
        if accum_steps <= 1:
            return jax.value_and_grad(loss)(params, batch)

        def split(x):
            b = x.shape[0]
            assert b % accum_steps == 0, (b, accum_steps)
            return x.reshape(accum_steps, b // accum_steps, *x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            lsum, gsum = carry
            lval, g = jax.value_and_grad(loss)(params, mb)
            return (lsum + lval,
                    jax.tree.map(jnp.add, gsum, g)), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (lsum, gsum), _ = jax.lax.scan(body, (jnp.float32(0.0), zeros), micro)
        inv = 1.0 / accum_steps
        return lsum * inv, jax.tree.map(lambda g: g * inv, gsum)

    def train_step(params, opt_state, batch):
        lval, grads = grads_of(params, batch)
        lr = schedule(opt_state["step"])
        params, opt_state, info = OPT.adamw_update(params, grads, opt_state, cfg, lr)
        metrics = {"loss": lval, "lr": lr, **info}
        return params, opt_state, metrics

    return train_step


def build_prefill_step(arch: ArchConfig, shape: ShapeConfig,
                       ctx: Optional[ShardingCtx] = None,
                       cache_dtype=jnp.bfloat16) -> Callable:
    B, S = shape.global_batch, shape.seq_len

    def prefill_step(params, batch):
        if arch.family == "encdec":
            enc_out = ED.encode(arch, params, batch["frames"], ctx)
            caches = ED.make_caches(arch, B, S, cache_dtype)
            hidden, caches = ED.decode(arch, params, batch["tokens"], enc_out,
                                       ctx, caches=caches)
            logits = hidden[:, -1:] @ params["unembed"]
            return caches, logits, enc_out
        caches = LM.make_caches(arch, B, S, cache_dtype)
        hidden, caches = LM.forward(arch, params, batch["tokens"], ctx,
                                    caches=caches,
                                    prefix_embeds=batch.get("patches"))
        logits = LM.logits_fn(arch, params, hidden[:, -1:], ctx)
        return caches, logits

    return prefill_step


def build_serve_step(arch: ArchConfig, ctx: Optional[ShardingCtx] = None, *,
                     sampling=None, eos_id: Optional[int] = None,
                     paged: bool = False, spec=None) -> Callable:
    """Decode-step builder.

    Without ``sampling`` (legacy form) the step is the stateless
    ``(params, caches, batch) -> (next_token, caches)`` greedy kernel the
    dry-run and differential suites lower.

    With ``sampling`` (a :class:`repro.serving.sampler.SamplingParams`)
    the step is the serving runtime's fused kernel — decode state threads
    through on device, token selection (greedy/temperature/top-k) and all
    per-slot lifecycle arithmetic (EOS detection, emission budgets,
    position advance) happen inside the jit, and only a small per-step
    ``record`` ({token, emit, finished}, one entry per slot) ever needs
    host readback:

        serve_step(params, caches, state) -> (state', caches', record)

    EOS semantics match the engine contract: EOS is a stop signal, not an
    output token — it is never emitted, never counts toward ``max_new``,
    and an EOS arriving straight out of prefill finishes the slot without
    emitting anything.

    With ``paged=True`` (sampling form only, all-attention families)
    ``caches`` is the page-pool tree (``serving.pages``) and the state's
    ``page_table``/``seq_len`` leaves drive the per-slot KV mapping:
    inactive slots' table rows are nulled *inside* the step, so the
    host's lagging retire bookkeeping (lookahead dispatch) can never
    route a stale write into a freed — possibly re-allocated — page.

    With ``spec`` (a :class:`repro.serving.config.SpecConfig`) the step
    is the **speculative** kernel: the draft model proposes ``k`` tokens
    per slot (k+1 sequential single-token forwards against the state's
    ``draft_caches``; the extra forward is the catch-up that closes the
    draft-cache gap at full acceptance), the target verifies all ``k+1``
    positions in one batched ``append=True`` forward, and the
    longest-accepted-prefix commit — emission budgets, EOS, rollback of
    positions/seq_len — happens per slot inside the same jit. Params are
    then the pair ``{"target": ..., "draft": ...}``, the record's
    ``token``/``emit`` are ``[slots, k+1]`` (commit order within the
    step), and acceptance bookkeeping lands in ``state.accepted`` /
    ``state.proposed``. Greedy target sampling commits exactly the
    tokens the single-step path would (accept requires the draft
    proposal to equal the previous target sample; the first divergence
    breaks the chain), so greedy streams are bit-exact vs target-only.
    Dense-attention, non-windowed LMs only (both models).
    """
    if paged and sampling is None:
        raise ValueError("paged serve steps require the sampling "
                         "(state-threaded) form")
    if paged:
        from repro.serving.pages import check_paged_supported
        check_paged_supported(arch)
    if spec is not None:
        if sampling is None:
            raise ValueError("speculative serve steps require the sampling "
                             "(state-threaded) form")
        draft = spec.draft
        if draft is None:
            raise ValueError("SpecConfig.draft unresolved — pair the plan "
                             "with a draft arch (repro.plan(..., draft=...)) "
                             "or set SpecConfig(draft=...)")
        for label, a in (("target", arch), ("draft", draft)):
            if a.family != "dense":
                raise NotImplementedError(
                    f"speculative decoding requires dense-attention "
                    f"non-windowed LMs; {label} {a.name!r} has family "
                    f"{a.family!r}")
        if draft.vocab_size != arch.vocab_size:
            raise ValueError(
                f"draft vocab {draft.vocab_size} != target vocab "
                f"{arch.vocab_size}: proposals must be target tokens")
        return _build_spec_serve_step(arch, draft, int(spec.k), ctx,
                                      sampling=sampling, eos_id=eos_id,
                                      paged=paged)
    if sampling is None:
        def serve_step(params, caches, batch):
            if arch.family == "encdec":
                hidden, caches = ED.decode(arch, params, batch["tokens"],
                                           batch["enc_out"], ctx, caches=caches,
                                           positions=batch["positions"],
                                           enc_lens=batch.get("enc_len"))
                logits = hidden @ params["unembed"]
            else:
                hidden, caches = LM.forward(arch, params, batch["tokens"], ctx,
                                            caches=caches,
                                            positions=batch["positions"])
                logits = LM.logits_fn(arch, params, hidden, ctx)
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return next_tok, caches

        return serve_step

    from repro.serving import sampler as SMP
    from repro.serving.state import DecodeState
    eos = jnp.int32(-1 if eos_id is None else eos_id)

    def serve_step(params, caches, state):
        if arch.family == "encdec":
            # cross-attending decode: every slot attends its own cached
            # enc_out row, padded source positions masked by enc_len
            hidden, caches = ED.decode(arch, params, state.tokens,
                                       state.enc_out, ctx, caches=caches,
                                       positions=state.positions,
                                       enc_lens=state.enc_len)
            logits = hidden @ params["unembed"]
        elif paged:
            # stale-write gate: inactive slots write the null page
            table = jnp.where(state.active[:, None], state.page_table, 0)
            hidden, caches = LM.forward(arch, params, state.tokens, ctx,
                                        caches=caches,
                                        positions=state.positions,
                                        page_table=table)
            logits = LM.logits_fn(arch, params, hidden, ctx)
        else:
            hidden, caches = LM.forward(arch, params, state.tokens, ctx,
                                        caches=caches,
                                        positions=state.positions)
            logits = LM.logits_fn(arch, params, hidden, ctx)
        rng, nxt = SMP.sample(logits[:, -1], state.rng, sampling)
        cur = state.tokens[:, 0]
        active = state.active
        eos_at_prefill = active & (cur == eos)
        emit = active & ~eos_at_prefill
        emitted = state.emitted + emit.astype(jnp.int32)
        stop = emit & ((emitted >= state.max_new) | (nxt == eos))
        new_active = emit & ~stop
        state = DecodeState(
            # inert slots hold token/position so the grid stays fixed-shape
            tokens=jnp.where(new_active, nxt, cur)[:, None],
            positions=state.positions + new_active.astype(jnp.int32)[:, None],
            active=new_active, emitted=emitted, max_new=state.max_new,
            rng=rng, enc_out=state.enc_out, enc_len=state.enc_len,
            page_table=state.page_table,
            seq_len=(None if state.seq_len is None
                     else state.seq_len + active.astype(jnp.int32)))
        record = {"token": jnp.where(emit, cur, -1), "emit": emit,
                  "finished": active & ~new_active}
        return state, caches, record

    return serve_step


def _build_spec_serve_step(arch: ArchConfig, draft: ArchConfig, k: int,
                           ctx: Optional[ShardingCtx] = None, *,
                           sampling, eos_id: Optional[int] = None,
                           paged: bool = False) -> Callable:
    """The fused draft-k + batched-verify + commit step (see
    :func:`build_serve_step`).

    Commit semantics replicate the single-token lifecycle exactly, one
    sub-step ``j`` per verify position: sub-step ``j`` consumes input
    ``in_j`` (the current token at j=0, draft proposal ``d_j`` after)
    and samples ``t_j`` from the target's logits at that position. A
    sub-step runs (``can_j``) while the slot is still live *and* the
    draft's proposal matched the previous target sample — the first
    mismatch breaks the chain for the rest of the step (the slot
    continues next step from the corrected token), while a budget/EOS
    stop kills the slot permanently. Per-slot PRNG keys advance once
    per executed sub-step, exactly the once-per-active-step cadence of
    the non-speculative path, so seeded sampled streams are invariant
    to speculation depth."""
    from repro.serving import sampler as SMP
    from repro.serving.state import DecodeState
    eos = jnp.int32(-1 if eos_id is None else eos_id)

    def serve_step(params, caches, state):
        tpar, dpar = params["target"], params["draft"]
        active = state.active
        cur = state.tokens[:, 0]
        pos0 = state.positions[:, 0]

        # --- draft: k greedy proposals + the catch-up forward ----------
        dcaches = state.draft_caches
        tok = cur
        drafts = []
        for j in range(k + 1):
            dh, dcaches = LM.forward(draft, dpar, tok[:, None], ctx,
                                     caches=dcaches,
                                     positions=(pos0 + j)[:, None],
                                     append=True)
            if j < k:
                dl = LM.logits_fn(draft, dpar, dh, ctx)
                tok = jnp.argmax(dl[:, -1], axis=-1).astype(jnp.int32)
                drafts.append(tok)
            # j == k: catch-up — consuming d_k at pos0+k completes the
            # draft cache through the full-acceptance frontier; its
            # logits are discarded.
        drafts = jnp.stack(drafts, axis=1)  # [B, k]

        # --- target: one batched verify over [cur, d_1..d_k] -----------
        vtoks = jnp.concatenate([cur[:, None], drafts], axis=1)
        vpos = pos0[:, None] + jnp.arange(k + 1, dtype=jnp.int32)[None]
        if paged:
            # stale-write gate: inactive slots write the null page
            table = jnp.where(active[:, None], state.page_table, 0)
            hidden, caches = LM.forward(arch, tpar, vtoks, ctx, caches=caches,
                                        positions=vpos, page_table=table,
                                        append=True)
        else:
            hidden, caches = LM.forward(arch, tpar, vtoks, ctx, caches=caches,
                                        positions=vpos, append=True)
        logits = LM.logits_fn(arch, tpar, hidden, ctx)  # [B, k+1, V]

        # --- longest-accepted-prefix commit, per slot ------------------
        keys = state.rng
        emitted = state.emitted
        alive = active      # survives the step (False after a stop-break)
        live = active       # still chaining within this step
        tokf = cur          # next step's input token
        pos_adv = jnp.zeros_like(pos0)
        seq_adv = jnp.zeros_like(pos0)
        rec_tok, rec_emit = [], []
        t_prev = cur  # unused at j=0
        for j in range(k + 1):
            in_j = cur if j == 0 else drafts[:, j - 1]
            can = active if j == 0 else live & (in_j == t_prev)
            new_keys, t_j = SMP.sample(logits[:, j], keys, sampling)
            keys = jnp.where(can[:, None], new_keys, keys)
            eos_at_input = can & (in_j == eos)
            emit = can & ~eos_at_input
            emitted = emitted + emit.astype(jnp.int32)
            stop = emit & ((emitted >= state.max_new) | (t_j == eos))
            new_live = emit & ~stop
            tokf = jnp.where(new_live, t_j, jnp.where(can, in_j, tokf))
            alive = jnp.where(can, new_live, alive)
            pos_adv = pos_adv + new_live.astype(jnp.int32)
            seq_adv = seq_adv + can.astype(jnp.int32)
            rec_tok.append(jnp.where(emit, in_j, -1))
            rec_emit.append(emit)
            live, t_prev = new_live, t_j

        a32 = active.astype(jnp.int32)
        state = DecodeState(
            tokens=tokf[:, None],
            positions=state.positions + pos_adv[:, None],
            active=alive, emitted=emitted, max_new=state.max_new,
            rng=keys, enc_out=state.enc_out, enc_len=state.enc_len,
            page_table=state.page_table,
            seq_len=(None if state.seq_len is None
                     else state.seq_len + seq_adv),
            draft_caches=dcaches,
            accepted=state.accepted + (seq_adv - a32),
            proposed=state.proposed + k * a32)
        emit2d = jnp.stack(rec_emit, axis=1)             # [B, k+1]
        record = {"token": jnp.stack(rec_tok, axis=1),   # [B, k+1]
                  "emit": emit2d,
                  "finished": active & ~alive,
                  "committed": emit2d.sum(axis=1).astype(jnp.int32)}
        return state, caches, record

    return serve_step


def build_step(arch: ArchConfig, shape: ShapeConfig,
               ctx: Optional[ShardingCtx] = None,
               opt_cfg: Optional[OPT.AdamWConfig] = None) -> Callable:
    if shape.kind == "train":
        return build_train_step(arch, opt_cfg or OPT.AdamWConfig(), ctx)
    if shape.kind == "prefill":
        return build_prefill_step(arch, shape, ctx)
    return build_serve_step(arch, ctx)
