"""Multi-device conformance harness.

The paper's partition-space exploration is only trustworthy if every
candidate partition is *functionally equivalent* to the single-device
design (§5E deploys exactly the partition the model picked). This package
makes that guarantee testable:

* :mod:`repro.testing.mesh_fixtures` — context-managed fake-device meshes
  (``--xla_force_host_platform_device_count``), a subprocess runner for
  cases that need a fresh XLA client, and a registry of parametrized mesh
  shapes (dp-only, tp-only, mixed, 3-axis).
* :mod:`repro.testing.differential` — the plan-invariance property
  ``∀ plan: f_plan(x) ≈ f_golden(x)``: run a single-device golden
  forward / decode / train-step, re-run it under every mesh plan the
  planner proposes, and compare per-leaf within max-abs/ulp tolerances.
* :mod:`repro.testing.invariants` — structural checks reusable by any
  test: capacity report consistent with mesh memory, NamedShardings cover
  every param leaf, XFER byte accounting matches HLO collective bytes.

Importing this package never initialises a JAX backend; the fixtures are
safe to use from launcher entry points that must set ``XLA_FLAGS`` before
the first backend touch.
"""
from repro.testing.mesh_fixtures import (  # noqa: F401
    MESH_SHAPES,
    backend_initialized,
    build_mesh,
    fake_devices,
    force_host_device_count,
    mesh_shape,
    mesh_shape_names,
    run_in_subprocess,
)
