"""Differential conformance: ``∀ plan: f_plan(x) ≈ f_golden(x)``.

The paper's claim is that a partitioned multi-accelerator execution is
*numerically the same computation* as the single-device design, just
faster (§5E deploys exactly the partition the model picked). This module
states that as a testable property: for one (arch × shape) cell, run the
golden computation with no mesh and no sharding constraints, then re-run
the identical computation — same params, same inputs, same seed — under
**every** candidate plan the planner proposes for a mesh, and require the
outputs to agree per-leaf within a max-abs / ulp tolerance (sharded
execution may legitimately reorder floating-point reductions; it must not
change what is computed).

Three step kinds are covered, matching the registry's builders:

* ``forward``   — full-sequence prefill: logits + populated caches;
* ``decode``    — one serve step from fresh caches: next token (exact)
                  + cache state;
* ``train_step``— one fwd+bwd+AdamW update: metrics + updated params.

``decode_paged`` is an opt-in fourth kind (not in the default ``KINDS``
— only the families ``repro.serving.pages`` supports): the same serve
step driven through the paged KV pools and a fully-mapped page table,
asserting the paged layout is plan-invariant too.

Run standalone in a fresh (fake-device) process::

    python -m repro.testing.differential --arch qwen1.5-0.5b \
        --meshes dp8,dp4_tp2,tp8 --kinds forward,decode,train_step

which prints one line per (mesh × plan × kind) and ``DIFFERENTIAL_OK``
when every comparison holds — the marker ``tests/test_conformance.py``
waits for through :func:`repro.testing.mesh_fixtures.run_in_subprocess`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.execution_plan import ExecutionPlan
from repro.core.planner import candidate_plans, evaluate_plan
from repro.testing.mesh_fixtures import MeshAxes, mesh_shape

KINDS = ("forward", "decode", "train_step")
#: opt-in extra kind — paged-KV serve step (pages.PAGED_FAMILIES only)
PAGED_KIND = "decode_paged"
#: page size for the decode_paged cell (divides the conformance seq_len)
PAGED_CELL_PAGE_SIZE = 8


@dataclasses.dataclass(frozen=True)
class Tolerance:
    """Per-element acceptance: ``|got - want| <= max_abs`` OR within
    ``max_ulp`` floating-point spacings of the golden value. Integer and
    boolean leaves must match exactly."""

    max_abs: float = 2e-4
    max_ulp: float = 1024.0


# Documented defaults (see API.md "Testing & conformance"): float32 CPU,
# reduced archs. Sharded reductions reorder sums; train_step additionally
# feeds the reordering through an optimizer update, hence the looser bound.
TOLERANCES: Dict[str, Tolerance] = {
    "forward": Tolerance(max_abs=2e-4),
    "decode": Tolerance(max_abs=2e-4),
    "decode_paged": Tolerance(max_abs=2e-4),
    "train_step": Tolerance(max_abs=5e-4),
}


class ConformanceError(AssertionError):
    """A plan's output diverged from the golden run past tolerance."""


@dataclasses.dataclass
class LeafDiff:
    path: str
    max_abs_err: float
    max_ulp_err: float
    ok: bool


@dataclasses.dataclass
class CaseResult:
    """One (mesh × plan × kind) comparison."""

    mesh_name: str
    plan: str
    kind: str
    max_abs_err: float
    worst_leaf: str
    ok: bool

    def describe(self) -> str:
        status = "ok" if self.ok else "FAIL"
        return (f"[differential] {status} mesh={self.mesh_name} kind={self.kind} "
                f"plan=[{self.plan}] max_abs_err={self.max_abs_err:.3e} "
                f"({self.worst_leaf})")


def _leaf_path(path) -> str:
    import jax
    return jax.tree_util.keystr(path)


def compare_trees(got, want, tol: Tolerance) -> List[LeafDiff]:
    """Per-leaf comparison of two pytrees with identical structure."""
    import jax
    g_leaves, g_def = jax.tree_util.tree_flatten_with_path(got)
    w_leaves, w_def = jax.tree_util.tree_flatten_with_path(want)
    if g_def != w_def:
        raise ConformanceError(f"tree structure diverged: {g_def} vs {w_def}")
    diffs: List[LeafDiff] = []
    for (path, g), (_, w) in zip(g_leaves, w_leaves):
        g = np.asarray(g)
        w = np.asarray(w)
        if g.shape != w.shape:
            raise ConformanceError(
                f"{_leaf_path(path)}: shape diverged {g.shape} vs {w.shape}")
        if not np.issubdtype(w.dtype, np.floating):
            exact = bool(np.array_equal(g, w))
            diffs.append(LeafDiff(_leaf_path(path), 0.0 if exact else np.inf,
                                  0.0 if exact else np.inf, exact))
            continue
        g64 = g.astype(np.float64)
        w64 = w.astype(np.float64)
        # Non-finite values must match exactly (equal infs, NaN vs NaN):
        # |inf - inf| is NaN and np.spacing(inf) is NaN, and either would
        # otherwise slip through the tolerance arithmetic as a pass.
        with np.errstate(invalid="ignore", divide="ignore"):
            exact = (g64 == w64) | (np.isnan(g64) & np.isnan(w64))
            err = np.abs(g64 - w64)
            err = np.where(exact, 0.0, err)
            err = np.where(np.isnan(err), np.inf, err)  # non-finite mismatch
            spacing = np.spacing(np.maximum(np.abs(w64), np.abs(g64)))
            ulp = np.where((spacing > 0) & np.isfinite(spacing),
                           err / spacing, np.inf)
            ulp = np.where(exact, 0.0, ulp)
        ok_mask = (err <= tol.max_abs) | (ulp <= tol.max_ulp)
        diffs.append(LeafDiff(_leaf_path(path), float(err.max(initial=0.0)),
                              float(ulp.max(initial=0.0)), bool(ok_mask.all())))
    return diffs


# ---------------------------------------------------------------------------
# inputs + golden run
# ---------------------------------------------------------------------------

def make_batch(arch: ArchConfig, shape: ShapeConfig, seed: int = 0) -> Dict:
    """Deterministic batch realising ``REG.input_specs`` (ints uniform over
    the vocab, floats standard normal) — works for every registered family,
    modality frontends included."""
    import jax.numpy as jnp

    from repro.models import registry as REG
    rng = np.random.RandomState(seed)
    batch = {}
    for name, spec in REG.input_specs(arch, shape, jnp.float32).items():
        if np.issubdtype(np.dtype(spec.dtype), np.integer):
            if name == "positions":
                arr = np.zeros(spec.shape, np.int32)
            else:
                arr = rng.randint(1, arch.vocab_size, size=spec.shape).astype(np.int32)
        else:
            arr = rng.standard_normal(spec.shape).astype(np.float32)
        batch[name] = jnp.asarray(arr)
    return batch


def kind_shape(shape: ShapeConfig, kind: str) -> ShapeConfig:
    """The same (seq, batch) cell re-typed for one step kind — plan
    enumeration depends on the kind (train/prefill cells admit
    seq-sharded plans that decode cells never propose)."""
    shape_kind = {"forward": "prefill", "decode": "decode",
                  "decode_paged": "decode", "train_step": "train"}.get(kind)
    if shape_kind is None:
        raise ValueError(f"unknown kind {kind!r}; known: "
                         f"{KINDS + (PAGED_KIND,)}")
    return ShapeConfig(shape.name, shape.seq_len, shape.global_batch, shape_kind)


def _builders(arch: ArchConfig, shape: ShapeConfig, ctx, kind: str):
    """(step_fn, run_shape) for one kind; ctx=None is the golden path."""
    import jax.numpy as jnp

    from repro.models import registry as REG
    from repro.optim import adamw as OPT
    run_shape = kind_shape(shape, kind)
    if kind == "forward":
        return REG.build_prefill_step(arch, run_shape, ctx,
                                      cache_dtype=jnp.float32), run_shape
    if kind in ("decode", "decode_paged"):
        # the serving runtime's fused state-threaded step (greedy): plan
        # invariance must hold for the kernel serving actually runs —
        # sampling, lifecycle masks and the step record included. Since
        # the all-architecture admission PR this covers encdec too (the
        # cross-attending step over per-slot enc_out); decode_paged is
        # the same step routed through the page pools.
        from repro.serving.sampler import GREEDY
        return REG.build_serve_step(arch, ctx, sampling=GREEDY,
                                    paged=kind == "decode_paged"), run_shape
    return REG.build_train_step(arch, OPT.AdamWConfig(), ctx), run_shape


def _paged_setup(arch, slots: int, seq_len: int):
    """Paged pools + a fully-mapped page table (distinct non-null pages
    per slot) for the ``decode_paged`` cell."""
    import jax.numpy as jnp

    from repro.serving import pages as PG
    ps = PAGED_CELL_PAGE_SIZE
    m = PG.num_pages_per_slot(seq_len, ps)
    caches = PG.make_paged_caches(
        arch, PG.default_kv_pages(slots, seq_len, ps), ps, jnp.float32)
    table = jnp.arange(1, slots * m + 1, dtype=jnp.int32).reshape(slots, m)
    return caches, table


def _decode_state(batch, slots: int, table=None):
    """DecodeState realising the decode batch: every slot live, generous
    budget, deterministic per-slot keys (enc-dec: the batch's enc_out
    cached per slot at full source length). ``table`` (paged cells) is
    the ``[slots, M]`` page-table; ``seq_len`` starts at the batch's
    positions like the scheduler's admission does."""
    import dataclasses as _dc

    import jax.numpy as jnp

    from repro.serving.state import make_decode_state
    enc = batch.get("enc_out")
    st = make_decode_state(
        slots, enc_shape=None if enc is None else tuple(enc.shape[1:]),
        table_len=None if table is None else table.shape[1])
    paged = ({} if table is None else
             {"page_table": table,
              "seq_len": batch["positions"].astype(jnp.int32)})
    return _dc.replace(
        st, tokens=batch["tokens"], positions=batch["positions"],
        active=jnp.ones((slots,), bool),
        max_new=jnp.full((slots,), 8, jnp.int32),
        enc_out=None if enc is None else jnp.asarray(enc, jnp.float32),
        enc_len=None if enc is None else jnp.full((slots,), enc.shape[1],
                                                  jnp.int32), **paged)


def golden_run(arch: ArchConfig, shape: ShapeConfig, kind: str,
               params, seed: int = 0):
    """Single-device reference: no mesh, no sharding constraints."""
    import jax
    import jax.numpy as jnp

    from repro.models import registry as REG
    from repro.optim import adamw as OPT
    fn, run_shape = _builders(arch, shape, None, kind)
    batch = make_batch(arch, run_shape, seed)
    if kind in ("decode", "decode_paged"):
        if kind == "decode_paged":
            caches, table = _paged_setup(arch, run_shape.global_batch,
                                         run_shape.seq_len)
        else:
            caches = REG.make_caches(arch, run_shape.global_batch,
                                     run_shape.seq_len, jnp.float32)
            table = None
        state = _decode_state(batch, run_shape.global_batch, table)
        return jax.jit(fn)(params, caches, state)
    if kind == "train_step":
        opt_state = OPT.adamw_init(params, OPT.AdamWConfig())
        return jax.jit(fn)(params, opt_state, batch)
    return jax.jit(fn)(params, batch)


def plan_run(eplan: ExecutionPlan, kind: str, params, seed: int = 0):
    """The identical computation under one plan's mesh + NamedShardings."""
    import jax
    import jax.numpy as jnp

    from repro.models import registry as REG
    from repro.optim import adamw as OPT
    mesh = eplan.build_mesh()
    ctx = eplan.ctx(mesh)
    fn, run_shape = _builders(eplan.arch, eplan.shape, ctx, kind)
    batch = make_batch(eplan.arch, run_shape, seed)
    run_plan = (eplan if eplan.shape.kind == run_shape.kind
                else dataclasses.replace(eplan, shape=run_shape))
    params_sh = jax.device_put(params, eplan.param_shardings(params, mesh))
    batch_sh = jax.device_put(batch, run_plan.batch_shardings(batch, mesh))
    with mesh:
        if kind in ("decode", "decode_paged"):
            if kind == "decode_paged":
                # pools have no slot axis — no plan cache shardings; the
                # compiler places them (the engine does the same).
                caches, table = _paged_setup(eplan.arch,
                                             run_shape.global_batch,
                                             run_shape.seq_len)
            else:
                caches = REG.make_caches(eplan.arch, run_shape.global_batch,
                                         run_shape.seq_len, jnp.float32)
                caches = jax.device_put(
                    caches, eplan.cache_shardings(caches, mesh))
                table = None
            from repro.core.xfer import tree_shardings
            from repro.serving.state import decode_state_dims
            state = _decode_state(batch, run_shape.global_batch, table)
            state = jax.device_put(
                state, tree_shardings(
                    ctx, state,
                    decode_state_dims(enc=state.enc_out is not None,
                                      paged=table is not None)))
            return jax.jit(fn)(params_sh, caches, state)
        if kind == "train_step":
            opt_state = OPT.adamw_init(params, OPT.AdamWConfig())
            opt_state = jax.device_put(opt_state,
                                       eplan.opt_shardings(opt_state, mesh))
            return jax.jit(fn)(params_sh, opt_state, batch_sh)
        return jax.jit(fn)(params_sh, batch_sh)


# ---------------------------------------------------------------------------
# plan enumeration + the invariance property
# ---------------------------------------------------------------------------

def proposed_plans(arch: ArchConfig, shape: ShapeConfig, mesh_axes: MeshAxes,
                   limit: Optional[int] = None) -> List[ExecutionPlan]:
    """Every candidate plan the planner proposes for this cell, each
    wrapped as a deployable ExecutionPlan (not just the Eq. 15 winner —
    plan invariance must hold for the whole search space)."""
    plans = []
    for sp in candidate_plans(arch, shape, mesh_axes):
        rep = evaluate_plan(arch, shape, sp)
        plans.append(ExecutionPlan(arch=arch, shape=shape, report=rep,
                                   mesh_axes=tuple(mesh_axes)))
    plans.sort(key=lambda p: p.sharding_plan.describe())
    return plans[:limit] if limit else plans


def check_plan_invariance(
        arch: ArchConfig, shape: ShapeConfig,
        meshes: Sequence[str] = ("dp8", "dp4_tp2", "tp8"),
        kinds: Iterable[str] = KINDS, *, seed: int = 0,
        tolerances: Optional[Dict[str, Tolerance]] = None,
        plan_limit: Optional[int] = None,
        verbose: bool = True) -> List[CaseResult]:
    """Assert ``f_plan(x) ≈ f_golden(x)`` for every proposed plan.

    Computes one golden result per kind, then replays it under every
    candidate plan of every named mesh. Returns the per-case records;
    raises :class:`ConformanceError` listing every failing case.
    """
    import jax

    from repro.models import registry as REG
    tolerances = tolerances or TOLERANCES
    params = REG.init_params(arch, jax.random.PRNGKey(seed), jnp_dtype_f32())
    results: List[CaseResult] = []
    for kind in kinds:
        tol = tolerances.get(kind, Tolerance())
        golden = jax.tree.map(np.asarray,
                              golden_run(arch, shape, kind, params, seed))
        cell = kind_shape(shape, kind)
        for mesh_name in meshes:
            axes = mesh_shape(mesh_name)
            for eplan in proposed_plans(arch, cell, axes, plan_limit):
                got = plan_run(eplan, kind, params, seed)
                diffs = compare_trees(jax.tree.map(np.asarray, got), golden, tol)
                worst = max(diffs, key=lambda d: d.max_abs_err,
                            default=LeafDiff("", 0.0, 0.0, True))
                case = CaseResult(mesh_name, eplan.sharding_plan.describe(),
                                  kind, worst.max_abs_err, worst.path,
                                  all(d.ok for d in diffs))
                results.append(case)
                if verbose:
                    print(case.describe(), flush=True)
    bad = [c for c in results if not c.ok]
    if bad:
        raise ConformanceError(
            f"{len(bad)}/{len(results)} plan runs diverged from golden:\n"
            + "\n".join(c.describe() for c in bad))
    return results


def jnp_dtype_f32():
    import jax.numpy as jnp
    return jnp.float32


# ---------------------------------------------------------------------------
# CLI — run inside a fresh fake-device process
# ---------------------------------------------------------------------------

OK_MARKER = "DIFFERENTIAL_OK"


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    from repro.configs import get_arch
    ap = argparse.ArgumentParser(
        description="Plan-invariance differential suite (run with a forced "
                    "fake-device count; see repro.testing.mesh_fixtures)")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--meshes", default="dp8,dp4_tp2,tp8",
                    help="comma-separated mesh-shape names")
    ap.add_argument("--kinds", default=",".join(KINDS))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-limit", type=int, default=None)
    args = ap.parse_args(argv)
    arch = get_arch(args.arch).reduced()
    shape = ShapeConfig("conformance", args.seq, args.batch, "decode")
    results = check_plan_invariance(
        arch, shape, meshes=args.meshes.split(","),
        kinds=tuple(args.kinds.split(",")), seed=args.seed,
        plan_limit=args.plan_limit)
    print(f"{OK_MARKER} arch={args.arch} cases={len(results)}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
