"""Structural invariants any test can assert about an ExecutionPlan.

Where :mod:`repro.testing.differential` proves a plan computes the *same
function*, these checks prove the plan's own bookkeeping is coherent:

* :func:`check_sharding_coverage` — the derived NamedShardings cover
  every param leaf (same treedef, every leaf on the plan's mesh, every
  sharded dim divisible by its axis product, no mesh axis used twice in
  one spec);
* :func:`check_capacity_report` — the planner's HBM residency report is
  reproducible from :func:`repro.core.planner.capacity_bytes` and its
  ``fits_hbm`` verdict is consistent with the hardware spec it was made
  against (capacity report consistent with mesh memory);
* :func:`check_xfer_accounting` — the plan's analytic XFER weight-gather
  byte accounting matches the all-gather wire bytes the compiled HLO
  actually contains (within a tolerance band: activation gathers ride on
  the same collective type).

All failures raise :class:`InvariantViolation` (an AssertionError) with a
message naming the leaf / number that broke.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

from repro.core import hw
from repro.core.execution_plan import ExecutionPlan
from repro.core.planner import HBM_HEADROOM, INT8_NOTE, capacity_bytes

PyTree = Any


class InvariantViolation(AssertionError):
    pass


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise InvariantViolation(msg)


# ---------------------------------------------------------------------------
# NamedSharding coverage
# ---------------------------------------------------------------------------

def _spec_axes(entry):
    if entry is None:
        return ()
    return entry if isinstance(entry, tuple) else (entry,)


def check_sharding_coverage(eplan: ExecutionPlan,
                            params: Optional[PyTree] = None) -> int:
    """Every param leaf gets a valid NamedSharding; returns the leaf count.

    ``params`` defaults to abstract ``eval_shape`` leaves, so the check is
    cheap enough for the fast tier and works on a 1-device mesh (where
    every spec degrades to replication but the structure must still hold).
    """
    import jax

    from repro.models import registry as REG
    if params is None:
        params = jax.eval_shape(
            lambda k: REG.init_params(eplan.arch, k), jax.random.PRNGKey(0))
    mesh = eplan.build_mesh()
    shardings = eplan.param_shardings(params, mesh)
    p_leaves, p_def = jax.tree_util.tree_flatten_with_path(params)
    s_leaves = jax.tree.leaves(shardings)
    _require(len(p_leaves) == len(s_leaves),
             f"sharding tree covers {len(s_leaves)} leaves, params have "
             f"{len(p_leaves)}")
    axis_sizes = dict(eplan.mesh_axes)
    for (path, leaf), sh in zip(p_leaves, s_leaves):
        name = jax.tree_util.keystr(path)
        _require(isinstance(sh, jax.sharding.NamedSharding),
                 f"{name}: expected NamedSharding, got {type(sh).__name__}")
        _require(sh.mesh.shape == mesh.shape,
                 f"{name}: sharding mesh {dict(sh.mesh.shape)} != plan mesh "
                 f"{dict(mesh.shape)}")
        used = []
        for dim, entry in zip(leaf.shape, tuple(sh.spec)):
            axes = _spec_axes(entry)
            prod = 1
            for a in axes:
                _require(a in axis_sizes, f"{name}: unknown mesh axis {a!r}")
                _require(a not in used, f"{name}: mesh axis {a!r} used twice")
                used.append(a)
                prod *= axis_sizes[a]
            _require(dim % prod == 0,
                     f"{name}: dim {dim} not divisible by axis product {prod} "
                     f"({axes})")
        # spec never names more dims than the leaf has
        _require(len(tuple(sh.spec)) <= len(leaf.shape),
                 f"{name}: spec rank {len(tuple(sh.spec))} > leaf rank "
                 f"{len(leaf.shape)}")
    return len(p_leaves)


# ---------------------------------------------------------------------------
# capacity report vs mesh memory
# ---------------------------------------------------------------------------

def check_capacity_report(eplan: ExecutionPlan,
                          hw_spec: Optional[hw.HardwareSpec] = None) -> None:
    """The report's HBM number is reproducible and its verdict consistent.

    Recomputes :func:`capacity_bytes` for the plan (honouring the int8-Adam
    retry the planner notes) and requires (a) the reported bytes match,
    (b) ``fits_hbm`` agrees with the 92%-of-HBM headroom rule the planner
    applies, (c) a plan reported as fitting actually fits the spec's HBM.
    """
    spec = hw_spec or hw.V5E
    rep = eplan.report
    opt_bpp = 2.0 if INT8_NOTE in rep.note else 8.0
    cap = capacity_bytes(eplan.arch, eplan.shape, rep.plan, spec,
                         opt_bytes_per_param=opt_bpp)
    _require(cap > 0, f"capacity_bytes returned {cap}")
    _require(math.isclose(cap, rep.hbm_bytes_per_device, rel_tol=1e-9),
             f"report.hbm_bytes_per_device={rep.hbm_bytes_per_device:.6g} "
             f"but capacity_bytes recomputes {cap:.6g} "
             f"(opt_bytes_per_param={opt_bpp})")
    fits = cap <= HBM_HEADROOM * spec.hbm_bytes
    _require(rep.fits_hbm == fits,
             f"report.fits_hbm={rep.fits_hbm} inconsistent with recomputed "
             f"{cap / 2**30:.2f} GiB vs {HBM_HEADROOM} x "
             f"{spec.hbm_bytes / 2**30:.0f} GiB")
    if rep.fits_hbm:
        _require(cap <= spec.hbm_bytes,
                 f"plan marked fitting but needs {cap / 2**30:.2f} GiB of "
                 f"{spec.hbm_bytes / 2**30:.0f} GiB HBM")


# ---------------------------------------------------------------------------
# XFER byte accounting vs compiled HLO
# ---------------------------------------------------------------------------

def expected_xfer_gather_bytes(eplan: ExecutionPlan,
                               params: Optional[PyTree] = None) -> float:
    """Per-device wire bytes one forward's XFER weight gathers must move.

    Derived from the *actual* placement, not the analytic layer model: for
    every stacked layer-stack leaf (the ``scan_layers`` prefetch datapath
    — paper Fig. 8), the ring all-gather that undoes the ``xfer`` sharding
    delivers (gathered-shard bytes − stored-shard bytes) to each device.
    Edge tensors (embed/unembed) are excluded: GSPMD may legally serve a
    token lookup from the distributed table without materialising it.
    Zero for non-XFER plans.
    """
    import jax

    from repro.core.xfer import tree_shardings
    from repro.models import registry as REG
    if not eplan.sharding_plan.xfer:
        return 0.0
    if params is None:
        params = jax.eval_shape(
            lambda k: REG.init_params(eplan.arch, k), jax.random.PRNGKey(0))
    mesh = eplan.build_mesh()
    ctx = eplan.ctx(mesh)
    dims = REG.param_dims(eplan.arch)
    stored = tree_shardings(ctx, params, dims)

    def drop_xfer(d):
        return tuple(None if r == "xfer" else r for r in d)

    gathered = tree_shardings(ctx, params, jax.tree.map(
        drop_xfer, dims, is_leaf=lambda x: isinstance(x, tuple)))

    def shard_bytes(leaf, sh):
        shape = sh.shard_shape(tuple(leaf.shape))
        n = 1
        for d in shape:
            n *= d
        return n * leaf.dtype.itemsize

    p_leaves, _ = jax.tree_util.tree_flatten_with_path(params)
    total = 0.0
    for (path, leaf), s_sh, g_sh in zip(p_leaves,
                                        jax.tree.leaves(stored),
                                        jax.tree.leaves(gathered)):
        if "body" not in jax.tree_util.keystr(path):
            continue
        total += max(shard_bytes(leaf, g_sh) - shard_bytes(leaf, s_sh), 0)
    return total


def measured_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-type wire bytes the compiled module moves (trip-count aware)."""
    from repro.launch.hlo_analysis import analyze
    cost = analyze(hlo_text)
    return {k: v["wire_bytes"] for k, v in cost.coll.items()}


def check_xfer_accounting(eplan: ExecutionPlan, hlo_text: str, *,
                          lower_tol: float = 0.25,
                          upper_factor: float = 4.0) -> Dict[str, float]:
    """The compiled module's all-gather traffic matches the plan's books.

    For an XFER plan the module must contain at least the predicted weight
    -gather bytes (within ``lower_tol`` slack — XLA may keep a leaf it
    proves cheaper to recompute) and at most ``upper_factor`` times them
    (activation gathers share the collective type; a double-gather bug
    blows well past this band). For a non-XFER plan the expectation is 0
    and the band does not apply. Returns the numbers for reporting.
    """
    expected = expected_xfer_gather_bytes(eplan)
    measured = measured_collective_bytes(hlo_text).get("all-gather", 0.0)
    out = {"expected_xfer_bytes": expected, "measured_all_gather_bytes": measured}
    if expected <= 0:
        return out
    _require(measured >= expected * (1.0 - lower_tol),
             f"XFER plan predicts {expected:.3e} all-gather wire bytes/device "
             f"but compiled HLO contains only {measured:.3e}")
    _require(measured <= expected * upper_factor,
             f"compiled HLO moves {measured:.3e} all-gather bytes/device — "
             f">{upper_factor}x the {expected:.3e} the XFER accounting "
             "predicts (double-gather?)")
    return out
