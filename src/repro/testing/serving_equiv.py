"""Decode-equivalence conformance: new serving runtime ≡ reference engine.

The device-resident engine (donated DecodeState, bucketed prefill,
one-step-lookahead dispatch — PR "serving runtime" refactor) must not
change *what* is computed: for greedy decoding, every request's token
stream must be **bit-exact** against the pre-refactor engine. This module
keeps a frozen copy of that engine (:class:`ReferenceEngine` — host-side
numpy bookkeeping, pad-to-``max_len`` prefill, per-step device sync) as
the executable specification and replays identical workloads through
both.

Scenario coverage:

* ``basic``  — all requests admitted at once (fits in the slot grid);
* ``churn``  — more requests than slots, so finished slots re-admit
  mid-stream (skipped for MoE archs: expert-capacity contention couples
  slots, so token streams legitimately depend on admission timing, which
  lookahead shifts by design);
* ``eos``    — an ``eos_id`` chosen from a probe run so it actually
  fires, including straight out of prefill (finish with zero tokens);
* ``shared`` — paged engines only (``--paged``): requests sharing a
  prompt prefix arrive after the owner registered its pages, so their
  prefill aliases those pages (with a copy-on-write frontier page) —
  streams must still match the unshared dense reference, and the
  engine's ``prefix_hit_rate`` must be positive.

Prefill-length policy keeps the comparison exact per family: dense attn
archs run buckets smaller than ``max_len`` (attention is
padding-invariant: right-pad keys are causally masked), MoE prompts are
sized so the bucket equals ``max_len`` (expert capacity scales with the
prefill token count), and recurrent/hybrid/windowed archs rely on the
length-exact prefill (``seq_lens`` mask-carry + ring-exact windowed
fill): the reference pads to ``max_len``, the live scheduler to the
power-of-two bucket, and both recover the identical length-exact state.
Enc-dec archs carry per-request source frames; the reference encodes
them **unpadded** (the golden semantics) while the live engine encodes
a right-padded masked batch — padded frames contribute exactly zero.

Batched admission is covered implicitly: whenever several requests
admit in one engine step (the ``basic`` scenario admits a full slot
grid at step 0, ``churn`` re-admits bursts mid-stream), the live engine
groups them into one batched prefill per bucket while the reference
prefills strictly one request at a time — bit-equal streams certify the
grouped path against the golden unbatched one.

Run standalone in a fresh (fake-device) process::

    python -m repro.testing.serving_equiv --arch qwen1.5-0.5b --mesh dp4_tp2

prints one line per scenario and ``SERVING_EQUIV_OK`` when every stream
matches — the marker ``tests/test_conformance.py`` waits for. Add
``--disagg`` to run the live engine split into prefill and decode mesh
slices (cross-mesh KV streaming): streams must stay bit-exact against
the same fused reference and the analytic KV-transfer bytes must
reconcile with the compiled HLO.

``--spec`` switches to the speculative-decoding conformance mode
(:func:`check_spec_equivalence`): draft-k + batched-verify greedy
streams must be bit-identical to the target-only golden — with a
perfect draft (arch + params == target), with an independently-seeded
cold draft (the rollback path), and with a paged target — and the
perfect-draft runs must show ``accepted_tokens_mean > 1``. ``--sampled``
checks seeded stochastic invariance (:func:`check_sampled_invariance`):
temperature/top-k streams keyed per request id must be bit-identical
across lookahead 0/1/2, across plans, and across the paged and
speculative engines.

``--quant`` switches to the INT8 conformance mode
(:func:`check_quant_equivalence`): every engine runs with
``QuantConfig(weights="int8", kv="int8")`` and the property splits in
two. (1) **Exact self-consistency** — quantized greedy streams must be
bit-identical across the unplanned dense engine, the planned dense
engine, the paged engine and the disaggregated engine: per-token KV
quantization commutes with the gather/slice/pad plumbing those engines
differ by, so quantization is *not* an excuse for divergence between
them. (2) **Documented tolerance vs the FP32 golden** — INT8 changes
the arithmetic, so streams may legitimately flip tokens where the
argmax margin is below the quantization noise; the accuracy contract is
on logits: prefill logits from the round-tripped (quantize→dequantize)
weights + int8 KV must stay within ``QUANT_LOGITS_TOL`` relative error
of the FP32 logits (stream token agreement is reported informationally).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig

OK_MARKER = "SERVING_EQUIV_OK"

SCENARIOS = ("basic", "churn", "eos")
#: extra scenario for paged engines: prefix sharing via the page registry
PAGED_SCENARIOS = SCENARIOS + ("shared",)

#: documented INT8 accuracy contract (see module docstring and API.md
#: "Quantized serving"): max |logits_q - logits_fp| / max(1, max|logits_fp|)
#: over a prefill probe with round-tripped int8 weights + int8 KV cache.
QUANT_LOGITS_TOL = 5e-2


# ---------------------------------------------------------------------------
# frozen reference: the pre-refactor ServingEngine, verbatim semantics
# ---------------------------------------------------------------------------

class ReferenceEngine:
    """Pre-refactor serving engine (executable specification).

    Kept byte-for-byte in behavior: host numpy slot bookkeeping,
    pad-to-``max_len`` single-row prefill with host argmax, whole-grid
    Python-level cache splice, one blocking device sync per step, EOS as
    an uncounted stop signal with same-step re-admission.

    Two deliberate fixes versus the historical engine (both documented
    semantics the live runtime shares): the prefill cache dtype (see
    ``_prefill_slot``) and **length-exact prefill** — the reference
    passes ``seq_lens`` so recurrent/windowed state never integrates the
    ``max_len`` padding tail. Without the fix the padded length would be
    part of the computation and bucketed prefill could never match.

    Enc-dec extension: requests carry ``frames``; prefill runs the
    encoder over the *unpadded* frames (golden semantics), keeps
    ``enc_out`` in a host-side per-slot grid, and the decode step
    cross-attends it under the ``enc_len`` mask — one request at a time,
    the unbatched specification for the engine's batched admission.
    """

    def __init__(self, arch, params, *, slots: int, max_len: int,
                 ctx=None, eos_id: Optional[int] = None, dtype=None,
                 max_src_len: Optional[int] = None):
        import jax
        import jax.numpy as jnp

        from repro.core.execution_plan import ExecutionPlan
        from repro.models import registry as REG
        dtype = jnp.float32 if dtype is None else dtype
        self._dtype = dtype
        self.plan = None
        self.mesh = None
        if isinstance(arch, ExecutionPlan):
            self.plan = arch
            exe = self.plan.compile()
            arch = self.plan.arch
            ctx = exe.ctx if ctx is None else ctx
            self.mesh = exe.mesh
        self.arch = arch
        self.slots = slots
        self.max_len = max_len
        self.max_src_len = max_src_len if max_src_len is not None else max_len
        self.eos_id = eos_id
        self.caches = REG.make_caches(arch, slots, max_len, dtype)
        self.enc_out = (np.zeros((slots, self.max_src_len, arch.d_model),
                                 np.float32)
                        if arch.family == "encdec" else None)
        self.enc_len = np.zeros((slots,), np.int32)
        if self.plan is not None:
            params = jax.device_put(
                params, self.plan.param_shardings(params, self.mesh))
            self.caches = jax.device_put(
                self.caches, self.plan.cache_shardings(self.caches, self.mesh))
            with self.mesh:
                self.serve_step = jax.jit(REG.build_serve_step(arch, ctx))
        else:
            self.serve_step = jax.jit(REG.build_serve_step(arch, ctx))
        self.params = params
        self.active: Dict[int, Optional[object]] = {i: None for i in range(slots)}
        self.positions = np.zeros((slots, 1), np.int32)
        self.tokens = np.zeros((slots, 1), np.int32)
        self.queue: List[object] = []
        self.completed: List[object] = []
        self._prefill_cache_fn = None

    def submit(self, req):
        from repro.serving.scheduler import RequestValidationError
        total = len(req.prompt)
        if total + req.max_new_tokens > self.max_len:
            raise RequestValidationError(
                f"request {req.rid}: prompt {total} + max_new_tokens "
                f"{req.max_new_tokens} exceeds max_len {self.max_len} "
                f"(the slot's KV row holds prompt and decoded tokens)")
        self.queue.append(req)

    def _admit(self):
        for slot, occupant in self.active.items():
            if occupant is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            self._prefill_slot(slot, req)
            self.active[slot] = req

    def _prefill_slot(self, slot: int, req):
        import jax
        import jax.numpy as jnp

        from repro.models import registry as REG
        s = len(req.prompt)
        if self.arch.family == "encdec":
            row_cache, logits = self._prefill_encdec(slot, req)
        else:
            if self._prefill_cache_fn is None:
                from repro.models import lm as LM
                # One deliberate fix vs the historical engine: it derived
                # this dtype from the *first* flattened cache leaf, which is
                # the int32 ``count`` scalar — prefill K/V rows were
                # silently truncated to integers. The reference reflects the
                # intended semantics (the grid's floating dtype).
                dtype = self._dtype

                def prefill(params, tokens, lens):
                    caches = REG.make_caches(self.arch, 1, self.max_len, dtype)
                    # deliberate fix #2: length-exact prefill (seq_lens
                    # mask-carry) — the padded tail never enters recurrent
                    # or windowed state, so the padded length is irrelevant
                    hidden, caches = LM.forward(self.arch, params, tokens,
                                                caches=caches, seq_lens=lens)
                    h_last = jax.lax.dynamic_slice_in_dim(hidden, lens[0] - 1,
                                                          1, axis=1)
                    return caches, LM.logits_fn(self.arch, params, h_last)

                self._prefill_cache_fn = jax.jit(prefill)
            toks = np.zeros((1, self.max_len), np.int32)
            toks[0, :s] = req.prompt
            row_cache, logits = self._prefill_cache_fn(
                self.params, jnp.asarray(toks),
                jnp.asarray([s], jnp.int32))

        def fix_pos(path, leaf):
            key = getattr(path[-1], "key", None)
            if key == "pos" and leaf.ndim >= 1 and leaf.shape[-1] == self.max_len:
                rng = jnp.arange(self.max_len)
                return jnp.where(rng[None, :] < s if leaf.ndim == 2 else rng < s,
                                 leaf, -1)
            return leaf
        row_cache = jax.tree_util.tree_map_with_path(fix_pos, row_cache)
        self.caches = jax.tree.map(_legacy_splice_leaf(slot, self.slots),
                                   self.caches, row_cache)
        self.tokens[slot, 0] = int(jnp.argmax(logits[0, -1]))  # device sync
        self.positions[slot, 0] = s

    def _prefill_encdec(self, slot: int, req):
        """Golden unbatched enc-dec admission: encode the request's frames
        at their exact length (no padding, no mask — the semantics the
        live engine's padded/masked batch must reproduce bit-for-bit),
        cache ``enc_out`` host-side for the slot, prefill the decoder
        self-attention row over the padded prompt."""
        import jax
        import jax.numpy as jnp

        from repro.models import encdec as ED
        s = len(req.prompt)
        s_src = len(req.frames)
        if self._prefill_cache_fn is None:
            dtype = self._dtype

            def prefill(params, frames, tokens, lens):
                enc = ED.encode(self.arch, params, frames)
                caches = ED.make_caches(self.arch, 1, self.max_len, dtype)
                hidden, caches = ED.decode(self.arch, params, tokens, enc,
                                           caches=caches)
                h_last = jax.lax.dynamic_slice_in_dim(hidden, lens[0] - 1, 1,
                                                      axis=1)
                return caches, h_last @ params["unembed"], enc

            self._prefill_cache_fn = jax.jit(prefill)
        toks = np.zeros((1, self.max_len), np.int32)
        toks[0, :s] = req.prompt
        row_cache, logits, enc = self._prefill_cache_fn(
            self.params, jnp.asarray(req.frames[None], jnp.float32),
            jnp.asarray(toks), jnp.asarray([s], jnp.int32))
        self.enc_out[slot] = 0.0
        self.enc_out[slot, :s_src] = np.asarray(enc[0], np.float32)
        self.enc_len[slot] = s_src
        return row_cache, logits

    def step(self):
        import jax.numpy as jnp
        self._admit()
        batch = {"tokens": jnp.asarray(self.tokens),
                 "positions": jnp.asarray(self.positions)}
        if self.enc_out is not None:
            batch["enc_out"] = jnp.asarray(self.enc_out, self._dtype)
            batch["enc_len"] = jnp.asarray(self.enc_len)
        next_tok, self.caches = self.serve_step(self.params, self.caches, batch)
        next_np = np.asarray(next_tok)  # forces device sync
        freed = False
        for slot, req in self.active.items():
            if req is None:
                continue
            tok = int(self.tokens[slot, 0])
            if self.eos_id is not None and tok == self.eos_id:
                self._finish(slot, req)
                freed = True
                continue
            req.out_tokens.append(tok)
            nxt = int(next_np[slot])
            if req.done or (self.eos_id is not None and nxt == self.eos_id):
                self._finish(slot, req)
                freed = True
                continue
            self.tokens[slot, 0] = nxt
            self.positions[slot, 0] += 1
        if freed and self.queue:
            self._admit()

    def _finish(self, slot: int, req):
        self.completed.append(req)
        self.active[slot] = None

    def run_until_drained(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(r is not None for r in self.active.values())) \
                and steps < max_steps:
            self.step()
            steps += 1
        return steps


def _legacy_splice_leaf(slot: int, slots: int):
    """The old engine's shape-heuristic splice (kept for the reference;
    the live scheduler carries the batch axis explicitly instead)."""
    import jax.numpy as jnp

    def f(grid, row):
        if not hasattr(grid, "ndim") or grid.ndim == 0:
            return grid
        for ax in range(grid.ndim):
            if grid.shape[ax] == slots and ax < row.ndim and row.shape[ax] == 1:
                idx = [slice(None)] * grid.ndim
                idx[ax] = slot
                return grid.at[tuple(idx)].set(jnp.take(row, 0, axis=ax))
        return grid
    return f


# ---------------------------------------------------------------------------
# the equivalence property
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EquivCase:
    scenario: str
    mesh_name: str
    requests: int
    ok: bool
    detail: str = ""

    def describe(self) -> str:
        status = "ok" if self.ok else "FAIL"
        return (f"[serving_equiv] {status} scenario={self.scenario} "
                f"mesh={self.mesh_name} requests={self.requests}"
                + (f" — {self.detail}" if self.detail else ""))


class ServingEquivError(AssertionError):
    """A request's token stream diverged between new and reference engine."""


def _prompts(arch: ArchConfig, n: int, max_len: int, seed: int,
             max_new: int = 0):
    """Prompt lengths per family (see module docstring): dense,
    recurrent, hybrid and enc-dec exercise buckets < max_len (prefill is
    length-exact); MoE pins the bucket to max_len (expert capacity
    scales with the prefill token count). ``max_new`` caps lengths so
    prompt + budget fits the slot's KV row (both engines now reject
    over-budget submissions up front)."""
    rng = np.random.RandomState(seed)
    if arch.family == "moe":
        lo, hi = max_len // 2 + 1, max_len - 2  # pow2ceil(len) == max_len
    else:
        lo, hi = 4, max(6, max_len // 4)
    hi = min(hi, max_len - max_new)
    assert lo <= hi, f"max_new {max_new} leaves no valid prompt length"
    out = []
    for _ in range(n):
        s = int(rng.randint(lo, hi + 1))
        out.append(rng.randint(1, min(arch.vocab_size, 512), size=s)
                   .astype(np.int32))
    return out


def _frames(arch: ArchConfig, n: int, max_src_len: int, seed: int):
    """Per-request encoder frames for enc-dec archs (None otherwise);
    lengths vary so the engine's padded+masked encoder batch is
    exercised against the reference's exact-length encoder."""
    if arch.family != "encdec":
        return [None] * n
    rng = np.random.RandomState(seed + 7)
    out = []
    for _ in range(n):
        s = int(rng.randint(2, max_src_len + 1))
        out.append(rng.standard_normal((s, arch.d_model)).astype(np.float32))
    return out


def _run(engine_cls, plan_or_arch, params, prompts, *, slots, max_len,
         max_new, eos_id=None, dtype=None, frames=None, **engine_kw):
    from repro.serving.engine import Request
    eng = engine_cls(plan_or_arch, params, slots=slots, max_len=max_len,
                     eos_id=eos_id, dtype=dtype, **engine_kw)
    frames = frames or [None] * len(prompts)
    for i, p in enumerate(prompts):
        kw = {"src_frames": frames[i]} if frames[i] is not None else {}
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=max_new, **kw))
    eng.run_until_drained(max_steps=4000)
    streams = {r.rid: list(r.out_tokens) for r in eng.completed}
    if hasattr(eng, "verify_xfer"):
        # disaggregated engine: reconcile analytic KV-transfer bytes
        # against the compiled HLO output bytes (raises out-of-band)
        eng.verify_xfer()
    return streams


def check_decode_equivalence(arch: ArchConfig, mesh_name: Optional[str] = None,
                             *, slots: int = 4, max_len: int = 32,
                             max_new: int = 6, seed: int = 0,
                             scenarios: Sequence[str] = SCENARIOS,
                             paged: bool = False, page_size: int = 8,
                             disagg: int = 0,
                             verbose: bool = True) -> List[EquivCase]:
    """Replay identical greedy workloads through the new engine and the
    frozen reference; raise :class:`ServingEquivError` on any divergent
    stream. Returns per-scenario records.

    ``paged=True`` runs the *live* engine with the page-pool KV cache
    (``page_size`` must divide ``max_len`` for exact equivalence — equal
    kv extent per shard); the reference stays dense, certifying the paged
    layout against the golden unbatched semantics. Paged MoE restricts to
    the ``basic`` scenario: an idle paged slot attends null-page garbage
    (masked from its own stream, but MoE expert capacity couples batch
    rows, so scenarios with idle phases legitimately diverge — same
    reason ``churn`` skips MoE), and its emission budget is clamped so
    prompt + budget fits the non-wrapping page table.

    ``disagg=k`` (requires a mesh) runs the live engine **disaggregated**:
    ``k`` rows of the data axis become the prefill slice, the rest the
    decode slice, and finished KV streams cross-mesh into the decode
    grid. Streams must stay bit-exact against the same fused reference
    (sub-plans inherit the fused sharding structure, so per-request
    arithmetic is unchanged), and every live run additionally reconciles
    the engine's analytic KV-transfer bytes against the compiled HLO
    (``verify_xfer``). The ``shared`` scenario is excluded: prefix
    aliasing needs the decode-side page registry at prefill time, which
    the split disables."""
    import warnings

    import jax
    import jax.numpy as jnp

    from repro.models import registry as REG
    from repro.serving.engine import ServingEngine

    if disagg and mesh_name is None:
        raise ValueError("disagg requires a mesh (the device grid is "
                         "split into prefill and decode slices)")
    if arch.family == "moe":
        max_len = min(max_len, 16)  # keep the bucket == max_len prefill cheap
        if paged:
            scenarios = tuple(s for s in scenarios if s == "basic")
            max_new = min(max_new, 2)  # prompt + max_new <= max_len (no wrap)
    live_kw = {"paged": True, "page_size": page_size} if paged else {}
    plan_or_arch = arch
    mesh_label = mesh_name or "none"
    if mesh_name is not None:
        import repro
        from repro.testing.mesh_fixtures import mesh_shape
        shape = ShapeConfig("serving_equiv", max_len, slots, "decode")
        plan_or_arch = repro.plan(arch, shape, mesh_shape(mesh_name))
    params = REG.init_params(arch, jax.random.PRNGKey(seed), jnp.float32)

    if disagg:
        from repro.serving.config import (DisaggConfig, PagingConfig,
                                          ServeConfig)
        from repro.serving.disagg import DisaggServingEngine

        def live_engine(plan, params, *, slots, max_len, eos_id=None,
                        dtype=None, paged=False, page_size=8):
            cfg = ServeConfig(
                slots=slots, max_len=max_len, eos_id=eos_id,
                paging=PagingConfig(paged=paged, page_size=page_size),
                disagg=DisaggConfig(prefill_data=disagg))
            return DisaggServingEngine(plan, params, config=cfg, dtype=dtype)
    else:
        live_engine = ServingEngine

    def run_both(prompts, n_slots, eos_id=None, frames=None):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            got = _run(live_engine, plan_or_arch, params, prompts,
                       slots=n_slots, max_len=max_len, max_new=max_new,
                       eos_id=eos_id, dtype=jnp.float32, frames=frames,
                       **live_kw)
        want = _run(ReferenceEngine, plan_or_arch, params, prompts,
                    slots=n_slots, max_len=max_len, max_new=max_new,
                    eos_id=eos_id, dtype=jnp.float32, frames=frames)
        return got, want

    def diff(got, want):
        bad = []
        for rid in sorted(want):
            if got.get(rid) != want[rid]:
                bad.append(f"rid={rid}: new={got.get(rid)} ref={want[rid]}")
        if set(got) != set(want):
            bad.append(f"completed sets differ: {sorted(got)} vs {sorted(want)}")
        return bad

    results: List[EquivCase] = []

    def record(scenario, requests, bad):
        case = EquivCase(scenario, mesh_label, requests, not bad,
                         "; ".join(bad))
        results.append(case)
        if verbose:
            print(case.describe(), flush=True)

    if "basic" in scenarios:
        prompts = _prompts(arch, slots, max_len, seed, max_new)
        got, want = run_both(prompts, slots,
                             frames=_frames(arch, slots, max_len, seed))
        record("basic", len(prompts), diff(got, want))

    if "churn" in scenarios and arch.family != "moe":
        # mid-stream slot re-admission: 2.5x oversubscription on half the
        # slots. MoE skipped: capacity contention couples slots, so
        # streams depend on admission timing (shifted by lookahead).
        n_slots = max(slots // 2, 1)
        n_req = int(n_slots * 2.5) + 1
        prompts = _prompts(arch, n_req, max_len, seed + 1, max_new)
        got, want = run_both(prompts, n_slots,
                             frames=_frames(arch, n_req, max_len, seed + 1))
        record("churn", len(prompts), diff(got, want))

    if "eos" in scenarios:
        # probe greedy streams, then pick (a) the first token of request 0
        # (EOS straight out of prefill) and (b) a mid-stream token.
        n_req = min(2, slots)
        prompts = _prompts(arch, n_req, max_len, seed + 2, max_new)
        frames = _frames(arch, n_req, max_len, seed + 2)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            probe = _run(live_engine, plan_or_arch, params, prompts,
                         slots=n_req, max_len=max_len,
                         max_new=max_new, dtype=jnp.float32, frames=frames,
                         **live_kw)
        candidates = {probe[0][0]}  # EOS at prefill for request 0
        candidates.update(t for toks in probe.values() for t in toks[1:])
        for eos in sorted(candidates)[:2]:
            got, want = run_both(prompts, n_req, eos_id=int(eos),
                                 frames=frames)
            record(f"eos[{eos}]", len(prompts), diff(got, want))

    if ("shared" in scenarios and paged and not disagg
            and arch.family != "moe"):
        # Prefix reuse via the page registry: the owner is admitted (and
        # its prompt's pages registered) one engine step before the
        # sharers arrive, so their prefill gathers the owner's pages. The
        # ``page_size + 1``-token prefix ends mid-page, exercising
        # copy-on-write of the owner's frontier page. The reference
        # recomputes every prompt from scratch — matching streams certify
        # that aliased prefixes decode bit-identically.
        from repro.serving.engine import Request
        prng = np.random.RandomState(seed + 3)
        vocab = min(arch.vocab_size, 512)
        pre = prng.randint(1, vocab, size=page_size + 1).astype(np.int32)
        tails = [prng.randint(1, vocab, size=s).astype(np.int32)
                 for s in (4, 6, 3)]
        prompts = [np.concatenate([pre, t]) for t in tails]
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            eng = ServingEngine(plan_or_arch, params, slots=slots,
                                max_len=max_len, dtype=jnp.float32,
                                **live_kw)
        eng.submit(Request(rid=0, prompt=prompts[0],
                           max_new_tokens=max_new))
        eng.step()  # owner admitted + registered before the sharers
        for i, p in enumerate(prompts[1:], start=1):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=max_new))
        eng.run_until_drained(max_steps=4000)
        got = {r.rid: list(r.out_tokens) for r in eng.completed}
        want = _run(ReferenceEngine, plan_or_arch, params, prompts,
                    slots=slots, max_len=max_len, max_new=max_new,
                    dtype=jnp.float32)
        bad = diff(got, want)
        hit = eng.prefill_stats()["prefix_hit_rate"]
        if not bad and hit <= 0:
            bad = [f"shared prompts did not alias pages "
                   f"(prefix_hit_rate={hit})"]
        record("shared", len(prompts), bad)

    bad = [c for c in results if not c.ok]
    if bad:
        raise ServingEquivError(
            f"{len(bad)}/{len(results)} serving-equivalence cases diverged:\n"
            + "\n".join(c.describe() for c in bad))
    return results


# ---------------------------------------------------------------------------
# speculative decoding: lossless vs the target-only golden
# ---------------------------------------------------------------------------

def check_spec_equivalence(arch: ArchConfig, mesh_name: Optional[str] = None,
                           *, k: int = 4, slots: int = 4, max_len: int = 32,
                           max_new: int = 6, seed: int = 0,
                           page_size: int = 8,
                           verbose: bool = True) -> List[EquivCase]:
    """Speculative serving conformance (``--spec``).

    The speculative engine (draft-k proposals + one batched target verify
    + longest-accepted-prefix commit, ``SpecConfig``) claims to be
    **lossless**: greedy token streams must be bit-identical to the
    target-only golden (:class:`ReferenceEngine`) whatever the draft
    proposes. Certified here with three drafts over basic / churn / eos
    workloads:

    * ``self``  — draft arch *and params* equal the target: every
      proposal is accepted, so the run must also show
      ``accepted_tokens_mean > 1`` (the speedup precondition the bench
      gates on);
    * ``cold``  — same draft arch with independently-initialised params:
      acceptance is incidental, streams must match regardless (the
      mismatch/rollback path);
    * ``paged`` — the ``self`` draft with a paged target (draft stays
      dense): the table-gather verify path.

    Churn additionally exercises slot re-admission under speculation
    (draft-cache re-splice + acceptance-counter zeroing). Raises
    :class:`ServingEquivError` on any divergence."""
    import warnings

    import jax
    import jax.numpy as jnp

    from repro.models import registry as REG
    from repro.serving.config import PagingConfig, ServeConfig, SpecConfig
    from repro.serving.engine import Request, ServingEngine

    plan_or_arch = arch
    mesh_label = mesh_name or "none"
    if mesh_name is not None:
        import repro
        from repro.testing.mesh_fixtures import mesh_shape
        shape = ShapeConfig("serving_equiv", max_len, slots, "decode")
        plan_or_arch = repro.plan(arch, shape, mesh_shape(mesh_name),
                                  draft=arch)
    params = REG.init_params(arch, jax.random.PRNGKey(seed), jnp.float32)
    cold = REG.init_params(arch, jax.random.PRNGKey(seed + 31), jnp.float32)

    def run_spec(dparams, prompts, n_slots, *, paged=False, eos_id=None):
        cfg = ServeConfig(
            slots=n_slots, max_len=max_len, eos_id=eos_id,
            paging=PagingConfig(paged=paged, page_size=page_size),
            spec=SpecConfig(draft=arch, k=k))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            eng = ServingEngine(plan_or_arch,
                                {"target": params, "draft": dparams},
                                config=cfg, dtype=jnp.float32)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=max_new))
        eng.run_until_drained(max_steps=4000)
        return ({r.rid: list(r.out_tokens) for r in eng.completed},
                eng.step_stats())

    def diff(got, want):
        bad = [f"rid={rid}: spec={got.get(rid)} golden={want[rid]}"
               for rid in sorted(want) if got.get(rid) != want[rid]]
        if set(got) != set(want):
            bad.append(f"completed sets differ: {sorted(got)} vs "
                       f"{sorted(want)}")
        return bad

    results: List[EquivCase] = []

    def record(scenario, requests, bad, detail=""):
        case = EquivCase(scenario, mesh_label, requests, not bad,
                         "; ".join(bad) or detail)
        results.append(case)
        if verbose:
            print(case.describe(), flush=True)

    def run_case(wl, prompts, n_slots, eos_id=None):
        golden = _run(ReferenceEngine, plan_or_arch, params, prompts,
                      slots=n_slots, max_len=max_len, max_new=max_new,
                      eos_id=eos_id, dtype=jnp.float32)
        for name, dparams, paged in (("self", params, False),
                                     ("cold", cold, False),
                                     ("paged", params, True)):
            got, stats = run_spec(dparams, prompts, n_slots, paged=paged,
                                  eos_id=eos_id)
            bad = diff(got, golden)
            mean = stats["accepted_tokens_mean"]
            if not bad and name != "cold" and mean <= 1.0:
                bad = [f"accepted_tokens_mean={mean:.2f} <= 1 with a "
                       f"perfect draft — speculation is not accepting"]
            record(f"spec-{wl}/{name}", len(prompts), bad,
                   f"accepted_tokens_mean={mean:.2f}")

    prompts = _prompts(arch, slots, max_len, seed, max_new)
    run_case("basic", prompts, slots)

    n_churn = max(slots // 2, 1)
    churn = _prompts(arch, int(n_churn * 2.5) + 1, max_len, seed + 1,
                     max_new)
    run_case("churn", churn, n_churn)

    # eos: pick a token that actually fires (first emitted + a mid-stream
    # one from a greedy probe), so accept-window rollback at EOS is hit
    n_eos = min(2, slots)
    eprompts = _prompts(arch, n_eos, max_len, seed + 2, max_new)
    probe = _run(ReferenceEngine, plan_or_arch, params, eprompts,
                 slots=n_eos, max_len=max_len, max_new=max_new,
                 dtype=jnp.float32)
    candidates = {probe[0][0]}
    candidates.update(t for toks in probe.values() for t in toks[1:])
    for eos in sorted(candidates)[:2]:
        run_case(f"eos[{eos}]", eprompts, n_eos, eos_id=int(eos))

    bad = [c for c in results if not c.ok]
    if bad:
        raise ServingEquivError(
            f"{len(bad)}/{len(results)} speculative-serving cases "
            f"diverged:\n" + "\n".join(c.describe() for c in bad))
    return results


# ---------------------------------------------------------------------------
# sampled-stream invariance: seeded stochastic decode is schedule-free
# ---------------------------------------------------------------------------

def check_sampled_invariance(arch: ArchConfig,
                             mesh_name: Optional[str] = None, *,
                             alt_mesh: Optional[str] = None,
                             slots: int = 4, max_len: int = 32,
                             max_new: int = 6, seed: int = 0,
                             page_size: int = 8, spec_k: int = 4,
                             verbose: bool = True) -> List[EquivCase]:
    """Seeded stochastic decoding conformance (``--sampled``).

    Per-request sampling keys are ``fold_in(PRNGKey(seed), rid)``
    (scheduler admission) and advance exactly once per executed decode
    sub-step, so a temperature / top-k stream is a pure function of
    ``(seed, rid, prompt)`` — **bit-identical** across:

    * lookahead 0 / 1 / 2 (dispatch depth shifts admission timing),
    * the planned (sharded) engine vs the unplanned one, and a second
      plan on a different mesh shape when ``alt_mesh`` names one
      (across plans),
    * the paged engine,
    * the speculative engine (the commit loop consumes keys in the same
      once-per-accepted-step order the plain step does, so speculation
      depth never perturbs a sampled stream).

    A churn workload (requests > slots) makes admission timing actually
    differ between variants. Raises :class:`ServingEquivError` on any
    divergence."""
    import warnings

    import jax
    import jax.numpy as jnp

    from repro.models import registry as REG
    from repro.serving.config import PagingConfig, ServeConfig, SpecConfig
    from repro.serving.engine import Request, ServingEngine
    from repro.serving.sampler import SamplingParams

    plan_or_arch = arch
    alt_plan = None
    mesh_label = mesh_name or "none"
    if mesh_name is not None:
        import repro
        from repro.testing.mesh_fixtures import mesh_shape
        shape = ShapeConfig("serving_equiv", max_len, slots, "decode")
        plan_or_arch = repro.plan(arch, shape, mesh_shape(mesh_name),
                                  draft=arch)
        if alt_mesh is not None:
            alt_plan = repro.plan(arch, shape, mesh_shape(alt_mesh),
                                  draft=arch)
    params = REG.init_params(arch, jax.random.PRNGKey(seed), jnp.float32)

    def run_one(sampling, prompts, n_slots, *, lookahead=1, planned=True,
                paged=False, spec=False, plan=None):
        cfg = ServeConfig(
            slots=n_slots, max_len=max_len, seed=seed, sampling=sampling,
            lookahead=lookahead,
            paging=PagingConfig(paged=paged, page_size=page_size),
            spec=SpecConfig(draft=arch, k=spec_k) if spec else None)
        p = ({"target": params, "draft": params} if spec else params)
        target = (plan if plan is not None
                  else plan_or_arch if planned else arch)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            eng = ServingEngine(target, p, config=cfg, dtype=jnp.float32)
        for i, pr in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=pr, max_new_tokens=max_new))
        eng.run_until_drained(max_steps=4000)
        return {r.rid: list(r.out_tokens) for r in eng.completed}

    def diff(got, want, label):
        bad = [f"{label} rid={rid}: {got.get(rid)} != {want[rid]}"
               for rid in sorted(want) if got.get(rid) != want[rid]]
        if set(got) != set(want):
            bad.append(f"{label} completed sets differ")
        return bad

    results: List[EquivCase] = []

    def record(scenario, requests, bad):
        case = EquivCase(scenario, mesh_label, requests, not bad,
                         "; ".join(bad))
        results.append(case)
        if verbose:
            print(case.describe(), flush=True)

    n_slots = max(slots // 2, 1)
    prompts = _prompts(arch, int(n_slots * 2.5) + 1, max_len, seed + 1,
                       max_new)
    variants = [("lookahead0", dict(lookahead=0)),
                ("lookahead2", dict(lookahead=2)),
                ("unplanned", dict(planned=False)),
                ("paged", dict(paged=True)),
                ("spec", dict(spec=True))]
    if alt_plan is not None:
        variants.append((f"plan[{alt_mesh}]", dict(plan=alt_plan)))
    for sname, sampling in (
            ("temperature", SamplingParams(method="temperature",
                                           temperature=0.7)),
            ("top_k", SamplingParams(method="top_k", top_k=5,
                                     temperature=0.9))):
        want = run_one(sampling, prompts, n_slots)  # lookahead=1, planned
        for vname, kw in variants:
            got = run_one(sampling, prompts, n_slots, **kw)
            record(f"sampled-{sname}/{vname}", len(prompts),
                   diff(got, want, vname))

    bad = [c for c in results if not c.ok]
    if bad:
        raise ServingEquivError(
            f"{len(bad)}/{len(results)} sampled-invariance cases "
            f"diverged:\n" + "\n".join(c.describe() for c in bad))
    return results


# ---------------------------------------------------------------------------
# INT8 conformance: engine/plan self-consistency + FP32 tolerance
# ---------------------------------------------------------------------------

def _quant_logits_probe(arch: ArchConfig, params, max_len: int, prompt):
    """Relative logits error of the INT8 serving arithmetic vs FP32.

    Runs the same length-exact prefill the engines run, once with the
    FP32 params + FP32 KV cache and once with round-tripped
    (quantize→dequantize) weights + an int8 KV cache (quantize-at-write,
    dequantize-at-read — exactly the engine path). Returns
    ``max |Δlogits| / max(1, max|logits_fp|)`` at the last prompt
    position."""
    import jax
    import jax.numpy as jnp

    from repro.models import lm as LM
    from repro.models import registry as REG
    from repro.quant import dequantize_params, quantize_params

    s = len(prompt)
    toks = np.zeros((1, max_len), np.int32)
    toks[0, :s] = prompt
    lens = jnp.asarray([s], jnp.int32)

    def prefill(params, caches, tokens, lens):
        hidden, _ = LM.forward(arch, params, tokens, caches=caches,
                               seq_lens=lens)
        h_last = jax.lax.dynamic_slice_in_dim(hidden, lens[0] - 1, 1, axis=1)
        return LM.logits_fn(arch, params, h_last)

    fn = jax.jit(prefill)
    lf = fn(params, REG.make_caches(arch, 1, max_len, jnp.float32),
            jnp.asarray(toks), lens)
    lq = fn(dequantize_params(quantize_params(params)),
            REG.make_caches(arch, 1, max_len, jnp.float32, kv_quant=True),
            jnp.asarray(toks), lens)
    lf = np.asarray(lf, np.float64)
    lq = np.asarray(lq, np.float64)
    return float(np.abs(lq - lf).max() / max(1.0, np.abs(lf).max()))


def check_quant_equivalence(arch: ArchConfig, mesh_name: str, *,
                            slots: int = 4, max_len: int = 32,
                            max_new: int = 6, seed: int = 0,
                            page_size: int = 8, prefill_data: int = 2,
                            verbose: bool = True) -> List[EquivCase]:
    """INT8 serving conformance (``--quant``; see module docstring).

    Every live engine runs with ``QuantConfig(weights="int8",
    kv="int8")``. The **unplanned dense quantized engine** is the
    quantized golden; the planned dense, paged and disaggregated
    quantized engines must reproduce its greedy streams **bit-exactly**
    (basic and churn workloads). Separately, the quantized arithmetic is
    held to the documented FP32 tolerance: the prefill-logits probe must
    stay within :data:`QUANT_LOGITS_TOL` relative error (enc-dec archs
    skip the probe — their serving path shares the same quantizers).
    Raises :class:`ServingEquivError` on any violation."""
    import warnings

    import jax
    import jax.numpy as jnp

    import repro
    from repro.models import registry as REG
    from repro.quant import QuantConfig
    from repro.serving.config import (DisaggConfig, PagingConfig,
                                      ServeConfig)
    from repro.serving.disagg import DisaggServingEngine
    from repro.serving.engine import ServingEngine
    from repro.testing.mesh_fixtures import mesh_shape

    if mesh_name is None:
        raise ValueError("--quant requires a mesh: the property is plan "
                         "and engine invariance of the quantized streams")
    qconf = QuantConfig(weights="int8", kv="int8")
    shape = ShapeConfig("serving_equiv", max_len, slots, "decode")
    plan = repro.plan(arch, shape, mesh_shape(mesh_name), quant=qconf)
    params = REG.init_params(arch, jax.random.PRNGKey(seed), jnp.float32)

    def factory(planned, *, paged=False, disagg=0, quant=qconf):
        def build(plan_or_arch, params, *, slots, max_len, eos_id=None,
                  dtype=None):
            cfg = ServeConfig(
                slots=slots, max_len=max_len, eos_id=eos_id,
                paging=PagingConfig(paged=paged, page_size=page_size),
                disagg=DisaggConfig(prefill_data=disagg) if disagg else None,
                quant=quant)
            cls = DisaggServingEngine if disagg else ServingEngine
            return cls(plan if planned else arch, params, config=cfg,
                       dtype=dtype)
        return build

    variants = [("dense", factory(True)),
                ("paged", factory(True, paged=True)),
                ("disagg", factory(True, disagg=prefill_data))]

    def run_quiet(build, prompts, n_slots):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            return _run(build, None, params, prompts, slots=n_slots,
                        max_len=max_len, max_new=max_new, dtype=jnp.float32,
                        frames=_frames(arch, len(prompts), max_len, seed))

    def diff(got, want):
        bad = [f"rid={rid}: got={got.get(rid)} golden={want[rid]}"
               for rid in sorted(want) if got.get(rid) != want[rid]]
        if set(got) != set(want):
            bad.append(f"completed sets differ: {sorted(got)} vs "
                       f"{sorted(want)}")
        return bad

    results: List[EquivCase] = []

    def record(scenario, requests, bad, detail=""):
        case = EquivCase(scenario, mesh_name, requests, not bad,
                         "; ".join(bad) or detail)
        results.append(case)
        if verbose:
            print(case.describe(), flush=True)

    workloads = [("basic", slots, _prompts(arch, slots, max_len, seed,
                                           max_new))]
    n_churn = max(slots // 2, 1)
    workloads.append(("churn", n_churn,
                      _prompts(arch, int(n_churn * 2.5) + 1, max_len,
                               seed + 1, max_new)))

    fp32_streams = {}
    for wl, n_slots, prompts in workloads:
        # quantized golden: the *unplanned* dense engine — every planned
        # variant below must reproduce it bit-exactly
        golden = run_quiet(factory(False), prompts, n_slots)
        fp32_streams[wl] = (golden, run_quiet(
            factory(False, quant=QuantConfig()), prompts, n_slots))
        for name, build in variants:
            got = run_quiet(build, prompts, n_slots)
            record(f"quant-{wl}/{name}", len(prompts), diff(got, golden))

    # informational: how often INT8 greedy streams agree with FP32
    # (token flips where the argmax margin is below quantization noise
    # are expected — the hard accuracy gate is the logits probe below)
    match = total = 0
    for golden, fp in fp32_streams.values():
        for rid in fp:
            a, b = golden.get(rid, []), fp[rid]
            match += sum(x == y for x, y in zip(a, b))
            total += max(len(a), len(b))
    agree = match / max(total, 1)

    if arch.family == "encdec":
        record("quant-vs-fp32", total, [],
               f"logits probe skipped (encdec), token agreement "
               f"{agree:.0%}")
    else:
        prompt = _prompts(arch, 1, max_len, seed + 5, max_new)[0]
        err = _quant_logits_probe(arch, params, max_len, prompt)
        bad = ([f"prefill logits rel err {err:.4f} exceeds documented "
                f"tolerance {QUANT_LOGITS_TOL}"]
               if err > QUANT_LOGITS_TOL else [])
        record("quant-vs-fp32", total, bad,
               f"logits rel err {err:.4f} <= {QUANT_LOGITS_TOL}, "
               f"token agreement {agree:.0%}")

    bad = [c for c in results if not c.ok]
    if bad:
        raise ServingEquivError(
            f"{len(bad)}/{len(results)} quantized-serving cases failed:\n"
            + "\n".join(c.describe() for c in bad))
    return results


# ---------------------------------------------------------------------------
# CLI — run inside a fresh fake-device process
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# elastic live replan: migrated streams vs the never-migrated reference
# ---------------------------------------------------------------------------

def check_replan_equivalence(arch: ArchConfig, mesh_name: str, alt_mesh: str,
                             *, slots: int = 4, max_len: int = 32,
                             max_new: int = 6, seed: int = 0,
                             paged: bool = False, page_size: int = 8,
                             migrate_step: int = 3, ckpt: bool = True,
                             verbose: bool = True) -> List[EquivCase]:
    """Live plan→plan migration conformance (``--replan``).

    Greedy streams served by an engine that **migrates mid-stream** from
    the ``mesh_name`` plan to the ``alt_mesh`` plan
    (``ServingEngine.migrate``) must be bit-identical to the frozen
    reference that never migrates — dense or (``paged=True``) paged, and
    across a device-count change (e.g. ``dp2_tp2 → dp4_tp2`` grows the
    deployment 4 → 8 devices mid-stream). The migration fires after
    ``migrate_step`` engine steps, while streams are in flight (and, in
    the churn cell, while requests are still queued), so live rows,
    queued requests and the page pool all cross the move.

    ``ckpt=True`` adds the checkpoint differential: params saved from
    the mesh-A deployment (``Checkpointer.save`` — logical shapes) and
    restored straight onto the mesh-B plan's shardings
    (``restore_sharded``) must serve the same bit-exact streams, proving
    the restore-onto-a-different-mesh path plan-invariant.
    """
    import tempfile
    import warnings

    import jax
    import jax.numpy as jnp

    import repro
    from repro.models import registry as REG
    from repro.serving.config import PagingConfig, ServeConfig
    from repro.serving.engine import Request
    from repro.testing.mesh_fixtures import mesh_shape

    if arch.family == "moe":
        max_len = min(max_len, 16)
        if paged:
            max_new = min(max_new, 2)
    shape = ShapeConfig("serving_equiv", max_len, slots, "decode")
    plan_a = repro.plan(arch, shape, mesh_shape(mesh_name))
    plan_b = repro.plan(arch, shape, mesh_shape(alt_mesh))
    params = REG.init_params(arch, jax.random.PRNGKey(seed), jnp.float32)
    mesh_label = f"{mesh_name}->{alt_mesh}"
    results: List[EquivCase] = []

    def record(scenario, requests, bad):
        case = EquivCase(scenario, mesh_label, requests, not bad,
                         "; ".join(bad))
        results.append(case)
        if verbose:
            print(case.describe(), flush=True)

    def diff(got, want):
        bad = []
        for rid in sorted(want):
            if got.get(rid) != want[rid]:
                bad.append(f"rid={rid}: new={got.get(rid)} ref={want[rid]}")
        if set(got) != set(want):
            bad.append(f"completed sets differ: {sorted(got)} vs "
                       f"{sorted(want)}")
        return bad

    def serve_cfg(n_slots):
        return ServeConfig(slots=n_slots, max_len=max_len,
                           paging=PagingConfig(paged=paged,
                                               page_size=page_size))

    def run_migrating(prompts, n_slots, frames=None):
        eng = plan_a.compile().serve(params=params, config=serve_cfg(n_slots))
        for i, p in enumerate(prompts):
            kw = ({"src_frames": frames[i]}
                  if frames and frames[i] is not None else {})
            eng.submit(Request(rid=i, prompt=p, max_new_tokens=max_new, **kw))
        steps, report = 0, None
        while eng.queue or eng.scheduler.has_active():
            if steps == migrate_step:
                report = eng.migrate(plan_b)
            eng.step()
            steps += 1
            if steps > 4000:
                raise ServingEquivError(
                    f"replan drain exceeded 4000 steps ({mesh_label})")
        eng._flush()
        if report is None:
            raise ServingEquivError(
                f"workload drained before migrate_step={migrate_step}; "
                f"nothing migrated ({mesh_label})")
        return {r.rid: list(r.out_tokens) for r in eng.completed}, report

    def reference(prompts, n_slots, frames=None):
        return _run(ReferenceEngine, plan_a, params, prompts, slots=n_slots,
                    max_len=max_len, max_new=max_new, dtype=jnp.float32,
                    frames=frames)

    # mid-stream: every slot live at the migration point
    prompts = _prompts(arch, slots, max_len, seed, max_new)
    frames = _frames(arch, slots, max_len, seed)
    got, report = run_migrating(prompts, slots, frames)
    bad = diff(got, reference(prompts, slots, frames))
    if not bad and report.active_slots == 0:
        bad = ["migration carried no in-flight slots — the cell proved "
               "nothing"]
    if not bad and not report.verified:
        bad = [f"transfer byte accounting unverified: {report}"]
    record("mid-stream", len(prompts), bad)

    # churn: oversubscribed slots, so the queue is non-empty when the
    # plan moves — queued requests must admit on the *new* mesh
    if arch.family != "moe":
        n_slots = max(slots // 2, 1)
        n_req = int(n_slots * 2.5) + 1
        prompts = _prompts(arch, n_req, max_len, seed + 1, max_new)
        frames = _frames(arch, n_req, max_len, seed + 1)
        got, report = run_migrating(prompts, n_slots, frames)
        record("churn", len(prompts),
               diff(got, reference(prompts, n_slots, frames)))

    # checkpoint differential: save on mesh A, restore onto mesh B's
    # shardings, serve on plan B — streams must match the plan-A golden
    if ckpt:
        from repro.checkpoint.checkpointer import Checkpointer
        placed = plan_a.compile().shard_params(params)
        like = jax.eval_shape(
            lambda: REG.init_params(arch, jax.random.PRNGKey(seed),
                                    jnp.float32))
        with tempfile.TemporaryDirectory() as td:
            ck = Checkpointer(td, async_save=False)
            ck.save(0, placed, block=True)
            restored, _, got_step = ck.restore_sharded(
                like, plan_b.param_shardings(like, plan_b.build_mesh()))
        bad = [] if got_step == 0 else [f"restored step {got_step}, want 0"]
        if restored is None:
            bad = ["restore_sharded returned no tree"]
        else:
            prompts = _prompts(arch, slots, max_len, seed + 5, max_new)
            frames = _frames(arch, slots, max_len, seed + 5)
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                got = _run(lambda plan, p, **kw: plan.compile().serve(
                    params=p, config=serve_cfg(kw["slots"])),
                    plan_b, restored, prompts, slots=slots, max_len=max_len,
                    max_new=max_new, dtype=jnp.float32, frames=frames)
            bad += diff(got, reference(prompts, slots, frames))
        record("ckpt[A->B]", len(prompts), bad)

    bad = [c for c in results if not c.ok]
    if bad:
        raise ServingEquivError(
            f"{len(bad)}/{len(results)} replan-equivalence cases "
            f"diverged:\n" + "\n".join(c.describe() for c in bad))
    return results


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    from repro.configs import get_arch
    ap = argparse.ArgumentParser(
        description="New-vs-reference serving engine decode equivalence "
                    "(run with a forced fake-device count for meshes; see "
                    "repro.testing.mesh_fixtures)")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mesh", default=None,
                    help="mesh-shape name (e.g. dp4_tp2); default unsharded")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenarios", default=None,
                    help="comma list; defaults to basic,churn,eos "
                         "(+shared when --paged)")
    ap.add_argument("--paged", action="store_true",
                    help="run the live engine with the paged KV cache")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--disagg", action="store_true",
                    help="run the live engine disaggregated (prefill/decode "
                         "mesh split; requires --mesh) and reconcile "
                         "KV-transfer bytes against compiled HLO")
    ap.add_argument("--prefill-data", type=int, default=2,
                    help="data-axis rows assigned to the prefill slice "
                         "(with --disagg)")
    ap.add_argument("--quant", action="store_true",
                    help="INT8 conformance mode: quantized streams must "
                         "be engine/plan-invariant (dense/paged/disagg) "
                         "and the logits probe within QUANT_LOGITS_TOL "
                         "of FP32 (requires --mesh)")
    ap.add_argument("--spec", action="store_true",
                    help="speculative-decoding conformance mode: draft-k "
                         "+ batched-verify greedy streams must be "
                         "bit-identical to the target-only golden (dense "
                         "and paged target), with accepted_tokens_mean > "
                         "1 under a perfect draft")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="proposal depth for --spec / --sampled")
    ap.add_argument("--sampled", action="store_true",
                    help="seeded temperature/top-k streams must be "
                         "bit-identical across lookahead 0/1/2, plans, "
                         "paged and speculative engines")
    ap.add_argument("--alt-mesh", default=None,
                    help="second mesh-shape name for the --sampled "
                         "across-plans variant and the --replan target "
                         "plan")
    ap.add_argument("--replan", action="store_true",
                    help="elastic live-migration conformance: streams that "
                         "migrate --mesh -> --alt-mesh mid-stream "
                         "(ServingEngine.migrate) must be bit-exact vs "
                         "the never-migrated reference, plus the "
                         "checkpoint save-on-A/restore-on-B differential "
                         "(requires --mesh and --alt-mesh; composes with "
                         "--paged)")
    ap.add_argument("--migrate-step", type=int, default=3,
                    help="engine step at which --replan migrates")
    args = ap.parse_args(argv)
    arch = get_arch(args.arch).reduced()
    if args.replan:
        if not args.mesh or not args.alt_mesh:
            ap.error("--replan requires --mesh and --alt-mesh")
        results = check_replan_equivalence(
            arch, args.mesh, args.alt_mesh, slots=args.slots,
            max_len=args.max_len, max_new=args.max_new, seed=args.seed,
            paged=args.paged, page_size=args.page_size,
            migrate_step=args.migrate_step)
        print(f"{OK_MARKER} arch={args.arch} "
              f"mesh={args.mesh}->{args.alt_mesh} replan=1 "
              f"paged={int(args.paged)} cases={len(results)}")
        return 0
    if args.spec:
        results = check_spec_equivalence(
            arch, args.mesh, k=args.spec_k, slots=args.slots,
            max_len=args.max_len, max_new=args.max_new, seed=args.seed,
            page_size=args.page_size)
        print(f"{OK_MARKER} arch={args.arch} mesh={args.mesh or 'none'} "
              f"spec=1 k={args.spec_k} cases={len(results)}")
        return 0
    if args.sampled:
        results = check_sampled_invariance(
            arch, args.mesh, alt_mesh=args.alt_mesh, slots=args.slots,
            max_len=args.max_len, max_new=args.max_new, seed=args.seed,
            page_size=args.page_size, spec_k=args.spec_k)
        print(f"{OK_MARKER} arch={args.arch} mesh={args.mesh or 'none'} "
              f"sampled=1 cases={len(results)}")
        return 0
    if args.quant:
        results = check_quant_equivalence(
            arch, args.mesh, slots=args.slots, max_len=args.max_len,
            max_new=args.max_new, seed=args.seed,
            page_size=args.page_size, prefill_data=args.prefill_data)
        print(f"{OK_MARKER} arch={args.arch} mesh={args.mesh} quant=1 "
              f"cases={len(results)}")
        return 0
    default_scen = PAGED_SCENARIOS if args.paged else SCENARIOS
    scenarios = (tuple(args.scenarios.split(","))
                 if args.scenarios else default_scen)
    results = check_decode_equivalence(
        arch, args.mesh, slots=args.slots, max_len=args.max_len,
        max_new=args.max_new, seed=args.seed, scenarios=scenarios,
        paged=args.paged, page_size=args.page_size,
        disagg=args.prefill_data if args.disagg else 0)
    print(f"{OK_MARKER} arch={args.arch} mesh={args.mesh or 'none'} "
          f"paged={int(args.paged)} disagg={int(args.disagg)} "
          f"cases={len(results)}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
