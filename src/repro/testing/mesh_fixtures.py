"""Fake-device meshes: XLA_FLAGS handling + subprocess runner + mesh shapes.

XLA's host platform can simulate N devices on one CPU via
``--xla_force_host_platform_device_count=N`` — but only if the flag is in
``XLA_FLAGS`` *before* the first backend initialisation, and only in a
process whose backend has not already been created. Everything here deals
with those two constraints:

* :func:`force_host_device_count` edits ``XLA_FLAGS`` by **appending**
  (user-set flags survive; a previous force flag is replaced) and refuses
  to touch the environment once the backend is initialised — the bug the
  old ``launch/dryrun.py`` / ``bench/hillclimb.py`` import-time
  ``os.environ["XLA_FLAGS"] = ...`` overwrite had.
* :func:`fake_devices` is the context-managed form for launcher
  entry points (set, run, restore).
* :func:`run_in_subprocess` runs a script under a fresh XLA client with a
  forced device count — the only reliable way to get an N-device mesh
  from inside an already-initialised pytest process.
* :data:`MESH_SHAPES` is the registry of small mesh shapes the
  conformance suite parametrizes over (named by parallelism role:
  ``dp`` maps to the ``data`` axis, ``tp``/``ep`` to ``model``, the
  3-axis entry adds a data-like ``pod`` axis — planner axis-role
  conventions, see ``core/planner.candidate_plans``).

This module imports no JAX at module scope on purpose: launcher code must
be able to call :func:`force_host_device_count` before its own first
``import jax``.
"""
from __future__ import annotations

import contextlib
import os
import subprocess
import sys
import warnings
from typing import Dict, List, Optional, Tuple

FORCE_FLAG = "--xla_force_host_platform_device_count"

MeshAxes = Tuple[Tuple[str, int], ...]

# Small meshes for 8 fake devices, keyed by parallelism role. The planner
# treats every non-"model" axis as data-like (batch/seq roles) and "model"
# as the TP/EP axis, so role names map onto the repo's axis names.
MESH_SHAPES: Dict[str, MeshAxes] = {
    "dp8": (("data", 8), ("model", 1)),
    "tp8": (("data", 1), ("model", 8)),
    "dp4_tp2": (("data", 4), ("model", 2)),
    "dp2_tp4": (("data", 2), ("model", 4)),
    # 4-device grid: the replan conformance cells migrate between this
    # and an 8-device shape in one process (grow/shrink the device set)
    "dp2_tp2": (("data", 2), ("model", 2)),
    "pod2_dp2_tp2": (("pod", 2), ("data", 2), ("model", 2)),
}


def mesh_shape(name: str) -> MeshAxes:
    if name not in MESH_SHAPES:
        raise KeyError(f"unknown mesh shape {name!r}; known: {sorted(MESH_SHAPES)}")
    return MESH_SHAPES[name]


def mesh_shape_names(num_devices: Optional[int] = 8) -> List[str]:
    """Registered mesh-shape names, optionally filtered to a device count."""
    out = []
    for name, axes in MESH_SHAPES.items():
        n = 1
        for _, s in axes:
            n *= s
        if num_devices is None or n == num_devices:
            out.append(name)
    return out


def backend_initialized() -> bool:
    """True once this process has created an XLA backend (device count is
    locked from then on; XLA_FLAGS edits no longer take effect)."""
    xla_bridge = sys.modules.get("jax._src.xla_bridge")
    if xla_bridge is None:
        return False  # jax internals not even imported yet
    return bool(getattr(xla_bridge, "_backends", None))


def _merged_flags(existing: str, n: int) -> str:
    """Append the force flag to an XLA_FLAGS string, replacing any previous
    force flag but preserving every other user-set flag."""
    kept = [f for f in existing.split()
            if not f.startswith(FORCE_FLAG + "=") and f != FORCE_FLAG]
    kept.append(f"{FORCE_FLAG}={n}")
    return " ".join(kept)


def force_host_device_count(n: int, env: Optional[Dict[str, str]] = None) -> bool:
    """Request ``n`` fake host devices by editing ``XLA_FLAGS`` in place.

    Appends to the existing value instead of overwriting it. When ``env``
    is None the edit targets ``os.environ`` and is refused (returns False,
    with a warning) if the XLA backend already exists in this process —
    the flag could no longer take effect and clobbering the environment
    would only mislead child processes that inherit it deliberately.

    Pass an explicit ``env`` dict (e.g. a copy for ``subprocess.run``) to
    edit unconditionally — a fresh child process always honours the flag.
    """
    if n <= 0:
        raise ValueError(f"device count must be positive, got {n}")
    if env is None:
        if backend_initialized():
            warnings.warn(
                f"force_host_device_count({n}): XLA backend already "
                "initialised — flag would be ignored; leaving XLA_FLAGS "
                "untouched (use run_in_subprocess for a fresh client)",
                RuntimeWarning, stacklevel=2)
            return False
        env = os.environ
    env["XLA_FLAGS"] = _merged_flags(env.get("XLA_FLAGS", ""), n)
    return True


@contextlib.contextmanager
def fake_devices(n: int):
    """Context manager: ``n`` fake host devices for code run inside.

    Must enter before the first backend initialisation (launcher
    entry points, subprocess scripts). The previous ``XLA_FLAGS`` value is
    restored on exit — the *backend*, however, keeps whatever device count
    it first initialised with; the restore only protects later child
    processes from inheriting the forced flag.

    Yields True when the flag was applied, False when the backend was
    already up (in which case the environment is untouched).
    """
    before = os.environ.get("XLA_FLAGS")
    applied = force_host_device_count(n)
    try:
        yield applied
    finally:
        if applied:
            if before is None:
                os.environ.pop("XLA_FLAGS", None)
            else:
                os.environ["XLA_FLAGS"] = before


def run_in_subprocess(script: str, *, devices: int = 8, timeout: int = 600,
                      marker: Optional[str] = None,
                      extra_env: Optional[Dict[str, str]] = None,
                      ) -> subprocess.CompletedProcess:
    """Run ``script`` in a fresh python with ``devices`` fake host devices.

    A fresh process gets its own XLA client, so the forced device count
    applies no matter what this process's backend looks like — the pattern
    every multi-device CPU test uses. ``PYTHONPATH`` and the rest of the
    environment are inherited; the force flag is appended to (not
    overwriting) any inherited ``XLA_FLAGS``.

    When ``marker`` is given, asserts it appears on the child's stdout and
    raises AssertionError carrying the stderr tail otherwise — the
    standard "print sentinel on success" subprocess-test contract.
    """
    env = dict(os.environ)
    if extra_env:
        env.update(extra_env)
    force_host_device_count(devices, env=env)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=timeout, env=env)
    if marker is not None:
        assert marker in r.stdout, (
            f"subprocess did not print {marker!r} (rc={r.returncode})\n"
            f"--- stdout tail ---\n{r.stdout[-1000:]}\n"
            f"--- stderr tail ---\n{r.stderr[-2000:]}")
    return r


def build_mesh(axes: MeshAxes):
    """Materialise a registered mesh shape over the live device set.

    Requires the live process to already have enough devices (i.e. you are
    inside a :func:`run_in_subprocess` child or a forced-count launcher).
    """
    import jax

    from repro.launch.mesh import make_mesh
    n = 1
    for _, s in axes:
        n *= s
    avail = jax.devices()
    if n > len(avail):
        raise RuntimeError(
            f"mesh {dict(axes)} needs {n} devices but only {len(avail)} "
            f"exist — run under run_in_subprocess(devices={n}) or force "
            "the host device count before jax initialises")
    return make_mesh(tuple(s for _, s in axes), tuple(a for a, _ in axes),
                     devices=avail[:n])
