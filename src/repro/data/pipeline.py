"""Deterministic synthetic LM data pipeline — host-sharded, checkpointable.

Tokens are a pure function of (seed, step, position), so:
  * every host computes exactly its own shard (no data redistribution),
  * restart-after-failure replays the stream exactly by setting `step`
    (the iterator state is one integer — trivially checkpointable),
  * elastic re-scaling re-partitions the same global stream.

The generator is a counter-mode hash (splitmix64-style), not jax.random,
so it is cheap on CPU feeders and identical across jax versions.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig

_MASK = np.uint64(0xFFFFFFFFFFFFFFFF)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)) & _MASK
    z = x
    z = ((z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)) & _MASK
    z = ((z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)) & _MASK
    return z ^ (z >> np.uint64(31))


@dataclasses.dataclass
class PipelineState:
    step: int = 0

    def to_dict(self) -> dict:
        return {"step": self.step}

    @classmethod
    def from_dict(cls, d: dict) -> "PipelineState":
        return cls(step=int(d["step"]))


@dataclasses.dataclass
class TokenPipeline:
    """Yields {tokens, labels} batches for an (arch, shape) cell.

    ``host_index``/``host_count`` select this host's batch rows; the global
    stream is identical regardless of the host grid (elasticity).
    """

    arch: ArchConfig
    shape: ShapeConfig
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    state: PipelineState = dataclasses.field(default_factory=PipelineState)

    def __post_init__(self):
        if self.shape.global_batch % self.host_count:
            raise ValueError("global_batch must divide evenly across hosts")
        self.local_batch = self.shape.global_batch // self.host_count

    def _tokens(self, step: int, rows: np.ndarray, length: int) -> np.ndarray:
        pos = np.arange(length, dtype=np.uint64)[None, :]
        base = (np.uint64(self.seed) * np.uint64(0x100000001B3)
                + np.uint64(step) * np.uint64(0x1000193)) & _MASK
        h = _splitmix64(base + rows[:, None] * np.uint64(0x10001) + pos)
        return (h % np.uint64(self.arch.vocab_size)).astype(np.int32)

    def next_batch(self) -> Dict[str, np.ndarray]:
        step = self.state.step
        rows = (np.arange(self.local_batch, dtype=np.uint64)
                + np.uint64(self.host_index * self.local_batch))
        text_len = self.shape.seq_len
        if self.arch.frontend == "vision_patches":
            text_len -= self.arch.frontend_tokens
        if self.arch.family == "encdec":
            tgt = max(self.shape.seq_len // 8, 1)
            toks = self._tokens(step, rows, tgt + 1)
            frames = self._frames(step, rows, self.shape.seq_len)
            batch = {"frames": frames, "tokens": toks[:, :-1], "labels": toks[:, 1:]}
        else:
            toks = self._tokens(step, rows, text_len + 1)
            batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
            if self.arch.frontend == "vision_patches":
                batch["patches"] = self._frames(step, rows, self.arch.frontend_tokens)
        self.state.step += 1
        return batch

    def _frames(self, step: int, rows: np.ndarray, length: int) -> np.ndarray:
        """Stub modality embeddings: deterministic pseudo-gaussian floats."""
        pos = np.arange(length * self.arch.d_model, dtype=np.uint64)[None, :]
        h = _splitmix64(np.uint64(self.seed ^ 0xABCD) + np.uint64(step)
                        + rows[:, None] * np.uint64(0x7F4A7C15) + pos)
        u = (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)
        g = np.sqrt(-2.0 * np.log(np.maximum(u, 1e-12))) * np.cos(2 * np.pi * u)
        return (g.reshape(len(rows), length, self.arch.d_model) * 0.02).astype(np.float32)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
