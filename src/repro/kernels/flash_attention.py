"""Flash attention (online softmax) Pallas TPU kernel.

Grid = (batch·heads, Sq/Bq, T/Bk), kv innermost. Running max/sum and the
output accumulator live in VMEM scratch and persist across the kv axis —
the score matrix never touches HBM (this is the traffic the HLO analyzer
books as `vmem_resident_bytes` on the reference path).

Supports causal masking and a local attention window (RecurrentGemma's
block pattern) via position arithmetic on block indices.

INT8 KV (``QuantConfig(kv="int8")`` serving) passes per-token f32 scales
as ``k_scale``/``v_scale`` ``[BH, T, 1]``; dequantisation fuses into the
kernel — each int8 kv block rehydrates in VMEM right before the dot, so
the fp extent never round-trips HBM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_body(q_ref, k, v, o_ref, m_ref, l_ref, acc_ref, *,
                bq: int, bk: int, n_kv: int, causal: bool, window: int):
    """Online-softmax update for one kv block; ``k``/``v`` arrive already
    rehydrated to f32 ``[bk, d]``."""
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # [bq, d]
    s = q @ k.T / math.sqrt(q.shape[-1])  # [bq, bk]

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, **kw):
    _flash_body(q_ref, k_ref[0].astype(jnp.float32),
                v_ref[0].astype(jnp.float32), o_ref, m_ref, l_ref, acc_ref,
                **kw)


def _flash_kernel_q8(q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                     m_ref, l_ref, acc_ref, **kw):
    # fused dequant: [bk, d] int8 * [bk, 1] f32, in VMEM
    _flash_body(q_ref, k_ref[0].astype(jnp.float32) * ks_ref[0],
                v_ref[0].astype(jnp.float32) * vs_ref[0], o_ref,
                m_ref, l_ref, acc_ref, **kw)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bk", "causal", "window", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    k_scale: jax.Array = None, v_scale: jax.Array = None,
                    bq: int = 512, bk: int = 512, causal: bool = True,
                    window: int = 0, interpret: bool = True) -> jax.Array:
    """q: [BH, S, D]; k, v: [BH, T, D] (KV already broadcast across groups).
    ``k_scale``/``v_scale``: optional [BH, T, 1] f32 per-token scales for
    int8 ``k``/``v`` (dequant fused in-kernel)."""
    if (k_scale is None) != (v_scale is None):
        raise ValueError("pass both k_scale and v_scale, or neither")
    bh, sq, d = q.shape
    t = k.shape[1]
    bq, bk = min(bq, sq), min(bk, t)
    assert sq % bq == 0 and t % bk == 0
    grid = (bh, sq // bq, t // bk)
    quant = k_scale is not None

    in_specs = [
        pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
    ]
    args = [q, k, v]
    kernel = _flash_kernel
    if quant:
        scale_spec = pl.BlockSpec((1, bk, 1), lambda b, i, j: (b, j, 0))
        in_specs += [scale_spec, scale_spec]
        args += [k_scale, v_scale]
        kernel = _flash_kernel_q8

    return pl.pallas_call(
        functools.partial(kernel, bq=bq, bk=bk, n_kv=grid[2],
                          causal=causal, window=window),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running sum
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(*args)
