"""Paged decode attention (single query token) Pallas TPU kernel.

The serving page pool (`repro.serving.pages`) stores KV in fixed-size
pages ``[P, ps, G, D]``; each decode row owns an int32 page-table row
mapping logical position blocks to physical pages. The gather fallback in
``models.blocks._paged_decode_attention`` materialises the full
``[B, M·ps, G, D]`` kv extent through the table in HBM before attending;
this kernel instead walks the table with **scalar prefetch**
(`pltpu.PrefetchScalarGridSpec`): the page id for grid step ``(b, j)`` is
read from the prefetched table to index the kv pool's BlockSpec, so each
page is DMA'd HBM→VMEM exactly once and the gathered extent never exists
in HBM. Online softmax state (running max / sum / accumulator) lives in
VMEM scratch across the page axis, like kernels/flash_attention.py.

INT8 KV pools (``serving.pages`` under ``QuantConfig(kv="int8")``) pass
the per-token f32 scale pools as ``k_scale``/``v_scale`` ``[P, ps, G, 1]``;
the dequantisation is fused into the kernel — each int8 page and its
scale page are DMA'd together and rehydrated in VMEM right before the
dot, so the fp extent never exists in HBM (the whole point of the int8
cache: HBM traffic per page drops ~4x for bf16→int8-and-scale).

Runs in interpret mode off-TPU (the default), matching the other kernels
in this package; `kernels/ref.py:paged_attention_ref` is the jnp oracle.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_body(lens_ref, q_ref, k, v, o_ref, m_ref, l_ref, acc_ref, *,
                ps: int, rep: int, n_pages: int):
    """Online-softmax update for one (row, page) grid step; ``k``/``v``
    are the current page already rehydrated to f32 ``[ps, G, D]``."""
    b, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)    # [H, D]
    h, d = q.shape
    g = k.shape[1]
    qg = q.reshape(g, rep, d) / math.sqrt(d)
    s = jnp.einsum("grd,pgd->grp", qg, k).reshape(h, ps)  # head h → group h//rep

    pos = j * ps + jax.lax.broadcasted_iota(jnp.int32, (h, ps), 1)
    s = jnp.where(pos < lens_ref[b], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    pv = jnp.einsum("grp,pgd->grd", p.reshape(g, rep, ps), v).reshape(h, d)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
    m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _paged_kernel(lens_ref, table_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, ps: int, rep: int, n_pages: int):
    _paged_body(lens_ref, q_ref,
                k_ref[0].astype(jnp.float32), v_ref[0].astype(jnp.float32),
                o_ref, m_ref, l_ref, acc_ref, ps=ps, rep=rep, n_pages=n_pages)


def _paged_kernel_q8(lens_ref, table_ref, q_ref, k_ref, v_ref,
                     ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                     ps: int, rep: int, n_pages: int):
    # fused dequant: [ps, G, D] int8 * [ps, G, 1] f32, in VMEM
    _paged_body(lens_ref, q_ref,
                k_ref[0].astype(jnp.float32) * ks_ref[0],
                v_ref[0].astype(jnp.float32) * vs_ref[0],
                o_ref, m_ref, l_ref, acc_ref, ps=ps, rep=rep, n_pages=n_pages)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q: jax.Array, kp: jax.Array, vp: jax.Array,
                    page_table: jax.Array, lengths: jax.Array, *,
                    k_scale: jax.Array = None, v_scale: jax.Array = None,
                    interpret: bool = True) -> jax.Array:
    """q: [B, H, D]; kp, vp: [P, ps, G, D] page pools;
    page_table: [B, M] int32 physical page per logical block;
    lengths: [B] int32 valid kv count per row (positions >= length are
    masked — unwritten page tails and null-page garbage never attend).
    ``k_scale``/``v_scale``: optional [P, ps, G, 1] f32 per-token scale
    pools for int8 ``kp``/``vp`` (dequant fused in-kernel).
    Returns [B, H, D]."""
    if (k_scale is None) != (v_scale is None):
        raise ValueError("pass both k_scale and v_scale, or neither")
    b, h, d = q.shape
    ps, g = kp.shape[1], kp.shape[2]
    m = page_table.shape[1]
    rep = h // g
    quant = k_scale is not None

    kv_spec = pl.BlockSpec((1, ps, g, d),
                           lambda bi, j, lens, table: (table[bi, j], 0, 0, 0))
    in_specs = [
        pl.BlockSpec((1, h, d), lambda bi, j, lens, table: (bi, 0, 0)),
        kv_spec, kv_spec,
    ]
    args = [q, kp, vp]
    kernel = _paged_kernel
    if quant:
        scale_spec = pl.BlockSpec(
            (1, ps, g, 1), lambda bi, j, lens, table: (table[bi, j], 0, 0, 0))
        in_specs += [scale_spec, scale_spec]
        args += [k_scale, v_scale]
        kernel = _paged_kernel_q8

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # lengths, page_table
        grid=(b, m),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, h, d), lambda bi, j, lens, table: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h,), jnp.float32),     # running max
            pltpu.VMEM((h,), jnp.float32),     # running sum
            pltpu.VMEM((h, d), jnp.float32),   # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(kernel, ps=ps, rep=rep, n_pages=m),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), page_table.astype(jnp.int32), *args)
