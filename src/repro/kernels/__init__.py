"""Pallas TPU kernels (validated in interpret mode on CPU) + jnp oracles."""
from repro.kernels.ops import attention, attention_ref, lru_scan, lru_scan_ref  # noqa: F401
from repro.kernels.ops import int8_matmul, int8_matmul_ref  # noqa: F401
from repro.kernels.ops import matmul, matmul_ref, mlstm, mlstm_ref  # noqa: F401
