"""Dequant-fused INT8-weight matmul Pallas TPU kernel.

The INT8 serving path (``QuantConfig(weights="int8")``) keeps weights
HBM-resident as per-channel int8 with an f32 scale per output column
(``repro.quant.quantize_params``). This kernel streams the *int8* tiles
HBM→VMEM — the bandwidth win the quantisation buys — and fuses the
rehydration into the matmul epilogue: per-channel symmetric scaling
commutes with the contraction (``(x @ q) * scale == x @ (q * scale)``),
so the int8 tile feeds the MXU via ``preferred_element_type=f32`` and the
scale multiplies the accumulated ``[tr, tm]`` tile exactly once at flush,
not per contraction step. Same ⟨Tm,Tn,Tr⟩ tiling and double-buffered
pipeline structure as kernels/xfer_matmul.py.

Runs in interpret mode off-TPU; ``kernels/ref.py:quant_matmul_ref`` is
the jnp oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quant_matmul_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, n_steps: int):
    """Grid = (R/Tr, M/Tm, N/Tn); acc persists across the inner N axis;
    the per-column scale applies once at flush."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_steps - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tr", "tm", "tn", "interpret"))
def quant_matmul(x: jax.Array, w_q: jax.Array, scale: jax.Array, *,
                 tr: int = 256, tm: int = 256, tn: int = 256,
                 interpret: bool = True) -> jax.Array:
    """x: [R, N] fp @ w_q: [N, M] int8 with scale: [1, M] f32 -> [R, M].

    ``w_q``/``scale`` are a per-channel :class:`repro.quant.QTensor`'s
    leaves (scale keeps rank with the reduced axis at extent 1).
    """
    r, n = x.shape
    n2, m = w_q.shape
    assert n == n2, (x.shape, w_q.shape)
    scale = scale.reshape(1, m).astype(jnp.float32)
    tr, tm, tn = min(tr, r), min(tm, m), min(tn, n)
    assert r % tr == 0 and m % tm == 0 and n % tn == 0, (
        f"dims {(r, n, m)} not divisible by tiles {(tr, tn, tm)}")
    grid = (r // tr, m // tm, n // tn)

    return pl.pallas_call(
        functools.partial(_quant_matmul_kernel, n_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, tn), lambda i, j, k: (i, k)),  # IFM tile (fp)
            pl.BlockSpec((tn, tm), lambda i, j, k: (k, j)),  # WEI tile (int8)
            pl.BlockSpec((1, tm), lambda i, j, k: (0, j)),   # per-col scale
        ],
        out_specs=pl.BlockSpec((tr, tm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, m), x.dtype),
        scratch_shapes=[pltpu.VMEM((tr, tm), jnp.float32)],
        interpret=interpret,
    )(x, w_q, scale)
