"""Public jit'd wrappers: dispatch Pallas kernels on TPU, interpret-mode
Pallas on CPU (validation), with the jnp references always available."""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_kernel import mlstm_chunkwise
from repro.kernels.paged_attention import paged_attention
from repro.kernels.quant_matmul import quant_matmul
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.xfer_matmul import xfer_matmul


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def matmul(x, w, *, tr=256, tm=256, tn=256):
    return xfer_matmul(x, w, tr=tr, tm=tm, tn=tn, interpret=not _on_tpu())


def int8_matmul(x, w_q, scale, *, tr=256, tm=256, tn=256):
    return quant_matmul(x, w_q, scale, tr=tr, tm=tm, tn=tn,
                        interpret=not _on_tpu())


def attention(q, k, v, *, k_scale=None, v_scale=None, causal=True, window=0,
              bq=512, bk=512):
    return flash_attention(q, k, v, k_scale=k_scale, v_scale=v_scale,
                           causal=causal, window=window, bq=bq, bk=bk,
                           interpret=not _on_tpu())


def lru_scan(a, b, h0, *, bs=256):
    return rglru_scan(a, b, h0, bs=bs, interpret=not _on_tpu())


def paged_attn(q, kp, vp, page_table, lengths, *, k_scale=None, v_scale=None):
    return paged_attention(q, kp, vp, page_table, lengths,
                           k_scale=k_scale, v_scale=v_scale,
                           interpret=not _on_tpu())


def mlstm(q, k, v, it, ft, *, bq=256):
    return mlstm_chunkwise(q, k, v, it, ft, bq=bq, interpret=not _on_tpu())


# references re-exported for tests/benchmarks
matmul_ref = ref.matmul_ref
int8_matmul_ref = ref.quant_matmul_ref
attention_ref = ref.flash_attention_ref
lru_scan_ref = ref.rglru_scan_ref
mlstm_ref = ref.mlstm_ref
paged_attn_ref = ref.paged_attention_ref
