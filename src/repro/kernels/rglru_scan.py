"""RG-LRU blocked linear-scan Pallas TPU kernel.

Recurrence h_t = a_t ⊙ h_{t-1} + b_t over [B, S, W]. Grid = (B, S/Bs) with
the sequence axis iterated innermost *sequentially* (TPU grid order), so
the carry h lives in VMEM scratch across blocks; within a block the scan
runs over rows of a VMEM tile. HBM traffic = read a,b once + write h once
(the paper's memory-bound streaming layer at machine balance).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, carry_ref, *, bs: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = h0_ref[0]

    a = a_ref[0]  # [bs, W] f32
    b = b_ref[0]

    def step(i, h):
        h = a[i] * h + b[i]
        o_ref[0, i, :] = h.astype(o_ref.dtype)
        return h

    carry_ref[...] = jax.lax.fori_loop(0, bs, step, carry_ref[...])


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def rglru_scan(a: jax.Array, b: jax.Array, h0: jax.Array, *,
               bs: int = 256, interpret: bool = True) -> jax.Array:
    """a, b: [B, S, W] (f32); h0: [B, W]. Returns h sequence [B, S, W]."""
    bsz, s, w = a.shape
    bs = min(bs, s)
    assert s % bs == 0
    grid = (bsz, s // bs)
    return pl.pallas_call(
        functools.partial(_rglru_kernel, bs=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bs, w), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, w), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bs, w), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), a.dtype),
        scratch_shapes=[pltpu.VMEM((w,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
