"""Chunkwise mLSTM Pallas TPU kernel (xLSTM matrix memory).

Grid = (B·H, S/Bq) over time chunks, sequential on the chunk axis. The
recurrent state (C [d,d], n [d], m [1]) persists in VMEM scratch across
chunks; within a chunk the decay-biased attention form runs on the MXU
(two [bq,d]×[d,d]-class matmuls + one [bq,bq] intra-chunk product).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref, o_ref,
                  c_ref, n_ref, m_ref, *, bq: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)

    q = q_ref[0].astype(jnp.float32)  # [bq, d]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    it = i_ref[0].astype(jnp.float32)  # [bq]
    logf = jax.nn.log_sigmoid(f_ref[0].astype(jnp.float32))  # [bq]

    F = jnp.cumsum(logf)  # [bq]
    m_carry = m_ref[0]
    # intra-chunk decay bias D_ij = F_i - F_j + i_j  (j <= i)
    bias = F[:, None] - F[None, :] + it[None, :]
    causal = jax.lax.broadcasted_iota(jnp.int32, (bq, bq), 1) <= \
        jax.lax.broadcasted_iota(jnp.int32, (bq, bq), 0)
    bias = jnp.where(causal, bias, NEG_INF)
    w_state = F + m_carry  # log-coefficient of carried state per row
    m_i = jnp.maximum(jnp.maximum(jnp.max(bias, axis=-1), w_state), NEG_INF)

    scores = (q @ k.T) * jnp.exp(bias - m_i[:, None])  # [bq, bq]
    s_coef = jnp.exp(w_state - m_i)  # [bq]
    num = scores @ v + s_coef[:, None] * (q @ c_ref[...])
    den = jnp.sum(scores, axis=-1) + s_coef * (q @ n_ref[...])
    den = jnp.maximum(jnp.abs(den), jnp.exp(-m_i))
    o_ref[0] = (num / den[:, None]).astype(o_ref.dtype)

    # fold chunk into state
    Fe = F[-1]
    w_log = Fe - F + it  # [bq]
    m_new = jnp.maximum(jnp.max(w_log), Fe + m_carry)
    wts = jnp.exp(w_log - m_new)
    carry = jnp.exp(Fe + m_carry - m_new)
    c_ref[...] = carry * c_ref[...] + (k * wts[:, None]).T @ v
    n_ref[...] = carry * n_ref[...] + jnp.sum(k * wts[:, None], axis=0)
    m_ref[...] = jnp.full_like(m_ref, m_new)


@functools.partial(jax.jit, static_argnames=("bq", "interpret"))
def mlstm_chunkwise(q: jax.Array, k: jax.Array, v: jax.Array,
                    it: jax.Array, ft: jax.Array, *,
                    bq: int = 256, interpret: bool = True) -> jax.Array:
    """q,k,v: [BH, S, D]; it, ft: [BH, S] gate pre-activations. -> [BH, S, D].

    k is expected pre-scaled by 1/sqrt(D) (as in models/recurrent.py).
    """
    bh, s, d = q.shape
    bq = min(bq, s)
    assert s % bq == 0
    grid = (bh, s // bq)
    return pl.pallas_call(
        functools.partial(_mlstm_kernel, bq=bq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bq), lambda i, j: (i, j)),
            pl.BlockSpec((1, bq), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((d, d), jnp.float32),
            pltpu.VMEM((d,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, it, ft)
