"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)).astype(x.dtype)


def quant_matmul_ref(x: jax.Array, w_q: jax.Array,
                     scale: jax.Array) -> jax.Array:
    """Dequantize-then-matmul oracle for kernels/quant_matmul.py:
    x [R, N] fp @ (w_q [N, M] int8 * scale [1, M] f32)."""
    w = w_q.astype(jnp.float32) * scale.reshape(1, w_q.shape[1])
    return jnp.dot(x.astype(jnp.float32), w).astype(x.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        k_scale: jax.Array = None, v_scale: jax.Array = None,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q: [BH, S, D]; k, v: [BH, T, D]; optional [BH, T, 1] per-token
    scales dequantize int8 k/v before attending."""
    if k_scale is not None:
        k = k.astype(jnp.float32) * k_scale
        v = v.astype(jnp.float32) * v_scale
    bh, sq, d = q.shape
    t = k.shape[1]
    s = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(t)[None, :]
    mask = jnp.ones((sq, t), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,btd->bsd", p, v.astype(jnp.float32)).astype(q.dtype)


def paged_attention_ref(q: jax.Array, kp: jax.Array, vp: jax.Array,
                        page_table: jax.Array, lengths: jax.Array,
                        k_scale: jax.Array = None,
                        v_scale: jax.Array = None) -> jax.Array:
    """Gather-then-attend oracle for kernels/paged_attention.py.

    q: [B, H, D]; kp, vp: [P, ps, G, D]; page_table: [B, M] int32;
    lengths: [B] valid kv count; optional [P, ps, G, 1] scale pools
    dequantize int8 kp/vp after the gather. Returns [B, H, D]."""
    b, h, d = q.shape
    ps, g = kp.shape[1], kp.shape[2]
    t = page_table.shape[1] * ps
    rep = h // g
    k = kp[page_table].reshape(b, t, g, d).astype(jnp.float32)
    v = vp[page_table].reshape(b, t, g, d).astype(jnp.float32)
    if k_scale is not None:
        k = k * k_scale[page_table].reshape(b, t, g, 1)
        v = v * v_scale[page_table].reshape(b, t, g, 1)
    qg = q.astype(jnp.float32).reshape(b, g, rep, d) / math.sqrt(d)
    s = jnp.einsum("bgrd,btgd->bgrt", qg, k)
    valid = jnp.arange(t)[None] < lengths[:, None]  # [B, t]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrt,btgd->bgrd", p, v)
    return o.reshape(b, h, d).astype(q.dtype)


def rglru_scan_ref(a: jax.Array, b: jax.Array, h0: jax.Array) -> jax.Array:
    """h_t = a_t * h_{t-1} + b_t; returns the h sequence [B, S, W]."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                         (a.astype(jnp.float32).transpose(1, 0, 2),
                          b.astype(jnp.float32).transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2).astype(a.dtype)


def mlstm_ref(q: jax.Array, k: jax.Array, v: jax.Array,
              it: jax.Array, ft: jax.Array) -> jax.Array:
    """Strict per-step recurrent reference. q,k,v: [BH,S,D]; it,ft: [BH,S]."""
    bh, s, d = q.shape

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, i_t, f_t = xs
        logf = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(logf + m, i_t)
        fs = jnp.exp(logf + m - m_new)[:, None]
        is_ = jnp.exp(i_t - m_new)[:, None]
        C = fs[..., None] * C + is_[..., None] * (kt[:, :, None] * vt[:, None, :])
        n = fs * n + is_ * kt
        num = jnp.einsum("bkv,bk->bv", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bk,bk->b", n, qt)),
                          jnp.exp(-m_new))[:, None]
        return (C, n, m_new), num / den

    C0 = jnp.zeros((bh, d, d), jnp.float32)
    n0 = jnp.zeros((bh, d), jnp.float32)
    m0 = jnp.full((bh,), NEG_INF, jnp.float32)
    xs = (q.astype(jnp.float32).transpose(1, 0, 2),
          k.astype(jnp.float32).transpose(1, 0, 2),
          v.astype(jnp.float32).transpose(1, 0, 2),
          it.astype(jnp.float32).T, ft.astype(jnp.float32).T)
    _, hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return hs.transpose(1, 0, 2).astype(q.dtype)
