"""⟨Tm, Tn, Tr, Tc⟩-tiled matmul — the paper's accelerator core (§3 ②) on TPU.

The paper's on-chip design streams IFM/WEI tiles into double-buffered BRAM
while a Tm×Tn MAC array consumes them (Fig. 5b). The TPU analogue: a
Pallas grid over (rows/Tr, cols/Tm, contraction/Tn) with BlockSpec-tiled
VMEM windows; the Pallas TPU pipeline double-buffers the HBM→VMEM streams
exactly like the paper's "×2" in Eqs. 3–5, and the MXU plays the MAC
array. The contraction dimension is the innermost grid axis, accumulating
into a VMEM scratch accumulator (f32), written back once per (Tr, Tm)
tile — the paper's ``tO_mem`` overlap (Eq. 13).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_steps: int):
    """Grid = (R/Tr, M/Tm, N/Tn); acc persists across the inner N axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tr", "tm", "tn", "interpret"))
def xfer_matmul(x: jax.Array, w: jax.Array, *, tr: int = 256, tm: int = 256,
                tn: int = 256, interpret: bool = True) -> jax.Array:
    """x: [R, N] @ w: [N, M] -> [R, M] with explicit ⟨Tm,Tn,Tr⟩ tiling.

    (Tc is folded into Tr: an LM matmul's spatial extent is 1-D, DESIGN §4.)
    """
    r, n = x.shape
    n2, m = w.shape
    assert n == n2, (x.shape, w.shape)
    tr, tm, tn = min(tr, r), min(tm, m), min(tn, n)
    assert r % tr == 0 and m % tm == 0 and n % tn == 0, (
        f"dims {(r, n, m)} not divisible by tiles {(tr, tn, tm)}")
    grid = (r // tr, m // tm, n // tn)

    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, tn), lambda i, j, k: (i, k)),  # IFM tile
            pl.BlockSpec((tn, tm), lambda i, j, k: (k, j)),  # WEI tile
        ],
        out_specs=pl.BlockSpec((tr, tm), lambda i, j, k: (i, j)),  # OFM tile
        out_shape=jax.ShapeDtypeStruct((r, m), x.dtype),
        scratch_shapes=[pltpu.VMEM((tr, tm), jnp.float32)],
        interpret=interpret,
    )(x, w)
