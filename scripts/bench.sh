#!/usr/bin/env bash
# Run the benchmark subsystem. With no arguments: the quick set, gated
# against the committed baseline (what CI's bench-quick job does).
#   scripts/bench.sh                       # quick + regression gate
#   scripts/bench.sh --full                # everything, no gate
#   scripts/bench.sh --quick --filter 'kernel_*'
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [ "$#" -eq 0 ]; then
    exec python -m repro.bench --quick --out "${BENCH_OUT:-.}" \
        --compare benchmarks/baseline
fi
exec python -m repro.bench "$@"
