#!/usr/bin/env bash
# Smoke gate for every PR: tier-1 tests, the quickstart example (exercises
# the plan -> compile -> execute pipeline end-to-end on the live device
# set), and one dry-run cell (512 simulated devices: full-config lowering
# + compile + HLO cost analysis).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== examples/quickstart.py =="
python examples/quickstart.py

echo "== launch/dryrun.py (one cell) =="
python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape decode_32k \
    --out "${DRYRUN_OUT:-/tmp/repro_smoke_dryrun}"

echo "== smoke OK =="
