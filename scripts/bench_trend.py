#!/usr/bin/env python
"""Append one run's ``BENCH_*.json`` records to a long-format trend CSV,
and optionally render the accumulated trend as an SVG artifact.

CI's ``bench-quick`` job downloads the previous run's ``bench-trend``
artifact, appends the current run with this script, re-uploads, and
renders the plot (ROADMAP trend-tracking item)::

    PYTHONPATH=src python scripts/bench_trend.py \
        --results bench-out --csv bench-trend.csv \
        --run-id "$GITHUB_RUN_ID" --sha "$GITHUB_SHA" \
        --plot bench-trend.svg

Long format (no per-scenario schema knowledge needed to append or plot):

    utc,run_id,sha,scenario,device_kind,jax_version,config_hash,metric,value

``--plot`` is dependency-free (hand-written SVG): one sparkline panel per
(scenario × gate-metric) series, so a latency creep across runs is visible
at a glance without downloading the CSV.
"""
from __future__ import annotations

import argparse
import csv
import datetime
import html
import pathlib
import sys
from typing import Dict, List, Optional, Sequence, Tuple

HEADER = ["utc", "run_id", "sha", "scenario", "device_kind", "jax_version",
          "config_hash", "metric", "value"]


def append_trend(results_dir: pathlib.Path, csv_path: pathlib.Path,
                 run_id: str, sha: str,
                 now: Optional[str] = None) -> int:
    """Append every metric of every BENCH_*.json under ``results_dir``.

    Creates the CSV (with header) when absent; refuses a CSV whose header
    does not match (a schema change needs a new artifact name, not a
    silently mixed file). Returns the number of rows appended.
    """
    from repro.bench.schema import load_results
    results = load_results(results_dir)
    now = now or datetime.datetime.now(datetime.timezone.utc).isoformat(
        timespec="seconds")
    exists = csv_path.exists() and csv_path.stat().st_size > 0
    if exists:
        with csv_path.open(newline="") as f:
            head = next(csv.reader(f), None)
        if head != HEADER:
            raise SystemExit(
                f"{csv_path}: unexpected header {head!r} (want {HEADER!r}) — "
                "refusing to append mixed schemas")
    rows = 0
    with csv_path.open("a", newline="") as f:
        w = csv.writer(f)
        if not exists:
            w.writerow(HEADER)
        for name in sorted(results):
            r = results[name]
            for metric in sorted(r.metrics):
                w.writerow([now, run_id, sha, name, r.device_kind,
                            r.jax_version, r.config_hash, metric,
                            repr(r.metrics[metric])])
                rows += 1
            if r.model_rel_error is not None:
                w.writerow([now, run_id, sha, name, r.device_kind,
                            r.jax_version, r.config_hash, "model_rel_error",
                            repr(r.model_rel_error)])
                rows += 1
    return rows


# ---------------------------------------------------------------------------
# --plot: dependency-free SVG sparkline small-multiples
# ---------------------------------------------------------------------------

# Visual tokens (light surface; see the repo's dataviz conventions): one
# series per panel -> a single hue, text in ink tokens, recessive grid.
_SURFACE = "#fcfcfb"
_INK = "#0b0b0b"
_INK_2 = "#52514e"
_SERIES = "#2a78d6"
_GRID = "#e4e3df"

_PANEL_W, _PANEL_H, _PAD = 340, 64, 12
_COLS = 2


def _gate_metrics() -> Dict[str, str]:
    """scenario -> its registered gate metric (the lower-is-better number
    the CI gate diffs); empty when the registry cannot be imported (the
    plot then falls back to the first series in the CSV)."""
    try:
        from repro.bench.registry import all_scenarios
    except Exception:
        return {}
    return {name: s.gate_metric for name, s in all_scenarios().items()
            if s.gate_metric is not None}


def read_series(csv_path: pathlib.Path) -> Dict[Tuple[str, str], List[float]]:
    """(scenario, metric) -> values in run order, from the long CSV."""
    series: Dict[Tuple[str, str], List[float]] = {}
    with csv_path.open(newline="") as f:
        rd = csv.DictReader(f)
        for row in rd:
            try:
                v = float(row["value"])
            except (TypeError, ValueError):
                continue
            series.setdefault((row["scenario"], row["metric"]), []).append(v)
    return series


def _polyline(vals: Sequence[float], x0: float, y0: float,
              w: float, h: float) -> List[Tuple[float, float]]:
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    n = len(vals)
    return [(x0 + w * (i / max(n - 1, 1)),
             y0 + h - h * ((v - lo) / span)) for i, v in enumerate(vals)]


def _fmt(v: float) -> str:
    if v == 0:
        return "0"
    if abs(v) >= 100:
        return f"{v:.0f}"
    return f"{v:.3g}"


def select_panels(series: Dict[Tuple[str, str], List[float]]
                  ) -> List[Tuple[str, str]]:
    """The (scenario, metric) keys worth a panel: gate metrics when the
    registry resolves them, else the first few series in the CSV."""
    gates = _gate_metrics()
    keys = sorted(k for k in series if gates.get(k[0]) == k[1])
    return keys or sorted(series)[:12]


def render_svg(series: Dict[Tuple[str, str], List[float]],
               keys: Optional[Sequence[Tuple[str, str]]] = None) -> str:
    """Sparkline small-multiples: one panel per (scenario, gate metric)."""
    keys = list(keys) if keys is not None else select_panels(series)
    rows = (len(keys) + _COLS - 1) // _COLS
    width = _COLS * (_PANEL_W + _PAD) + _PAD
    height = rows * (_PANEL_H + 30 + _PAD) + _PAD + 22
    out = [f'<svg xmlns="http://www.w3.org/2000/svg" width="{width}" '
           f'height="{height}" font-family="system-ui, sans-serif">',
           f'<rect width="{width}" height="{height}" fill="{_SURFACE}"/>',
           f'<text x="{_PAD}" y="{_PAD + 8}" font-size="13" fill="{_INK}" '
           f'font-weight="600">bench-trend — gate metric per run '
           f'(lower is better)</text>']
    for i, key in enumerate(keys):
        vals = series[key]
        col, row_i = i % _COLS, i // _COLS
        px = _PAD + col * (_PANEL_W + _PAD)
        py = 30 + _PAD + row_i * (_PANEL_H + 30 + _PAD)
        label = html.escape(f"{key[0]} · {key[1]}")
        out.append(f'<text x="{px}" y="{py + 10}" font-size="11" '
                   f'fill="{_INK}">{label}</text>')
        gx0, gy0 = px, py + 16
        gw, gh = _PANEL_W - 90, _PANEL_H - 16
        out.append(f'<line x1="{gx0}" y1="{gy0 + gh}" x2="{gx0 + gw}" '
                   f'y2="{gy0 + gh}" stroke="{_GRID}" stroke-width="1"/>')
        pts = _polyline(vals, gx0, gy0, gw, gh)
        if len(pts) > 1:
            path = " ".join(f"{x:.1f},{y:.1f}" for x, y in pts)
            out.append(f'<polyline points="{path}" fill="none" '
                       f'stroke="{_SERIES}" stroke-width="2" '
                       f'stroke-linejoin="round" stroke-linecap="round"/>')
        lx, ly = pts[-1]
        out.append(f'<circle cx="{lx:.1f}" cy="{ly:.1f}" r="4" '
                   f'fill="{_SERIES}"><title>{html.escape(_fmt(vals[-1]))}'
                   f' (latest of {len(vals)} runs)</title></circle>')
        out.append(f'<text x="{lx + 8:.1f}" y="{ly + 4:.1f}" font-size="11" '
                   f'fill="{_INK_2}">{html.escape(_fmt(vals[-1]))}</text>')
        lo, hi = min(vals), max(vals)
        out.append(f'<text x="{gx0}" y="{gy0 + gh + 12}" font-size="9" '
                   f'fill="{_INK_2}">min {html.escape(_fmt(lo))} · '
                   f'max {html.escape(_fmt(hi))} · n={len(vals)}</text>')
    out.append("</svg>")
    return "\n".join(out)


def plot_trend(csv_path: pathlib.Path, svg_path: pathlib.Path) -> int:
    series = read_series(csv_path)
    if not series:
        print(f"bench_trend: no data rows in {csv_path} — skipping plot")
        return 0
    keys = select_panels(series)
    svg_path.write_text(render_svg(series, keys))
    print(f"bench_trend: wrote {svg_path} ({len(keys)} panels)")
    return len(keys)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--results", required=True,
                    help="directory of BENCH_*.json files from this run")
    ap.add_argument("--csv", required=True, help="trend CSV to append to")
    ap.add_argument("--run-id", default="local")
    ap.add_argument("--sha", default="unknown")
    ap.add_argument("--plot", default=None, metavar="SVG",
                    help="also render the accumulated CSV as an SVG")
    args = ap.parse_args(argv)
    results = pathlib.Path(args.results)
    if not results.is_dir() or not list(results.glob("BENCH_*.json")):
        print(f"bench_trend: no BENCH_*.json under {results} — nothing to append")
        return 0
    rows = append_trend(results, pathlib.Path(args.csv), args.run_id, args.sha)
    print(f"bench_trend: appended {rows} rows to {args.csv}")
    if args.plot:
        plot_trend(pathlib.Path(args.csv), pathlib.Path(args.plot))
    return 0


if __name__ == "__main__":
    sys.exit(main())
