#!/usr/bin/env python
"""Append one run's ``BENCH_*.json`` records to a long-format trend CSV.

First step of the ROADMAP trend-tracking item: CI's ``bench-quick`` job
downloads the previous run's ``bench-trend`` artifact, appends the current
run with this script, and re-uploads — so the artifact accumulates one row
per (run × scenario × metric) over time::

    PYTHONPATH=src python scripts/bench_trend.py \
        --results bench-out --csv bench-trend.csv \
        --run-id "$GITHUB_RUN_ID" --sha "$GITHUB_SHA"

Long format (no per-scenario schema knowledge needed to append or plot):

    utc,run_id,sha,scenario,device_kind,jax_version,config_hash,metric,value
"""
from __future__ import annotations

import argparse
import csv
import datetime
import pathlib
import sys
from typing import List, Optional

HEADER = ["utc", "run_id", "sha", "scenario", "device_kind", "jax_version",
          "config_hash", "metric", "value"]


def append_trend(results_dir: pathlib.Path, csv_path: pathlib.Path,
                 run_id: str, sha: str,
                 now: Optional[str] = None) -> int:
    """Append every metric of every BENCH_*.json under ``results_dir``.

    Creates the CSV (with header) when absent; refuses a CSV whose header
    does not match (a schema change needs a new artifact name, not a
    silently mixed file). Returns the number of rows appended.
    """
    from repro.bench.schema import load_results
    results = load_results(results_dir)
    now = now or datetime.datetime.now(datetime.timezone.utc).isoformat(
        timespec="seconds")
    exists = csv_path.exists() and csv_path.stat().st_size > 0
    if exists:
        with csv_path.open(newline="") as f:
            head = next(csv.reader(f), None)
        if head != HEADER:
            raise SystemExit(
                f"{csv_path}: unexpected header {head!r} (want {HEADER!r}) — "
                "refusing to append mixed schemas")
    rows = 0
    with csv_path.open("a", newline="") as f:
        w = csv.writer(f)
        if not exists:
            w.writerow(HEADER)
        for name in sorted(results):
            r = results[name]
            for metric in sorted(r.metrics):
                w.writerow([now, run_id, sha, name, r.device_kind,
                            r.jax_version, r.config_hash, metric,
                            repr(r.metrics[metric])])
                rows += 1
            if r.model_rel_error is not None:
                w.writerow([now, run_id, sha, name, r.device_kind,
                            r.jax_version, r.config_hash, "model_rel_error",
                            repr(r.model_rel_error)])
                rows += 1
    return rows


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--results", required=True,
                    help="directory of BENCH_*.json files from this run")
    ap.add_argument("--csv", required=True, help="trend CSV to append to")
    ap.add_argument("--run-id", default="local")
    ap.add_argument("--sha", default="unknown")
    args = ap.parse_args(argv)
    results = pathlib.Path(args.results)
    if not results.is_dir() or not list(results.glob("BENCH_*.json")):
        print(f"bench_trend: no BENCH_*.json under {results} — nothing to append")
        return 0
    rows = append_trend(results, pathlib.Path(args.csv), args.run_id, args.sha)
    print(f"bench_trend: appended {rows} rows to {args.csv}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
