"""End-to-end driver: train a small LM for a few hundred steps with
checkpointing and automatic restart (deliverable b; the paper's kind is
real-time *inference*, so examples/serve_batch.py is the paper-dictated
driver and this is the training-side counterpart).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses qwen1.5-0.5b's family at ~55M scale (12 layers, d=512, tied embed) —
a real LM, small enough for this 1-core CPU container (recorded run:
experiments/train_lm_300.log, 240 steps). Pass --d-model 768 for ~110M on
real hardware.
"""
import argparse
import dataclasses
import time

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data.pipeline import TokenPipeline
from repro.models import registry as REG
from repro.optim import adamw as OPT
from repro.runtime.driver import DriverConfig, TrainDriver


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_100m")
    ap.add_argument("--d-model", type=int, default=512)
    args = ap.parse_args()

    arch = dataclasses.replace(
        get_arch("qwen1.5-0.5b"), name="qwen-small", num_layers=12,
        d_model=args.d_model, num_heads=8, num_kv_heads=8,
        head_dim=args.d_model // 8, d_ff=int(args.d_model * 2.75),
        vocab_size=32_000)
    n = arch.param_count()
    print(f"[train_lm] {arch.name}: {n/1e6:.1f}M params")

    shape = ShapeConfig("train", args.seq, args.batch, "train")
    params = REG.init_params(arch, jax.random.PRNGKey(0))
    cfg = OPT.AdamWConfig(lr=6e-4)
    opt = OPT.adamw_init(params, cfg)
    sched = OPT.cosine_schedule(6e-4, warmup=20, total=args.steps)
    step = jax.jit(REG.build_train_step(arch, cfg, lr_schedule=sched),
                   donate_argnums=(0, 1))
    driver = TrainDriver(step, params, opt,
                         TokenPipeline(arch, shape, seed=0),
                         Checkpointer(args.ckpt, keep=2),
                         DriverConfig(total_steps=args.steps,
                                      checkpoint_every=50))
    t0 = time.time()
    result = driver.run()
    dt = time.time() - t0
    log = result["log"]
    print(f"[train_lm] {len(log)} steps, {dt:.0f}s "
          f"({dt/max(len(log),1)*1e3:.0f} ms/step)")
    print(f"[train_lm] loss: {log[0]['loss']:.4f} -> {log[-1]['loss']:.4f}")
    if args.steps >= 20:  # too few steps to demand improvement through warmup
        assert log[-1]["loss"] < log[0]["loss"], "loss must improve"
    tok_s = args.batch * args.seq * len(log) / dt
    print(f"[train_lm] throughput {tok_s:.0f} tok/s on CPU; OK")


if __name__ == "__main__":
    main()
