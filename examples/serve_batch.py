"""Batched serving example: continuous batching over a request stream.

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import registry as REG
from repro.serving.engine import Request, ServingEngine

arch = get_arch("recurrentgemma-2b").reduced()
params = REG.init_params(arch, jax.random.PRNGKey(0))
# recurrent archs need length-aligned prompts (engine docstring): use 8
engine = ServingEngine(arch, params, slots=4, max_len=64, dtype=jnp.float32)

rng = np.random.RandomState(1)
t0 = time.time()
for i in range(10):
    engine.submit(Request(rid=i,
                          prompt=rng.randint(1, 200, size=8).astype(np.int32),
                          max_new_tokens=6))
steps = engine.run_until_drained(max_steps=200)
dt = time.time() - t0
lat = [r.finished_at - r.submitted_at for r in engine.completed]
print(f"[serve] arch={arch.name} {len(engine.completed)} requests in {steps} decode steps")
print(f"[serve] wall {dt:.2f}s  mean latency {np.mean(lat)*1e3:.0f}ms  "
      f"p99 {np.percentile(lat, 99)*1e3:.0f}ms")
for r in engine.completed[:4]:
    print(f"  rid={r.rid}: {r.out_tokens}")
assert len(engine.completed) == 10
print("serve_batch OK")
