"""Batched serving example: plan → compile → continuous batching.

    PYTHONPATH=src python examples/serve_batch.py

The engine comes out of the deployment pipeline, so its params, KV/state
cache grid and decode state are placed with the NamedShardings the
planner chose; decode state stays on device and step N+1 is dispatched
before step N's tokens are read back (one-step lookahead).
"""
import time

import numpy as np

import repro
from repro.configs.base import ShapeConfig
from repro.serving import ServeConfig
from repro.serving.engine import Request
from repro.serving.sampler import SamplingParams

# recurrent-state archs prefill length-aligned (scheduler pads to max_len)
exe = repro.deploy(repro.get_arch("recurrentgemma-2b").reduced(),
                   ShapeConfig("serve_demo", 64, 4, "decode"))
print(f"deployed: {exe.describe()}")
engine = exe.serve(config=ServeConfig(
    slots=4, max_len=64,
    sampling=SamplingParams()))  # greedy; try method="top_k"

rng = np.random.RandomState(1)
t0 = time.time()
for i in range(10):
    engine.submit(Request(rid=i,
                          prompt=rng.randint(1, 200, size=8).astype(np.int32),
                          max_new_tokens=6))
steps = engine.run_until_drained(max_steps=200)
dt = time.time() - t0
lat = [r.finished_at - r.submitted_at for r in engine.completed]
print(f"[serve] arch={engine.arch.name} {len(engine.completed)} requests "
      f"in {steps} decode steps")
stats = engine.step_stats()
print(f"[serve] wall {dt:.2f}s  mean latency {np.mean(lat)*1e3:.0f}ms  "
      f"p99 {np.percentile(lat, 99)*1e3:.0f}ms  "
      f"step p50 {stats['step_p50_ms']:.1f}ms")
for r in engine.completed[:4]:
    print(f"  rid={r.rid}: {r.out_tokens}")
assert len(engine.completed) == 10
print("serve_batch OK")
