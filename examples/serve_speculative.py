"""Speculative serving example: a small draft model co-planned with a
large target, draft-k proposals + one batched verify per engine step.

    PYTHONPATH=src python examples/serve_speculative.py

``repro.plan(target, shape, draft=...)`` places BOTH models with one
planner pass (the capacity report accounts both footprints), and
``ServeConfig(spec=SpecConfig(k=...))`` turns the fused decode step into
draft-k + batched-verify + on-device commit: up to k+1 tokens retire per
slot per dispatch. The pairing here is the config zoo's qwen1.5-0.5b as
the draft for a yi-9b target (both ``.reduced()`` so the demo runs on a
1-CPU container; the API is identical at full scale).

Both models run zero weights so every greedy proposal matches the target
(argmax of all-zero logits agrees everywhere) — the demo shows the
*mechanism* at 100% acceptance. With real weights the acceptance rate,
and therefore the speedup, is set by draft quality; watch
``step_stats()['draft_acceptance']`` in your own deployments.
"""
import dataclasses
import time

import jax
import numpy as np

import repro
from repro.configs.base import ShapeConfig
from repro.models import registry as REG
from repro.serving import ServeConfig, SpecConfig
from repro.serving.engine import Request

target = repro.get_arch("yi-9b").reduced()
target = dataclasses.replace(target, name=target.name + "-deep8l",
                             num_layers=8)  # a target worth speculating for
draft = repro.get_arch("qwen1.5-0.5b").reduced()
draft = dataclasses.replace(draft, name=draft.name + "-draft1l",
                            num_layers=1)

plan = repro.plan(target, ShapeConfig("spec_demo", 64, 4, "decode"),
                  draft=draft)
print(f"planned: target={target.name} draft={draft.name} "
      f"mesh={[list(a) for a in plan.mesh_axes]}")
exe = plan.compile()
tparams = jax.tree.map(np.zeros_like,
                       REG.init_params(target, jax.random.PRNGKey(0)))
dparams = jax.tree.map(np.zeros_like,
                       REG.init_params(draft, jax.random.PRNGKey(1)))

rng = np.random.RandomState(1)
prompts = [rng.randint(1, 200, size=8).astype(np.int32) for _ in range(10)]


def run(engine, label):
    # warm the jits outside the timed window: admission compiles one
    # prefill per (bucket, group size), so cover every group size churn
    # can produce — for the spec engine each group warms both models
    wid = -1
    for group in range(1, 5):
        for _ in range(group):
            engine.submit(Request(rid=wid, prompt=prompts[0],
                                  max_new_tokens=9))
            wid -= 1
        engine.run_until_drained(max_steps=200)
    t0 = time.time()
    for i, p in enumerate(prompts):
        engine.submit(Request(rid=i, prompt=p, max_new_tokens=9))
    steps = engine.run_until_drained(max_steps=400)
    dt = time.time() - t0
    stats = engine.step_stats()
    toks = sum(len(r.out_tokens) for r in engine.completed if r.rid >= 0)
    print(f"[{label}] {toks} tokens in {steps} decode steps "
          f"({dt:.2f}s wall, {1e3 / stats['tokens_per_s']:.2f} ms/token)")
    return engine, stats


base, base_stats = run(
    exe.serve(tparams, config=ServeConfig(slots=4, max_len=64)),
    "target-only")
spec, spec_stats = run(
    exe.serve({"target": tparams, "draft": dparams},
              config=ServeConfig(slots=4, max_len=64,
                                 spec=SpecConfig(k=8))),
    "speculative")

print(f"[spec] accepted_tokens_mean={spec_stats['accepted_tokens_mean']:.2f} "
      f"draft_acceptance={spec_stats['draft_acceptance']:.2f}")
want = {r.rid: list(r.out_tokens) for r in base.completed if r.rid >= 0}
got = {r.rid: list(r.out_tokens) for r in spec.completed if r.rid >= 0}
assert got == want, "spec greedy streams must match target-only"
assert spec_stats["accepted_tokens_mean"] > 1.0
print("serve_speculative OK")
