"""Design-space exploration example — the paper's Fig. 15 workflow on the
TPU pod: scale an arch across pod sizes, watch the bottleneck move, and see
where XFER weight distribution wins (capacity) vs plain replication.

    PYTHONPATH=src python examples/planner_dse.py [--arch yi-9b]

Each cell goes through `repro.plan`, so what is printed here is exactly the
ExecutionPlan that `compile()` would deploy.
"""
import argparse

import repro
from repro.configs import ARCH_IDS, SHAPES
from repro.core.planner import candidate_plans, evaluate_plan

ap = argparse.ArgumentParser()
ap.add_argument("--arch", choices=list(ARCH_IDS), default="yi-9b")
ap.add_argument("--shape", choices=list(SHAPES), default="train_4k")
args = ap.parse_args()
arch, shape = repro.get_arch(args.arch), SHAPES[args.shape]

print(f"== scaling {args.arch} / {args.shape} ==")
base = None
for data, model in ((4, 4), (8, 8), (16, 16), (32, 16)):
    plan = repro.plan(arch, shape, (("data", data), ("model", model)))
    n = plan.num_devices
    t = plan.predicted_seconds
    if base is None:
        base = (n, t)
    print(f"{n:5d} chips: {t*1e3:10.1f} ms  plan [{plan.sharding_plan.describe()}]  "
          f"speedup {base[1]/t:6.2f}x (linear would be {n/base[0]:.0f}x)  "
          f"hbm {plan.hbm_bytes_per_device/2**30:5.2f} GB {plan.report.note}")

print("\n== all candidate plans on 16x16 (paper Fig. 7 partitions) ==")
for cand in candidate_plans(arch, shape, (("data", 16), ("model", 16))):
    rep = evaluate_plan(arch, shape, cand)
    flag = "FITS" if rep.fits_hbm else "OOM "
    print(f"  {cand.describe():58s} {rep.predicted_seconds*1e3:10.1f} ms "
          f"{rep.hbm_bytes_per_device/2**30:6.2f} GB {flag}")
