"""Quickstart: plan a cell, inspect the bottleneck, run a tiny train step.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_arch
from repro.core.planner import plan_cell
from repro.data.pipeline import TokenPipeline
from repro.configs.base import ShapeConfig
from repro.models import registry as REG
from repro.optim import adamw as OPT

# 1. The paper's DSE (Eq. 15): pick the best partition for a cell.
arch = get_arch("minitron-8b")
for shape_id in ("train_4k", "decode_32k"):
    rep = plan_cell(arch, SHAPES[shape_id], (("data", 16), ("model", 16)))
    print(f"{shape_id:12s} -> {rep.plan.describe()}  "
          f"predicted {rep.predicted_seconds*1e3:.1f} ms/step, "
          f"HBM {rep.hbm_bytes_per_device/2**30:.2f} GB/chip  {rep.note}")
    for name, sec, bound in rep.per_layer[:3]:
        print(f"    {name:16s} {sec*1e3:9.3f} ms  bound={bound}")

# 2. Run a reduced config end-to-end on this host.
small = arch.reduced()
shape = ShapeConfig("demo", 64, 4, "train")
params = REG.init_params(small, jax.random.PRNGKey(0))
cfg = OPT.AdamWConfig(lr=1e-3)
opt = OPT.adamw_init(params, cfg)
step = jax.jit(REG.build_train_step(small, cfg))
pipe = TokenPipeline(small, shape)
for i in range(5):
    params, opt, m = step(params, opt, pipe.next_batch())
    print(f"step {i}: loss {float(m['loss']):.4f}")
print("quickstart OK")
