"""Quickstart: the three-stage deployment pipeline on one host.

    PYTHONPATH=src python examples/quickstart.py

Stage 1 (`repro.plan`) runs the paper's DSE (Eq. 15) for a cell; stage 2
(`.compile()`) binds the winning ShardingPlan to a live mesh and jits the
steps; stage 3 (`.train()` / `.serve()`) executes it.
"""
import tempfile

import repro
from repro.configs import SHAPES
from repro.configs.base import ShapeConfig

# 1. Plan: pick the best partition for a production cell and inspect it.
arch = repro.get_arch("minitron-8b")
for shape_id in ("train_4k", "decode_32k"):
    plan = repro.plan(arch, SHAPES[shape_id], (("data", 16), ("model", 16)))
    rep = plan.report
    print(f"{shape_id:12s} -> {plan.sharding_plan.describe()}  "
          f"predicted {plan.predicted_seconds*1e3:.1f} ms/step, "
          f"HBM {plan.hbm_bytes_per_device/2**30:.2f} GB/chip  {rep.note}")
    for name, sec, bound in rep.per_layer[:3]:
        print(f"    {name:16s} {sec*1e3:9.3f} ms  bound={bound}")
    for name, tiling, ports in plan.layer_choices[:2]:
        print(f"    {name:16s} tiling={tiling} ports={ports}")

# 2-3. Compile + execute a reduced config end-to-end on this host: the same
# pipeline, with the mesh fitted to the live device set (mesh=None).
exe = repro.plan(arch.reduced(), ShapeConfig("demo", 64, 4, "train")).compile()
print(f"deployed: {exe.describe()}")
# fresh checkpoint dir: reusing one would resume at the final step and
# train nothing on a second run
driver = exe.train(steps=5, ckpt_dir=tempfile.mkdtemp(prefix="repro_quickstart_"),
                   ckpt_every=100)
result = driver.run()
for m in result["log"]:
    print(f"step {m['step']}: loss {m['loss']:.4f}")
print("quickstart OK")
