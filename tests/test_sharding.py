"""ShardingCtx spec resolution + XFER scan + MoE dispatch + HLO analyzer."""
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.core.planner import ShardingPlan
from repro.core.xfer import ShardingCtx, scan_layers, tree_shardings

AXES = (("pod", 2), ("data", 16), ("model", 16))
PLAN = ShardingPlan(AXES, batch_axes=("pod", "data"), tp_axes=("model",), xfer=True)


def _ctx():
    return ShardingCtx(mesh=None, plan=PLAN)


def test_spec_divisibility_fallback():
    ctx = _ctx()
    # 24 not divisible by 32 (pod*data): falls back to pod only (24 % 2 == 0)
    assert ctx.spec((24, 8), ("batch", None)) == P("pod", None)
    # 64 divisible by 32: both axes used
    assert ctx.spec((64, 8), ("batch", None)) == P(("pod", "data"), None)
    # axis used at most once across dims
    spec = ctx.spec((64, 16), ("batch", "batch"))
    flat = []
    for part in spec:
        if part is None:
            continue
        flat += list(part) if isinstance(part, tuple) else [part]
    assert len(flat) == len(set(flat))


@given(st.integers(1, 512), st.integers(1, 512))
@settings(max_examples=100, deadline=None)
def test_spec_always_divides(a, b):
    ctx = _ctx()
    spec = ctx.spec((a, b), ("batch", "tp"))
    sizes = dict(AXES)
    for dim, part in zip((a, b), spec):
        if part is None:
            continue
        axes = part if isinstance(part, tuple) else (part,)
        prod = 1
        for ax in axes:
            prod *= sizes[ax]
        assert dim % prod == 0


def test_xfer_role_empty_when_off():
    plan = ShardingPlan(AXES, batch_axes=("data",), tp_axes=("model",), xfer=False)
    ctx = ShardingCtx(mesh=None, plan=plan)
    assert ctx.spec((4096, 4096), ("xfer", "tp")) == P(None, "model")


def test_scan_layers_matches_python_loop(key):
    stacked = {"w": jax.random.normal(key, (4, 8, 8))}
    x = jax.random.normal(key, (2, 8))

    def layer(p, h):
        return jnp.tanh(h @ p["w"])

    out = scan_layers(layer, stacked, x)
    ref = x
    for i in range(4):
        ref = jnp.tanh(ref @ stacked["w"][i])
    np.testing.assert_allclose(out, ref, rtol=1e-6)


def test_tree_shardings_structure(key):
    from repro.launch.mesh import make_test_mesh
    from repro.models import registry as REG
    arch = get_arch("deepseek-moe-16b").reduced()
    mesh = make_test_mesh()
    plan = ShardingPlan(tuple((n, s) for n, s in mesh.shape.items()),
                        batch_axes=("data",), tp_axes=("model",), xfer=True,
                        ep_axes=("model",))
    ctx = ShardingCtx(mesh, plan)
    params = REG.init_params(arch, key)
    sh = tree_shardings(ctx, params, REG.param_dims(arch))
    assert jax.tree.structure(sh) == jax.tree.structure(params)


def test_moe_capacity_drop():
    """With a tiny capacity factor, overflow tokens are dropped, not wrong."""
    import dataclasses
    from repro.models import blocks as B
    arch = dataclasses.replace(get_arch("deepseek-moe-16b").reduced(),
                               moe_capacity_factor=0.01)
    key = jax.random.PRNGKey(0)
    p = B.attn_init(key, arch, moe=True)
    x = jax.random.normal(key, (2, 8, arch.d_model)) * 0.1
    h = B.moe_apply(arch, p, x)
    assert h.shape == x.shape
    assert np.all(np.isfinite(np.asarray(h)))


def test_moe_matches_dense_when_single_expert(key):
    """E=1, top-1, no shared ⇒ routed MoE == plain MLP with that expert."""
    import dataclasses
    from repro.models import blocks as B
    from repro.models import layers as L
    base = get_arch("llama4-maverick-400b-a17b").reduced()
    arch = dataclasses.replace(base, num_experts=1, top_k=1,
                               num_shared_experts=0, moe_capacity_factor=4.0)
    p = B.attn_init(key, arch, moe=True)
    x = jax.random.normal(key, (2, 8, arch.d_model)) * 0.1
    out = B.moe_apply(arch, p, x)
    mlp_p = {k: v[0] for k, v in p["moe"].items()}
    ref = L.mlp_apply(mlp_p, x, arch.mlp)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-4, atol=1e-5)


def test_hlo_analyzer_counts_scan_trips():
    from repro.launch.hlo_analysis import analyze

    def body(c, x):
        return c @ x, None

    def f(c, xs):
        return jax.lax.scan(body, c, xs)[0]

    c = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    xs = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)
    cost = analyze(jax.jit(f).lower(c, xs).compile().as_text())
    assert abs(cost.flops - 7 * 2 * 128 ** 3) / (7 * 2 * 128 ** 3) < 0.01


def test_hlo_analyzer_embedding_not_overcounted():
    from repro.launch.hlo_analysis import analyze

    def f(emb, idx):
        return jnp.take(emb, idx, axis=0).sum()

    emb = jax.ShapeDtypeStruct((50_000, 256), jnp.float32)
    idx = jax.ShapeDtypeStruct((32,), jnp.int32)
    cost = analyze(jax.jit(f).lower(emb, idx).compile().as_text())
    # reads ~32 rows, not the 51MB table
    assert cost.hbm_bytes < 5e6
