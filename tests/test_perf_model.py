"""Property tests (hypothesis) on the paper's analytic model — Eqs. 1–22."""
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.layer_model import ConvLayer, alexnet_layers
from repro.core.partition import PartitionFactors, enumerate_partitions
from repro.core.perf_model import Ports, TilePipelineModel, Tiling
from repro.core.bottleneck import diagnose
from repro.core.topology import TorusSpec

MODEL = TilePipelineModel()

layer_st = st.builds(
    ConvLayer,
    name=st.just("l"),
    B=st.integers(1, 8),
    M=st.integers(16, 512),
    N=st.integers(16, 512),
    R=st.integers(8, 128),
    C=st.integers(1, 64),
    K=st.sampled_from([1, 3, 5, 11]),
)
tiling_st = st.builds(
    Tiling,
    Tm=st.sampled_from([16, 64, 128, 256]),
    Tn=st.sampled_from([16, 64, 128, 256]),
    Tr=st.sampled_from([8, 32, 128]),
    Tc=st.sampled_from([1, 8, 32]),
)
ports_st = st.builds(Ports, Ip=st.integers(1, 8), Wp=st.integers(1, 8),
                     Op=st.integers(1, 8))


@given(layer_st, tiling_st, ports_st)
@settings(max_examples=200, deadline=None)
def test_latency_terms_positive_and_lat_is_max(layer, tiling, ports):
    lat = MODEL.seconds(layer, tiling, ports)
    assert lat.t_comp > 0 and lat.t_ifm > 0 and lat.t_ofm > 0
    # Eq. 12: Lat1 is the max of its streams
    assert lat.lat1 >= lat.t_comp and lat.lat1 >= lat.t_ifm
    assert lat.lat1 >= lat.t_wei
    # Eq. 13/14: composition is monotone
    assert lat.lat2 >= lat.trip_inner * lat.lat1
    assert lat.total >= lat.trip_outer * lat.lat2


@given(layer_st, tiling_st, ports_st,
       st.sampled_from([1, 2, 4]), st.sampled_from([1, 2, 4]))
@settings(max_examples=150, deadline=None)
def test_partitioning_never_hurts_per_device_work(layer, tiling, ports, pb, pm):
    """More devices ⇒ per-device latency does not increase (P1)."""
    base = MODEL.seconds(layer, tiling, ports, PartitionFactors())
    part = MODEL.seconds(layer, tiling, ports, PartitionFactors(Pb=min(pb, layer.B),
                                                                Pm=min(pm, layer.M)))
    assert part.total <= base.total * 1.0001


@given(layer_st, tiling_st, ports_st)
@settings(max_examples=100, deadline=None)
def test_xfer_reduces_weight_stream_time(layer, tiling, ports):
    """Eq. 16: XFER divides tW by the weight-shared degree."""
    p = PartitionFactors(Pb=min(2, layer.B), Pr=min(2, layer.R))
    base = MODEL.seconds(layer, tiling, ports, p, xfer=False)
    xfer = MODEL.seconds(layer, tiling, ports, p, xfer=True)
    assert xfer.t_wei <= base.t_wei + 1e-12
    if p.weight_shared_degree > 1 and layer.weighted:
        assert xfer.t_link_w > 0


@given(layer_st, tiling_st, ports_st)
@settings(max_examples=100, deadline=None)
def test_bottleneck_matches_dominant_term(layer, tiling, ports):
    d = diagnose(layer, tiling, ports)
    lat = d.latency
    if d.bottleneck == "compute":
        assert lat.t_comp >= max(lat.t_ifm, lat.t_wei) - 1e-15
    if d.bottleneck == "OFM":
        assert lat.lat2 == lat.t_ofm


def test_cycle_domain_matches_paper_formulas():
    """Eqs. 8–11 verbatim in the cycle domain."""
    layer = ConvLayer("conv", 1, 128, 64, 32, 32, 3)
    t = Tiling(32, 16, 8, 8)
    ports = Ports(2, 2, 2)
    lat = MODEL.cycles(layer, t, ports)
    assert lat.t_ifm == 16 * 8 * 8 / 2  # Eq. 8
    assert lat.t_wei == 32 * 16 * 9 / 2  # Eq. 9
    assert lat.t_ofm == 32 * 8 * 8 / 2  # Eq. 10
    assert lat.t_comp == 9 * 8 * 8  # Eq. 11
    assert lat.lat1 == max(lat.t_comp, lat.t_ifm, lat.t_wei)


def test_bram_dsp_formulas():
    """Eqs. 1–5 resource formulas."""
    layer = ConvLayer("conv", 1, 128, 64, 32, 32, 3)
    t = Tiling(64, 7, 7, 14)
    assert MODEL.dsp_usage(t, bits=16) == 64 * 7
    assert MODEL.dsp_usage(t, bits=32) == 5 * 64 * 7
    b = MODEL.bram_usage(layer, t, bits=16)
    assert b == (2 * 7 * 1 + 2 * 64 * 1 + 64 * 7 * 1)  # 16b: single-buf weights


def test_bram_dsp_match_paper_table4():
    """Exact parity with the paper's reported Table 4 resources."""
    l5 = ConvLayer("conv5", 1, 256, 192, 13, 13, 3)
    # design A: 32b float, (Tm,Tn)=(8,32) -> BRAM 592, DSP 1280
    tA = Tiling(8, 32, 13, 13)
    assert MODEL.bram_usage(l5, tA, bits=32) == 592
    assert MODEL.dsp_usage(tA, bits=32) == 1280
    # design C: 16b fixed, (64,20) -> BRAM 1448, DSP 1280
    tC = Tiling(64, 20, 13, 13)
    assert MODEL.bram_usage(l5, tC, bits=16) == 1448
    assert MODEL.dsp_usage(tC, bits=16) == 1280


@given(st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_partition_enumeration_products(n):
    for p in enumerate_partitions(n, B=64, R=64, C=64, M=512, N=512):
        assert p.total == n


def test_torus_eq22_budget_scales_with_lat1():
    torus = TorusSpec(rows=2, cols=2)
    t = Tiling(64, 64, 32)
    ok_small, need, budget_small = torus.xfer_feasible(t, 3, 1e-6)
    ok_big, _, budget_big = torus.xfer_feasible(t, 3, 1e-3)
    assert budget_big > budget_small
    assert ok_big or not ok_small  # larger budget can only help


def test_alexnet_descriptor_macs():
    """AlexNet conv1 MAC count matches the public figure (~105M)."""
    l1 = alexnet_layers()[0]
    assert abs(l1.macs - 96 * 3 * 55 * 55 * 11 * 11) < 1
