"""Fast structural invariants + the testing harness's own machinery.

The 1-device cases exercise the checks' structure (tree coverage, axis
validity, capacity reproducibility); the real sharded variants run in the
slow suite (tests/test_conformance.py) on 8 fake devices.
"""
import dataclasses
import warnings

import pytest

import repro
from repro.configs.base import ShapeConfig
from repro.testing import invariants as I
from repro.testing import mesh_fixtures as MF
from repro.testing.differential import (Tolerance, compare_trees, kind_shape,
                                        make_batch, proposed_plans)

ARCH = repro.get_arch("qwen1.5-0.5b").reduced()
DEGENERATE = (("data", 1), ("model", 1))


# ------------------------- sharding coverage ---------------------------

def test_sharding_coverage_every_candidate_plan():
    shape = ShapeConfig("inv", 32, 8, "decode")
    plans = proposed_plans(ARCH, shape, DEGENERATE)
    assert plans
    for eplan in plans:
        assert I.check_sharding_coverage(eplan) > 0


def test_sharding_coverage_counts_all_leaves():
    import jax
    shape = ShapeConfig("inv", 32, 8, "decode")
    eplan = proposed_plans(ARCH, shape, DEGENERATE)[0]
    from repro.models import registry as REG
    params = jax.eval_shape(lambda k: REG.init_params(ARCH, k),
                            jax.random.PRNGKey(0))
    assert I.check_sharding_coverage(eplan) == len(jax.tree.leaves(params))


# ------------------------- capacity report -----------------------------

def test_capacity_report_reproducible_full_size():
    # hypothetical 256-chip mesh: pure planning, no devices needed
    eplan = repro.plan("minitron-8b", "train_4k", (("data", 16), ("model", 16)))
    I.check_capacity_report(eplan)


def test_capacity_report_int8_note_handled():
    # llama4 train fits MESH2 only with int8 Adam states (planner note)
    eplan = repro.plan("llama4-maverick-400b-a17b", "train_4k",
                       (("pod", 2), ("data", 16), ("model", 16)))
    assert "int8" in eplan.report.note
    I.check_capacity_report(eplan)


def test_capacity_report_detects_corruption():
    eplan = repro.plan("minitron-8b", "train_4k", (("data", 16), ("model", 16)))
    bad = dataclasses.replace(
        eplan, report=dataclasses.replace(eplan.report,
                                          hbm_bytes_per_device=123.0))
    with pytest.raises(I.InvariantViolation, match="recomputes"):
        I.check_capacity_report(bad)


# ------------------------- xfer accounting -----------------------------

def test_expected_xfer_bytes_zero_without_xfer():
    shape = ShapeConfig("inv", 32, 8, "decode")
    off = [p for p in proposed_plans(ARCH, shape, DEGENERATE)
           if not p.sharding_plan.xfer]
    assert off and I.expected_xfer_gather_bytes(off[0]) == 0.0
    # and the band check degrades to report-only for non-XFER plans
    out = I.check_xfer_accounting(off[0], "HloModule empty")
    assert out["expected_xfer_bytes"] == 0.0


def test_measured_collective_bytes_parses_hlo():
    hlo = ("HloModule m\n"
           "ENTRY %main () -> f32[16] {\n"
           "  %p = f32[4]{0} parameter(0)\n"
           "  ROOT %ag = f32[16]{0} all-gather(%p), replica_groups={{0,1,2,3}}, "
           "dimensions={0}\n"
           "}\n")
    got = I.measured_collective_bytes(hlo)
    assert got.get("all-gather", 0.0) > 0


# ------------------------- differential helpers ------------------------

def test_compare_trees_tolerance_and_exactness():
    import numpy as np
    a = {"x": np.array([1.0, 2.0], np.float32), "i": np.array([1, 2])}
    b = {"x": np.array([1.0, 2.0 + 1e-5], np.float32), "i": np.array([1, 2])}
    diffs = compare_trees(a, b, Tolerance(max_abs=1e-4))
    assert all(d.ok for d in diffs)
    diffs = compare_trees(a, b, Tolerance(max_abs=1e-7, max_ulp=1.0))
    assert not all(d.ok for d in diffs)
    # integer leaves must match exactly
    c = {"x": b["x"], "i": np.array([1, 3])}
    diffs = compare_trees(c, b, Tolerance(max_abs=1e-4))
    assert not all(d.ok for d in diffs)


def test_compare_trees_rejects_nonfinite_divergence():
    """An overflowing sharded run (inf/NaN where golden is finite) must
    fail, not slip through the ulp escape hatch (spacing(inf) is NaN)."""
    import numpy as np
    want = {"x": np.array([1.0, 2.0], np.float32)}
    inf_got = {"x": np.array([np.inf, 2.0], np.float32)}
    assert not all(d.ok for d in compare_trees(inf_got, want, Tolerance()))
    nan_got = {"x": np.array([np.nan, 2.0], np.float32)}
    assert not all(d.ok for d in compare_trees(nan_got, want, Tolerance()))
    # matching non-finite values are equal, not divergent
    both = {"x": np.array([np.inf, np.nan], np.float32)}
    diffs = compare_trees(both, {"x": both["x"].copy()}, Tolerance())
    assert all(d.ok for d in diffs) and diffs[0].max_abs_err == 0.0
    # mismatched infinity signs diverge
    neg = {"x": np.array([-np.inf, np.nan], np.float32)}
    assert not all(d.ok for d in compare_trees(neg, both, Tolerance()))


def test_make_batch_is_deterministic_and_spec_complete():
    import numpy as np
    for kind in ("forward", "decode", "train_step"):
        shape = kind_shape(ShapeConfig("mb", 16, 2, "decode"), kind)
        a = make_batch(ARCH, shape, seed=3)
        b = make_batch(ARCH, shape, seed=3)
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
        from repro.models import registry as REG
        assert set(a) == set(REG.input_specs(ARCH, shape))


def test_proposed_plans_cover_xfer_both_ways():
    shape = ShapeConfig("pp", 32, 8, "train")
    plans = proposed_plans(ARCH, shape, (("data", 4), ("model", 2)))
    flags = {p.sharding_plan.xfer for p in plans}
    assert flags == {True, False}


# ------------------------- mesh fixtures -------------------------------

def test_merged_flags_appends_and_replaces():
    merged = MF._merged_flags("--xla_foo=1 --xla_force_host_platform_device_count=4", 8)
    assert merged.split() == ["--xla_foo=1",
                              "--xla_force_host_platform_device_count=8"]
    assert MF._merged_flags("", 2) == "--xla_force_host_platform_device_count=2"


def test_force_host_device_count_env_dict():
    env = {"XLA_FLAGS": "--xla_bar=7"}
    assert MF.force_host_device_count(8, env=env)
    assert "--xla_bar=7" in env["XLA_FLAGS"]
    assert "--xla_force_host_platform_device_count=8" in env["XLA_FLAGS"]
    with pytest.raises(ValueError):
        MF.force_host_device_count(0, env=env)


def test_force_host_device_count_noops_after_backend_init():
    import os

    import jax
    jax.devices()  # ensure the backend exists
    assert MF.backend_initialized()
    before = os.environ.get("XLA_FLAGS")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert not MF.force_host_device_count(8)
        # context-manager form: applied=False, env untouched
        with MF.fake_devices(8) as applied:
            assert not applied
            assert os.environ.get("XLA_FLAGS") == before
    assert any("already initialised" in str(x.message) for x in w)
    assert os.environ.get("XLA_FLAGS") == before


def test_mesh_shape_registry():
    # dp2_tp2 is the 4-device grid the replan cells grow/shrink through;
    # everything else fills all 8 fake devices
    assert set(MF.mesh_shape_names(8)) == set(MF.MESH_SHAPES) - {"dp2_tp2"}
    assert MF.mesh_shape_names(4) == ["dp2_tp2"]
    assert set(MF.mesh_shape_names(None)) == set(MF.MESH_SHAPES)
    for name in MF.MESH_SHAPES:
        n = 1
        for _, s in MF.mesh_shape(name):
            n *= s
        assert n == (4 if name == "dp2_tp2" else 8), name
    with pytest.raises(KeyError, match="unknown mesh shape"):
        MF.mesh_shape("nope")


def test_build_mesh_from_registered_axes():
    mesh = MF.build_mesh(DEGENERATE)
    assert dict(mesh.shape) == {"data": 1, "model": 1}
    # more devices than this 1-CPU process has: refuse with the
    # run_in_subprocess pointer instead of a bare XLA error
    with pytest.raises(RuntimeError, match="run_in_subprocess"):
        MF.build_mesh(MF.mesh_shape("dp8"))


def test_run_in_subprocess_forces_device_count():
    r = MF.run_in_subprocess(
        "import jax; print('DEVCOUNT', jax.device_count())",
        devices=2, timeout=300, marker="DEVCOUNT 2")
    assert r.returncode == 0
