"""Planner (Eq. 15 DSE) behaviour across cells and meshes.

The property-based block at the bottom uses hypothesis (the vendored shim
in tests/_vendor when the real library is absent — see conftest.py).
"""
import dataclasses

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import ARCH_IDS, SHAPES, cell_is_runnable, get_arch
from repro.core.planner import candidate_plans, capacity_bytes, plan_cell

MESH1 = (("data", 16), ("model", 16))
MESH2 = (("pod", 2), ("data", 16), ("model", 16))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
@pytest.mark.parametrize("shape_id", list(SHAPES))
def test_plan_every_cell(arch_id, shape_id):
    arch, shape = get_arch(arch_id), SHAPES[shape_id]
    if not cell_is_runnable(arch, shape)[0]:
        pytest.skip("cell skipped by design")
    rep = plan_cell(arch, shape, MESH1)
    assert rep.predicted_seconds > 0
    f = rep.plan.factors
    assert f.Pb * f.Pr <= 256 and f.Pm <= 256
    # batch factor divides global batch
    assert shape.global_batch % max(f.Pb, 1) == 0


def test_multipod_speedup_over_single_pod():
    arch, shape = get_arch("minitron-8b"), SHAPES["train_4k"]
    t1 = plan_cell(arch, shape, MESH1).predicted_seconds
    t2 = plan_cell(arch, shape, MESH2).predicted_seconds
    assert t2 < t1  # 512 chips beat 256
    assert t2 < 0.75 * t1  # and by a sane margin


def test_xfer_wins_capacity_for_training():
    """Paper's core claim, capacity side: distributing weights over the
    sharing group divides per-device HBM residency."""
    arch, shape = get_arch("phi3-medium-14b"), SHAPES["train_4k"]
    plans = candidate_plans(arch, shape, MESH1)
    on = [p for p in plans if p.xfer and p.factors.Pb == 16]
    off = [p for p in plans if not p.xfer and p.factors.Pb == 16]
    assert on and off
    cap_on = capacity_bytes(arch, shape, on[0])
    cap_off = capacity_bytes(arch, shape, off[0])
    # params shard 16x further; opt states (ZeRO-1) shard either way, so the
    # total drops by the param+grad share (~2x here), not the full 16x.
    assert cap_on < 0.6 * cap_off


def test_planner_prefers_tp_for_low_batch_decode():
    arch, shape = get_arch("minitron-8b"), SHAPES["decode_32k"]
    rep = plan_cell(arch, shape, MESH1)
    assert rep.plan.factors.Pm >= 16  # model parallelism engaged


def test_force_xfer_flag():
    arch, shape = get_arch("yi-9b"), SHAPES["train_4k"]
    on = plan_cell(arch, shape, MESH1, force_xfer=True)
    off = plan_cell(arch, shape, MESH1, force_xfer=False)
    assert on.plan.xfer and not off.plan.xfer
    # time-domain prediction: gathers overlap, so xfer is never much slower
    assert on.predicted_seconds <= off.predicted_seconds * 1.2


def test_llama4_train_needs_multipod_or_int8():
    arch, shape = get_arch("llama4-maverick-400b-a17b"), SHAPES["train_4k"]
    r1 = plan_cell(arch, shape, MESH1)
    r2 = plan_cell(arch, shape, MESH2)
    assert not r1.fits_hbm  # 784B params cannot fit 256 x 16GB
    assert r2.fits_hbm and "int8" in r2.note


# ---------------------------------------------------------------------------
# property-based: dedupe-key stability, determinism, monotonicity
# ---------------------------------------------------------------------------

_RUNNABLE = [(a, s) for a in ARCH_IDS for s in SHAPES
             if cell_is_runnable(get_arch(a), SHAPES[s])[0]]


def _dedupe_key(p):
    # the identity candidate_plans dedupes on — ep_axes included: MoE plans
    # differing only in expert-parallel assignment are distinct candidates
    return (p.batch_axes, p.seq_axes, p.tp_axes, p.xfer, p.ep_axes)


@settings(max_examples=15, deadline=None)
@given(st.sampled_from(_RUNNABLE), st.sampled_from([2, 4, 16]),
       st.sampled_from([1, 2, 8, 16]))
def test_candidate_dedupe_keys_unique_and_stable(cell, data, model):
    arch, shape = get_arch(cell[0]), SHAPES[cell[1]]
    mesh = (("data", data), ("model", model))
    plans = candidate_plans(arch, shape, mesh)
    keys = [_dedupe_key(p) for p in plans]
    assert len(set(keys)) == len(keys), f"duplicate candidates for {cell}"
    # stable across calls (same candidates, same order)
    assert [_dedupe_key(p) for p in candidate_plans(arch, shape, mesh)] == keys
    # ep_axes is load-bearing in the key: erasing it must change identity
    for p in plans:
        if p.ep_axes:
            assert _dedupe_key(dataclasses.replace(p, ep_axes=())) != _dedupe_key(p)


@settings(max_examples=12, deadline=None)
@given(st.sampled_from(_RUNNABLE), st.sampled_from([1, 2, 4, 16]),
       st.sampled_from([1, 2, 8, 16]))
def test_plan_cell_deterministic(cell, data, model):
    """Same cell in, same PlanReport out — the DSE has no hidden state."""
    arch, shape = get_arch(cell[0]), SHAPES[cell[1]]
    mesh = (("data", data), ("model", model))
    r1, r2 = plan_cell(arch, shape, mesh), plan_cell(arch, shape, mesh)
    assert r1.plan == r2.plan
    assert r1.predicted_seconds == r2.predicted_seconds
    assert r1.per_layer == r2.per_layer
    assert r1.layer_choices == r2.layer_choices
    assert (r1.hbm_bytes_per_device, r1.fits_hbm, r1.note) == \
           (r2.hbm_bytes_per_device, r2.fits_hbm, r2.note)


@settings(max_examples=12, deadline=None)
@given(st.sampled_from(_RUNNABLE), st.sampled_from([1, 2, 4, 8]),
       st.sampled_from([1, 4, 16]))
def test_more_data_devices_never_slower(cell, data, model):
    """Monotonicity: doubling the data axis never increases predicted
    latency — as long as the batch still divides, so the new devices can
    actually absorb work (Pb/Pr). Deliberately NOT asserted for the tp
    axis or for indivisible batches (long_500k has batch 1): there, extra
    devices buy only collectives, and the model honestly predicts the
    slowdown — that prediction is the planner's reason to not use them.
    """
    arch, shape = get_arch(cell[0]), SHAPES[cell[1]]
    if shape.global_batch % (2 * data) != 0:
        return
    t1 = plan_cell(arch, shape, (("data", data), ("model", model))).predicted_seconds
    t2 = plan_cell(arch, shape, (("data", 2 * data), ("model", model))).predicted_seconds
    assert t2 <= t1 * (1 + 1e-9), (cell, data, model, t1, t2)
