"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


_TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
        jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


@pytest.mark.parametrize("r,n,m,tiles", [
    (256, 256, 256, (128, 128, 128)),
    (512, 384, 128, (128, 128, 128)),
    (128, 128, 512, (64, 64, 256)),
    (384, 128, 384, (384, 128, 128)),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_xfer_matmul(r, n, m, tiles, dtype, key):
    k1, k2 = jax.random.split(key)
    x = _rand(k1, (r, n), dtype)
    w = _rand(k2, (n, m), dtype)
    tr, tn, tm = tiles
    out = ops.matmul(x, w, tr=tr, tn=tn, tm=tm)
    ref = ops.matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_TOL[dtype])


@pytest.mark.parametrize("s,t,d,blocks,window", [
    (256, 256, 64, (128, 128), 0),
    (128, 128, 32, (64, 32), 0),
    (256, 256, 64, (64, 64), 64),
    (64, 256, 64, (64, 128), 0),  # cross/short-query
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(s, t, d, blocks, window, dtype, key):
    k1, k2, k3 = jax.random.split(key, 3)
    q = _rand(k1, (3, s, d), dtype)
    k = _rand(k2, (3, t, d), dtype)
    v = _rand(k3, (3, t, d), dtype)
    causal = s == t
    out = ops.attention(q, k, v, causal=causal, window=window,
                        bq=blocks[0], bk=blocks[1])
    ref = ops.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_TOL[dtype])


@pytest.mark.parametrize("b,s,w,bs", [(2, 256, 128, 64), (1, 128, 256, 128),
                                      (3, 512, 64, 256)])
def test_rglru_scan(b, s, w, bs, key):
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.nn.sigmoid(jax.random.normal(k1, (b, s, w)))
    bb = jax.random.normal(k2, (b, s, w))
    h0 = jax.random.normal(k3, (b, w))
    out = ops.lru_scan(a, bb, h0, bs=bs)
    ref = ops.lru_scan_ref(a, bb, h0)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bh,s,d,bq", [(2, 128, 32, 32), (1, 256, 64, 64),
                                       (4, 64, 16, 64)])
def test_mlstm_chunkwise(bh, s, d, bq, key):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (bh, s, d))
    k = jax.random.normal(ks[1], (bh, s, d)) / np.sqrt(d)
    v = jax.random.normal(ks[2], (bh, s, d))
    it = jax.random.normal(ks[3], (bh, s))
    ft = jax.random.normal(ks[4], (bh, s)) + 2.0
    out = ops.mlstm(q, k, v, it, ft, bq=bq)
    ref = ops.mlstm_ref(q, k, v, it, ft)
    np.testing.assert_allclose(out, ref, rtol=3e-3, atol=3e-3)


@pytest.mark.parametrize("b,h,g,d,ps,m", [(3, 8, 2, 16, 8, 4),
                                          (2, 4, 4, 32, 16, 2),
                                          (1, 6, 1, 64, 8, 3)])
def test_paged_attention(b, h, g, d, ps, m, key):
    """Scalar-prefetch paged decode kernel vs the gather-then-attend
    oracle: GQA head grouping, partial frontier pages (length masking)
    and arbitrary page-table permutations."""
    ks = jax.random.split(key, 3)
    n_pages = b * m + 2
    q = jax.random.normal(ks[0], (b, h, d))
    kp = jax.random.normal(ks[1], (n_pages, ps, g, d))
    vp = jax.random.normal(ks[2], (n_pages, ps, g, d))
    rng = np.random.RandomState(0)
    table = np.stack([rng.permutation(np.arange(1, n_pages))[:m]
                      for _ in range(b)])
    # partial page / mid / full extents
    lengths = rng.randint(1, m * ps + 1, size=b).astype(np.int32)
    lengths[-1] = m * ps
    out = ops.paged_attn(q, kp, vp, jnp.asarray(table), jnp.asarray(lengths))
    ref = ops.paged_attn_ref(q, kp, vp, jnp.asarray(table),
                             jnp.asarray(lengths))
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("r,n,m,tiles", [
    (256, 256, 256, (128, 128, 128)),
    (512, 384, 128, (128, 128, 128)),
    (128, 128, 512, (64, 64, 256)),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul(r, n, m, tiles, dtype, key):
    """Dequant-fused int8 matmul vs dequantize-then-matmul oracle: the
    per-output-channel scale is applied once at the accumulator flush
    ((x @ q) * s == x @ (q * s)), so results match the oracle to fp
    accumulation error."""
    from repro import quant as Q
    k1, k2 = jax.random.split(key)
    x = _rand(k1, (r, n), dtype)
    t = Q.quantize(jax.random.normal(k2, (n, m), jnp.float32), axis=0)
    scale = t.scale.reshape(1, m)
    tr, tn, tm = tiles
    out = ops.int8_matmul(x, t.q, scale, tr=tr, tn=tn, tm=tm)
    ref = ops.int8_matmul_ref(x, t.q, scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_TOL[dtype])


@pytest.mark.parametrize("s,t,d,blocks", [
    (256, 256, 64, (128, 128)),
    (128, 128, 32, (64, 32)),
    (64, 256, 64, (64, 128)),  # cross/short-query
])
def test_flash_attention_int8_kv(s, t, d, blocks, key):
    """Dequant-fused flash attention: int8 k/v + per-token scales flow
    through the online softmax identically to pre-dequantized fp k/v."""
    from repro import quant as Q
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (3, s, d))
    tk = Q.quantize_kv(jax.random.normal(k2, (3, t, d)))
    tv = Q.quantize_kv(jax.random.normal(k3, (3, t, d)))
    causal = s == t
    out = ops.attention(q, tk.q, tv.q, k_scale=tk.scale, v_scale=tv.scale,
                        causal=causal, bq=blocks[0], bk=blocks[1])
    ref = ops.attention_ref(q, tk.q, tv.q, k_scale=tk.scale,
                            v_scale=tv.scale, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("b,h,g,d,ps,m", [(3, 8, 2, 16, 8, 4),
                                          (2, 4, 4, 32, 16, 2)])
def test_paged_attention_int8_kv(b, h, g, d, ps, m, key):
    """Paged decode kernel over int8 page pools: the per-token scale
    pages ride the same page-table indirection as k/v and dequantize in
    VMEM; output matches the gather-dequant-attend oracle."""
    from repro import quant as Q
    ks = jax.random.split(key, 3)
    n_pages = b * m + 2
    q = jax.random.normal(ks[0], (b, h, d))
    tk = Q.quantize_kv(jax.random.normal(ks[1], (n_pages, ps, g, d)))
    tv = Q.quantize_kv(jax.random.normal(ks[2], (n_pages, ps, g, d)))
    rng = np.random.RandomState(0)
    table = np.stack([rng.permutation(np.arange(1, n_pages))[:m]
                      for _ in range(b)])
    lengths = rng.randint(1, m * ps + 1, size=b).astype(np.int32)
    lengths[-1] = m * ps
    out = ops.paged_attn(q, tk.q, tv.q, jnp.asarray(table),
                         jnp.asarray(lengths),
                         k_scale=tk.scale, v_scale=tv.scale)
    ref = ops.paged_attn_ref(q, tk.q, tv.q, jnp.asarray(table),
                             jnp.asarray(lengths), tk.scale, tv.scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_model_attention_matches_kernel(key):
    """models/layers.attention (jnp path) == flash kernel on plain causal."""
    from repro.models import layers as L
    k1, k2, k3 = jax.random.split(key, 3)
    b, s, h, d = 2, 128, 4, 32
    q = jax.random.normal(k1, (b, s, h, d))
    k = jax.random.normal(k2, (b, s, h, d))
    v = jax.random.normal(k3, (b, s, h, d))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    out_model = L.attention(q, k, v, pos, pos, causal=True, q_block=64)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    out_kernel = ops.attention(qf, kf, vf, causal=True, bq=64, bk=64)
    out_kernel = out_kernel.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out_model, out_kernel, rtol=2e-4, atol=2e-4)
