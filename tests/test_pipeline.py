"""GPipe pipeline-parallel baseline (ISLPED16 comparison): 2-stage pipeline
must match the sequential forward exactly and be differentiable.

Runs via testing.mesh_fixtures.run_in_subprocess because the 8-device host
platform must be forced before jax initialises (the main test process
keeps 1 device).
"""
import pytest

from repro.testing.mesh_fixtures import run_in_subprocess

_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch
from repro.launch.mesh import make_mesh
from repro.models import lm as LM
from repro.models import registry as REG
from repro.runtime.pipeline import pipelined_forward, pipelined_loss
arch = get_arch("qwen1.5-0.5b").reduced()
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
params = REG.init_params(arch, jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, arch.vocab_size)
with mesh:
    pp = jax.jit(lambda p, t: pipelined_forward(arch, p, t, mesh,
                                                num_microbatches=4))(params, toks)
ref, _ = LM.forward(arch, params, toks)
np.testing.assert_allclose(np.asarray(pp), np.asarray(ref), rtol=2e-4, atol=2e-4)
with mesh:
    g = jax.jit(jax.grad(lambda p: pipelined_loss(arch, p, toks, toks, mesh)))(params)
assert float(jnp.abs(g["embed"]).sum()) > 0
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_two_stage_pipeline_matches_sequential():
    run_in_subprocess(_SCRIPT, devices=8, timeout=600, marker="PIPELINE_OK")
