"""Paged KV-cache subsystem tests: pool accounting (host mirror vs the
jitted pure functions), typed exhaustion + degrade-to-queueing, refcount
exactly-once lifecycle under EOS/churn, prefix-registry copy-on-write
divergence, and paged-vs-dense bit-exact engine streams.

Full decode equivalence vs the frozen reference (meshed, all scenarios)
lives in the slow conformance suite; this file is the fast tier-1 cover
for ``repro.serving.pages``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.configs.base import ShapeConfig
from repro.models import registry as REG
from repro.serving import pages as PG
from repro.serving import RequestValidationError, ServeConfig
from repro.serving.engine import Request
from repro.serving.pages import (PagePool, PagePoolExhausted, PrefixRegistry,
                                 make_pool_state, pool_alloc, pool_free_count,
                                 pool_release, pool_retain)

ARCH = repro.get_arch("qwen1.5-0.5b").reduced()
DECODE_SHAPE = ShapeConfig("d", 32, 4, "decode")


@pytest.fixture(scope="module")
def params():
    return REG.init_params(ARCH, jax.random.PRNGKey(0), jnp.float32)


def _serve(params, *, slots=4, max_len=32, eos_id=None, **kw):
    plan = repro.plan(ARCH, DECODE_SHAPE)
    cfg = ServeConfig.from_kwargs(slots=slots, max_len=max_len,
                                  eos_id=eos_id, **kw)
    return plan.compile().serve(params, config=cfg)


def _drain(eng, prompts, budgets, max_steps=200):
    for i, p in enumerate(prompts):
        b = budgets[i] if isinstance(budgets, (list, tuple)) else budgets
        eng.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=b))
    eng.run_until_drained(max_steps=max_steps)
    return {r.rid: r.out_tokens for r in eng.completed}


# ------------------------------ PagePool -------------------------------

def test_pool_alloc_release_accounting():
    pool = PagePool(8, page_size=4)
    assert pool.free_pages == 7 and pool.used_pages == 0  # page 0 reserved
    a = pool.alloc(3)
    assert a == [1, 2, 3]  # lowest-free-first
    pool.release([2])
    assert pool.alloc(1) == [2]  # freed page is reused first
    pool.retain([1])
    pool.release([1])
    assert pool.used_pages == 3  # retained page survives one release
    pool.release([1, 2, 3])
    assert pool.used_pages == 0 and pool.free_pages == 7


def test_pool_double_free_raises():
    pool = PagePool(4, page_size=4)
    (p,) = pool.alloc(1)
    pool.release([p])
    with pytest.raises(AssertionError, match="double free"):
        pool.release([p])


def test_pool_exhausted_is_typed_and_names_waiters():
    pool = PagePool(4, page_size=4)  # 3 usable pages
    with pytest.raises(PagePoolExhausted) as ei:
        pool.alloc(5, waiting=[11, 12])
    assert ei.value.waiting == [11, 12]
    assert "waiting rids=[11, 12]" in str(ei.value)
    assert pool.free_pages == 3  # failed alloc takes nothing


def test_host_pool_matches_jitted_pool_state():
    """The scheduler's host mirror and the device pure functions implement
    the same policy: replay a random alloc/retain/release trace on both
    and compare refcounts after every op."""
    rng = np.random.RandomState(3)
    pool = PagePool(16, page_size=4)
    st = make_pool_state(16)
    live = []
    for _ in range(60):
        op = rng.randint(3)
        if op == 0 and pool.free_pages:
            n = int(rng.randint(1, pool.free_pages + 1))
            got = pool.alloc(n)
            st, pages = pool_alloc(st, n)
            assert np.asarray(pages).tolist() == got
            live += got
        elif op == 1 and live:
            pick = [live[i] for i in rng.choice(len(live),
                                                rng.randint(1, 4))]
            pool.retain(pick)
            st = pool_retain(st, jnp.asarray(pick, jnp.int32))
            live += pick
        elif op == 2 and live:
            i = int(rng.randint(len(live)))
            p = live.pop(i)
            pool.release([p])
            st = pool_release(st, jnp.asarray([p], jnp.int32))
        np.testing.assert_array_equal(np.asarray(st.refcount), pool.refcount)
        assert int(pool_free_count(st)) == pool.free_pages


# --------------------------- sizing helpers ----------------------------

def test_page_sizing_helpers():
    assert PG.num_pages_per_slot(32, 8) == 4
    assert PG.num_pages_per_slot(33, 8) == 5
    assert PG.default_kv_pages(4, 32, 8) == 17  # 4*4 + null page


# -------------------- engine: paged == dense streams -------------------

def test_paged_engine_matches_dense_streams(params):
    """Bit-exact greedy streams dense vs paged (same params, prompts and
    budgets) including a mid-stream slot re-admission (8 requests, 3
    slots) — the tier-1 cut of the conformance property."""
    rng = np.random.RandomState(0)
    prompts = [rng.randint(1, 100, size=s).astype(np.int32)
               for s in (4, 7, 11, 6, 9, 5, 8, 12)]
    dense = _drain(_serve(params, slots=3), prompts, 5)
    eng = _serve(params, slots=3, paged=True, page_size=8)
    paged = _drain(eng, prompts, 5)
    assert dense == paged and len(paged) == 8
    # every retired slot returned its pages (registry holds only pins)
    sched = eng.scheduler
    sched.registry.clear()
    assert sched.pool.used_pages == 0


def test_paged_submit_rejects_over_budget_prompt(params):
    eng = _serve(params, slots=2, paged=True, page_size=8)
    with pytest.raises(RequestValidationError, match="max_new_tokens"):
        eng.submit(Request(rid=0,
                           prompt=np.arange(1, 30, dtype=np.int32),
                           max_new_tokens=8))  # 29 + 8 > max_len 32


# ---------------- exhaustion degrades to queueing ----------------------

def test_exhaustion_degrades_to_queueing_then_drains(params):
    """kv_pages sized for two in-flight requests: a four-request burst
    admits two, re-queues two on ``PagePoolExhausted``, and still drains
    completely (with dense-identical streams) as retiring slots release
    their pages."""
    rng = np.random.RandomState(1)
    prompts = [rng.randint(1, 100, size=6).astype(np.int32)
               for _ in range(4)]
    # need ceil((6+4)/8) = 2 pages per request → 5 = null + 2 requests
    eng = _serve(params, slots=4, paged=True, page_size=8, kv_pages=5,
                 prefix_cache=False)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=4))
    eng.step()
    assert sum(r is not None for r in eng.active.values()) == 2
    assert len(eng.scheduler.queue) == 2  # re-queued, not dropped
    eng.run_until_drained(max_steps=200)
    got = {r.rid: r.out_tokens for r in eng.completed}
    want = _drain(_serve(params, slots=4), [p.copy() for p in prompts], 4)
    assert got == want and len(got) == 4
    assert eng.scheduler.pool.used_pages == 0


# ------------- refcounts reach zero exactly once (EOS + churn) ---------

def test_refcount_zero_exactly_once_under_eos_and_churn(params):
    """EOS straight out of prefill + slot churn: every page refcount
    returns to zero exactly once — a double release raises inside
    ``PagePool.release`` and would fail the drain."""
    rng = np.random.RandomState(2)
    prompts = [rng.randint(1, 100, size=s).astype(np.int32)
               for s in (5, 8, 6, 9, 4, 7)]
    probe = _drain(_serve(params, slots=2, paged=True, page_size=8,
                          prefix_cache=False), prompts, 4)
    eos = probe[0][0]  # rid 0 finishes with zero emitted tokens
    eng = _serve(params, slots=2, eos_id=int(eos), paged=True, page_size=8,
                 prefix_cache=False)
    got = _drain(eng, prompts, 4)
    assert len(got) == 6 and got[0] == []
    assert eng.scheduler.pool.used_pages == 0
    assert eng.scheduler.pool.free_pages == eng.scheduler.pool.kv_pages - 1


# ---------------- prefix sharing + copy-on-write -----------------------

def test_prefix_reuse_cow_divergence(params):
    """Sharers joining after the owner registered a mid-page prefix (17
    tokens, page_size 8 → two full pages + CoW frontier) produce streams
    identical to the dense engine, with registry hits recorded and pages
    actually aliased (pool usage below the unshared requirement)."""
    rng = np.random.RandomState(4)
    pre = rng.randint(1, 100, size=17).astype(np.int32)
    tails = [rng.randint(1, 100, size=s).astype(np.int32) for s in (4, 6, 3)]
    prompts = [np.concatenate([pre, t]) for t in tails]

    def run(paged):
        kw = dict(paged=True, page_size=8) if paged else {}
        eng = _serve(params, slots=4, max_len=32, **kw)
        eng.submit(Request(rid=0, prompt=prompts[0].copy(),
                           max_new_tokens=5))
        eng.step()  # owner admitted; its prefix pages registered
        for i, p in enumerate(prompts[1:], start=1):
            eng.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=5))
        eng.run_until_drained(max_steps=200)
        return {r.rid: r.out_tokens for r in eng.completed}, eng

    want, _ = run(paged=False)
    got, eng = run(paged=True)
    assert got == want and len(got) == 3
    reg = eng.scheduler.registry
    assert reg.hits >= 2  # both sharers matched the owner's prefix
    assert eng.prefill_stats()["prefix_hit_rate"] > 0
    # after drain only registry pins remain; clearing them empties the pool
    reg.clear()
    assert eng.scheduler.pool.used_pages == 0


def test_registry_lookup_and_evict():
    pool = PagePool(32, page_size=4)
    reg = PrefixRegistry(pool)
    toks = np.arange(1, 12, dtype=np.int32)  # 11 tokens: 2 full + tail 3
    pages = pool.alloc(3)
    reg.register(toks, pages)
    # full-page boundary match (8 tokens) for a diverging continuation
    other = np.concatenate([toks[:8], np.asarray([99, 98], np.int32)])
    m, chain, frontier = reg.lookup(other)
    assert (m, list(chain), frontier) == (8, pages[:2], None)
    # token-granular tail match → frontier page offered for CoW
    longer = np.concatenate([toks, np.asarray([99], np.int32)])
    m, chain, frontier = reg.lookup(longer)
    assert (m, list(chain), frontier) == (11, pages[:2], pages[2])
    # a prompt equal to the registered prefix must NOT fully match
    # (at least one token must go through prefill)
    m, _, _ = reg.lookup(toks)
    assert m == 8
    assert reg.hits == 3 and reg.misses == 0
    # owner releases its chain; only registry pins remain (nested
    # prefixes pin each other: full[4], full[8] and the tail all hold
    # page 1) — eviction must still free everything
    pool.release(pages)
    freed = reg.evict_unreferenced()
    assert freed == 6  # full[4]:1 + full[8]:2 + tail:(2 chain + frontier)
    assert pool.used_pages == 0
    assert not reg.full and not reg.tail
