"""repro.bench subsystem: schema round-trip, regression gate, calibration.

Everything here is deterministic — synthetic measurements and closed-form
model evaluations — so these tests gate the bench *machinery*, not the
speed of the host they happen to run on.
"""
import json
import statistics

import numpy as np
import pytest

from repro.bench.registry import Scenario
from repro.bench.runner import compare
from repro.bench.schema import SCHEMA_VERSION, BenchResult, load_results
from repro.bench.timers import percentile, stats_from_samples
from repro.core.layer_model import ConvLayer
from repro.core.perf_model import Calibration, TilePipelineModel


def _result(name="demo", **metrics) -> BenchResult:
    return BenchResult(name=name, device_kind="cpu",
                       config={"size": 128, "dtype": "float32"},
                       metrics=metrics or {"p50_ms": 1.0},
                       model_predicted_s=0.9e-3, measured_s=1.0e-3,
                       extras={"rows": [{"a": 1}]})


# ----------------------------- schema ---------------------------------

def test_schema_roundtrip(tmp_path):
    r = _result(p50_ms=1.25, tokens_per_s=42.0)
    # numpy scalars are coerced to native floats at construction, so the
    # JSON never contains stringified metrics the gate would choke on
    assert type(_result(p50_ms=np.float32(1.5)).metrics["p50_ms"]) is float
    path = r.write(tmp_path)
    assert path.name == "BENCH_demo.json"
    back = BenchResult.read(path)
    assert back == r
    # derived fields serialised for human readers but not round-trip state
    raw = json.loads(path.read_text())
    assert raw["schema_version"] == SCHEMA_VERSION
    assert raw["model_rel_error"] == pytest.approx(abs(0.9e-3 - 1e-3) / 1e-3)
    assert back.config_hash == r.config_hash != ""


def test_schema_version_mismatch_rejected(tmp_path):
    r = _result()
    d = r.to_dict()
    d["schema_version"] = SCHEMA_VERSION + 1
    p = tmp_path / "BENCH_demo.json"
    p.write_text(json.dumps(d))
    with pytest.raises(ValueError, match="schema_version"):
        BenchResult.read(p)


def test_load_results_directory(tmp_path):
    _result("a").write(tmp_path)
    _result("b").write(tmp_path)
    got = load_results(tmp_path)
    assert sorted(got) == ["a", "b"]


def test_config_hash_stable_and_sensitive():
    a, b = _result(), _result()
    assert a.config_hash == b.config_hash
    c = _result()
    c.config = {**c.config, "size": 256}
    assert BenchResult(name="demo", device_kind="cpu", config=c.config,
                       metrics={}).config_hash != a.config_hash


# ------------------------- timers -------------------------------------

def test_percentiles_and_stats():
    s = stats_from_samples([0.001, 0.002, 0.003, 0.004, 0.010])
    assert s.p50_ms == pytest.approx(3.0)
    assert s.min_ms == pytest.approx(1.0)
    assert s.p95_ms > s.p50_ms
    assert percentile([], 50) == 0.0


# ------------------------- regression gate -----------------------------

def _specs(tolerance=0.15):
    return {"demo": Scenario(name="demo", fn=lambda: None,
                             gate_metric="p50_ms", tolerance=tolerance)}


def test_compare_flags_synthetic_regression(tmp_path):
    _result(p50_ms=1.0).write(tmp_path)
    current = {"demo": _result(p50_ms=1.30)}  # +30% > 15% budget
    cmp = compare(current, tmp_path, scenarios=_specs())
    assert len(cmp.regressions) == 1 and not cmp.ok
    r = cmp.regressions[0]
    assert r.scenario == "demo" and r.metric == "p50_ms"
    assert r.growth == pytest.approx(0.30)
    assert "+30.0%" in r.describe()


def test_compare_within_budget_passes(tmp_path):
    _result(p50_ms=1.0).write(tmp_path)
    cmp = compare({"demo": _result(p50_ms=1.1)}, tmp_path, scenarios=_specs())
    assert cmp.regressions == [] and cmp.gated == 1 and cmp.ok
    # improvements never trip the gate
    cmp = compare({"demo": _result(p50_ms=0.5)}, tmp_path, scenarios=_specs())
    assert cmp.regressions == [] and cmp.ok


def test_compare_skips_changed_config_and_missing(tmp_path):
    _result(p50_ms=1.0).write(tmp_path)
    changed = _result(p50_ms=5.0)
    changed.config = {**changed.config, "size": 999}
    changed.config_hash = ""
    changed.__post_init__()  # re-derive hash for the new config
    cmp = compare({"demo": changed, "unknown": _result("unknown")},
                  tmp_path, scenarios=_specs())
    assert cmp.regressions == []
    assert any("config changed" in n for n in cmp.notes)
    assert any("unknown" in n for n in cmp.notes)
    # nothing was actually gated -> the comparison must NOT read as a pass
    assert cmp.gated == 0 and not cmp.ok


def test_cli_compare_exits_nonzero_on_regression(tmp_path):
    """End-to-end: a doctored baseline must fail `--compare` with rc 1."""
    from repro.bench.cli import main
    out1 = tmp_path / "baseline"
    scen = "collectives_hlo_parse"  # deterministic gate metric (wire_gb)
    assert main(["--quick", "--filter", scen, "--out", str(out1)]) == 0
    f = out1 / f"BENCH_{scen}.json"
    rec = json.loads(f.read_text())
    rec["metrics"]["wire_gb"] *= 0.5  # pretend main was 2x better
    f.write_text(json.dumps(rec))
    rc = main(["--quick", "--filter", scen, "--out", str(tmp_path / "cur"),
               "--compare", str(out1)])
    assert rc == 1
    # honest baseline passes
    assert main(["--quick", "--filter", scen, "--out", str(tmp_path / "c2"),
                 "--compare", str(tmp_path / "cur")]) == 0
    # a gate that compares nothing (missing baseline dir) fails closed
    assert main(["--quick", "--filter", scen, "--out", str(tmp_path / "c3"),
                 "--compare", str(tmp_path / "nonexistent")]) == 1


def test_compare_broken_baseline_record_fails_closed(tmp_path):
    """Structurally broken baseline JSON (missing required fields) must take
    the 'baseline unreadable' path, not crash — and must not read as a pass."""
    (tmp_path / "BENCH_demo.json").write_text(
        json.dumps({"schema_version": SCHEMA_VERSION, "metrics": {}}))
    cmp = compare({"demo": _result(p50_ms=1.0)}, tmp_path, scenarios=_specs())
    assert any("unreadable" in n for n in cmp.notes)
    assert cmp.gated == 0 and cmp.gateable == 1 and not cmp.ok


def test_cli_report_only_filter_does_not_trip_gate(tmp_path):
    """--filter selecting only report-only scenarios is not a gate failure."""
    from repro.bench.cli import main
    out1 = tmp_path / "a"
    assert main(["--quick", "--filter", "xfer_weight_gather",
                 "--out", str(out1)]) == 0
    assert main(["--quick", "--filter", "xfer_weight_gather",
                 "--out", str(tmp_path / "b"), "--compare", str(out1)]) == 0


def test_runner_rejects_result_name_mismatch(tmp_path):
    """A scenario whose BenchResult.name drifts from its registered name
    would silently fall out of the gate — the runner flags it as an error."""
    from repro.bench.runner import run
    bad = Scenario(name="good_name", fn=lambda: _result("other_name"))
    report = run([bad], out_dir=tmp_path, verbose=False)
    assert "good_name" in report.errors
    assert "other_name" in report.errors["good_name"]
    assert not report.results and not list(tmp_path.glob("BENCH_*.json"))


# ------------------------- calibration ---------------------------------

def _toy_layers():
    shapes = [(256, 256, 256), (512, 512, 512), (1024, 128, 256),
              (2048, 128, 128), (384, 768, 384)]
    return [ConvLayer(f"toy_{r}x{n}x{m}", B=1, M=m, N=n, R=r, C=1,
                      bytes_per_elem=4, tokens_folded=True)
            for r, n, m in shapes]


def test_calibration_recovers_known_constants():
    """Fitting against measurements generated by a known-calibration model
    must drive per-layer error far below the uncalibrated model's."""
    from repro.bench.calibrate import (Sample, fit_calibration,
                                       per_layer_errors, predict_seconds)
    model = TilePipelineModel()
    true = Calibration(flops_scale=2e-3, hbm_scale=0.25, overhead_s=2e-4)
    oracle = model.calibrated(true)
    samples = [
        Sample(layer=l,
               measured_s=predict_seconds(oracle, Sample(layer=l, measured_s=1.0)))
        for l in _toy_layers()]
    before = per_layer_errors(model, samples)
    fitted = fit_calibration(samples, model)
    after = per_layer_errors(model.calibrated(fitted), samples)
    assert statistics.median(before) > 0.5  # datasheet roofs are way off
    assert statistics.median(after) < 0.05
    assert max(after) < 0.25
    assert statistics.median(after) < statistics.median(before)


def test_calibration_identity_and_serialisation():
    c = Calibration()
    assert c.identity
    d = Calibration(flops_scale=0.5, overhead_s=1e-4)
    assert not d.identity
    assert Calibration.from_dict(d.as_dict()) == d
    # unknown keys (newer writers) are ignored, not fatal
    assert Calibration.from_dict({**d.as_dict(), "future": 1.0}) == d


def test_calibrated_model_scales_seconds():
    from repro.bench.calibrate import Sample, predict_seconds
    layer = _toy_layers()[0]
    base = TilePipelineModel()
    s = Sample(layer=layer, measured_s=1.0)
    t0 = predict_seconds(base, s)
    slow = base.calibrated(Calibration(flops_scale=0.5, hbm_scale=0.5))
    assert predict_seconds(slow, s) == pytest.approx(2 * t0, rel=1e-6)
    bumped = base.calibrated(Calibration(overhead_s=0.1))
    assert predict_seconds(bumped, s) == pytest.approx(t0 + 0.1, rel=1e-6)


# ------------------------- engine step hooks ---------------------------

def test_engine_step_timing_hooks(key):
    import repro
    from repro.configs.base import ShapeConfig
    from repro.serving import ServeConfig
    from repro.serving.engine import Request

    arch = repro.get_arch("qwen1.5-0.5b").reduced()
    seen = []
    plan = repro.plan(arch, ShapeConfig("hooks", 32, 2, "decode"))
    engine = plan.compile().serve(config=ServeConfig(slots=2, max_len=32),
                                  on_step=seen.append)
    engine.submit(Request(rid=0, prompt=np.arange(1, 5, dtype=np.int32),
                          max_new_tokens=3))
    engine.run_until_drained(max_steps=10)
    stats = engine.step_stats()
    assert stats["steps"] == len(engine.step_times) > 0
    assert stats["tokens"] == 3.0
    assert stats["tokens_per_s"] > 0
    assert stats["step_p95_ms"] >= stats["step_p50_ms"] > 0
    assert [s["step"] for s in seen] == list(range(len(engine.step_times)))
    assert all(s["wall_s"] > 0 for s in seen)
    # lookahead dispatch: every emitted token is accounted exactly once
    assert sum(s["tokens"] for s in seen) == 3
    engine.reset_step_stats()
    assert len(engine.step_times) == 0 and engine.step_stats()["steps"] == 0


def test_engine_prefill_timing_hooks(key):
    """The admission path records per-request wall time (bucketed prefill
    dispatch + splice) — the probe the prefill_latency scenario gates on."""
    import repro
    from repro.configs.base import ShapeConfig
    from repro.serving import ServeConfig
    from repro.serving.engine import Request

    arch = repro.get_arch("qwen1.5-0.5b").reduced()
    plan = repro.plan(arch, ShapeConfig("hooks_p", 32, 2, "decode"))
    engine = plan.compile().serve(config=ServeConfig(slots=2, max_len=32))
    for i, n in enumerate((4, 6, 5)):
        engine.submit(Request(rid=i, prompt=np.arange(1, n + 1, dtype=np.int32),
                              max_new_tokens=1))
    engine.run_until_drained(max_steps=20)
    stats = engine.prefill_stats()
    assert stats["prefills"] == 3.0
    assert stats["prompt_tokens"] == 15.0
    assert stats["prefill_p95_ms"] >= stats["prefill_p50_ms"] > 0
    assert stats["prefill_tokens_per_s"] > 0
    engine.reset_step_stats()
    assert engine.prefill_stats()["prefills"] == 0.0


# ------------------------- bench-trend csv -----------------------------

def test_bench_trend_appends_long_format(tmp_path):
    import csv
    import os
    import sys
    scripts = os.path.join(os.path.dirname(os.path.dirname(__file__)), "scripts")
    sys.path.insert(0, scripts)
    try:
        import bench_trend
    finally:
        sys.path.remove(scripts)
    results = tmp_path / "out"
    results.mkdir()
    _result("a", p50_ms=1.0, tokens_per_s=9.0).write(results)
    _result("b", wire_gb=2.0).write(results)
    trend = tmp_path / "bench-trend.csv"
    n1 = bench_trend.append_trend(results, trend, run_id="1", sha="aaa")
    n2 = bench_trend.append_trend(results, trend, run_id="2", sha="bbb")
    assert n1 == n2 == 5  # 3 metrics + 2 model_rel_error rows per run
    rows = list(csv.reader(trend.open()))
    assert rows[0] == bench_trend.HEADER
    assert len(rows) == 1 + n1 + n2  # header written exactly once
    runs = {r[1] for r in rows[1:]}
    assert runs == {"1", "2"}
    metrics = {(r[3], r[7]) for r in rows[1:]}
    assert ("a", "tokens_per_s") in metrics and ("b", "wire_gb") in metrics
    assert ("a", "model_rel_error") in metrics
    # mixed-schema protection: a foreign header is refused
    alien = tmp_path / "alien.csv"
    alien.write_text("when,who\n1,2\n")
    with pytest.raises(SystemExit, match="refusing"):
        bench_trend.append_trend(results, alien, run_id="3", sha="ccc")
    # CLI: empty results dir is a no-op success (first CI run)
    assert bench_trend.main(["--results", str(tmp_path / "nothing"),
                             "--csv", str(trend)]) == 0


def test_bench_trend_plot_renders_gate_metric_sparklines(tmp_path):
    """--plot renders one SVG panel per (scenario × gate metric) series
    accumulated in the trend CSV — the ROADMAP trend-plotting item."""
    import os
    import sys
    scripts = os.path.join(os.path.dirname(os.path.dirname(__file__)), "scripts")
    sys.path.insert(0, scripts)
    try:
        import bench_trend
    finally:
        sys.path.remove(scripts)
    trend = tmp_path / "trend.csv"
    import csv as _csv
    with trend.open("w", newline="") as f:
        w = _csv.writer(f)
        w.writerow(bench_trend.HEADER)
        for run in range(4):
            w.writerow([f"t{run}", run, "s", "serve_decode", "cpu", "j", "h",
                        "step_p50_ms", 1.5 - 0.1 * run])
            w.writerow([f"t{run}", run, "s", "serve_decode", "cpu", "j", "h",
                        "tokens_per_s", 500 + run])  # not the gate metric
    svg_path = tmp_path / "trend.svg"
    bench_trend.plot_trend(trend, svg_path)
    svg = svg_path.read_text()
    assert svg.startswith("<svg") and "polyline" in svg
    assert "serve_decode" in svg and "step_p50_ms" in svg
    assert "tokens_per_s" not in svg  # gate metrics only
    # empty CSV: no-op, no file
    empty = tmp_path / "empty.csv"
    empty.write_text(",".join(bench_trend.HEADER) + "\n")
    assert bench_trend.plot_trend(empty, tmp_path / "none.svg") == 0
    assert not (tmp_path / "none.svg").exists()
    # CLI end-to-end: append + plot in one invocation
    results = tmp_path / "out"
    results.mkdir()
    _result("serve_decode", step_p50_ms=1.0).write(results)
    assert bench_trend.main(["--results", str(results), "--csv",
                             str(tmp_path / "t2.csv"), "--plot",
                             str(tmp_path / "t2.svg")]) == 0
    assert (tmp_path / "t2.svg").exists()


# ------------------------- registry wiring -----------------------------

def test_registry_quick_set_covers_required_scenarios():
    from repro.bench.registry import select
    quick = {s.name for s in select(quick_only=True)}
    # the CI gate must include kernels, transfer, planner, e2e serving and
    # the calibration report (ISSUE 2 acceptance criteria), plus the
    # train-step / prefill / multi-device decode coverage (ISSUE 3)
    assert {"kernel_xfer_matmul", "kernel_flash_attention",
            "collectives_hlo_parse", "planner_dse", "serve_decode",
            "calibration", "train_step", "prefill_latency",
            "serve_decode_multidev", "serve_throughput"} <= quick
    full = {s.name for s in select(quick_only=False)}
    assert {"paper_tables", "tpu_xfer"} <= full
    assert quick <= full


def test_filter_glob():
    from repro.bench.registry import select
    names = {s.name for s in select(quick_only=True, pattern="kernel_*")}
    assert names and all(n.startswith("kernel_") for n in names)
