"""Disaggregated prefill/decode serving — fast tier-1 smoke.

Runs in a fresh 2-fake-device subprocess (the forced host device count
must precede backend init): the plan's data axis splits 1+1 into a
prefill slice and a decode slice, one burst of requests runs end-to-end
with cross-mesh KV streaming, and the greedy streams must be bit-exact
against the fused engine on the full mesh. Also covers the structural
``ExecutionPlan.disaggregate`` contract (disjoint device slices,
inherited sharding structure, role-validation errors) and the
HLO-reconciled transfer accounting (``verify_xfer``).

Full scenario coverage (churn/eos/paged, 8 devices) lives in the slow
conformance suite (tests/test_conformance.py ``--disagg`` cells).
"""
import pytest

from repro.testing.mesh_fixtures import run_in_subprocess

_SMOKE_SCRIPT = r"""
import numpy as np
import jax, jax.numpy as jnp
import repro
from repro.configs.base import ShapeConfig
from repro.models import registry as REG
from repro.serving import DisaggConfig, Request, ServeConfig, ServingEngine
from repro.serving.disagg import DisaggServingEngine

arch = repro.get_arch("qwen1.5-0.5b").reduced()
shape = ShapeConfig("d", 32, 2, "decode")
plan = repro.plan(arch, shape, (("data", 2), ("model", 1)))

# --- structural contract -------------------------------------------------
roles = plan.disaggregate(prefill_data=1)
assert roles.prefill.role == "prefill" and roles.decode.role == "decode"
pre_ids = {d.id for d in np.asarray(roles.prefill.devices,
                                    dtype=object).ravel()}
dec_ids = {d.id for d in np.asarray(roles.decode.devices,
                                    dtype=object).ravel()}
assert pre_ids and dec_ids and not (pre_ids & dec_ids)
assert pre_ids | dec_ids == {d.id for d in jax.devices()}
# sub-plans inherit the fused model-parallel structure
sp = plan.sharding_plan
for sub in (roles.prefill, roles.decode):
    ssp = sub.sharding_plan
    assert (ssp.tp_axes, ssp.seq_axes) == (sp.tp_axes, sp.seq_axes)
try:
    plan.disaggregate(prefill_data=2)  # would leave no decode rows
except ValueError:
    pass
else:
    raise AssertionError("prefill_data == axis size must be rejected")

# --- end-to-end: disagg streams == fused streams -------------------------
params = REG.init_params(arch, jax.random.PRNGKey(0), jnp.float32)
rng = np.random.RandomState(0)
prompts = [rng.randint(1, 100, size=s).astype(np.int32)
           for s in (5, 9, 6, 11)]

def drain(eng):
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=4))
    eng.run_until_drained(max_steps=400)
    return {r.rid: list(r.out_tokens) for r in eng.completed}

exe = plan.compile()
cfg = ServeConfig(slots=2, max_len=32, disagg=DisaggConfig(prefill_data=1))
eng = exe.serve(params, config=cfg)  # serve() routes to the disagg engine
assert isinstance(eng, DisaggServingEngine)
got = drain(eng)
want = drain(exe.serve(params, config=ServeConfig(slots=2, max_len=32)))
assert got == want and len(got) == len(prompts), (got, want)

# --- transfer accounting -------------------------------------------------
stats = eng.xfer_stats()
assert stats["kv_xfer_bytes"] > 0 and stats["kv_xfer_dispatches"] > 0, stats
assert stats["kv_xfer_inflight"] == 0, stats  # fully drained
recon = eng.verify_xfer()  # raises if compiled HLO bytes leave the band
assert recon, recon
print("DISAGG_SMOKE_OK", stats)
"""


def test_disagg_smoke_two_devices():
    run_in_subprocess(_SMOKE_SCRIPT, devices=2, timeout=900,
                      marker="DISAGG_SMOKE_OK")
