import importlib.util
import os
import sys

# The image has no `hypothesis` and pip installs are off-limits: fall back
# to the vendored shim in tests/_vendor (real library wins when present).
if importlib.util.find_spec("hypothesis") is None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_vendor"))

import jax
import numpy as np
import pytest

# Tests run on the real device set (1 CPU device) — the 512-device forcing
# happens ONLY inside launch/dryrun.py (its own process).

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
