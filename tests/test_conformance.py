"""Multi-device conformance: plan invariance + XFER accounting (slow).

Each case runs in a fresh 8-fake-device subprocess (the forced host
device count must precede backend init) via
``repro.testing.mesh_fixtures.run_in_subprocess``. The differential suite
asserts the paper's implicit contract — every candidate partition the
planner proposes computes the same function as the single-device golden
run — for three arch families across three mesh shapes each.
"""
import pytest

from repro.testing.differential import OK_MARKER
from repro.testing.mesh_fixtures import MESH_SHAPES, run_in_subprocess
from repro.testing.serving_equiv import OK_MARKER as SERVING_OK_MARKER

# arch family coverage: dense / MoE (EP + router) / hybrid-recurrent.
# Mesh coverage per arch: dp-only, mixed dp×tp, tp-only or 3-axis.
CONFORMANCE_CELLS = {
    "qwen1.5-0.5b": "dp8,dp4_tp2,tp8",
    "deepseek-moe-16b": "dp4_tp2,tp8,pod2_dp2_tp2",
    "recurrentgemma-2b": "dp8,dp2_tp4,pod2_dp2_tp2",
}


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", sorted(CONFORMANCE_CELLS))
def test_plan_invariance_forward_decode_train(arch_id):
    meshes = CONFORMANCE_CELLS[arch_id]
    for m in meshes.split(","):
        assert m in MESH_SHAPES
    script = (
        "from repro.testing import differential\n"
        f"raise SystemExit(differential.main(['--arch', '{arch_id}', "
        f"'--meshes', '{meshes}']))\n")
    run_in_subprocess(script, devices=8, timeout=1800, marker=OK_MARKER)


# One representative mesh per arch family: the equivalence property is
# engine-vs-engine under a fixed plan (plan-space invariance is the
# differential suite's job above). Scenarios cover EOS-at-prefill and
# mid-stream slot re-admission (churn); see repro.testing.serving_equiv.
# Since the all-architecture admission PR this spans every family the
# runtime serves: dense, MoE, hybrid-recurrent, pure-recurrent (ssm) and
# enc-dec (per-slot enc_out + masked cross-attention vs the golden
# unbatched reference), all through batched bucketed prefill.
SERVING_EQUIV_CELLS = {
    "qwen1.5-0.5b": "dp4_tp2",
    "deepseek-moe-16b": "tp8",
    "recurrentgemma-2b": "dp2_tp4",
    "xlstm-350m": "dp4_tp2",
    "seamless-m4t-medium": "dp4_tp2",
}


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", sorted(SERVING_EQUIV_CELLS))
def test_decode_equivalence_new_engine_vs_reference(arch_id):
    """Bit-exact greedy token streams: device-resident engine (bucketed
    prefill, donated state, lookahead dispatch) vs the frozen reference
    engine, on an 8-fake-device mesh."""
    mesh = SERVING_EQUIV_CELLS[arch_id]
    assert mesh in MESH_SHAPES
    script = (
        "from repro.testing import serving_equiv\n"
        f"raise SystemExit(serving_equiv.main(['--arch', '{arch_id}', "
        f"'--mesh', '{mesh}']))\n")
    run_in_subprocess(script, devices=8, timeout=1800,
                      marker=SERVING_OK_MARKER)


# Paged-KV serving equivalence: live engine on the page-pool cache vs the
# dense frozen reference. One dense and one MoE cell (the families
# repro.serving.pages supports beside vlm); the dense cell includes the
# ``shared`` prefix-reuse scenario (registry hit + copy-on-write page).
PAGED_EQUIV_CELLS = {
    "qwen1.5-0.5b": "dp4_tp2",
    "deepseek-moe-16b": "tp8",
}


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", sorted(PAGED_EQUIV_CELLS))
def test_decode_equivalence_paged_vs_reference(arch_id):
    """Bit-exact greedy streams with the paged KV cache: page-table
    indirection, splice-to-pages prefill and prefix-page aliasing must
    not change a single token vs the dense golden reference."""
    mesh = PAGED_EQUIV_CELLS[arch_id]
    assert mesh in MESH_SHAPES
    script = (
        "from repro.testing import serving_equiv\n"
        f"raise SystemExit(serving_equiv.main(['--arch', '{arch_id}', "
        f"'--mesh', '{mesh}', '--paged']))\n")
    run_in_subprocess(script, devices=8, timeout=1800,
                      marker=SERVING_OK_MARKER)


# Disaggregated prefill/decode: the live engine splits the 8-device grid
# into a prefill slice and a decode slice (dp4_tp2 → 2+2 data rows, tp=2
# each) and streams finished KV cross-mesh. Streams must stay bit-exact
# vs the same fused frozen reference, and every live run reconciles the
# analytic KV-transfer bytes against the compiled prefill HLO
# (``verify_xfer``). One dense cell and one paged cell (page chains are
# allocated decode-side from dense transferred rows).
DISAGG_EQUIV_CELLS = {
    "qwen1.5-0.5b": (),
    "qwen1.5-0.5b-paged": ("--paged",),
}


@pytest.mark.slow
@pytest.mark.parametrize("cell", sorted(DISAGG_EQUIV_CELLS))
def test_decode_equivalence_disagg_vs_reference(cell):
    """Bit-exact greedy streams under disaggregation: prefill on its own
    mesh slice, KV streamed to the decode slice, spliced without
    stalling the decode step — token-identical to the fused reference,
    with HLO-reconciled transfer accounting."""
    extra = list(DISAGG_EQUIV_CELLS[cell])
    script = (
        "from repro.testing import serving_equiv\n"
        f"raise SystemExit(serving_equiv.main(['--arch', 'qwen1.5-0.5b', "
        f"'--mesh', 'dp4_tp2', '--disagg'{''.join(', ' + repr(a) for a in extra)}]))\n")
    run_in_subprocess(script, devices=8, timeout=1800,
                      marker=SERVING_OK_MARKER)


# INT8 serving conformance: with QuantConfig(weights="int8", kv="int8")
# the quantized greedy streams must be bit-identical across the
# unplanned dense, planned dense, paged and disaggregated engines
# (per-token KV quantization commutes with gather/slice/pad, so engine
# plumbing may not change a single quantized token), and the prefill
# logits probe must stay within the documented QUANT_LOGITS_TOL of FP32.
@pytest.mark.slow
def test_decode_equivalence_quantized_engines():
    """INT8 weight+KV serving: engine/plan-invariant quantized streams
    plus the documented FP32 logits tolerance, on an 8-fake-device
    mesh."""
    script = (
        "from repro.testing import serving_equiv\n"
        "raise SystemExit(serving_equiv.main(['--arch', 'qwen1.5-0.5b', "
        "'--mesh', 'dp4_tp2', '--quant']))\n")
    run_in_subprocess(script, devices=8, timeout=1800,
                      marker=SERVING_OK_MARKER)


# Speculative decoding conformance: draft-k + batched verify must commit
# exactly the greedy stream the target-only engine would (acceptance only
# reorders *when* tokens commit, never *which*), for a self-draft (full
# acceptance incl. the k+1 catch-up forward), a cold draft (rollback
# path) and a paged target, with accepted_tokens_mean > 1 asserted on
# the non-adversarial drafts.
@pytest.mark.slow
def test_decode_equivalence_speculative():
    """Bit-exact greedy streams under speculative decoding (dense and
    paged target, accepting and rejecting drafts) vs the target-only
    frozen reference, on an 8-fake-device mesh."""
    script = (
        "from repro.testing import serving_equiv\n"
        "raise SystemExit(serving_equiv.main(['--arch', 'qwen1.5-0.5b', "
        "'--mesh', 'dp4_tp2', '--spec']))\n")
    run_in_subprocess(script, devices=8, timeout=1800,
                      marker=SERVING_OK_MARKER)


# Seeded stochastic sampling conformance: a (seed, rid) pair defines ONE
# temperature / top-k stream, whatever the runtime shape — lookahead 0/2,
# unplanned vs planned, paged, speculative, and a *different* execution
# plan (per-request fold_in keys + partitionable threefry make the bits
# mesh-invariant; see serving.sampler).
@pytest.mark.slow
def test_sampled_stream_invariance():
    """Seeded temperature/top-k streams are bit-identical across
    lookahead settings, engines and plans on an 8-fake-device mesh."""
    script = (
        "from repro.testing import serving_equiv\n"
        "raise SystemExit(serving_equiv.main(['--arch', 'qwen1.5-0.5b', "
        "'--mesh', 'dp4_tp2', '--sampled', '--alt-mesh', 'dp2_tp4']))\n")
    run_in_subprocess(script, devices=8, timeout=1800,
                      marker=SERVING_OK_MARKER)


# Elastic live replan conformance: a deployment that migrates between
# execution plans mid-stream (ServingEngine.migrate — resharded
# param/cache/state transfer derived from the two plans' NamedShardings)
# must serve bit-exact greedy streams vs the never-migrated reference.
# One dense same-device-count cell (dp4_tp2 → dp2_tp4) and one paged
# grow cell (dp2_tp2 → dp4_tp2: 4 → 8 devices mid-stream); each also
# runs the checkpoint save-on-mesh-A/restore-on-mesh-B differential
# (restore_sharded must be plan-invariant).
REPLAN_EQUIV_CELLS = {
    "dense-dp4_tp2-dp2_tp4": ("dp4_tp2", "dp2_tp4", ()),
    "paged-4dev-8dev": ("dp2_tp2", "dp4_tp2", ("--paged",)),
}


@pytest.mark.slow
@pytest.mark.parametrize("cell", sorted(REPLAN_EQUIV_CELLS))
def test_replan_equivalence_vs_reference(cell):
    """Bit-exact greedy streams across a live plan→plan migration
    (in-flight rows, queued requests and the page pool all cross), plus
    the cross-mesh checkpoint restore differential."""
    mesh, alt, extra = REPLAN_EQUIV_CELLS[cell]
    assert mesh in MESH_SHAPES and alt in MESH_SHAPES
    args = ["--arch", "qwen1.5-0.5b", "--mesh", mesh, "--alt-mesh", alt,
            "--replan", *extra]
    script = (
        "from repro.testing import serving_equiv\n"
        f"raise SystemExit(serving_equiv.main({list(args)!r}))\n")
    run_in_subprocess(script, devices=8, timeout=1800,
                      marker=SERVING_OK_MARKER)


@pytest.mark.slow
def test_plan_invariance_decode_paged():
    """The paged serve step is plan-invariant like the dense one: same
    step, page-pool caches + fully-mapped table, every candidate plan."""
    script = (
        "from repro.testing import differential\n"
        "raise SystemExit(differential.main(['--arch', 'qwen1.5-0.5b', "
        "'--meshes', 'dp4_tp2,tp8', '--kinds', 'decode_paged']))\n")
    run_in_subprocess(script, devices=8, timeout=1800, marker=OK_MARKER)


_XFER_ACCT_SCRIPT = r"""
import jax, jax.numpy as jnp
import repro
from repro.configs.base import ShapeConfig
from repro.core.execution_plan import ExecutionPlan
from repro.core.planner import ShardingPlan, evaluate_plan
from repro.models import registry as REG
from repro.testing import invariants as I
from repro.testing.differential import make_batch

arch = repro.get_arch("qwen1.5-0.5b").reduced()
shape = ShapeConfig("xferacct", 32, 8, "prefill")
axes = (("data", 8), ("model", 1))
sp = ShardingPlan(mesh_axes=axes, batch_axes=("data",), seq_axes=(),
                  tp_axes=("model",), xfer=True)
eplan = ExecutionPlan(arch=arch, shape=shape,
                      report=evaluate_plan(arch, shape, sp), mesh_axes=axes)
assert eplan.sharding_plan.xfer

mesh = eplan.build_mesh()
ctx = eplan.ctx(mesh)
fn = REG.build_prefill_step(arch, shape, ctx, cache_dtype=jnp.float32)
batch = make_batch(arch, shape)
params = jax.eval_shape(lambda k: REG.init_params(arch, k),
                        jax.random.PRNGKey(0))
p_sh = eplan.param_shardings(params, mesh)
b_sh = eplan.batch_shardings(batch, mesh)
with mesh:
    hlo = jax.jit(fn, in_shardings=(p_sh, b_sh)).lower(
        params, batch).compile().as_text()
out = I.check_xfer_accounting(eplan, hlo)
assert out["expected_xfer_bytes"] > 0, out
print("XFER_ACCT_OK", out)
"""


@pytest.mark.slow
def test_xfer_accounting_matches_compiled_hlo():
    """The plan's XFER weight-gather byte accounting is within the
    documented band of the all-gather wire bytes in the compiled HLO."""
    run_in_subprocess(_XFER_ACCT_SCRIPT, devices=8, timeout=900,
                      marker="XFER_ACCT_OK")


_COVERAGE_8DEV_SCRIPT = r"""
import repro
from repro.configs.base import ShapeConfig
from repro.testing import invariants as I
from repro.testing.differential import proposed_plans
from repro.testing.mesh_fixtures import mesh_shape

arch = repro.get_arch("qwen1.5-0.5b").reduced()
shape = ShapeConfig("cov", 32, 8, "train")
checked = 0
for mesh_name in ("dp8", "dp4_tp2", "tp8"):
    for eplan in proposed_plans(arch, shape, mesh_shape(mesh_name)):
        I.check_sharding_coverage(eplan)
        I.check_capacity_report(eplan)
        checked += 1
assert checked >= 6, checked
print("COVERAGE_8DEV_OK", checked)
"""


@pytest.mark.slow
def test_invariants_hold_on_8_device_meshes():
    """Structural invariants on real (non-degenerate) 8-device meshes,
    where specs actually shard instead of degrading to replication."""
    run_in_subprocess(_COVERAGE_8DEV_SCRIPT, devices=8, timeout=900,
                      marker="COVERAGE_8DEV_OK")
