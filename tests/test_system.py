"""End-to-end system tests: training loop, fault tolerance, serving,
XFER-vs-baseline numerical equivalence, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.core.planner import ShardingPlan
from repro.core.xfer import ShardingCtx
from repro.data.pipeline import TokenPipeline
from repro.launch.mesh import make_test_mesh
from repro.models import registry as REG
from repro.optim import adamw as OPT
from repro.runtime.driver import DriverConfig, StragglerMonitor, TrainDriver
from repro.serving import ServeConfig
from repro.runtime import compression as COMP

ARCH = get_arch("qwen1.5-0.5b").reduced()
SHAPE = ShapeConfig("t", 32, 4, "train")


def _setup(key, lr=1e-3):
    params = REG.init_params(ARCH, key)
    cfg = OPT.AdamWConfig(lr=lr)
    opt = OPT.adamw_init(params, cfg)
    step = jax.jit(REG.build_train_step(ARCH, cfg))
    return params, opt, step


def test_loss_decreases_over_training(key):
    params, opt, step = _setup(key)
    pipe = TokenPipeline(ARCH, SHAPE, seed=0)
    losses = []
    for _ in range(20):
        params, opt, m = step(params, opt, pipe.next_batch())
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_driver_restart_resumes_exactly(tmp_path, key):
    """Kill the step fn mid-run; the driver must restore and converge to the
    same final state as an uninterrupted run (deterministic replay)."""
    params, opt, step = _setup(key)

    # uninterrupted reference
    ck1 = Checkpointer(tmp_path / "a", keep=5, async_save=False)
    d1 = TrainDriver(step, params, opt, TokenPipeline(ARCH, SHAPE, seed=1), ck1,
                     DriverConfig(total_steps=8, checkpoint_every=2))
    d1.run()

    # interrupted run: fail once at step 5
    params2, opt2, step2 = _setup(key)
    calls = {"n": 0}

    def flaky(p, o, b):
        calls["n"] += 1
        if calls["n"] == 6:
            raise RuntimeError("injected device failure")
        return step2(p, o, b)

    ck2 = Checkpointer(tmp_path / "b", keep=5, async_save=False)
    d2 = TrainDriver(flaky, params2, opt2, TokenPipeline(ARCH, SHAPE, seed=1), ck2,
                     DriverConfig(total_steps=8, checkpoint_every=2))
    r2 = d2.run()
    assert r2["restarts"] == 1
    # identical final params
    for a, b in zip(jax.tree.leaves(d1.params), jax.tree.leaves(d2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_driver_gives_up_after_max_restarts(tmp_path, key):
    params, opt, _ = _setup(key)

    def always_fail(p, o, b):
        raise RuntimeError("dead")

    d = TrainDriver(always_fail, params, opt, TokenPipeline(ARCH, SHAPE),
                    Checkpointer(tmp_path, async_save=False),
                    DriverConfig(total_steps=4, max_restarts=2))
    with pytest.raises(RuntimeError):
        d.run()


def test_straggler_monitor_detects_outlier():
    m = StragglerMonitor(warmup=3)
    for _ in range(6):
        assert not m.observe(0.1)
    assert m.observe(1.0)
    assert m.events == 1


def test_xfer_on_off_same_loss(key):
    """Baseline (replicated) and XFER (distributed) shardings are the same
    computation — identical loss on the test mesh."""
    mesh = make_test_mesh()
    axes = tuple((n, s) for n, s in mesh.shape.items())
    plan_on = ShardingPlan(axes, batch_axes=("data",), tp_axes=("model",), xfer=True)
    plan_off = ShardingPlan(axes, batch_axes=("data",), tp_axes=("model",), xfer=False)
    pipe = TokenPipeline(ARCH, SHAPE, seed=2)
    batch = pipe.next_batch()
    losses = {}
    for name, plan in (("on", plan_on), ("off", plan_off)):
        ctx = ShardingCtx(mesh, plan)
        params = REG.init_params(ARCH, key)
        cfg = OPT.AdamWConfig()
        opt = OPT.adamw_init(params, cfg)
        with mesh:
            step = jax.jit(REG.build_train_step(ARCH, cfg, ctx))
            _, _, m = step(params, opt, batch)
        losses[name] = float(m["loss"])
    assert np.isclose(losses["on"], losses["off"], rtol=1e-6)


def test_serving_engine_continuous_batching(key):
    import repro
    from repro.serving.engine import Request
    params = REG.init_params(ARCH, key)
    plan = repro.plan(ARCH, ShapeConfig("serve_cb", 32, 2, "decode"))
    engine = plan.compile().serve(
        params, config=ServeConfig(slots=2, max_len=32))
    rng = np.random.RandomState(0)
    for i in range(5):
        engine.submit(Request(rid=i, prompt=rng.randint(1, 100, size=6).astype(np.int32),
                              max_new_tokens=3))
    steps = engine.run_until_drained(max_steps=100)
    assert len(engine.completed) == 5
    assert all(len(r.out_tokens) == 3 for r in engine.completed)
    # 2 slots, 5 requests, 3 tokens each -> at least ceil(5/2)*3 steps
    assert steps >= 9


def test_engine_matches_direct_decode(key):
    """Serving engine output == direct prefill+decode for a single request."""
    import repro
    from repro.serving.engine import Request
    params = REG.init_params(ARCH, key)
    prompt = np.arange(1, 9, dtype=np.int32)
    plan = repro.plan(ARCH, ShapeConfig("serve_direct", 24, 1, "decode"))
    engine = plan.compile().serve(
        params, config=ServeConfig(slots=1, max_len=24))
    engine.submit(Request(rid=0, prompt=prompt, max_new_tokens=3))
    engine.run_until_drained(max_steps=20)
    got = engine.completed[0].out_tokens

    # direct: greedy decode
    from repro.models import lm as LM
    toks = jnp.asarray(prompt)[None]
    caches = REG.make_caches(ARCH, 1, 24, jnp.float32)
    hidden, caches = LM.forward(ARCH, params, toks, caches=caches)
    logits = LM.logits_fn(ARCH, params, hidden[:, -1:])
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(2):
        hidden, caches = LM.forward(ARCH, params, jnp.asarray([[out[-1]]], jnp.int32),
                                    caches=caches,
                                    positions=jnp.full((1, 1), pos, jnp.int32))
        out.append(int(jnp.argmax(LM.logits_fn(ARCH, params, hidden)[0, -1])))
        pos += 1
    assert got == out


def test_gradient_compression_error_feedback(key):
    """EF property: running mean of decompressed grads ~= true grad."""
    g_true = jax.random.normal(key, (64,))
    err = jnp.zeros((64,))
    total = jnp.zeros((64,))
    for i in range(50):
        q, s, err = COMP.compress(g_true, err)
        total += COMP.decompress(q, s)
    np.testing.assert_allclose(total / 50, g_true, rtol=0, atol=0.02)


def test_int8_adam_close_to_fp32(key):
    params, _, _ = _setup(key)
    cfg32 = OPT.AdamWConfig(lr=1e-3)
    cfg8 = OPT.AdamWConfig(lr=1e-3, quantize=True)
    o32 = OPT.adamw_init(params, cfg32)
    o8 = OPT.adamw_init(params, cfg8)
    grads = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01, params)
    p32, _, _ = OPT.adamw_update(params, grads, o32, cfg32, jnp.float32(1e-3))
    p8, _, _ = OPT.adamw_update(params, grads, o8, cfg8, jnp.float32(1e-3))
    for a, b in zip(jax.tree.leaves(p32), jax.tree.leaves(p8)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


def test_elastic_replan():
    from repro.runtime.elastic import replan
    mesh, ctx, rep = replan(ARCH, SHAPE)
    assert mesh.devices.size == len(jax.devices())
    assert rep.predicted_seconds > 0


def test_grad_accumulation_matches_full_batch(key):
    """accum=2 must produce the same update as the full batch (equal-sized
    microbatches; CE is a token mean, so grad means compose linearly)."""
    params = REG.init_params(ARCH, key)
    cfg = OPT.AdamWConfig(lr=1e-3)
    batch = TokenPipeline(ARCH, SHAPE, seed=4).next_batch()
    full = jax.jit(REG.build_train_step(ARCH, cfg))
    acc = jax.jit(REG.build_train_step(ARCH, cfg, accum_steps=2))
    p1, _, m1 = full(params, OPT.adamw_init(params, cfg), batch)
    p2, _, m2 = acc(params, OPT.adamw_init(params, cfg), batch)
    assert np.isclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=5e-5)
