"""Per-arch reduced-config smoke tests: one train step + prefill + decode,
asserting output shapes and finiteness (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_arch
from repro.models import registry as REG
from repro.optim import adamw as OPT


def _batch_for(arch, shape, key):
    specs = REG.input_specs(arch, shape, dtype=jnp.float32)
    batch = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32:
            batch[k] = jax.random.randint(key, v.shape, 0, arch.vocab_size
                                          if k in ("tokens", "labels") else 4
                                          ).astype(jnp.int32)
        else:
            batch[k] = jax.random.normal(key, v.shape, v.dtype) * 0.02
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step(arch_id, key):
    arch = get_arch(arch_id).reduced()
    shape = SHAPES["train_4k"].reduced()
    params = REG.init_params(arch, key)
    cfg = OPT.AdamWConfig(lr=1e-3)
    opt = OPT.adamw_init(params, cfg)
    batch = _batch_for(arch, shape, key)
    step = jax.jit(REG.build_train_step(arch, cfg))
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"])), arch_id
    assert int(o2["step"]) == 1
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_and_decode(arch_id, key):
    arch = get_arch(arch_id).reduced()
    shape = SHAPES["prefill_32k"].reduced()
    params = REG.init_params(arch, key)
    batch = _batch_for(arch, shape, key)
    pre = jax.jit(REG.build_prefill_step(arch, shape, cache_dtype=jnp.float32))
    out = pre(params, batch)
    caches, logits = out[0], out[1]
    assert logits.shape[0] == shape.global_batch
    assert logits.shape[-1] == arch.vocab_size
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    serve = jax.jit(REG.build_serve_step(arch))
    B = shape.global_batch
    dbatch = {"tokens": jnp.ones((B, 1), jnp.int32),
              "positions": jnp.full((B, 1), shape.seq_len, jnp.int32)}
    if arch.family == "encdec":
        dbatch["enc_out"] = out[2]
    for _ in range(2):
        tok, caches = serve(params, caches, dbatch)
        dbatch = dict(dbatch, tokens=tok[:, None],
                      positions=dbatch["positions"] + 1)
    assert tok.shape == (B,)
    assert np.all(np.asarray(tok) >= 0)


@pytest.mark.parametrize("arch_id", ["recurrentgemma-2b", "xlstm-350m"])
def test_long_context_decode_state_is_bounded(arch_id, key):
    """long_500k archs: decode state size is O(1) in context length."""
    arch = get_arch(arch_id).reduced()
    c1 = REG.make_caches(arch, 1, 1024, jnp.float32)
    c2 = REG.make_caches(arch, 1, 64, jnp.float32)
    b1 = sum(x.size for x in jax.tree.leaves(c1))
    b2 = sum(x.size for x in jax.tree.leaves(c2))
    # attention window bounds kv; recurrent state is constant
    assert b1 <= b2 * (arch.window or 1) if arch.window else b1 == b2


def test_param_counts_match_public_sizes():
    """Full configs land near their public parameter counts."""
    expected = {
        "minitron-8b": (7.0e9, 9.0e9),
        "yi-9b": (8.0e9, 9.5e9),
        "qwen1.5-0.5b": (0.3e9, 0.65e9),
        "phi3-medium-14b": (13e9, 15.5e9),
        "llama4-maverick-400b-a17b": (380e9, 800e9),  # brief's cfg is larger
        "deepseek-moe-16b": (15e9, 18e9),
        "paligemma-3b": (2.0e9, 3.2e9),  # backbone only (no vision tower)
        "recurrentgemma-2b": (2.0e9, 3.0e9),
    }
    for arch_id, (lo, hi) in expected.items():
        n = get_arch(arch_id).param_count()
        assert lo <= n <= hi, (arch_id, n)


def test_moe_active_params():
    a = get_arch("llama4-maverick-400b-a17b")
    assert a.active_param_count() < 0.05 * a.param_count()
    d = get_arch("deepseek-moe-16b")
    assert d.active_param_count() < 0.25 * d.param_count()
