"""Three-stage deployment API: plan → compile → execute round-trips.

The multi-device placement test runs via
testing.mesh_fixtures.run_in_subprocess because the 8-device host
platform must be forced before jax initialises (the main test process
keeps 1 device) — same pattern as test_pipeline.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.configs.base import ShapeConfig
from repro.serving import ServeConfig
from repro.serving.engine import Request, ServingEngine
from repro.testing.mesh_fixtures import run_in_subprocess

ARCH = repro.get_arch("qwen1.5-0.5b").reduced()
TRAIN_SHAPE = ShapeConfig("t", 32, 4, "train")
DECODE_SHAPE = ShapeConfig("d", 32, 4, "decode")


def test_plan_wraps_dse_output():
    plan = repro.plan("qwen1.5-0.5b", "train_4k", (("data", 16), ("model", 16)))
    assert isinstance(plan, repro.ExecutionPlan)
    assert plan.num_devices == 256
    assert plan.predicted_seconds > 0
    assert plan.sharding_plan is plan.report.plan
    # accelerator-level DSE choices are carried along
    assert plan.layer_choices and all(len(c) == 3 for c in plan.layer_choices)
    names = [n for n, _, _ in plan.layer_choices]
    assert names == [n for n, _, _ in plan.report.per_layer]


def test_plan_accepts_config_objects_and_auto_mesh():
    plan = repro.plan(ARCH, TRAIN_SHAPE)  # mesh=None -> fit live devices
    assert plan.num_devices == len(jax.devices())
    mesh = plan.build_mesh()
    assert mesh is plan.build_mesh()  # cached


def test_plan_compile_train_roundtrip(tmp_path):
    exe = repro.plan(ARCH, TRAIN_SHAPE).compile()
    driver = exe.train(steps=3, ckpt_dir=str(tmp_path), ckpt_every=100)
    assert driver.plan is exe.plan
    result = driver.run()
    assert result["final_step"] == 3
    assert all(np.isfinite(m["loss"]) for m in result["log"])


def test_plan_compile_serve_roundtrip():
    plan = repro.plan(ARCH, DECODE_SHAPE)
    engine = plan.compile().serve(config=ServeConfig(slots=2, max_len=32))
    assert engine.plan is plan
    # engine params are placed with the plan's NamedShardings
    want = plan.param_shardings(engine.params, engine.mesh)
    for leaf, sh in zip(jax.tree.leaves(engine.params), jax.tree.leaves(want)):
        assert leaf.sharding.is_equivalent_to(sh, leaf.ndim)
    rng = np.random.RandomState(0)
    for i in range(3):
        engine.submit(Request(rid=i, prompt=rng.randint(1, 100, size=6).astype(np.int32),
                              max_new_tokens=2))
    engine.run_until_drained(max_steps=50)
    assert len(engine.completed) == 3
    assert all(len(r.out_tokens) == 2 for r in engine.completed)


def test_deploy_is_plan_then_compile():
    exe = repro.deploy(ARCH, DECODE_SHAPE)
    assert isinstance(exe, repro.Executable)
    assert exe.plan.compile() is exe  # compile() caches the Executable


def test_coerce_shape_rejects_unknown_id():
    with pytest.raises(KeyError, match="unknown shape"):
        repro.plan(ARCH, "no_such_shape")


def test_coerce_arch_rejects_unknown_id():
    with pytest.raises(KeyError, match="unknown arch"):
        repro.plan("no-such-arch", TRAIN_SHAPE)


def test_coerce_mesh_rejects_nonpositive_size():
    with pytest.raises(ValueError, match="must be positive"):
        repro.plan(ARCH, TRAIN_SHAPE, (("data", 0), ("model", 2)))
    with pytest.raises(ValueError, match="must be positive"):
        repro.plan(ARCH, DECODE_SHAPE, (("data", 4), ("model", -1)))


def test_coerce_mesh_rejects_duplicate_axis_names():
    with pytest.raises(ValueError, match="duplicate mesh axis"):
        repro.plan(ARCH, TRAIN_SHAPE, (("data", 2), ("data", 2)))


def test_compile_rejects_mesh_larger_than_live_devices():
    """Planning a hypothetical big mesh works; binding it to hardware with
    fewer live devices must fail with the re-plan hint, at compile time."""
    plan = repro.plan(ARCH, DECODE_SHAPE, (("data", 16), ("model", 16)))
    assert plan.num_devices == 256  # planning itself is device-free
    with pytest.raises(ValueError, match="re-plan"):
        plan.compile()


def test_serving_engine_backcompat(key):
    """Legacy ServingEngine(arch, params, ...) construction still works —
    routed through the new scheduler — and warns about its deprecation."""
    from repro.models import registry as REG
    params = REG.init_params(ARCH, key)
    with pytest.warns(DeprecationWarning, match="ServingEngine"):
        engine = ServingEngine(ARCH, params, slots=2, max_len=32,
                               dtype=jnp.float32)
    assert engine.plan is None and engine.mesh is None
    engine.submit(Request(rid=0, prompt=np.arange(1, 7, dtype=np.int32),
                          max_new_tokens=2))
    engine.run_until_drained(max_steps=20)
    assert len(engine.completed) == 1
    assert len(engine.completed[0].out_tokens) == 2


def test_traindriver_accepts_execution_plan(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.runtime.driver import DriverConfig, TrainDriver
    plan = repro.plan(ARCH, TRAIN_SHAPE)
    driver = TrainDriver(plan, ckpt=Checkpointer(tmp_path, async_save=False),
                         cfg=DriverConfig(total_steps=2, checkpoint_every=100))
    result = driver.run()
    assert result["final_step"] == 2


def test_traindriver_legacy_signature_requires_state():
    from repro.runtime.driver import TrainDriver
    with pytest.raises(TypeError):
        TrainDriver(lambda p, o, b: (p, o, {"loss": 0.0}))


def test_engine_eos_stops_without_counting(key):
    """EOS neither enters out_tokens nor consumes max_new_tokens; the
    freed slot re-admits once the finishing record falls out of the
    lookahead window (EOS straight out of prefill emits nothing)."""
    plan = repro.plan(ARCH, DECODE_SHAPE)
    prompt = np.arange(10, 14, dtype=np.int32)
    # probe: greedy stream with no EOS — its tokens tell us where to cut
    probe = plan.compile().serve(config=ServeConfig(slots=1, max_len=32))
    probe.submit(Request(rid=0, prompt=prompt, max_new_tokens=4))
    probe.run_until_drained(max_steps=30)
    stream = probe.completed[0].out_tokens
    assert len(stream) == 4

    # (a) EOS = the 3rd generated token: stream stops after 2, uncounted
    mid = int(stream[2])
    if mid not in stream[:2]:
        eng = plan.compile().serve(config=ServeConfig(slots=1, max_len=32, eos_id=mid))
        eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
        eng.run_until_drained(max_steps=30)
        done = eng.completed[0]
        assert done.out_tokens == [int(t) for t in stream[:2]]
        assert mid not in done.out_tokens

    # (b) EOS = the prefill token: both requests finish emitting nothing,
    # and the single slot is re-admitted mid-run
    eos = int(stream[0])
    eng = plan.compile().serve(config=ServeConfig(slots=1, max_len=32, eos_id=eos))
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8))
    eng.submit(Request(rid=1, prompt=prompt, max_new_tokens=8))
    eng.run_until_drained(max_steps=30)
    assert sorted(r.rid for r in eng.completed) == [0, 1]
    assert all(r.out_tokens == [] for r in eng.completed)


_MULTIDEV_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
import repro
from repro.configs.base import ShapeConfig
from repro.serving import ServeConfig
from repro.serving.engine import Request

arch = repro.get_arch("qwen1.5-0.5b").reduced()
shape = ShapeConfig("d8", 32, 4, "decode")
plan = repro.plan(arch, shape, (("data", 4), ("model", 2)))
f = plan.sharding_plan.factors
exe = plan.compile()
engine = exe.serve(config=ServeConfig(slots=4, max_len=32))

# every param leaf is placed exactly as the plan derives
want = plan.param_shardings(engine.params, engine.mesh)
for leaf, sh in zip(jax.tree.leaves(engine.params), jax.tree.leaves(want)):
    assert leaf.sharding.is_equivalent_to(sh, leaf.ndim), (leaf.shape, leaf.sharding, sh)

# the tp-role dim of the embedding is split exactly Pm ways (plan.factors)
sizes = dict(plan.mesh_axes)
spec = engine.params["embed"].sharding.spec
axes = spec[0] if isinstance(spec[0], tuple) else ((spec[0],) if spec[0] else ())
pm = 1
for a in axes:
    pm *= sizes[a]
assert pm == f.Pm == 2, (spec, f)

# and the engine actually decodes on the 8-device mesh
rng = np.random.RandomState(0)
for i in range(4):
    engine.submit(Request(rid=i, prompt=rng.randint(1, 100, size=6).astype(np.int32),
                          max_new_tokens=2))
engine.run_until_drained(max_steps=30)
assert len(engine.completed) == 4
print("MULTIDEV_API_OK")
"""


@pytest.mark.slow
def test_serve_placement_matches_plan_on_8_devices():
    run_in_subprocess(_MULTIDEV_SCRIPT, devices=8, timeout=600,
                      marker="MULTIDEV_API_OK")
