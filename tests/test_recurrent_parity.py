"""Decode-vs-parallel parity: prefill a prompt, decode one token, and check
the result matches a full forward over prompt+1 (all four block kinds).

This is the invariant that makes the serving path trustworthy: the O(1)
recurrent/decode forms must agree with the parallel training forms.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models import registry as REG

PROMPT = 12


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS
                                     if get_arch(a).family != "encdec"])
def test_prefill_decode_matches_full_forward(arch_id, key):
    arch = get_arch(arch_id).reduced()
    if arch.frontend == "vision_patches":
        pytest.skip("prefix-embed archs covered in test_vlm_parity")
    B = 2
    total = PROMPT + 1
    toks = jax.random.randint(key, (B, total), 0, arch.vocab_size)
    params = REG.init_params(arch, key)

    # path A: full forward over prompt+1, take logits at last position
    from repro.models import lm as LM
    hidden, _ = LM.forward(arch, params, toks)
    logits_full = LM.logits_fn(arch, params, hidden[:, -1:])

    # path B: prefill prompt (cache len allows headroom), decode token
    caches = REG.make_caches(arch, B, total + 3, jnp.float32)
    hidden_p, caches = LM.forward(arch, params, toks[:, :PROMPT], caches=caches)
    dbatch = {"tokens": toks[:, PROMPT:PROMPT + 1],
              "positions": jnp.full((B, 1), PROMPT, jnp.int32)}
    hidden_d, caches = LM.forward(arch, params, dbatch["tokens"], caches=caches,
                                  positions=dbatch["positions"])
    logits_dec = LM.logits_fn(arch, params, hidden_d)

    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(logits_full[:, 0]),
                               rtol=2e-3, atol=2e-3)


def test_vlm_parity(key):
    """PaliGemma: prefix embeddings + decode parity."""
    arch = get_arch("paligemma-3b").reduced()
    from repro.models import lm as LM
    B, P = 2, arch.frontend_tokens
    patches = jax.random.normal(key, (B, P, arch.d_model)) * 0.02
    toks = jax.random.randint(key, (B, PROMPT + 1), 0, arch.vocab_size)
    params = REG.init_params(arch, key)

    hidden, _ = LM.forward(arch, params, toks, prefix_embeds=patches)
    logits_full = LM.logits_fn(arch, params, hidden[:, -1:])

    caches = REG.make_caches(arch, B, P + PROMPT + 4, jnp.float32)
    _, caches = LM.forward(arch, params, toks[:, :PROMPT], caches=caches,
                           prefix_embeds=patches)
    hidden_d, _ = LM.forward(arch, params, toks[:, PROMPT:PROMPT + 1],
                             caches=caches,
                             positions=jnp.full((B, 1), P + PROMPT, jnp.int32))
    logits_dec = LM.logits_fn(arch, params, hidden_d)
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(logits_full[:, 0]),
                               rtol=2e-3, atol=2e-3)


def test_encdec_parity(key):
    arch = get_arch("seamless-m4t-medium").reduced()
    from repro.models import encdec as ED
    B, S = 2, 16
    frames = jax.random.normal(key, (B, S, arch.d_model)) * 0.02
    toks = jax.random.randint(key, (B, PROMPT + 1), 0, arch.vocab_size)
    params = REG.init_params(arch, key)
    enc = ED.encode(arch, params, frames)

    hidden, _ = ED.decode(arch, params, toks, enc)
    logits_full = hidden[:, -1:] @ params["unembed"]

    caches = ED.make_caches(arch, B, PROMPT + 4, jnp.float32)
    _, caches = ED.decode(arch, params, toks[:, :PROMPT], enc, caches=caches)
    hidden_d, _ = ED.decode(arch, params, toks[:, PROMPT:PROMPT + 1], enc,
                            caches=caches,
                            positions=jnp.full((B, 1), PROMPT, jnp.int32))
    logits_dec = hidden_d @ params["unembed"]
    np.testing.assert_allclose(np.asarray(logits_dec[:, 0]),
                               np.asarray(logits_full[:, 0]),
                               rtol=2e-3, atol=2e-3)
