"""INT8 quantization (repro.quant): round-trip properties, quantizer
hardening regressions (adamw clip, compression treedef, sampler top_k
ties), cache/param structure, and planner-aware capacity.

The property block uses hypothesis (the vendored shim in tests/_vendor
when the real library is absent — see conftest.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import quant as Q
from repro.configs import SHAPES, get_arch
from repro.core.planner import ShardingPlan, capacity_bytes, plan_cell
from repro.models import registry as REG

MESH = (("data", 16), ("model", 16))
PLAN = ShardingPlan(MESH, batch_axes=("data",), tp_axes=("model",), xfer=False)


# ---------------------------------------------------------------------------
# round-trip properties (hypothesis)
# ---------------------------------------------------------------------------

def _adversarial(seed: int, n: int, log_amax: int) -> np.ndarray:
    """Wide-dynamic-range vectors whose amax element appears exactly (and
    duplicated, with both signs) — the rounding-edge case for the int8
    clip: amax/scale lands exactly on ±127."""
    rng = np.random.RandomState(seed)
    amax = np.float32(2.0) ** log_amax
    x = rng.standard_normal(n).astype(np.float32) * amax * rng.uniform(0, 1)
    x[0], x[1] = amax, -amax  # both clip edges, exact ties
    return x


@given(st.integers(0, 2**31 - 1), st.integers(2, 257), st.integers(-24, 24))
@settings(max_examples=50, deadline=None)
def test_roundtrip_error_bound_and_scale_positivity(seed, n, log_amax):
    x = _adversarial(seed, n, log_amax)
    t = Q.quantize(jnp.asarray(x))
    q = np.asarray(t.q)
    scale = np.asarray(t.scale, np.float64)
    assert q.dtype == np.int8
    assert (scale > 0).all()  # never zero/negative, even for zero input
    assert q.max() <= 127 and q.min() >= -127  # -128 never emitted
    err = np.abs(np.asarray(Q.dequantize(t), np.float64) - x.astype(np.float64))
    # symmetric round-to-nearest: half a quantization step (+ fp slack)
    assert (err <= scale * 0.5 + 1e-6 * scale * 127).all()


@given(st.integers(0, 2**31 - 1), st.integers(2, 257), st.integers(-24, 24))
@settings(max_examples=25, deadline=None)
def test_roundtrip_idempotence(seed, n, log_amax):
    """quantize(dequantize(t)) reproduces t bit-for-bit: the amax element
    dequantizes to ±127*scale, so the second pass derives the same scale
    and every code round-trips exactly."""
    x = _adversarial(seed, n, log_amax)
    t = Q.quantize(jnp.asarray(x))
    t2 = Q.quantize(Q.dequantize(t))
    np.testing.assert_array_equal(np.asarray(t.q), np.asarray(t2.q))
    np.testing.assert_allclose(np.asarray(t.scale), np.asarray(t2.scale),
                               rtol=1e-6)


def test_zero_and_tiny_inputs_quantize_safely():
    for x in (np.zeros(8, np.float32),
              np.full(8, 1e-38, np.float32),
              np.array([0.0, -0.0, 5e-39, -5e-39], np.float32)):
        t = Q.quantize(jnp.asarray(x))
        assert float(np.asarray(t.scale).min()) > 0
        assert np.isfinite(np.asarray(Q.dequantize(t))).all()


def test_per_channel_and_per_token_axes():
    x = jnp.asarray(np.random.RandomState(0)
                    .standard_normal((4, 6, 8)).astype(np.float32))
    t = Q.quantize(x)              # per-tensor: scalar scale
    assert np.asarray(t.scale).shape == ()
    tw = Q.quantize(x, axis=(0, 1))  # per-output-channel (weights)
    assert tw.scale.shape == (1, 1, 8)
    tk = Q.quantize_kv(x)           # per-token over the trailing head_dim
    assert tk.scale.shape == (4, 6, 1)
    for t_ in (tw, tk):
        err = np.abs(np.asarray(Q.dequantize(t_)) - np.asarray(x))
        bound = np.asarray(t_.scale) * 0.5 + 1e-6
        assert (err <= bound).all()


# ---------------------------------------------------------------------------
# hardening regressions: adamw clip, compression treedef, sampler ties
# ---------------------------------------------------------------------------

def test_adamw_quant_state_never_wraps():
    """Regression for the optimizer's historical unclipped `_quant`: fp
    error at the amax element could round to 128 and wrap to -128,
    flipping the largest moment's sign. The shared helper clips, so every
    int8 state leaf stays in [-127, 127] and updates stay finite."""
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
    cfg = AdamWConfig(quantize=True)
    rng = np.random.RandomState(1)
    params = {"w": jnp.asarray(rng.standard_normal((8, 16)).astype(np.float32)),
              "b": jnp.asarray(rng.standard_normal(16).astype(np.float32))}
    state = adamw_init(params, cfg)
    grads = jax.tree.map(
        lambda p: jnp.asarray((rng.standard_normal(p.shape) *
                               np.float32(2.0) ** 20).astype(np.float32)),
        params)
    for _ in range(3):
        params, state, stats = adamw_update(params, grads, state, cfg,
                                            lr=jnp.float32(1e-3))
    for leaf in jax.tree.leaves(state, is_leaf=Q.is_qtensor):
        if Q.is_qtensor(leaf):
            qv = np.asarray(leaf.q)
            assert qv.dtype == np.int8
            assert qv.max() <= 127 and qv.min() >= -127
    assert all(np.isfinite(np.asarray(p)).all()
               for p in jax.tree.leaves(params))


def test_compressed_grads_rejects_mismatched_error_tree():
    from repro.runtime.compression import compressed_grads, init_error_feedback
    grads = {"a": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    err = init_error_feedback(grads)
    out_g, out_e = compressed_grads(grads, err)  # matching trees: fine
    assert jax.tree.structure(out_g) == jax.tree.structure(grads)
    stale = {"a": err["a"], "c": err["b"]}  # renamed leaf (elastic replan)
    with pytest.raises(ValueError, match="error-feedback tree"):
        compressed_grads(grads, stale)


def test_top_k_ties_keep_exactly_k():
    """With logits tied at the k-th value, the old >=-threshold mask kept
    every tied candidate; the index mask keeps exactly k (lowest indices
    win), so sampling can never emit a token outside the true top-k."""
    from repro.serving.sampler import SamplingParams, sample
    v, s, k = 16, 64, 2
    logits = np.full((s, v), -10.0, np.float32)
    logits[:, :5] = 3.0  # five-way tie for the top value
    rng = jax.vmap(jax.random.PRNGKey)(jnp.arange(s, dtype=jnp.uint32))
    sp = SamplingParams(method="top_k", top_k=k, temperature=1.0)
    _, toks = sample(jnp.asarray(logits), rng, sp)
    toks = np.asarray(toks)
    assert set(toks.tolist()) <= set(range(k)), toks
    # and the survivors are actually reachable (not all-argmax collapse)
    assert len(set(toks.tolist())) > 1


# ---------------------------------------------------------------------------
# param/cache structure
# ---------------------------------------------------------------------------

def test_quantize_params_structure_and_roundtrip(key):
    arch = get_arch("qwen1.5-0.5b").reduced()
    params = REG.init_params(arch, key, jnp.float32)
    qp = Q.quantize_params(params)
    n_q = sum(Q.is_qtensor(x) for x in
              jax.tree.leaves(qp, is_leaf=Q.is_qtensor))
    assert n_q > 0
    for leaf in jax.tree.leaves(qp, is_leaf=Q.is_qtensor):
        if Q.is_qtensor(leaf):
            assert leaf.q.dtype == jnp.int8
            assert leaf.scale.dtype == jnp.float32
            assert leaf.scale.shape[-1] == leaf.q.shape[-1]  # per-channel
        else:  # rank<2 (norms/biases) and integer leaves pass through
            assert leaf.ndim < 2 or not jnp.issubdtype(leaf.dtype,
                                                       jnp.floating)
    deq = Q.dequantize_params(qp)
    assert (jax.tree.structure(deq, is_leaf=Q.is_qtensor)
            == jax.tree.structure(params))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(deq)):
        assert a.shape == b.shape and a.dtype == b.dtype
        # per-channel int8: worst-case half-step error, ~0.4% of amax
        amax = float(jnp.abs(a).max())
        assert float(jnp.abs(a - b).max()) <= amax / 127.0 * 0.51 + 1e-6


def test_quantized_caches_structure():
    arch = get_arch("qwen1.5-0.5b").reduced()
    fp = REG.make_caches(arch, 2, 16, jnp.float32)
    qc = REG.make_caches(arch, 2, 16, jnp.float32, kv_quant=True)
    assert not REG.caches_quantized(fp)
    assert REG.caches_quantized(qc)

    def leaves_named(tree, name):
        found = []

        def walk(t):
            if isinstance(t, dict):
                for k, v in t.items():
                    if k == name:
                        found.append(v)
                    else:
                        walk(v)
        walk(tree)
        return found

    ks, kq = leaves_named(qc, "k_scale"), leaves_named(qc, "k")
    assert ks and len(ks) == len(leaves_named(qc, "v_scale"))
    for k, s in zip(kq, ks):
        assert k.dtype == jnp.int8
        assert s.shape == k.shape[:-1] + (1,)  # per-token scale
    # the dims tree mirrors the quantized cache tree leaf-for-leaf
    dims = REG.cache_dims(arch, kv_quant=True)
    jax.tree.map(lambda c, d: None, qc, dims)  # raises on mismatch
    # the scheduler's probed splice/admit axes cover the scale leaves:
    # each k_scale entry resolves the same batch/length axes as its k
    axes = REG.cache_axes(arch, jnp.float32, kv_quant=True)
    for blk in axes["body"].values():
        assert blk["k_scale"].batch == blk["k"].batch
        assert blk["k_scale"].length == blk["k"].length


# ---------------------------------------------------------------------------
# planner-aware capacity
# ---------------------------------------------------------------------------

def test_capacity_shrinks_under_quant():
    arch, shape = get_arch("qwen1.5-0.5b"), SHAPES["decode_32k"]
    cap_fp = capacity_bytes(arch, shape, PLAN, opt_bytes_per_param=0.0)
    cap_q = capacity_bytes(arch, shape, PLAN, opt_bytes_per_param=0.0,
                           quant=Q.INT8_SERVE)
    # fp32 serving -> int8 weights + int8 KV: ~4x on the weight and KV
    # terms (activations and the scale leaves keep the total well short
    # of the full 4x, but the resident bytes must drop substantially)
    assert cap_q < 0.6 * cap_fp
    kv_only = Q.QuantConfig(kv="int8")
    cap_kv = capacity_bytes(arch, shape, PLAN, opt_bytes_per_param=0.0,
                            quant=kv_only)
    assert cap_q < cap_kv < cap_fp


def test_plan_cell_threads_quant():
    arch, shape = get_arch("qwen1.5-0.5b"), SHAPES["decode_32k"]
    rep_fp = plan_cell(arch, shape, MESH)
    rep_q = plan_cell(arch, shape, MESH, quant=Q.INT8_SERVE)
    assert rep_q.hbm_bytes_per_device < rep_fp.hbm_bytes_per_device


def test_quant_config_bytes_per_elem():
    cfg = Q.INT8_SERVE
    assert cfg.param_bytes_per_elem(2.0) == 1.0
    assert cfg.kv_bytes_per_elem(2.0, head_dim=64) == 1.0 + 4.0 / 64
    off = Q.QuantConfig()
    assert not off.enabled
    assert off.param_bytes_per_elem(2.0) == 2.0
    assert off.kv_bytes_per_elem(2.0, head_dim=64) == 2.0
    with pytest.raises(ValueError):
        Q.QuantConfig(weights="int4")
