"""Serving runtime unit tests: DecodeState, sampler, scheduler (bucketed
prefill + metadata splice), drain contract, and the deprecation shim.

Decode *equivalence* against the frozen reference engine lives in the
conformance suite (tests/test_conformance.py + repro.testing.serving_equiv);
this file covers the package's pieces in the fast tier-1 set.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.configs.base import ShapeConfig
from repro.models import registry as REG
from repro.serving.engine import IncompleteDrainError, Request, ServingEngine
from repro.serving.sampler import GREEDY, SamplingParams, sample
from repro.serving.scheduler import bucket_len, splice_row
from repro.serving.state import admit_slot, make_decode_state
from repro.testing.serving_equiv import _legacy_splice_leaf

ARCH = repro.get_arch("qwen1.5-0.5b").reduced()
DECODE_SHAPE = ShapeConfig("d", 32, 4, "decode")


# ------------------------- cache-axes metadata -------------------------

def test_cache_axes_metadata_matches_constructors():
    """Batch/length axes are derived structurally from make_caches for
    every family — including leaves whose batch axis is not leading."""
    ax = REG.cache_axes(ARCH)
    body = ax["body"]["b0_attn"]
    assert (body["k"].batch, body["k"].length) == (1, 2)
    assert (body["pos"].batch, body["pos"].length) == (1, 2)
    assert (body["count"].batch, body["count"].length) == (None, None)

    moe = REG.cache_axes(repro.get_arch("deepseek-moe-16b").reduced())
    assert (moe["prefix0"]["k"].batch, moe["prefix0"]["k"].length) == (0, 1)

    rec = REG.cache_axes(repro.get_arch("recurrentgemma-2b").reduced())
    flat = jax.tree_util.tree_flatten_with_path(
        rec, is_leaf=lambda x: isinstance(x, REG.CacheAxes))[0]
    # every leaf except the scalar attn `count` has an explicit batch axis
    assert all(a.batch is not None for p, a in flat
               if "count" not in jax.tree_util.keystr(p))
    # rglru conv state has no length axis
    conv = [a for p, a in flat if "conv" in jax.tree_util.keystr(p)]
    assert conv and all(a.length is None for a in conv)

    enc = REG.cache_axes(repro.get_arch("seamless-m4t-medium").reduced())
    k = enc["dec_body"]["k"]
    assert (k.batch, k.length) == (1, 2)  # layer-stacked: batch axis is NOT 0


def test_splice_row_regression_slots_collide_with_model_dim():
    """The old shape heuristic mis-splices when a non-batch dim equals the
    slot count and the row is shorter (bucketed prefill): the first
    matching axis broadcasts a length-1 row across the whole cache row,
    marking every position valid. The metadata-driven splice writes only
    the row's extent and invalidates the tail."""
    slots = 4  # cache length chosen == slots: the collision
    axes = {"k": REG.CacheAxes(batch=0, length=1),
            "pos": REG.CacheAxes(batch=0, length=1)}
    grid = {"k": jnp.zeros((slots, slots, 2)),
            "pos": jnp.full((slots, slots), -1, jnp.int32)}
    row = {"k": jnp.ones((1, 1, 2)),
           "pos": jnp.zeros((1, 1), jnp.int32)}  # one-token bucket, pos=0

    good = splice_row(grid, row, 2, axes)
    np.testing.assert_array_equal(np.asarray(good["pos"])[2], [0, -1, -1, -1])
    assert np.asarray(good["k"])[2, 0].tolist() == [1.0, 1.0]
    assert np.abs(np.asarray(good["k"])[2, 1:]).max() == 0.0
    np.testing.assert_array_equal(np.asarray(good["pos"])[[0, 1, 3]], -1)

    legacy = jax.tree.map(_legacy_splice_leaf(2, slots), grid, row)
    # the heuristic broadcast the single position over the whole row:
    # every cache slot claims pos=0 (valid) — stale-tail corruption
    assert np.asarray(legacy["pos"])[2].tolist() == [0, 0, 0, 0]


def test_splice_row_full_length_matches_legacy_on_well_formed_rows():
    """For max_len-aligned rows (the old engine's only case) the explicit
    splice and the heuristic agree on every real arch cache tree."""
    slots, length = 3, 8
    for arch_id in ("qwen1.5-0.5b", "recurrentgemma-2b"):
        arch = repro.get_arch(arch_id).reduced()
        axes = REG.cache_axes(arch, jnp.float32)
        grid = REG.make_caches(arch, slots, length, jnp.float32)
        row = jax.tree.map(lambda l: jnp.asarray(
            np.random.RandomState(0).standard_normal(l.shape).astype(l.dtype))
            if jnp.issubdtype(l.dtype, jnp.floating) else l,
            REG.make_caches(arch, 1, length, jnp.float32))
        got = splice_row(grid, row, 1, axes)
        want = jax.tree.map(_legacy_splice_leaf(1, slots), grid, row)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------- bucketing ------------------------------

def test_bucket_len_policy():
    assert bucket_len(3, 64, aligned=False) == 8    # min bucket
    assert bucket_len(9, 64, aligned=False) == 16   # next pow2
    assert bucket_len(16, 64, aligned=False) == 16  # exact
    assert bucket_len(40, 48, aligned=False) == 48  # clamped to max_len
    assert bucket_len(3, 64, aligned=True) == 64    # recurrent-state archs


def test_scheduler_alignment_policy_per_family():
    from repro.serving.scheduler import _bucketable
    assert _bucketable(repro.get_arch("qwen1.5-0.5b").reduced())
    assert _bucketable(repro.get_arch("deepseek-moe-16b").reduced())
    assert not _bucketable(repro.get_arch("recurrentgemma-2b").reduced())
    assert not _bucketable(repro.get_arch("xlstm-350m").reduced())
    assert not _bucketable(repro.get_arch("seamless-m4t-medium").reduced())


def test_submit_rejects_overlong_prompt(key):
    plan = repro.plan(ARCH, DECODE_SHAPE)
    engine = plan.compile().serve(slots=1, max_len=16)
    with pytest.raises(ValueError, match="exceeds"):
        engine.submit(Request(rid=0, prompt=np.arange(20, dtype=np.int32)))


# ------------------------------ sampler -------------------------------

def test_sampling_params_validation():
    with pytest.raises(ValueError, match="unknown sampling method"):
        SamplingParams(method="beam")
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(method="temperature", temperature=0.0)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(method="top_k", top_k=0)


def test_sampler_greedy_is_argmax_and_keeps_rng(key):
    logits = jax.random.normal(key, (3, 17))
    rng = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(3))
    rng2, toks = sample(logits, rng, GREEDY)
    assert rng2 is rng
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_sampler_topk_stays_in_topk_and_advances_rng(key):
    logits = jax.random.normal(key, (4, 33))
    rng = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(4))
    sp = SamplingParams(method="top_k", temperature=0.7, top_k=3)
    rng2, toks = sample(logits, rng, sp)
    assert not np.array_equal(np.asarray(rng2), np.asarray(rng))
    top3 = np.asarray(jax.lax.top_k(logits, 3)[1])
    for i, t in enumerate(np.asarray(toks)):
        assert t in top3[i]
    # deterministic given the same keys
    _, toks_again = sample(logits, rng, sp)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks_again))


def test_engine_temperature_sampling_decodes(key):
    plan = repro.plan(ARCH, DECODE_SHAPE)
    engine = plan.compile().serve(
        slots=2, max_len=32,
        sampling=SamplingParams(method="temperature", temperature=0.9))
    for i in range(3):
        engine.submit(Request(rid=i, prompt=np.arange(1, 7, dtype=np.int32),
                              max_new_tokens=3))
    engine.run_until_drained(max_steps=50)
    assert len(engine.completed) == 3
    assert all(len(r.out_tokens) == 3 for r in engine.completed)
    assert all(0 <= t < ARCH.vocab_size
               for r in engine.completed for t in r.out_tokens)


# --------------------------- decode state -----------------------------

def test_decode_state_shapes_and_admit():
    st = make_decode_state(4, seed=3)
    assert st.tokens.shape == (4, 1) and st.rng.shape == (4, 2)
    assert not bool(st.active.any())
    st2 = jax.jit(admit_slot)(st, jnp.int32(2), jnp.int32(7), jnp.int32(5),
                              jnp.int32(9), st.rng[2])
    assert np.asarray(st2.active).tolist() == [False, False, True, False]
    assert int(st2.tokens[2, 0]) == 7 and int(st2.positions[2, 0]) == 5
    assert int(st2.max_new[2]) == 9 and int(st2.emitted[2]) == 0
    # untouched slots keep their keys
    np.testing.assert_array_equal(np.asarray(st2.rng[0]), np.asarray(st.rng[0]))


# ------------------------- drain-contract tests ------------------------

def test_run_until_drained_raises_with_unfinished_rids(key):
    plan = repro.plan(ARCH, DECODE_SHAPE)
    engine = plan.compile().serve(slots=1, max_len=32)
    for i in range(3):
        engine.submit(Request(rid=i, prompt=np.arange(1, 7, dtype=np.int32),
                              max_new_tokens=8))
    with pytest.raises(IncompleteDrainError) as ei:
        engine.run_until_drained(max_steps=2)
    assert set(ei.value.unfinished) <= {0, 1, 2} and ei.value.unfinished


def test_run_until_drained_warn_mode(key):
    plan = repro.plan(ARCH, DECODE_SHAPE)
    engine = plan.compile().serve(slots=1, max_len=32)
    engine.submit(Request(rid=5, prompt=np.arange(1, 7, dtype=np.int32),
                          max_new_tokens=8))
    with pytest.warns(RuntimeWarning, match="rids=\\[5\\]"):
        steps = engine.run_until_drained(max_steps=1, on_incomplete="warn")
    assert steps == 1


# ------------------------ deprecation shim parity ----------------------

def test_legacy_construction_parity(key):
    """ServingEngine(arch, ...) routes through the new scheduler and
    produces the same greedy streams as plan-based construction."""
    params = REG.init_params(ARCH, key)
    prompts = [np.arange(1, 7, dtype=np.int32),
               np.arange(3, 12, dtype=np.int32)]

    with pytest.warns(DeprecationWarning):
        legacy = ServingEngine(ARCH, params, slots=2, max_len=32,
                               dtype=jnp.float32)
    plan = repro.plan(ARCH, DECODE_SHAPE)
    modern = plan.compile().serve(params, slots=2, max_len=32)
    for eng in (legacy, modern):
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=4))
        eng.run_until_drained(max_steps=50)
    got = {r.rid: r.out_tokens for r in legacy.completed}
    want = {r.rid: r.out_tokens for r in modern.completed}
    assert got == want and len(got) == 2


def test_lookahead_zero_matches_lookahead_one(key):
    params = REG.init_params(ARCH, key)
    plan = repro.plan(ARCH, DECODE_SHAPE)
    streams = []
    for la in (0, 1, 2):
        eng = plan.compile().serve(params, slots=2, max_len=32, lookahead=la)
        for i in range(5):
            eng.submit(Request(rid=i, prompt=np.arange(1, 7, dtype=np.int32),
                               max_new_tokens=3))
        eng.run_until_drained(max_steps=60)
        streams.append({r.rid: r.out_tokens for r in eng.completed})
    assert streams[0] == streams[1] == streams[2]
    assert all(len(s) == 5 for s in streams)
