"""Serving runtime unit tests: DecodeState, sampler, scheduler (bucketed
prefill + metadata splice), drain contract, and the deprecation shim.

Decode *equivalence* against the frozen reference engine lives in the
conformance suite (tests/test_conformance.py + repro.testing.serving_equiv);
this file covers the package's pieces in the fast tier-1 set.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.configs.base import ShapeConfig
from repro.models import registry as REG
from repro.serving import ServeConfig
from repro.serving.engine import IncompleteDrainError, Request, ServingEngine
from repro.serving.sampler import GREEDY, SamplingParams, sample
from repro.serving.scheduler import bucket_len, splice_row
from repro.serving.state import admit_slot, make_decode_state
from repro.testing.serving_equiv import _legacy_splice_leaf

ARCH = repro.get_arch("qwen1.5-0.5b").reduced()
DECODE_SHAPE = ShapeConfig("d", 32, 4, "decode")


# ------------------------- cache-axes metadata -------------------------

def test_cache_axes_metadata_matches_constructors():
    """Batch/length axes are derived structurally from make_caches for
    every family — including leaves whose batch axis is not leading."""
    ax = REG.cache_axes(ARCH)
    body = ax["body"]["b0_attn"]
    assert (body["k"].batch, body["k"].length) == (1, 2)
    assert (body["pos"].batch, body["pos"].length) == (1, 2)
    assert (body["count"].batch, body["count"].length) == (None, None)

    moe = REG.cache_axes(repro.get_arch("deepseek-moe-16b").reduced())
    assert (moe["prefix0"]["k"].batch, moe["prefix0"]["k"].length) == (0, 1)

    rec = REG.cache_axes(repro.get_arch("recurrentgemma-2b").reduced())
    flat = jax.tree_util.tree_flatten_with_path(
        rec, is_leaf=lambda x: isinstance(x, REG.CacheAxes))[0]
    # every leaf except the scalar attn `count` has an explicit batch axis
    assert all(a.batch is not None for p, a in flat
               if "count" not in jax.tree_util.keystr(p))
    # rglru conv state has no length axis
    conv = [a for p, a in flat if "conv" in jax.tree_util.keystr(p)]
    assert conv and all(a.length is None for a in conv)

    enc = REG.cache_axes(repro.get_arch("seamless-m4t-medium").reduced())
    k = enc["dec_body"]["k"]
    assert (k.batch, k.length) == (1, 2)  # layer-stacked: batch axis is NOT 0


def test_splice_row_regression_slots_collide_with_model_dim():
    """The old shape heuristic mis-splices when a non-batch dim equals the
    slot count and the row is shorter (bucketed prefill): the first
    matching axis broadcasts a length-1 row across the whole cache row,
    marking every position valid. The metadata-driven splice writes only
    the row's extent and invalidates the tail."""
    slots = 4  # cache length chosen == slots: the collision
    axes = {"k": REG.CacheAxes(batch=0, length=1),
            "pos": REG.CacheAxes(batch=0, length=1)}
    grid = {"k": jnp.zeros((slots, slots, 2)),
            "pos": jnp.full((slots, slots), -1, jnp.int32)}
    row = {"k": jnp.ones((1, 1, 2)),
           "pos": jnp.zeros((1, 1), jnp.int32)}  # one-token bucket, pos=0

    good = splice_row(grid, row, 2, axes)
    np.testing.assert_array_equal(np.asarray(good["pos"])[2], [0, -1, -1, -1])
    assert np.asarray(good["k"])[2, 0].tolist() == [1.0, 1.0]
    assert np.abs(np.asarray(good["k"])[2, 1:]).max() == 0.0
    np.testing.assert_array_equal(np.asarray(good["pos"])[[0, 1, 3]], -1)

    legacy = jax.tree.map(_legacy_splice_leaf(2, slots), grid, row)
    # the heuristic broadcast the single position over the whole row:
    # every cache slot claims pos=0 (valid) — stale-tail corruption
    assert np.asarray(legacy["pos"])[2].tolist() == [0, 0, 0, 0]


def test_splice_row_full_length_matches_legacy_on_well_formed_rows():
    """For max_len-aligned rows (the old engine's only case) the explicit
    splice and the heuristic agree on every real arch cache tree."""
    slots, length = 3, 8
    for arch_id in ("qwen1.5-0.5b", "recurrentgemma-2b"):
        arch = repro.get_arch(arch_id).reduced()
        axes = REG.cache_axes(arch, jnp.float32)
        grid = REG.make_caches(arch, slots, length, jnp.float32)
        row = jax.tree.map(lambda l: jnp.asarray(
            np.random.RandomState(0).standard_normal(l.shape).astype(l.dtype))
            if jnp.issubdtype(l.dtype, jnp.floating) else l,
            REG.make_caches(arch, 1, length, jnp.float32))
        got = splice_row(grid, row, 1, axes)
        want = jax.tree.map(_legacy_splice_leaf(1, slots), grid, row)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------- bucketing ------------------------------

def test_bucket_len_policy():
    assert bucket_len(3, 64, aligned=False) == 8    # min bucket
    assert bucket_len(9, 64, aligned=False) == 16   # next pow2
    assert bucket_len(16, 64, aligned=False) == 16  # exact
    assert bucket_len(40, 48, aligned=False) == 48  # clamped to max_len
    assert bucket_len(3, 64, aligned=True) == 64    # explicit alignment


def test_scheduler_alignment_policy_per_family():
    """Every family buckets now that prefill is length-exact (recurrent
    mask-carry, windowed ring-exact fill, masked encoder); windowed archs
    keep a bucket floor of ``window`` so the prefill row's ring size
    equals the grid's."""
    from repro.serving.scheduler import _bucketable, bucket_floor
    for arch_id in ("qwen1.5-0.5b", "deepseek-moe-16b", "recurrentgemma-2b",
                    "xlstm-350m", "seamless-m4t-medium", "paligemma-3b"):
        assert _bucketable(repro.get_arch(arch_id).reduced()), arch_id
    hybrid = repro.get_arch("recurrentgemma-2b").reduced()
    assert hybrid.window == 16
    assert bucket_floor(hybrid, max_len=64) == 16   # ring floor = window
    assert bucket_floor(hybrid, max_len=8) == 8     # clamped to max_len
    assert bucket_floor(repro.get_arch("xlstm-350m").reduced(), 64) == 8


def test_submit_rejects_overlong_prompt(key):
    plan = repro.plan(ARCH, DECODE_SHAPE)
    engine = plan.compile().serve(config=ServeConfig(slots=1, max_len=16))
    with pytest.raises(ValueError, match="exceeds"):
        engine.submit(Request(rid=0, prompt=np.arange(20, dtype=np.int32)))


# ------------------------------ sampler -------------------------------

def test_sampling_params_validation():
    with pytest.raises(ValueError, match="unknown sampling method"):
        SamplingParams(method="beam")
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(method="temperature", temperature=0.0)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(method="top_k", top_k=0)


def test_sampler_greedy_is_argmax_and_keeps_rng(key):
    logits = jax.random.normal(key, (3, 17))
    rng = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(3))
    rng2, toks = sample(logits, rng, GREEDY)
    assert rng2 is rng
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_sampler_topk_stays_in_topk_and_advances_rng(key):
    logits = jax.random.normal(key, (4, 33))
    rng = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(4))
    sp = SamplingParams(method="top_k", temperature=0.7, top_k=3)
    rng2, toks = sample(logits, rng, sp)
    assert not np.array_equal(np.asarray(rng2), np.asarray(rng))
    top3 = np.asarray(jax.lax.top_k(logits, 3)[1])
    for i, t in enumerate(np.asarray(toks)):
        assert t in top3[i]
    # deterministic given the same keys
    _, toks_again = sample(logits, rng, sp)
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(toks_again))


def test_engine_temperature_sampling_decodes(key):
    plan = repro.plan(ARCH, DECODE_SHAPE)
    engine = plan.compile().serve(config=ServeConfig(
        slots=2, max_len=32,
        sampling=SamplingParams(method="temperature", temperature=0.9)))
    for i in range(3):
        engine.submit(Request(rid=i, prompt=np.arange(1, 7, dtype=np.int32),
                              max_new_tokens=3))
    engine.run_until_drained(max_steps=50)
    assert len(engine.completed) == 3
    assert all(len(r.out_tokens) == 3 for r in engine.completed)
    assert all(0 <= t < ARCH.vocab_size
               for r in engine.completed for t in r.out_tokens)


# --------------------------- decode state -----------------------------

def test_decode_state_shapes_and_admit():
    st = make_decode_state(4, seed=3)
    assert st.tokens.shape == (4, 1) and st.rng.shape == (4, 2)
    assert not bool(st.active.any())
    st2 = jax.jit(admit_slot)(st, jnp.int32(2), jnp.int32(7), jnp.int32(5),
                              jnp.int32(9), st.rng[2])
    assert np.asarray(st2.active).tolist() == [False, False, True, False]
    assert int(st2.tokens[2, 0]) == 7 and int(st2.positions[2, 0]) == 5
    assert int(st2.max_new[2]) == 9 and int(st2.emitted[2]) == 0
    # untouched slots keep their keys
    np.testing.assert_array_equal(np.asarray(st2.rng[0]), np.asarray(st.rng[0]))


# ------------------------- drain-contract tests ------------------------

def test_run_until_drained_raises_with_unfinished_rids(key):
    plan = repro.plan(ARCH, DECODE_SHAPE)
    engine = plan.compile().serve(config=ServeConfig(slots=1, max_len=32))
    for i in range(3):
        engine.submit(Request(rid=i, prompt=np.arange(1, 7, dtype=np.int32),
                              max_new_tokens=8))
    with pytest.raises(IncompleteDrainError) as ei:
        engine.run_until_drained(max_steps=2)
    assert set(ei.value.unfinished) <= {0, 1, 2} and ei.value.unfinished


def test_run_until_drained_warn_mode(key):
    plan = repro.plan(ARCH, DECODE_SHAPE)
    engine = plan.compile().serve(config=ServeConfig(slots=1, max_len=32))
    engine.submit(Request(rid=5, prompt=np.arange(1, 7, dtype=np.int32),
                          max_new_tokens=8))
    with pytest.warns(RuntimeWarning, match="rids=\\[5\\]"):
        steps = engine.run_until_drained(max_steps=1, on_incomplete="warn")
    assert steps == 1


# ------------------------ deprecation shim parity ----------------------

def test_legacy_construction_parity(key):
    """ServingEngine(arch, ...) routes through the new scheduler and
    produces the same greedy streams as plan-based construction."""
    params = REG.init_params(ARCH, key)
    prompts = [np.arange(1, 7, dtype=np.int32),
               np.arange(3, 12, dtype=np.int32)]

    with pytest.warns(DeprecationWarning):
        legacy = ServingEngine(ARCH, params, slots=2, max_len=32,
                               dtype=jnp.float32)
    plan = repro.plan(ARCH, DECODE_SHAPE)
    modern = plan.compile().serve(
        params, config=ServeConfig(slots=2, max_len=32))
    for eng in (legacy, modern):
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=4))
        eng.run_until_drained(max_steps=50)
    got = {r.rid: r.out_tokens for r in legacy.completed}
    want = {r.rid: r.out_tokens for r in modern.completed}
    assert got == want and len(got) == 2


def test_legacy_shim_drains_with_varying_max_new(key):
    """Per-request ``max_new_tokens`` budgets through the legacy
    ``ServingEngine(arch, ...)`` shim: every stream stops at exactly its
    own budget (retirement is per-slot, not batch-wide) and the streams
    match plan-based construction."""
    params = REG.init_params(ARCH, key)
    rng = np.random.RandomState(7)
    prompts = [rng.randint(1, 100, size=s).astype(np.int32)
               for s in (6, 9, 4)]
    budgets = [2, 7, 5]

    with pytest.warns(DeprecationWarning):
        legacy = ServingEngine(ARCH, params, slots=2, max_len=32,
                               dtype=jnp.float32)
    plan = repro.plan(ARCH, DECODE_SHAPE)
    modern = plan.compile().serve(
        params, config=ServeConfig(slots=2, max_len=32))
    for eng in (legacy, modern):
        for i, (p, b) in enumerate(zip(prompts, budgets)):
            eng.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=b))
        eng.run_until_drained(max_steps=80)
    got = {r.rid: r.out_tokens for r in legacy.completed}
    want = {r.rid: r.out_tokens for r in modern.completed}
    assert got == want and len(got) == 3
    assert [len(got[i]) for i in range(3)] == budgets


# ---------------------- batched bucket admission -----------------------

def test_same_bucket_burst_is_one_prefill_dispatch(key):
    """Acceptance: a same-bucket admission burst of N requests issues O(1)
    prefill dispatches (one batched prefill + splice + state scatter),
    not N — asserted via prefill_stats()."""
    plan = repro.plan(ARCH, DECODE_SHAPE)
    engine = plan.compile().serve(config=ServeConfig(slots=4, max_len=32))
    rng = np.random.RandomState(0)
    for i in range(4):  # lengths 4..6 all land in the 8-bucket
        engine.submit(Request(rid=i,
                              prompt=rng.randint(1, 100, size=4 + (i % 3))
                              .astype(np.int32), max_new_tokens=3))
    engine.step()  # one serving-loop iteration admits the whole burst
    stats = engine.prefill_stats()
    assert stats["prefill_dispatches"] == 1.0
    assert stats["prefills"] == 4.0
    assert stats["prefill_batch_mean"] == 4.0
    assert all(r is not None for r in engine.active.values())
    engine.run_until_drained(max_steps=50)
    assert len(engine.completed) == 4


def test_mixed_bucket_batch_admits_in_one_step(key):
    """Churn shape: one step's admission wave spans several buckets —
    each bucket becomes exactly one dispatch, all slots fill in that
    step, and the streams match per-request (unbatched) admission."""
    rng = np.random.RandomState(1)
    prompts = [rng.randint(1, 100, size=s).astype(np.int32)
               for s in (3, 5, 9, 20)]  # buckets 8, 8, 16, 32

    def run(slots):
        plan = repro.plan(ARCH, DECODE_SHAPE)
        eng = plan.compile().serve(config=ServeConfig(slots=slots, max_len=32))
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=3))
        if slots == 4:
            eng.step()
            st = eng.prefill_stats()
            assert st["prefill_dispatches"] == 3.0  # {8: two, 16: one, 32: one}
            assert st["prefills"] == 4.0
            assert all(r is not None for r in eng.active.values())
        eng.run_until_drained(max_steps=80)
        return {r.rid: r.out_tokens for r in eng.completed}

    batched = run(slots=4)
    serial = run(slots=1)  # one slot -> strictly per-request prefill
    assert batched == serial and len(batched) == 4


def test_recurrent_padfree_prefill_bitexact_vs_aligned(key):
    """Pad-free prefill: for recurrent/hybrid archs the prefill state at a
    power-of-two bucket is bit-equal to the old max_len-aligned path (and
    to the unpadded prompt) — the property that let them leave max_len
    alignment."""
    from repro.models import lm as LM

    for arch_id in ("xlstm-350m", "recurrentgemma-2b"):
        arch = repro.get_arch(arch_id).reduced()
        params = REG.init_params(arch, key, jnp.float32)
        prompt = np.random.RandomState(2).randint(1, 100, 5).astype(np.int32)
        states = {}
        for pad in (16, 32):  # bucket vs max_len-aligned
            toks = np.zeros((1, pad), np.int32)
            toks[0, :5] = prompt
            caches = REG.make_caches(arch, 1, pad, jnp.float32)
            hidden, rows = LM.forward(arch, params, jnp.asarray(toks),
                                      caches=caches,
                                      seq_lens=jnp.asarray([5], jnp.int32))
            states[pad] = (np.asarray(hidden[0, 4]),
                           jax.tree_util.tree_flatten_with_path(
                               jax.tree.map(np.asarray, rows))[0])
        np.testing.assert_array_equal(states[16][0], states[32][0])
        for (p16, l16), (p32, l32) in zip(states[16][1], states[32][1]):
            ks = jax.tree_util.keystr(p16)
            if "count" in ks:  # count records the padded length (unspliced)
                continue
            if l16.shape == l32.shape:  # recurrent state (length-free) leaves
                np.testing.assert_array_equal(l16, l32, err_msg=f"{arch_id}{ks}")


# --------------------------- encdec / vlm admission ---------------------

def test_mixed_encdec_and_dense_workload_drains(key):
    """Acceptance: serve drains a mixed encdec + dense workload — encdec
    decode streams are bit-exact vs the golden unbatched reference
    (exact-length encoder, per-request prefill), while the dense engine's
    same-bucket burst stays a single batched dispatch."""
    from repro.testing.serving_equiv import ReferenceEngine

    arch = repro.get_arch("seamless-m4t-medium").reduced()
    rng = np.random.RandomState(3)
    prompts = [rng.randint(1, 100, size=s).astype(np.int32)
               for s in (4, 6, 5, 4, 7)]
    frames = [rng.standard_normal((f, arch.d_model)).astype(np.float32)
              for f in (3, 9, 16, 2, 6)]

    def submit_all(eng):
        for i, (p, f) in enumerate(zip(prompts, frames)):
            eng.submit(Request(rid=i, prompt=p.copy(), src_frames=f,
                               max_new_tokens=4))
        eng.run_until_drained(max_steps=100)
        return {r.rid: list(r.out_tokens) for r in eng.completed}

    plan = repro.plan(arch, ShapeConfig("ed", 32, 4, "decode"))
    engine = plan.compile().serve(
        config=ServeConfig(slots=2, max_len=32, max_src_len=16))
    got = submit_all(engine)  # 2 slots over 5 requests: churn + batching
    params = engine.params
    want = submit_all(ReferenceEngine(arch, params, slots=2, max_len=32,
                                      max_src_len=16, dtype=jnp.float32))
    assert got == want and len(got) == 5

    # the dense half of the workload: burst admission stays O(1) dispatch
    dense = repro.plan(ARCH, DECODE_SHAPE).compile().serve(
        config=ServeConfig(slots=3, max_len=32))
    for i in range(3):
        dense.submit(Request(rid=i, prompt=prompts[i][:4], max_new_tokens=2))
    dense.run_until_drained(max_steps=30)
    assert dense.prefill_stats()["prefill_dispatches"] == 1.0
    assert len(dense.completed) == 3


def test_encdec_submit_requires_frames_and_validates_lengths():
    arch = repro.get_arch("seamless-m4t-medium").reduced()
    plan = repro.plan(arch, ShapeConfig("ed", 32, 4, "decode"))
    engine = plan.compile().serve(
        config=ServeConfig(slots=1, max_len=16, max_src_len=8))
    with pytest.raises(ValueError, match="needs.*frames"):
        engine.submit(Request(rid=0, prompt=np.arange(1, 4, dtype=np.int32)))
    with pytest.raises(ValueError, match="max_src_len"):
        engine.submit(Request(
            rid=1, prompt=np.arange(1, 4, dtype=np.int32),
            src_frames=np.zeros((9, arch.d_model), np.float32)))


def test_vlm_prefix_admission_attends_patches(key):
    """vlm requests carry patch embeddings; the prefix is part of the
    cache row (bucketed on prefix + prompt) and changes the decode
    stream, and batched admission matches per-request admission."""
    arch = repro.get_arch("paligemma-3b").reduced()
    plan = repro.plan(arch, ShapeConfig("vlm", 32, 4, "decode"))
    rng = np.random.RandomState(4)
    prompt = rng.randint(1, 100, size=4).astype(np.int32)
    patch_sets = [rng.standard_normal((6, arch.d_model)).astype(np.float32)
                  for _ in range(2)]

    def run(slots, patches_list):
        eng = plan.compile().serve(config=ServeConfig(slots=slots, max_len=32))
        for i, pa in enumerate(patches_list):
            eng.submit(Request(rid=i, prompt=prompt.copy(),
                               patch_embeds=pa, max_new_tokens=3))
        eng.run_until_drained(max_steps=60)
        return {r.rid: list(r.out_tokens) for r in eng.completed}

    batched = run(2, patch_sets)
    serial = run(1, patch_sets)
    assert batched == serial and len(batched) == 2
    # the prefix is part of the cache row: admission sets the decode
    # position past prefix + prompt (6 + 4), vs prompt-only 4
    eng = plan.compile().serve(config=ServeConfig(slots=2, max_len=32))
    eng.submit(Request(rid=0, prompt=prompt.copy(),
                       patch_embeds=patch_sets[0], max_new_tokens=2))
    eng.submit(Request(rid=1, prompt=prompt.copy(), max_new_tokens=2))
    eng.step()
    pos = np.asarray(eng.state.positions)[:, 0]
    assert sorted(pos.tolist()) == [5, 11]  # 4+1 and 6+4+1 after one step
    # and the patch embeddings do reach the logits
    from repro.models import lm as LM
    h0, _ = LM.forward(arch, eng.params, jnp.asarray(prompt[None]),
                       prefix_embeds=jnp.asarray(patch_sets[0][None]))
    h1, _ = LM.forward(arch, eng.params, jnp.asarray(prompt[None]),
                       prefix_embeds=jnp.asarray(patch_sets[1][None]))
    assert not np.allclose(np.asarray(h0[:, -1]), np.asarray(h1[:, -1]))
    # prefix overflow is rejected at submit
    eng = plan.compile().serve(config=ServeConfig(slots=1, max_len=8))
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit(Request(rid=9, prompt=prompt.copy(),
                           patch_embeds=patch_sets[0]))


def test_lookahead_zero_matches_lookahead_one(key):
    params = REG.init_params(ARCH, key)
    plan = repro.plan(ARCH, DECODE_SHAPE)
    streams = []
    for la in (0, 1, 2):
        eng = plan.compile().serve(params, config=ServeConfig(
            slots=2, max_len=32, lookahead=la))
        for i in range(5):
            eng.submit(Request(rid=i, prompt=np.arange(1, 7, dtype=np.int32),
                               max_new_tokens=3))
        eng.run_until_drained(max_steps=60)
        streams.append({r.rid: r.out_tokens for r in eng.completed})
    assert streams[0] == streams[1] == streams[2]
    assert all(len(s) == 5 for s in streams)


# ---------------------------- telemetry --------------------------------

def test_step_stats_reset_between_drains(key):
    """A reused engine's counters describe exactly one drain:
    ``run_until_drained`` resets step/prefill telemetry at entry, so the
    second drain's stats never blend with the first's (regression: the
    deques used to accumulate across drains until they aged out)."""
    params = REG.init_params(ARCH, key)
    plan = repro.plan(ARCH, DECODE_SHAPE)
    eng = plan.compile().serve(params, config=ServeConfig(slots=2, max_len=32))
    for i in range(4):
        eng.submit(Request(rid=i, prompt=np.arange(1, 7, dtype=np.int32),
                           max_new_tokens=3))
    eng.run_until_drained(max_steps=60)
    first = eng.step_stats()
    assert first["tokens"] == 12.0 and first["steps"] > 0

    eng.submit(Request(rid=9, prompt=np.arange(1, 7, dtype=np.int32),
                       max_new_tokens=2))
    eng.run_until_drained(max_steps=60)
    second = eng.step_stats()
    pf = eng.prefill_stats()
    assert second["tokens"] == 2.0          # only the second drain's tokens
    assert second["steps"] < first["steps"]
    assert pf["prefills"] == 1.0 and pf["prefill_dispatches"] == 1.0
    assert second["queue_depth"] >= 0.0
    assert second["accepted_tokens_mean"] == 1.0  # plain decoding: 1 tok/slot-step


# ------------------------ speculative decoding -------------------------

def test_spec_engine_streams_match_target_only(key):
    """Draft-k + batched-verify smoke test on one device: a self-draft
    speculative engine commits bit-identical greedy streams to the
    target-only engine while accepting >1 token per slot-step."""
    from repro.serving import SpecConfig
    params = REG.init_params(ARCH, key)
    plan = repro.plan(ARCH, DECODE_SHAPE, draft=ARCH)

    base = plan.compile().serve(params, config=ServeConfig(slots=2, max_len=32))
    spec = plan.compile().serve({"target": params, "draft": params},
                                config=ServeConfig(slots=2, max_len=32,
                                                   spec=SpecConfig(k=3)))
    # budget = 2 full k+1 chains: a budget that stops a chain mid-way
    # counts the unconsumed proposals as rejected (by design), which
    # would obscure the full-acceptance assertion below
    for eng in (base, spec):
        for i in range(3):
            eng.submit(Request(rid=i, prompt=np.arange(1, 7, dtype=np.int32),
                               max_new_tokens=8))
        eng.run_until_drained(max_steps=60)
    want = {r.rid: r.out_tokens for r in base.completed}
    got = {r.rid: r.out_tokens for r in spec.completed}
    assert got == want and len(got) == 3
    stats = spec.step_stats()
    assert stats["accepted_tokens_mean"] > 1.0   # the speedup lever
    assert stats["draft_acceptance"] > 0.99      # self-draft: full acceptance
