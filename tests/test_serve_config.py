"""ServeConfig surface tests: the consolidated serve() API.

Covers the frozen config tree (ServeConfig / PagingConfig / DisaggConfig),
``from_kwargs`` legacy-kwarg funnelling, ``resolve()`` shape-derived
defaults, the ``Executable.serve`` deprecation shim, the engine's
resolved ``config`` attribute, and per-family Request payload validation
(``src_frames`` vs ``patch_embeds`` plus the unified prompt+budget
rejection). Disaggregated-engine behavior lives in tests/test_disagg.py.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.configs.base import ShapeConfig
from repro.models import registry as REG
from repro.serving import (DisaggConfig, PagingConfig, Request,
                           RequestValidationError, ServeConfig, ServingEngine)
from repro.serving.sampler import GREEDY

ARCH = repro.get_arch("qwen1.5-0.5b").reduced()
DECODE_SHAPE = ShapeConfig("d", 32, 4, "decode")


@pytest.fixture(scope="module")
def params():
    return REG.init_params(ARCH, jax.random.PRNGKey(0), jnp.float32)


@pytest.fixture(scope="module")
def exe():
    return repro.plan(ARCH, DECODE_SHAPE).compile()


# ----------------------------- the config tree --------------------------

def test_config_is_frozen():
    cfg = ServeConfig(slots=2, max_len=32)
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.slots = 4
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.paging.paged = True


def test_from_kwargs_maps_flat_paging_names():
    cfg = ServeConfig.from_kwargs(slots=2, max_len=32, paged=True,
                                  page_size=8, kv_pages=16,
                                  prefix_cache=False)
    assert cfg.slots == 2 and cfg.max_len == 32
    assert cfg.paging == PagingConfig(paged=True, page_size=8, kv_pages=16,
                                      prefix_cache=False)


def test_from_kwargs_rejects_unknown_and_mixed():
    with pytest.raises(TypeError, match="unexpected"):
        ServeConfig.from_kwargs(slots=2, max_len=32, bogus=1)
    with pytest.raises(TypeError):
        ServeConfig.from_kwargs(slots=2, max_len=32, paged=True,
                                paging=PagingConfig(paged=True))


def test_resolve_fills_defaults_from_shape():
    cfg = ServeConfig().resolve(DECODE_SHAPE)
    assert cfg.slots == DECODE_SHAPE.global_batch
    assert cfg.max_len == DECODE_SHAPE.seq_len
    assert cfg.sampling == GREEDY
    assert cfg.max_src_len == cfg.max_len
    # explicit values survive resolution
    cfg2 = ServeConfig(slots=2, max_len=16).resolve(DECODE_SHAPE)
    assert (cfg2.slots, cfg2.max_len) == (2, 16)


def test_resolve_without_shape_requires_slots_and_max_len():
    with pytest.raises(ValueError):
        ServeConfig().resolve()
    cfg = ServeConfig(slots=2, max_len=16).resolve()
    assert (cfg.slots, cfg.max_len) == (2, 16)


# --------------------------- the serve() shim ---------------------------

def test_serve_flat_kwargs_deprecated_but_equivalent(exe, params):
    with pytest.warns(DeprecationWarning, match="ServeConfig"):
        legacy = exe.serve(params, slots=2, max_len=32)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # the config path must not warn
        new = exe.serve(params, config=ServeConfig(slots=2, max_len=32))
    assert legacy.config == new.config
    assert new.config.slots == 2 and new.config.max_len == 32


def test_serve_rejects_config_plus_flat_kwargs(exe, params):
    with pytest.raises(TypeError, match="both config="):
        exe.serve(params, config=ServeConfig(slots=2, max_len=32), slots=4)


def test_engine_config_exposes_resolved_values(exe, params):
    eng = exe.serve(params, config=ServeConfig(
        slots=2, max_len=32, paging=PagingConfig(paged=True, page_size=8)))
    assert eng.config.paging.paged
    assert eng.config.paging.page_size == 8
    assert eng.config.paging.kv_pages == eng.kv_pages  # resolved geometry
    assert eng.config.sampling == GREEDY
    assert eng.config.lookahead == 1


def test_engine_rejects_config_plus_flat_kwargs(exe, params):
    with pytest.raises(TypeError):
        ServingEngine(exe.plan, params,
                      config=ServeConfig(slots=2, max_len=32), slots=4)


# ------------------------ request payload fields ------------------------

def test_request_frames_kwarg_deprecated():
    with pytest.warns(DeprecationWarning, match="src_frames"):
        req = Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                      frames=np.zeros((3, 8), np.float32))
    assert req.frames is not None  # alias property still answers
    with pytest.raises(RequestValidationError, match="not both"):
        Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                frames=np.zeros((3, 8), np.float32),
                src_frames=np.zeros((3, 8), np.float32))


def test_submit_rejects_wrong_family_payload(exe, params):
    eng = exe.serve(params, config=ServeConfig(slots=2, max_len=32))
    with pytest.raises(RequestValidationError, match="src_frames"):
        eng.submit(Request(rid=0, prompt=np.arange(1, 5, dtype=np.int32),
                           src_frames=np.zeros((3, ARCH.d_model),
                                               np.float32)))


def test_submit_rejects_prompt_plus_budget_over_max_len(exe, params):
    """Unified across dense and paged modes (the paged case asserts the
    same typed error in tests/test_paging.py)."""
    eng = exe.serve(params, config=ServeConfig(slots=2, max_len=32))
    with pytest.raises(RequestValidationError, match="max_new_tokens"):
        eng.submit(Request(rid=0, prompt=np.arange(1, 30, dtype=np.int32),
                           max_new_tokens=8))  # 29 + 8 > 32
    # an exactly-fitting request is accepted
    eng.submit(Request(rid=1, prompt=np.arange(1, 29, dtype=np.int32),
                       max_new_tokens=4))  # 28 + 4 == 32


def test_encdec_submit_validates_src_frames():
    arch = repro.get_arch("seamless-m4t-medium").reduced()
    params = REG.init_params(arch, jax.random.PRNGKey(0), jnp.float32)
    plan = repro.plan(arch, ShapeConfig("d", 16, 2, "decode"))
    eng = plan.compile().serve(params, config=ServeConfig(
        slots=2, max_len=16, max_src_len=8))
    prompt = np.arange(1, 5, dtype=np.int32)
    with pytest.raises(RequestValidationError, match="source frames"):
        eng.submit(Request(rid=0, prompt=prompt.copy(), max_new_tokens=2))
    with pytest.raises(RequestValidationError, match="patch_embeds"):
        eng.submit(Request(rid=1, prompt=prompt.copy(), max_new_tokens=2,
                           patch_embeds=np.zeros((3, arch.d_model),
                                                 np.float32)))
    with pytest.raises(RequestValidationError, match="max_src_len"):
        eng.submit(Request(rid=2, prompt=prompt.copy(), max_new_tokens=2,
                           src_frames=np.zeros((9, arch.d_model),
                                               np.float32)))
    # legacy frames= routes to src_frames for encdec at submit()
    with pytest.warns(DeprecationWarning):
        req = Request(rid=3, prompt=prompt.copy(), max_new_tokens=2,
                      frames=np.zeros((4, arch.d_model), np.float32))
    eng.submit(req)
    assert req.src_frames is not None


def test_disagg_config_rides_in_serve_config():
    cfg = ServeConfig(slots=2, max_len=32,
                      disagg=DisaggConfig(prefill_data=1))
    assert cfg.disagg.prefill_data == 1 and cfg.disagg.axis is None
    assert ServeConfig(slots=2, max_len=32).disagg is None
