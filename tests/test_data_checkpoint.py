"""Data-pipeline determinism/sharding + checkpoint atomicity/resume."""
import json
import pathlib

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data.pipeline import PipelineState, TokenPipeline

ARCH = get_arch("qwen1.5-0.5b").reduced()
SHAPE = ShapeConfig("t", 32, 8, "train")


def test_pipeline_deterministic_replay():
    p1 = TokenPipeline(ARCH, SHAPE, seed=7)
    ref = [p1.next_batch() for _ in range(3)]
    p2 = TokenPipeline(ARCH, SHAPE, seed=7)
    p2.state.step = 2  # resume at step 2
    np.testing.assert_array_equal(p2.next_batch()["tokens"], ref[2]["tokens"])


@given(st.sampled_from([1, 2, 4, 8]), st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_pipeline_host_sharding_partitions_global_stream(hosts, step):
    """Union of host shards == the single-host global batch (elasticity)."""
    global_pipe = TokenPipeline(ARCH, SHAPE, seed=3)
    global_pipe.state.step = step
    ref = global_pipe.next_batch()["tokens"]
    rows = []
    for h in range(hosts):
        p = TokenPipeline(ARCH, SHAPE, seed=3, host_index=h, host_count=hosts)
        p.state.step = step
        rows.append(p.next_batch()["tokens"])
    np.testing.assert_array_equal(np.concatenate(rows, axis=0), ref)


def test_pipeline_labels_shift():
    b = TokenPipeline(ARCH, SHAPE, seed=0).next_batch()
    assert b["tokens"].shape == b["labels"].shape == (8, 32)


def test_checkpoint_roundtrip_and_keep(tmp_path):
    ck = Checkpointer(tmp_path, keep=2, async_save=False)
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.ones((4,), np.int32)}}
    for step in (10, 20, 30):
        ck.save(step, tree, extra={"data_step": step})
    assert ck.available_steps() == [20, 30]  # keep-2 GC
    like = {"a": jnp.zeros((2, 3), jnp.float32), "b": {"c": jnp.zeros((4,), jnp.int32)}}
    restored, extra, step = ck.restore(like)
    assert step == 30 and extra["data_step"] == 30
    np.testing.assert_array_equal(restored["a"], tree["a"])


def test_checkpoint_corruption_fallback(tmp_path):
    ck = Checkpointer(tmp_path, keep=3, async_save=False)
    tree = {"a": np.ones((2, 2), np.float32)}
    ck.save(1, tree)
    ck.save(2, tree)
    # corrupt the newest checkpoint's index
    (pathlib.Path(tmp_path) / "step_000000002" / "index.json").write_text("{broken")
    like = {"a": jnp.zeros((2, 2), jnp.float32)}
    restored, _, step = ck.restore(like)
    assert step == 1 and restored is not None


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(tmp_path, keep=1, async_save=True)
    ck.save(5, {"a": np.zeros((8,), np.float32)})
    ck.wait()
    assert ck.available_steps() == [5]


def test_pipeline_state_serialization():
    st_ = PipelineState(step=42)
    assert PipelineState.from_dict(json.loads(json.dumps(st_.to_dict()))).step == 42
