"""Strategy combinators for the vendored hypothesis shim (see __init__)."""
from __future__ import annotations

from typing import Any, Callable, Sequence


class SearchStrategy:
    def __init__(self, draw: Callable[[Any], Any]):
        self._draw = draw

    def example(self, rng) -> Any:
        return self._draw(rng)

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred: Callable[[Any], bool],
               max_tries: int = 1000) -> "SearchStrategy":
        def draw(rng):
            for _ in range(max_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")
        return SearchStrategy(draw)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value))


def sampled_from(elements: Sequence) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda rng: rng.choice(elements))


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.getrandbits(1)))


def floats(min_value: float, max_value: float) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value))


def builds(target: Callable, *arg_strategies, **kw_strategies) -> SearchStrategy:
    def draw(rng):
        args = [s.example(rng) if isinstance(s, SearchStrategy) else s
                for s in arg_strategies]
        kwargs = {k: (s.example(rng) if isinstance(s, SearchStrategy) else s)
                  for k, s in kw_strategies.items()}
        return target(*args, **kwargs)
    return SearchStrategy(draw)


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(n)]
    return SearchStrategy(draw)
