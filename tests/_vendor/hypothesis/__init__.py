"""Minimal stand-in for the ``hypothesis`` property-testing library.

The container image does not ship ``hypothesis`` and nothing may be pip
installed, so ``tests/conftest.py`` puts this vendored shim on ``sys.path``
*only when the real library is absent*. It implements exactly the surface
the test-suite uses — ``@given`` over positional strategies, ``@settings``
with ``max_examples``/``deadline``, and the ``strategies`` combinators
``integers``/``sampled_from``/``just``/``builds`` — with deterministic
pseudo-random example generation (seeded per test name) and no shrinking.
"""
from __future__ import annotations

import functools
import random
import zlib

__version__ = "0.0-repro-shim"

_DEFAULT_MAX_EXAMPLES = 100


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strategies, **kw_strategies):
    from hypothesis.strategies import SearchStrategy

    for s in strategies + tuple(kw_strategies.values()):
        if not isinstance(s, SearchStrategy):
            raise TypeError(f"@given expects strategies, got {s!r}")

    def deco(fn):
        n = getattr(fn, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                ex_args = tuple(s.example(rng) for s in strategies)
                ex_kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                fn(*args, *ex_args, **{**kwargs, **ex_kw})

        # hide the strategy parameters from pytest's fixture resolution
        wrapper.__signature__ = _strip_params(fn, len(strategies),
                                              set(kw_strategies))
        return wrapper

    return deco


def _strip_params(fn, n_positional, kw_names):
    import inspect
    sig = inspect.signature(fn)
    params = list(sig.parameters.values())
    kept = params[: max(len(params) - n_positional - len(kw_names), 0)]
    kept = [p for p in kept if p.name not in kw_names]
    return sig.replace(parameters=kept)
