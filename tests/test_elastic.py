"""runtime/elastic.py: grid selection, load controller, live migration.

The property-based block uses hypothesis (the vendored shim in
``tests/_vendor`` when the real package is absent; see conftest.py).
The cross-mesh migration cells live in test_conformance.py (slow,
subprocess, 8 fake devices); here the migration machinery is exercised
end-to-end on the in-process device so tier-1 covers it.
"""
import types

import jax
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.runtime.elastic import (LoadController, _best_grid, replan,
                                   replan_execution)
from repro.serving import ServeConfig
from repro.serving.config import ElasticConfig
from repro.serving.scheduler import Request
from repro.testing.mesh_fixtures import run_in_subprocess

ARCH = get_arch("qwen1.5-0.5b").reduced()
SHAPE = ShapeConfig("elastic_t", 32, 4, "decode")


# ------------------------- _best_grid properties -------------------------
@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=1, max_value=256))
def test_best_grid_uses_at_most_n_and_factors(n):
    data, model = _best_grid(n)
    assert data >= 1 and model in (1, 2, 4, 8, 16, 32)
    assert data * model <= n


@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=1, max_value=255))
def test_best_grid_utilization_monotone(n):
    """More devices never means fewer used (the grid can always keep the
    smaller count's factorisation)."""
    used = lambda k: _best_grid(k)[0] * _best_grid(k)[1]
    assert used(n + 1) >= used(n)


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=1, max_value=256))
def test_best_grid_model_axis_divides_heads(n):
    data, model = _best_grid(n, ARCH)
    assert ARCH.num_heads % model == 0
    assert data * model <= n


def test_best_grid_arch_regression_nondividing_model():
    """Regression: with 64 devices the unconstrained grid is (8, 8), but
    a 4-head arch cannot split attention over model=8 — the arch-aware
    grid must fall back to a head-dividing model axis."""
    assert _best_grid(64) == (8, 8)
    assert ARCH.num_heads == 4
    data, model = _best_grid(64, ARCH)
    assert ARCH.num_heads % model == 0
    assert data * model == 64  # divisibility costs no devices here
    # replan threads the arch through (the auto-mesh api path does too)
    mesh, ctx, rep = replan(ARCH, SHAPE)
    assert ARCH.num_heads % mesh.shape["model"] == 0


# ---------------------------- load controller ----------------------------
def _fake_engine(depth, p50=1.0, ndev=4):
    eng = types.SimpleNamespace()
    eng.plan = types.SimpleNamespace(num_devices=ndev)
    eng.step_stats = lambda: {"steps": 100.0, "queue_depth": float(depth),
                              "step_p50_ms": float(p50)}
    eng.prefill_stats = lambda: {"prefills": 0.0}
    return eng


def test_load_controller_grow_shrink_hold():
    cfg = ElasticConfig(grow_queue_depth=4.0, shrink_queue_depth=0.5)
    devices = list(range(8))
    ladder = [2, 4, 8]
    grow = LoadController(_fake_engine(depth=10.0), cfg, devices=devices,
                          device_ladder=ladder)
    assert grow.decide() == ("grow", 8)
    shrink = LoadController(_fake_engine(depth=0.0), cfg, devices=devices,
                            device_ladder=ladder)
    assert shrink.decide() == ("shrink", 2)
    hold = LoadController(_fake_engine(depth=2.0), cfg, devices=devices,
                          device_ladder=ladder)
    assert hold.decide() == ("hold", None)
    # at the top rung there is nothing to grow into
    top = LoadController(_fake_engine(depth=10.0, ndev=8), cfg,
                         devices=devices, device_ladder=ladder)
    assert top.decide() == ("hold", None)


def test_load_controller_shrink_needs_latency_headroom():
    cfg = ElasticConfig(shrink_queue_depth=0.5, shrink_step_p50_ms=2.0)
    ctl = LoadController(_fake_engine(depth=0.0, p50=50.0), cfg,
                         devices=list(range(8)), device_ladder=[2, 4, 8])
    assert ctl.decide() == ("hold", None)


def test_load_controller_cooldown_blocks_resize():
    cfg = ElasticConfig(grow_queue_depth=1.0, cooldown_steps=1000)
    ctl = LoadController(_fake_engine(depth=10.0), cfg,
                         devices=list(range(8)), device_ladder=[2, 4, 8])
    assert ctl.decide()[0] == "grow"
    assert ctl.observe() is None  # 100 steps seen < 1000 cooldown


def test_elastic_config_validation_and_kwargs():
    with pytest.raises(ValueError):
        ElasticConfig(grow_queue_depth=1.0, shrink_queue_depth=2.0)
    cfg = ServeConfig.from_kwargs(slots=2, max_len=32,
                                  elastic=ElasticConfig())
    assert cfg.elastic is not None
    with pytest.raises(TypeError):
        ServeConfig.from_kwargs(elastic_mode=True)


# ------------------------- live migration (tier-1) ------------------------
def _drain(eng, plan_b=None, migrate_at=None):
    steps = 0
    report = None
    while eng.queue or eng.scheduler.has_active():
        if migrate_at is not None and steps == migrate_at:
            report = eng.migrate(plan_b)
        eng.step()
        steps += 1
        assert steps < 400
    eng._flush()
    return {r.rid: list(r.out_tokens) for r in eng.completed}, report


def test_migrate_mid_stream_bit_exact_single_device():
    """plan→plan migration on the in-process device: streams served
    across the move are bit-identical to the never-migrated run, no
    request is lost, and the transfer accounting verifies."""
    mesh = (("data", 1), ("model", 1))
    plan_a = repro.plan(ARCH, SHAPE, mesh)
    plan_b = repro.plan(ARCH, SHAPE, mesh)
    cfg = ServeConfig(slots=2, max_len=32)

    def engine():
        eng = plan_a.compile().serve(config=cfg)
        for rid in range(4):  # oversubscribed: queue crosses the move too
            eng.submit(Request(rid=rid, prompt=[2 + rid, 3, 5],
                               max_new_tokens=4))
        return eng

    want, _ = _drain(engine())
    got, report = _drain(engine(), plan_b, migrate_at=2)
    assert got == want
    assert report is not None and report.verified
    assert report.active_slots > 0
    assert sum(len(t) for t in got.values()) == 4 * 4  # zero tokens lost
    # same axes + same devices -> nothing physically moves
    assert report.moved_bytes == 0 and report.drained_slots == 0


def test_migrate_rejects_arch_change():
    plan_a = repro.plan(ARCH, SHAPE, (("data", 1), ("model", 1)))
    other = get_arch("minitron-8b").reduced()
    plan_b = repro.plan(other, SHAPE, (("data", 1), ("model", 1)))
    eng = plan_a.compile().serve(config=ServeConfig(slots=2, max_len=32))
    with pytest.raises(ValueError):
        eng.migrate(plan_b)


def test_serve_config_elastic_attaches_controller():
    plan = repro.plan(ARCH, SHAPE, (("data", 1), ("model", 1)))
    eng = plan.compile().serve(config=ServeConfig(
        slots=2, max_len=32, elastic=ElasticConfig(cooldown_steps=10**6)))
    assert isinstance(eng.elastic, LoadController)
    assert eng.maybe_resize() is None  # empty telemetry + cooldown: hold


# ------------------------ shrink replan (8 -> 6) -------------------------
_SHRINK_SCRIPT = """
import jax
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.runtime.elastic import replan_execution
from repro.serving import ServeConfig
from repro.serving.scheduler import Request

arch = get_arch("qwen1.5-0.5b").reduced()
shape = ShapeConfig("shrink", 32, 4, "decode")
devices = jax.devices()[:6]  # two of eight devices just went away
plan = replan_execution(arch, shape, devices)
assert plan.num_devices <= 6, plan.mesh_axes
assert arch.num_heads % dict(plan.mesh_axes)["model"] == 0
assert plan.feasible, plan.describe()
eng = plan.compile().serve(config=ServeConfig(slots=2, max_len=32))
for rid in range(3):
    eng.submit(Request(rid=rid, prompt=[2, 3, 5], max_new_tokens=4))
eng.run_until_drained(max_steps=500)
assert len(eng.completed) == 3
print("ELASTIC_SHRINK_OK", dict(plan.mesh_axes))
"""


@pytest.mark.slow
def test_replan_after_shrink_8_to_6_is_servable():
    """Losing 2 of 8 devices: replan must pick a feasible sub-grid of the
    6 survivors and the resulting plan must actually serve."""
    run_in_subprocess(_SHRINK_SCRIPT, devices=8, timeout=900,
                      marker="ELASTIC_SHRINK_OK")
