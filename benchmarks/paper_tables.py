"""Thin shim — the paper-parity table/figure benchmarks moved to
``repro.bench.paper_tables``; run them via::

    python -m repro.bench --full --filter paper_tables
"""
from repro.bench.paper_tables import *  # noqa: F401,F403
