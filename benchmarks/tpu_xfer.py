"""Thin shim — the TPU XFER-vs-baseline study moved to
``repro.bench.tpu_scenarios``; run it via::

    python -m repro.bench --full --filter tpu_xfer
"""
from repro.bench.tpu_scenarios import *  # noqa: F401,F403
