"""Thin shim — the cycle-domain design-search helpers moved into the
benchmark subsystem at ``repro.bench.designs`` (run with PYTHONPATH=src).

``timed``/``csv_row`` remain here for legacy callers only; new timing
code should use ``repro.bench.timers.measure`` (warmup + percentiles).
"""
import time

from repro.bench.designs import *  # noqa: F401,F403


def timed(fn, *args, repeats: int = 3, **kw):
    fn(*args, **kw)  # warmup
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return out, us


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
