"""Thin shim — the baseline-vs-optimized cell harness moved to
``repro.bench.hillclimb``::

    PYTHONPATH=src python -m benchmarks.hillclimb <arch> <shape> [baseline|optimized]
"""
from repro.bench.hillclimb import main

if __name__ == "__main__":
    main()
