"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Cycle-domain rows reproduce
the paper's ZCU102 numbers; time-domain rows are the TPU-pod adaptation;
kernel rows time the Pallas kernels (interpret mode) against their oracles.
"""
from __future__ import annotations

import sys


def _kernel_rows():
    import jax, jax.numpy as jnp
    from benchmarks.common import timed, csv_row
    from repro.kernels import ops
    rows = []
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (512, 512), jnp.float32)
    w = jax.random.normal(k, (512, 512), jnp.float32)
    _, us_ref = timed(lambda: ops.matmul_ref(x, w).block_until_ready())
    _, us_k = timed(lambda: ops.matmul(x, w, tr=128, tm=128, tn=128).block_until_ready())
    rows.append(("kernel_xfer_matmul_512", us_k, f"interpret-mode; jnp_ref={us_ref:.0f}us"))
    q = jax.random.normal(k, (4, 512, 64), jnp.float32)
    _, us_ref = timed(lambda: ops.attention_ref(q, q, q).block_until_ready())
    _, us_k = timed(lambda: ops.attention(q, q, q, bq=256, bk=256).block_until_ready())
    rows.append(("kernel_flash_attention_512", us_k, f"interpret-mode; jnp_ref={us_ref:.0f}us"))
    return rows


def main() -> None:
    from benchmarks import paper_tables as T
    from benchmarks import tpu_xfer as X
    from benchmarks.common import csv_row

    rows = []
    rows += T.table1_uniform_vs_custom()
    rows += T.table3_xfer_speedup()
    rows += T.table4_bottleneck_detection()
    rows += T.fig3_pipeline_beat()
    rows += T.fig14_model_accuracy()
    rows += T.fig15_scaling()
    rows += X.xfer_vs_baseline()
    rows += X.pipeline_baseline()
    rows += _kernel_rows()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        csv_row(name, us, derived)

    # roofline table (requires dry-run artifacts; prints summary only here)
    try:
        from benchmarks import roofline as R
        cells = R.load_cells("pod16x16")
        done = [c for c in cells if "flops_per_device" in c]
        fracs = [R.roofline_terms(c)["roofline_fraction"] for c in done]
        if fracs:
            import numpy as np
            csv_row("roofline_cells", 0.0,
                    f"{len(done)} cells; mean roofline frac "
                    f"{float(np.mean(fracs))*100:.1f}%; see EXPERIMENTS.md")
    except Exception as e:  # dry-run not yet executed
        csv_row("roofline_cells", 0.0, f"unavailable: {e}")


if __name__ == "__main__":
    main()
