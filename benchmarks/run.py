"""Thin shim — the benchmark harness is now ``python -m repro.bench``.

Bare ``python -m benchmarks.run`` keeps its old meaning (everything,
including the paper-parity tables); any explicit arguments pass through
to the new CLI (see BENCHMARKS.md).
"""
import sys

from repro.bench.cli import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] or ["--full"]))
