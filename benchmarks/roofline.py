"""Thin shim — the roofline table assembly moved to
``repro.bench.roofline`` (reads the same ``experiments/dryrun`` JSONs).
"""
from repro.bench.roofline import *  # noqa: F401,F403

if __name__ == "__main__":
    main()  # noqa: F405
